"""``repro.runtime`` — compiled whole-run execution.

Two layers:

* :mod:`~repro.runtime.plan` lowers a realised schedule + train job into a
  device-resident :class:`RunPlan` (stacked round masks, per-round delay
  scales, folded per-round PRNG data keys, static batch-synthesis tables),
* :mod:`~repro.runtime.executor` replays the plan — ``runtime="scan"``
  runs K rounds per XLA launch with ``jax.lax.scan`` (metrics streamed
  per round via an io_callback tap, read back per chunk, or discarded:
  ``metrics="tap"|"chunk"|"none"``; chunks overlap whenever the host
  does not need values mid-run), ``runtime="eager"`` is the
  one-launch-per-round parity oracle.  Plans compiled with
  ``grid_gammas=...`` carry a γ-axis that
  :meth:`~repro.runtime.PlanExecutor.run_grid` vmaps over — the whole
  stepsize grid in one compiled program.

``TrainerBackend`` drives both through :func:`execute`; they are also
usable directly against any ``AsyncTrainer``::

    plan = compile_plan(schedule, job, rounds=T, n_groups=n, seed=0)
    res = execute(trainer, plan, trainer.init_state(key),
                  runtime="scan", rounds_per_launch=16)
"""
from .plan import (RunPlan, compile_plan, fold_data_keys,
                   quantize_zipf_trajectory)
from .executor import (METRICS, METRIC_MODES, RUNTIMES, ExecResult,
                       ExecStats, PlanExecutor, execute, make_batch_fn,
                       run_eager, run_grid, run_scan)

__all__ = [
    "RunPlan", "compile_plan", "fold_data_keys", "quantize_zipf_trajectory",
    "METRICS", "METRIC_MODES", "RUNTIMES", "ExecResult", "ExecStats",
    "PlanExecutor", "execute", "make_batch_fn", "run_eager", "run_grid",
    "run_scan",
]
