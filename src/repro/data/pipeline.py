"""Synthetic heterogeneous token pipeline.

Each AsGrad worker group g ∈ [n] owns its own token distribution (a Zipf
law over a group-specific vocabulary permutation — cheap, deterministic,
and *measurably* heterogeneous: per-group gradients differ, which is the ζ²
regime the paper studies).  The pipeline is host-side numpy; batches are
laid out so group g owns the contiguous example slice [g·B/n, (g+1)·B/n),
matching ``AsyncTrainer._example_weights``.

Also provides epoch shuffling (random-reshuffling / shuffle-once) over a
finite synthetic corpus for the single-node special cases.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def zipf_pmf(vocab: int, a: float) -> np.ndarray:
    """Normalised Zipf pmf over ranks 1..vocab with exponent ``a``.

    The single source of the token marginal law: the host pipeline, the
    compiled plan's static inverse-CDF table, AND the scenario layer's
    drifting-exponent CDF bank all build from this, so a drifting world
    whose trajectory passes through ``a`` samples the exact distribution
    the stationary world at ``a`` uses."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    pmf = ranks ** (-float(a))
    return pmf / pmf.sum()


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_groups: int = 1
    heterogeneity: float = 1.0    # 0 = iid groups, larger = more skew
    zipf_a: float = 1.2
    seed: int = 0


class HeterogeneousTokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.n_groups:
            raise ValueError("global_batch must divide n_groups")
        rng = np.random.default_rng(cfg.seed)
        base = np.arange(cfg.vocab)
        self.perms = []
        for g in range(cfg.n_groups):
            p = base.copy()
            swap = int(cfg.heterogeneity * cfg.vocab)
            if swap > 1:
                idx = rng.choice(cfg.vocab, size=min(swap, cfg.vocab), replace=False)
                p[idx] = rng.permutation(p[idx])
            self.perms.append(p)
        self.pmf = zipf_pmf(cfg.vocab, cfg.zipf_a)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + 7919 * step + 1)
        per = cfg.global_batch // cfg.n_groups
        out = np.empty((cfg.global_batch, cfg.seq_len), np.int32)
        for g in range(cfg.n_groups):
            draws = rng.choice(cfg.vocab, size=(per, cfg.seq_len), p=self.pmf)
            out[g * per:(g + 1) * per] = self.perms[g][draws]
        return {"tokens": out}


class EpochShuffler:
    """RR / shuffle-once index streams over a corpus of N examples."""

    def __init__(self, n_examples: int, seed: int = 0, reshuffle: bool = True):
        self.n = n_examples
        self.reshuffle = reshuffle
        self._rng = np.random.default_rng(seed)
        self._perm = self._rng.permutation(self.n)
        self._i = 0

    def next_indices(self, k: int) -> np.ndarray:
        out = []
        while len(out) < k:
            take = min(k - len(out), self.n - self._i)
            out.extend(self._perm[self._i:self._i + take])
            self._i += take
            if self._i == self.n:
                self._i = 0
                if self.reshuffle:
                    self._perm = self._rng.permutation(self.n)
        return np.asarray(out)
