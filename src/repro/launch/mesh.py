"""Production meshes (TPU v5e pods; placeholder host devices for dry-runs).

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before the first jax init).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link


def _make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where the installed jax
    supports them (>= 0.5), plain make_mesh otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (1 device on CPU) as (data=1, model=n)."""
    return _make_mesh((1, len(jax.devices())), ("data", "model"))


def mesh_devices(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
