"""Multi-device pooled-update parity: shard_map over ZeRO-sharded pools.

The pooled path's claim is that the server update runs as one kernel per
dtype pool PER DEVICE, each device touching only its local ZeRO rows.  This
suite checks numerics on real (virtual) multi-device meshes:

* a 4-data × 2-model mesh for the pure-optim pooled apply on explicitly
  ZeRO-sharded pool buffers, and
* a 2-pod × 2-data × 2-model mesh for the trainer-level three-way
  (reference / per-leaf pallas / pooled) curve parity,

both under the documented FMA-contraction tolerances
(tests/test_optim_fused.py).

On a single-device host the suite re-launches itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (jax device topology
is fixed at first init, so the flag cannot be set in-process); inside that
subprocess the wrapper auto-skips and the real tests run.  CI also invokes
the 8-device run directly.
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

MULTI = jax.device_count() >= 8

F32 = jnp.float32


@pytest.mark.skipif(MULTI, reason="already on a multi-device host")
def test_multidevice_suite_in_subprocess():
    """Single-device hosts: run this file under 8 virtual CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, \
        f"8-device suite failed:\n{r.stdout}\n{r.stderr}"
    assert " passed" in r.stdout


def _mesh(shape, axes):
    from repro.launch.mesh import _make_mesh
    return _make_mesh(shape, axes)


def _tree(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "w": jax.random.normal(ks[0], (33, 7), F32).astype(jnp.bfloat16),
        "b": jax.random.normal(ks[1], (5,), F32),
        "scalar": jnp.asarray(0.37, F32),
        "big": jax.random.normal(ks[2], (1000,), F32).astype(jnp.bfloat16),
    }


def _grads_like(params, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), len(params))
    return {k: (jax.random.normal(kk, p.shape, F32).astype(p.dtype)
                if p.ndim else jnp.asarray(0.1 * (seed + 1), p.dtype))
            for kk, (k, p) in zip(ks, sorted(params.items()))}


@pytest.mark.skipif(not MULTI, reason="needs >= 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
@pytest.mark.parametrize("name,momentum", [("adam", 0.0), ("sgd", 0.9)])
def test_pooled_apply_parity_on_zero_sharded_state(name, momentum):
    """Pooled delayed apply on pools device_put with the pooled
    PartitionSpec over a 4-data × 2-model mesh ≡ the reference tree path,
    and the outputs keep the ZeRO sharding (no silent replication)."""
    from jax.sharding import NamedSharding
    from repro.distributed import pool_axes, pool_shard_count, pooled_pspec
    from repro.optim import (OptConfig, adam_init, build_layout, init_pools,
                             pool_tree, pooled_delayed_apply,
                             reference_delayed_apply, unpool_tree)

    mesh = _mesh((4, 2), ("data", "model"))
    axes = pool_axes(mesh)
    assert pool_shard_count(mesh) == 4
    sh = NamedSharding(mesh, pooled_pspec(mesh))
    cfg = OptConfig(name=name, lr=1e-2, momentum=momentum, clip_norm=1.0)
    tree = _tree()
    lay = build_layout(tree, 4)

    put = lambda pools: {dk: jax.device_put(p, sh) for dk, p in pools.items()}
    pools = init_pools(lay, tree, sharding=sh)

    p_ref, s_ref = tree, adam_init(tree)
    b_ref = jax.tree_util.tree_map(jnp.zeros_like, tree)
    count = jnp.zeros((), jnp.int32)

    @jax.jit
    def step(pools, g_pools, count, scale):
        return pooled_delayed_apply(g_pools, pools, count, cfg,
                                    lr_scale=scale, mesh=mesh, axes=axes,
                                    interpret=True)

    for i in range(3):
        g = _grads_like(p_ref, i)
        p_ref, b_ref, s_ref, gn_r = reference_delayed_apply(
            g, b_ref, s_ref, p_ref, cfg, lr_scale=0.5)
        pools, count, gn_p = step(pools, put(pool_tree(lay, g)), count,
                                  jnp.float32(0.5))
        np.testing.assert_allclose(float(gn_r), float(gn_p), rtol=1e-6)

    for dk, grp in pools.items():
        for buf in grp.values():
            assert buf.sharding.is_equivalent_to(sh, buf.ndim), \
                f"pool {dk} lost its ZeRO sharding: {buf.sharding}"
    got_p = unpool_tree(lay, {dk: b["p"] for dk, b in pools.items()})
    got_b = unpool_tree(lay, {dk: b["gbuf"] for dk, b in pools.items()})
    for k in tree:
        tol = dict(rtol=3e-2, atol=3e-2) \
            if jnp.asarray(tree[k]).dtype == jnp.bfloat16 \
            else dict(rtol=1e-5, atol=5e-7)
        np.testing.assert_allclose(np.asarray(got_p[k], np.float32),
                                   np.asarray(p_ref[k], np.float32), **tol)
        np.testing.assert_array_equal(np.asarray(got_b[k]),
                                      np.asarray(b_ref[k]))
    assert int(count) == int(s_ref["count"])


@pytest.mark.skipif(not MULTI, reason="needs >= 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_trainer_three_way_parity_pod_data_model_mesh():
    """Acceptance: on a 2-pod × 2-data × 2-model mesh (ZeRO domain = 4
    shards), reference / per-leaf pallas_interpret / pallas_pooled_interpret
    training curves agree within the documented tolerances, through
    ``jit_train_step`` (i.e. with the real pooled state shardings)."""
    from repro.configs import get_arch
    from repro.data import DataConfig, HeterogeneousTokenPipeline
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.optim import OptConfig as OC

    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    B, S = 8, 16
    pipe = HeterogeneousTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=S, global_batch=B, n_groups=4))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    curves, final_params = {}, {}
    for impl in ("reference", "pallas_interpret", "pallas_pooled_interpret"):
        tr = AsyncTrainer(cfg, mesh,
                          opt=OC(lr=1e-2, clip_norm=1.0, update_impl=impl),
                          async_cfg=AsyncConfig(delay_rounds=1))
        if impl.startswith("pallas_pooled"):
            assert tr.pool_axes == ("pod", "data")
            assert tr.pool_layout.n_shards == 4
        state = tr.init_state(jax.random.PRNGKey(0))
        step = tr.jit_train_step((B, S))
        losses = []
        for i in range(4):
            state, m = step(state, batch, jnp.ones((tr.n_groups,)))
            losses.append(float(m["loss"]))
        curves[impl] = losses
        final_params[impl] = tr.params_of(state)
    np.testing.assert_allclose(curves["reference"],
                               curves["pallas_interpret"], rtol=5e-3)
    np.testing.assert_allclose(curves["reference"],
                               curves["pallas_pooled_interpret"], rtol=5e-3)
    # bf16 element drift is chaotic over 4 steps: per-leaf norm comparison
    for a, b in zip(jax.tree_util.tree_leaves(final_params["reference"]),
                    jax.tree_util.tree_leaves(
                        final_params["pallas_pooled_interpret"])):
        na = float(jnp.linalg.norm(jnp.ravel(a).astype(F32)))
        nb = float(jnp.linalg.norm(jnp.ravel(b).astype(F32)))
        np.testing.assert_allclose(na, nb, rtol=5e-2, atol=1e-4)
