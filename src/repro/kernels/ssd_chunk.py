"""Mamba2 SSD intra-chunk Pallas kernel (state-space duality).

The chunked SSD algorithm's hot spot is the intra-chunk quadratic part —
an attention-like (CBᵀ ∘ L) X contraction plus the chunk-state reduction.
This kernel fuses, per (batch, chunk, head-block) grid cell:

    L      = exp(segsum(dt·A))      (c, c) lower-triangular decay
    scores = (C Bᵀ) ∘ L             (c, c)
    y      = scores @ (x·dt)        (c, P)
    state  = (B · decay_to_end)ᵀ @ (x·dt)   (N, P)   — chunk-final state

so the (c, c) decay/score matrices never touch HBM.  The inter-chunk scan
(S/c steps) stays in jnp — it is tiny and sequential.

Grid: (B, n_chunks, H).  Blocks: x (c, P), dt (c,), B/C (c, N) in VMEM;
c=chunk (default 128) and P, N are MXU-friendly multiples of 64/128.

Validated under interpret=True against ``ref.reference_ssd_chunk``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, *,
                      chunk):
    x = x_ref[0, :, 0, :].astype(F32)          # (c, P)
    dt = dt_ref[0, :, 0].astype(F32)           # (c,)
    A = a_ref[0]                               # scalar decay rate (this head)
    Bm = b_ref[0, :, :].astype(F32)            # (c, N)
    Cm = c_ref[0, :, :].astype(F32)            # (c, N)

    la = dt * A                                # (c,) log-decays
    cum = jnp.cumsum(la)                       # (c,)
    # segsum matrix: cum[i] − cum[j] for j ≤ i else −inf
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)

    xdt = x * dt[:, None]                      # (c, P)
    scores = (Cm @ Bm.T) * L                   # (c, c)
    y_ref[0, :, 0, :] = (scores @ xdt).astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1] - cum)      # (c,)
    st = (Bm * decay_to_end[:, None]).T @ xdt  # (N, P)
    st_ref[0, 0, :, :] = st.astype(st_ref.dtype)


def ssd_chunk_pallas(x, dt, A, B_, C_, *, interpret=False):
    """Intra-chunk SSD for pre-chunked operands.

    x: (B, nc, c, H, P); dt: (B, nc, c, H); A: (H,);
    B_/C_: (B, nc, c, N)  (n_groups = 1, head-shared).
    Returns (y_diag (B,nc,c,H,P), states (B,nc,H,N,P)) — inter-chunk
    recurrence and offset term are composed by the caller (ops.ssd_chunked).
    """
    Bb, nc, c, H, P = x.shape
    N = B_.shape[-1]

    kern = functools.partial(_ssd_chunk_kernel, chunk=c)
    grid = (Bb * nc, H)
    xr = x.reshape(Bb * nc, c, H, P)
    dtr = dt.reshape(Bb * nc, c, H)
    br = B_.reshape(Bb * nc, c, N)
    cr = C_.reshape(Bb * nc, c, N)

    y, st = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, 1, P), lambda g, h: (g, 0, h, 0)),
            pl.BlockSpec((1, c, 1), lambda g, h: (g, 0, h)),
            pl.BlockSpec((1,), lambda g, h: (h,)),
            pl.BlockSpec((1, c, N), lambda g, h: (g, 0, 0)),
            pl.BlockSpec((1, c, N), lambda g, h: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, 1, P), lambda g, h: (g, 0, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda g, h: (g, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb * nc, c, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb * nc, H, N, P), F32),
        ],
        interpret=interpret,
    )(xr, dtr, A.astype(F32), br, cr)
    return (y.reshape(Bb, nc, c, H, P),
            st.reshape(Bb, nc, H, N, P))
