"""The paper's experimental objective (§5):

    f_i(x) = (1/m) Σ_j log(1 + exp(−b_ij a_ijᵀ x)) + λ Σ_k x_k²/(1 + x_k²)

Nonconvex regulariser makes the problem non-convex; each worker i owns its
own dataset (a_i, b_i) — heterogeneity enters through the data.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


class LogRegProblem:
    """Distributed logistic regression + nonconvex regulariser.

    features: (n_workers, m, d); labels: (n_workers, m) in {−1, +1}.
    Exposes jax-pure per-worker full/stochastic gradients and global loss —
    the exact plug for :func:`repro.core.simulator.replay`.
    """

    def __init__(self, features, labels, lam: float = 0.1, batch_size: int | None = None):
        self.A = jnp.asarray(features, dtype=jnp.float32)
        self.b = jnp.asarray(labels, dtype=jnp.float32)
        if self.A.ndim != 3 or self.b.shape != self.A.shape[:2]:
            raise ValueError("features (n,m,d) and labels (n,m) expected")
        self.n, self.m, self.d = self.A.shape
        self.lam = float(lam)
        self.batch_size = batch_size  # None → full local gradient

    # ---- losses -------------------------------------------------------------
    def _reg(self, x):
        return self.lam * jnp.sum(x * x / (1.0 + x * x))

    def local_loss(self, x, worker):
        a = self.A[worker]
        b = self.b[worker]
        z = -b * (a @ x)
        return jnp.mean(jnp.logaddexp(0.0, z)) + self._reg(x)

    def loss(self, x):
        z = -self.b * jnp.einsum("nmd,d->nm", self.A, x)
        return jnp.mean(jnp.logaddexp(0.0, z)) + self._reg(x)

    # ---- gradients ------------------------------------------------------------
    def local_grad(self, x, worker):
        """Full local gradient ∇f_i(x)."""
        return jax.grad(self.local_loss)(x, worker)

    def stochastic_grad(self, x, worker, key):
        """Mini-batch gradient over the worker's local data (Assumption 2)."""
        bs = self.batch_size or self.m
        idx = jax.random.choice(key, self.m, (bs,), replace=False)
        a = self.A[worker][idx]
        b = self.b[worker][idx]

        def f(x):
            z = -b * (a @ x)
            return jnp.mean(jnp.logaddexp(0.0, z)) + self._reg(x)

        return jax.grad(f)(x)

    def full_grad(self, x):
        return jax.grad(self.loss)(x)

    # ---- plugs for the simulator ----------------------------------------------
    def grad_fn(self, stochastic: bool = False):
        if stochastic:
            return lambda x, w, key: self.stochastic_grad(x, w, key)
        return lambda x, w, key: self.local_grad(x, w)

    def per_worker_grad_fn(self):
        return lambda x, w: self.local_grad(x, w)

    # ---- problem constants for theory.py ---------------------------------------
    def smoothness_bound(self) -> float:
        """L ≤ max_i ||A_i||²_op/(4m) + 2λ (logistic) — cheap upper bound."""
        A = np.asarray(self.A)
        ops = [np.linalg.norm(A[i], ord=2) ** 2 / (4.0 * self.m) for i in range(self.n)]
        return float(max(ops) + 2.0 * self.lam)

    def zeta(self, x) -> float:
        gs = np.stack([np.asarray(self.local_grad(jnp.asarray(x), i)) for i in range(self.n)])
        gbar = gs.mean(0)
        return float(np.max(np.linalg.norm(gs - gbar, axis=-1)))

    # ---- single-node view (each data point = one client, §3.2) -----------------
    def as_single_node(self) -> "LogRegProblem":
        A = np.asarray(self.A).reshape(self.n * self.m, 1, self.d)
        b = np.asarray(self.b).reshape(self.n * self.m, 1)
        return LogRegProblem(A, b, lam=self.lam)
