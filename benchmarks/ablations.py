"""Ablations beyond the paper's main figures — all through ``repro.api``.

1. waiting-b (Alg 3/5): Prop. C.3/D.2 predict the stochastic term shrinks
   as 1/√b — measured on the exact tier across b.
2. shuffle-once vs per-cycle reshuffling (§3.2 allows both for Alg 6).
3. delay-adaptive stepsizes (Table 1 note b): pure async with γ_t =
   γ·min(1, τ_C/(τ_t+1)) vs constant γ under a heavy-tail straggler.
4. transformer-scale ordering: the AsyncTrainer (production tier) under
   pure vs shuffled masks on heterogeneous token data.
"""
from __future__ import annotations

import csv
import os

import numpy as np

from repro.api import (ExperimentSpec, SimulatorBackend, TrainerBackend,
                       TrainJob, delay_adaptive)
from repro.objectives import LogRegProblem, make_synthetic


def waiting_b_sweep(T_rounds=600, out="experiments/figs", quick=False):
    """Alg 3: larger b → smaller stochastic term (rate ∝ 1/√(Tb))."""
    n = 8
    A, b_ = make_synthetic(1.0, 1.0, n=n, m=200, d=200, seed=2)
    prob = LogRegProblem(A, b_, lam=0.1, batch_size=20)
    rows = []
    bs = (1, 2, 4, 8) if not quick else (1, 4)
    backend = SimulatorBackend()
    for b in bs:
        res = backend.run(ExperimentSpec(
            scheduler=f"pure_waiting:b={b}", timing="poisson:slow=6",
            objective=prob, T=T_rounds * b, stepsize=0.01, stochastic=True,
            log_every=max(T_rounds * b // 20, 1), seed=0))
        rows.append({"ablation": "waiting_b", "b": b,
                     "final_grad_norm": float(np.mean(res.grad_norms[-3:])),
                     "tau_max": res.trace["tau_max"]})
    return rows


def shuffle_once_vs_reshuffle(T=4000, quick=False):
    n = 10
    A, b_ = make_synthetic(1.0, 1.0, n=n, m=150, d=200, seed=3)
    prob = LogRegProblem(A, b_, lam=0.1)
    rows = []
    backend = SimulatorBackend()
    for scheduler in ("shuffled", "shuffled:reshuffle=0"):
        res = backend.run(ExperimentSpec(
            scheduler=scheduler, timing="poisson:slow=6", objective=prob,
            T=T if not quick else T // 4, stepsize=0.002, log_every=200,
            seed=0))
        rows.append({"ablation": "shuffle_once",
                     "mode": "reshuffle" if scheduler == "shuffled" else "once",
                     "final_grad_norm": float(np.mean(res.grad_norms[-3:]))})
    return rows


def delay_adaptive_ablation(T=4000, quick=False):
    """Heavy straggler: one worker 40× slower.  Delay-adaptive stepsizes
    keep the large-γ convergence without the stale-gradient blowup."""
    n = 8
    A, b_ = make_synthetic(1.0, 1.0, n=n, m=150, d=200, seed=4)
    prob = LogRegProblem(A, b_, lam=0.1)
    speeds = tuple([1.0] * (n - 1) + [40.0])
    T = T if not quick else T // 4
    rows = []
    # Measured finding (EXPERIMENTS.md §Claims): in the HETEROGENEOUS regime
    # delay-adaptive stepsizes shrink the straggler's updates to ~0, which
    # suppresses its data distribution entirely — the resulting bias hurts
    # more than the staleness it prevents.  This *supports* the paper's
    # design: balance contributions (shuffling) instead of suppressing them.
    gamma = 0.05
    backend = SimulatorBackend()
    for adaptive in (False, True):
        res = backend.run(ExperimentSpec(
            scheduler="pure", timing="fixed", objective=prob, T=T,
            stepsize=delay_adaptive(gamma) if adaptive else gamma,
            speeds=speeds, log_every=50, seed=0))
        half = len(res.grad_norms) // 2
        rows.append({"ablation": "delay_adaptive", "adaptive": adaptive,
                     "gamma": gamma, "tau_max": res.trace["tau_max"],
                     "final_grad_norm": float(np.mean(res.grad_norms[-3:])),
                     "worst_spike": float(np.max(res.grad_norms[half:]))})
    return rows


def transformer_ordering(steps=30, quick=False):
    """Production tier: shuffled masks beat pure masks on the reduced
    transformer with heterogeneous token data (loss after N rounds)."""
    steps = steps if not quick else 12
    n_groups = 4
    rows = []
    backend = TrainerBackend()
    for alg in ("pure", "shuffled"):
        res = backend.run(ExperimentSpec(
            scheduler=alg, timing="poisson:slow=8",
            objective=TrainJob(arch="qwen2-0.5b", global_batch=8, seq_len=32,
                               heterogeneity=1.0, delay_rounds=1),
            T=steps, n_workers=n_groups, stepsize=5e-3, seed=0))
        rows.append({"ablation": "transformer_ordering", "alg": alg,
                     "final_loss": float(np.mean(res.losses[-5:]))})
    return rows


def run(out="experiments/figs", quick=False):
    os.makedirs(out, exist_ok=True)
    rows = []
    rows += waiting_b_sweep(quick=quick)
    rows += shuffle_once_vs_reshuffle(quick=quick)
    rows += delay_adaptive_ablation(quick=quick)
    rows += transformer_ordering(quick=quick)
    with open(os.path.join(out, "ablations.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=sorted({k for r in rows for k in r}))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
