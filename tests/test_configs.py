"""Exact assigned dimensions — guards against accidental config drift."""
import pytest

from repro.configs import ARCHS, get_arch, SHAPES

ASSIGNED = {
    # name: (family, L, d_model, H, kv, d_ff, vocab)
    "grok-1-314b": ("moe", 64, 6144, 48, 8, 32768, 131072),
    "deepseek-moe-16b": ("moe", 28, 2048, 16, 16, 1408, 102400),
    "minitron-8b": ("dense", 32, 4096, 32, 8, 16384, 256000),
    "qwen2-0.5b": ("dense", 24, 896, 14, 2, 4864, 151936),
    "stablelm-1.6b": ("dense", 24, 2048, 32, 32, 5632, 100352),
    "zamba2-7b": ("hybrid", 81, 3584, 32, 32, 14336, 32000),
    "mamba2-370m": ("ssm", 48, 1024, 0, 0, 0, 50280),
    "seamless-m4t-large-v2": ("audio", 24, 1024, 16, 16, 8192, 256206),
    "pixtral-12b": ("vlm", 40, 5120, 32, 8, 14336, 131072),
    "qwen3-8b": ("dense", 36, 4096, 32, 8, 12288, 151936),
}


def test_all_ten_assigned_archs_present():
    assert sorted(ARCHS) == sorted(ASSIGNED)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_exact_assigned_dims(name):
    fam, L, d, H, kv, ff, V = ASSIGNED[name]
    c = get_arch(name)
    assert (c.family, c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.d_ff, c.vocab) == (fam, L, d, H, kv, ff, V)


def test_assigned_details():
    g = get_arch("grok-1-314b")
    assert g.n_experts == 8 and g.top_k == 2
    ds = get_arch("deepseek-moe-16b")
    assert ds.n_experts == 64 and ds.top_k == 6 and ds.n_shared_experts == 2
    assert get_arch("qwen2-0.5b").qkv_bias
    assert get_arch("qwen3-8b").qk_norm
    z = get_arch("zamba2-7b")
    assert z.ssm_state == 64 and z.attn_every == 6
    assert get_arch("mamba2-370m").ssm_state == 128
    assert get_arch("seamless-m4t-large-v2").enc_layers == 24
    assert get_arch("pixtral-12b").n_patches > 0


def test_assigned_shapes():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1
    assert SHAPES["decode_32k"].kind == "decode" and SHAPES["long_500k"].kind == "decode"


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_reduced_variants_within_smoke_budget(name):
    r = get_arch(name).reduced()
    assert r.n_layers <= 2 or r.family == "hybrid" and r.n_layers <= 2
    assert r.d_model <= 512
    if r.n_experts:
        assert r.n_experts <= 4
    if r.n_heads:
        assert r.n_heads % r.n_kv_heads == 0
