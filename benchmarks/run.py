"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the repo convention;
full curves/tables land in experiments/figs/*.csv|npz.

  python -m benchmarks.run [--quick] [--only fig1,fig2,fig3,table1,perf]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grids (CI-sized)")
    ap.add_argument("--only", default="fig1,fig2,fig3,table1,ablations,perf")
    args = ap.parse_args()
    which = set(args.only.split(","))

    print("name,us_per_call,derived")

    if "fig1" in which:
        from . import fig1_fullgrad
        t0 = time.time()
        rows = fig1_fullgrad.run(quick=args.quick)
        us = (time.time() - t0) * 1e6 / max(len(rows), 1)
        worst = max(r["final_grad_norm"] for r in rows if r["alg"] == "pure")
        best = min(r["final_grad_norm"] for r in rows if r["alg"] == "shuffled")
        print(f"fig1_fullgrad,{us:.0f},pure_worst={worst:.3g};shuffled_best={best:.3g}")

    if "fig2" in which:
        from . import fig2_stochastic
        t0 = time.time()
        rows = fig2_stochastic.run(quick=args.quick)
        us = (time.time() - t0) * 1e6 / max(len(rows), 1)
        best = min(r["final_grad_norm"] for r in rows if r["alg"] == "shuffled")
        print(f"fig2_stochastic,{us:.0f},shuffled_best={best:.3g}")

    if "fig3" in which:
        from . import fig3_grid
        t0 = time.time()
        rows = fig3_grid.run(quick=args.quick)
        us = (time.time() - t0) * 1e6 / max(len(rows), 1)
        n_shuffled_wins = sum(
            1 for r in rows if r["alg"] == "shuffled" and all(
                r["final_grad_norm"] <= q["final_grad_norm"] * 1.2
                for q in rows
                if q["alg"] != "shuffled" and q["pattern"] == r["pattern"]
                and q["alpha"] == r["alpha"]))
        print(f"fig3_grid,{us:.0f},shuffled_wins={n_shuffled_wins}")

    if "table1" in which:
        from . import table1_rates
        t0 = time.time()
        rows = table1_rates.run(quick=args.quick)
        us = (time.time() - t0) * 1e6 / max(len(rows), 1)
        ok = all(r["sigma2_ok"] and r["nu2_ok"] for r in rows)
        print(f"table1_rates,{us:.0f},bounds_hold={ok}")

    if "ablations" in which:
        from . import ablations
        t0 = time.time()
        rows = ablations.run(quick=args.quick)
        us = (time.time() - t0) * 1e6 / max(len(rows), 1)
        wb = {r["b"]: r["final_grad_norm"] for r in rows
              if r["ablation"] == "waiting_b"}
        mono = all(wb[b2] <= wb[b1] * 1.3 for b1, b2 in
                   zip(sorted(wb), sorted(wb)[1:]))
        print(f"ablations,{us:.0f},waiting_b_monotone={mono}")

    if "perf" in which:
        from . import perf_trainstep
        rows = perf_trainstep.run(quick=args.quick)
        for r in rows:
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
