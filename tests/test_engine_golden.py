"""Golden-trace regression tests for the discrete-event engine.

``build_schedule`` is the single source of truth for the ordering
(i_t, π_t) — the simulator replay, the trainer masks and the theory stats
all consume it.  A silent change in event ordering (heap tie-breaks, queue
pops, RNG call order) would shift every downstream result while each
individual test still "looks plausible".  These tests freeze one small
schedule per (scheduler × timing model) pair under ``tests/fixtures/engine``
and assert the realised ``workers``, ``assign_iters`` and the paper's delay
statistics (τ_max / τ_avg / τ_C, Defs 1–2) are **bit-identical** to the
frozen trace.

Regenerate (ONLY after an intentional semantic change, and say so in the
commit message):

    PYTHONPATH=src python tests/test_engine_golden.py --regen
"""
import json
import os

import numpy as np
import pytest

from repro.core import (PATTERNS, REGISTRY, TimingModel, build_schedule,
                        heterogeneous_speeds, make_scheduler)

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures", "engine")

#: fixture scenario — small enough to eyeball, big enough to exercise
#: queueing (random/fedbuff assign busy workers) and a reshuffle boundary
N_WORKERS = 5
T = 24
SEED = 0
SLOW = 4.0
WAITING = {"pure_waiting": 3, "fedbuff": 3, "minibatch": 3}

PAIRS = [(s, p) for s in sorted(REGISTRY) for p in PATTERNS]


def _build(name: str, pattern: str):
    sched = make_scheduler(name, N_WORKERS, b=WAITING.get(name, 1), seed=SEED)
    timing = TimingModel(heterogeneous_speeds(N_WORKERS, slow_factor=SLOW),
                         pattern, seed=SEED)
    return build_schedule(sched, timing, T)


def _fixture_path(name: str, pattern: str) -> str:
    return os.path.join(FIXTURE_DIR, f"{name}_{pattern}.json")


def _to_record(s) -> dict:
    return {
        "workers": [int(w) for w in s.workers],
        "assign_iters": [int(a) for a in s.assign_iters],
        "unfinished_assign_iters": [int(a)
                                    for a in s.unfinished_assign_iters],
        "tau_max": s.tau_max(),
        "tau_avg": s.tau_avg(),     # exact float64 repr round-trips JSON
        "tau_c": s.tau_c(),
        "wait_b": s.wait_b,
    }


@pytest.mark.parametrize("name,pattern", PAIRS,
                         ids=[f"{s}-{p}" for s, p in PAIRS])
def test_schedule_matches_golden_trace(name, pattern):
    path = _fixture_path(name, pattern)
    assert os.path.exists(path), (
        f"missing fixture {path}; regenerate with "
        "`PYTHONPATH=src python tests/test_engine_golden.py --regen`")
    with open(path) as f:
        want = json.load(f)
    got = _to_record(_build(name, pattern))
    np.testing.assert_array_equal(got["workers"], want["workers"])
    np.testing.assert_array_equal(got["assign_iters"], want["assign_iters"])
    np.testing.assert_array_equal(got["unfinished_assign_iters"],
                                  want["unfinished_assign_iters"])
    assert got["tau_max"] == want["tau_max"]
    assert got["tau_avg"] == want["tau_avg"]
    assert got["tau_c"] == want["tau_c"]
    assert got["wait_b"] == want["wait_b"]


def test_build_schedule_is_deterministic():
    """Two builds of the same spec must agree with themselves, not just the
    fixture (guards against hidden global RNG state)."""
    a = _to_record(_build("fedbuff", "poisson"))
    b = _to_record(_build("fedbuff", "poisson"))
    assert a == b


def _regen():
    os.makedirs(FIXTURE_DIR, exist_ok=True)
    for name, pattern in PAIRS:
        rec = _to_record(_build(name, pattern))
        rec["_scenario"] = {"n_workers": N_WORKERS, "T": T, "seed": SEED,
                            "slow_factor": SLOW,
                            "wait_b": WAITING.get(name, 1)}
        with open(_fixture_path(name, pattern), "w") as f:
            json.dump(rec, f, indent=1)
        print("wrote", _fixture_path(name, pattern))


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
