"""Table 1 convergence-rate calculator + tuned-stepsize rules.

Every row of Table 1 (the paper's headline result) is a function of the
problem constants (L, F₀, σ², ζ², G) and the schedule constants (τ_C, τ_max,
T, b, n).  These are *upper bounds on E‖∇f(x̂)‖²*; benchmarks/table1_rates.py
compares their shape against measured convergence.

Stepsize rules implement the Propositions' tuning (C.1–C.3, D.1–D.5).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    L: float          # smoothness (Assumption 1)
    F0: float         # initial suboptimality f(x0) − f*
    sigma2: float     # stochastic-gradient variance (Assumption 2)
    zeta2: float      # heterogeneity (Assumption 3)
    G: float = 0.0    # gradient bound (Assumption 4), 0 = unavailable


def _chk(c: ProblemConstants, bounded_grad: bool):
    if bounded_grad and c.G <= 0:
        raise ValueError("this rate requires Assumption 4 (G > 0)")


# ----------------------------------------------------------------------------
# Table 1 rows (our rates).
# ----------------------------------------------------------------------------

def pure_async(c: ProblemConstants, T: int, tau_c: int, tau_max: int,
               bounded_grad: bool = False) -> float:
    """Alg 2.  No-BG: L F₀ √(τ_max τ_C)/T + √(L F₀ σ²/T) + ζ².
    BG:  L F₀ τ_C/T + √(L F₀ σ²/T) + (L F₀ G τ_C/T)^{2/3} + ζ²."""
    if not bounded_grad:
        return (c.L * c.F0 * math.sqrt(tau_max * tau_c) / T
                + math.sqrt(c.L * c.F0 * c.sigma2 / T) + c.zeta2)
    _chk(c, True)
    return (c.L * c.F0 * tau_c / T
            + math.sqrt(c.L * c.F0 * c.sigma2 / T)
            + (c.L * c.F0 * c.G * tau_c / T) ** (2.0 / 3.0) + c.zeta2)


def pure_async_waiting(c: ProblemConstants, T: int, tau_c: int, tau_max: int,
                       b: int, bounded_grad: bool = False) -> float:
    """Alg 3."""
    if not bounded_grad:
        return (c.L * c.F0 * math.sqrt(tau_max * tau_c) / (T * math.sqrt(b))
                + math.sqrt(c.L * c.F0 * c.sigma2 / (T * b)) + c.zeta2)
    _chk(c, True)
    return (c.L * c.F0 * tau_c / (T * b)
            + math.sqrt(c.L * c.F0 * c.sigma2 / (T * b))
            + (c.L * c.F0 * c.G * tau_c / (T * b)) ** (2.0 / 3.0) + c.zeta2)


def random_async(c: ProblemConstants, T: int, tau_c: int) -> float:
    """Alg 4 (ours, BG): L F₁ τ_C/T + √(LF₁σ²/T) + √(LF₁ζ²/T) + (LF₁τ_C G/T)^{2/3}."""
    _chk(c, True)
    return (c.L * c.F0 * tau_c / T
            + math.sqrt(c.L * c.F0 * c.sigma2 / T)
            + math.sqrt(c.L * c.F0 * c.zeta2 / T)
            + (c.L * c.F0 * tau_c * c.G / T) ** (2.0 / 3.0))


def fedbuff(c: ProblemConstants, T: int, tau_c: int, b: int) -> float:
    """Alg 5 (random async with waiting), ours."""
    _chk(c, True)
    return (c.L * c.F0 * tau_c / T
            + math.sqrt(c.L * c.F0 * c.zeta2 / (T * b))
            + math.sqrt(c.L * c.F0 * c.sigma2 / (T * b))
            + (c.L * c.F0 * tau_c * c.G / (T * b)) ** (2.0 / 3.0))


def shuffled_async(c: ProblemConstants, T: int, n: int) -> float:
    """Alg 6 [NEW]: LnF₁/T + √(LF₁σ²/T) + (LF₁√n ζ/T)^{2/3} + (LF₁Gn/T)^{2/3}."""
    _chk(c, True)
    z = math.sqrt(c.zeta2)
    return (c.L * n * c.F0 / T
            + math.sqrt(c.L * c.F0 * c.sigma2 / T)
            + (c.L * c.F0 * math.sqrt(n) * z / T) ** (2.0 / 3.0)
            + (c.L * c.F0 * c.G * n / T) ** (2.0 / 3.0))


def minibatch_sgd(c: ProblemConstants, T: int, b: int) -> float:
    """Prop. C.2: LF₀/T + √(LF₀ζ²/(Tb)) (single-node view, ζ² = variance)."""
    return c.L * c.F0 / T + math.sqrt(c.L * c.F0 * c.zeta2 / (T * b))


def sgd_rr(c: ProblemConstants, T: int, n: int) -> float:
    """Prop. C.4: LF₀n/T + (LF₀√n ζ/T)^{2/3}."""
    z = math.sqrt(c.zeta2)
    return (c.L * c.F0 * n / T
            + (c.L * c.F0 * math.sqrt(n) * z / T) ** (2.0 / 3.0))


# ----------------------------------------------------------------------------
# Crossover analysis (Remark 1 / §D.3.3): shuffled beats random iff ζ ≥ √n·√ε.
# ----------------------------------------------------------------------------

def shuffled_beats_random(zeta: float, n: int, eps: float) -> bool:
    return zeta >= math.sqrt(n) * math.sqrt(eps)


# ----------------------------------------------------------------------------
# Tuned stepsizes from the Propositions (constants dropped, as in the paper).
# ----------------------------------------------------------------------------

def stepsize_pure_async(c: ProblemConstants, T: int, tau_c: int, tau_max: int) -> float:
    return min(1.0 / (c.L * math.sqrt(max(tau_max * tau_c, 1))),
               math.sqrt(c.F0 / (c.L * max(c.sigma2, 1e-12) * T)))


def stepsize_random_async(c: ProblemConstants, T: int, tau_c: int) -> float:
    cands = [1.0 / (c.L * max(tau_c, 1))]
    if c.sigma2 > 0:
        cands.append(math.sqrt(c.F0 / (c.L * c.sigma2 * T)))
    if c.zeta2 > 0:
        cands.append(math.sqrt(c.F0 / (c.L * c.zeta2 * T)))
    if c.G > 0:
        cands.append((c.F0 / (c.L ** 2 * tau_c ** 2 * c.G ** 2 * T)) ** (1.0 / 3.0))
    return min(cands)


def stepsize_shuffled_async(c: ProblemConstants, T: int, n: int) -> float:
    cands = [1.0 / (30.0 * c.L * n)]
    if c.zeta2 > 0:
        cands.append((c.F0 / (c.L ** 2 * n * c.zeta2 * T)) ** (1.0 / 3.0))
    if c.G > 0:
        cands.append((c.F0 / (c.L ** 2 * n ** 2 * c.G ** 2 * T)) ** (1.0 / 3.0))
    return min(cands)


RATES = {
    "pure": pure_async,
    "pure_waiting": pure_async_waiting,
    "random": random_async,
    "fedbuff": fedbuff,
    "shuffled": shuffled_async,
    "minibatch": minibatch_sgd,
    "rr": sgd_rr,
}
