"""Shared helpers for the paper-experiment benchmarks (§5 / App. A)."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core import (TimingModel, build_schedule, replay, make_scheduler,
                        heterogeneous_speeds)
from repro.objectives import LogRegProblem

# the paper's stepsize grid (App. A.1)
PAPER_GRID = (0.005, 0.004, 0.003, 0.002, 0.001, 0.0005, 0.0001)

ALGS = ("pure", "random", "shuffled")


def run_alg(prob: LogRegProblem, alg: str, pattern: str, T: int,
            stepsizes=PAPER_GRID, stochastic: bool = False, seed: int = 0,
            slow_factor: float = 8.0, log_every: int = 100):
    """Grid-search the stepsize (paper protocol: best final grad norm with
    small fluctuations) and return (best_gamma, ts, grad_norms, seconds)."""
    n = prob.n
    best = None
    t0 = time.time()
    for gamma in stepsizes:
        sched = make_scheduler(alg, n, seed=seed)
        tm = TimingModel(heterogeneous_speeds(n, slow_factor), pattern,
                         seed=seed)
        s = build_schedule(sched, tm, T)
        res = replay(s, prob.grad_fn(stochastic=stochastic),
                     jnp.zeros(prob.d), gamma, log_every=log_every,
                     full_grad_fn=prob.full_grad)
        tail = float(np.mean(res.grad_norms[-3:]))
        fluct = float(np.std(res.grad_norms[-5:]))
        score = tail + 0.5 * fluct
        if best is None or score < best[0]:
            best = (score, gamma, res.log_ts, res.grad_norms)
    _, gamma, ts, gns = best
    return gamma, ts, gns, time.time() - t0
