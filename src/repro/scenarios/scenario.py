"""Declarative scenario spec → realised non-stationary world.

A :class:`Scenario` is an ordered list of :mod:`transforms` applied on top
of any (scheduler, timing) pair from the existing registries.  Realising a
scenario (:func:`realise_world`) wraps both objects behind a shared
round-indexed :class:`WorldClock` and runs the UNMODIFIED discrete-event
engine, so the output is an ordinary :class:`repro.core.engine.Schedule` —
every downstream consumer (round masks, ``runtime.compile_plan``, the
compiled ``PlanExecutor``) works untouched.  Non-schedule channels
(membership, data drift, sparsification) come back as plain per-round
arrays on the :class:`ScenarioWorld` and are folded into the ``RunPlan`` at
lowering time.

Spec-string grammar (CLI / ``ExperimentSpec.scenario``)::

    spec      := transform (";" transform)*
    transform := name [":" key "=" value ("," key "=" value)*]

e.g. ``"straggler:k=2,factor=8,every=16,span=4;elastic:k=1,every=32"``.
Values parse as int when possible, else float.  The empty spec ``""`` is
the identity scenario — it still takes the wrapped path, and MUST
reproduce the stationary world bit-for-bit (tests pin this).

Bit-exactness design: the timing wrapper owns no RNG — it feeds modulated
speeds through the base model's own ``_draw``/``_draw_batch``, so a
neutral factor consumes the base stream identically.  The scheduler
wrapper delegates policy decisions to the base scheduler's RNG and touches
its own (separate) remap RNG only when an elastic transform actually has
to move a job off a down worker.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.delays import TimingModel
from ..core.engine import Schedule, build_schedule
from ..core.schedulers import Scheduler
from .transforms import TRANSFORMS, WorldTransform


# ---------------------------------------------------------------------------
# Spec parsing
# ---------------------------------------------------------------------------

def _coerce(v: str):
    try:
        return int(v)
    except ValueError:
        return float(v)


def parse_scenario(spec: str) -> "Scenario":
    """Parse the ``name:k=v,...;name2:...`` grammar into a Scenario."""
    transforms: list[WorldTransform] = []
    for part in filter(None, (p.strip() for p in spec.split(";"))):
        name, _, argstr = part.partition(":")
        name = name.strip()
        if name not in TRANSFORMS:
            raise ValueError(
                f"unknown transform {name!r}; want one of {sorted(TRANSFORMS)}")
        kwargs = {}
        for kv in filter(None, (a.strip() for a in argstr.split(","))):
            k, sep, v = kv.partition("=")
            if not sep:
                raise ValueError(f"malformed transform arg {kv!r} (want k=v)")
            kwargs[k.strip()] = _coerce(v.strip())
        try:
            transforms.append(TRANSFORMS[name](**kwargs))
        except TypeError as e:
            raise ValueError(f"bad args for transform {name!r}: {e}") from None
    return Scenario(transforms=tuple(transforms), spec=spec)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """An ordered composition of world transforms (plus its source spec)."""

    transforms: tuple = ()
    spec: str = ""

    parse = staticmethod(parse_scenario)

    @property
    def names(self) -> tuple:
        return tuple(t.name for t in self.transforms)


# ---------------------------------------------------------------------------
# World clock + wrappers
# ---------------------------------------------------------------------------

class WorldClock:
    """Shared mutable round counter.

    The scheduler wrapper advances it once per ``next_workers`` call — i.e.
    at every server-round boundary — so the timing wrapper can look up
    round-indexed trajectories without the engine knowing anything changed.
    The final boundary of a T-receipt run calls ``next_workers`` at
    t == T, so the clock legitimately reaches ``rounds`` (= T // wait_b);
    trajectory tables are sized rounds+1 (or clamp) for exactly this.
    """

    def __init__(self):
        self.round = 0

    def reset(self) -> None:
        self.round = 0


class ScenarioTimingModel:
    """Timing wrapper: draws from the BASE model's RNG stream at
    transform-modulated speeds.  With no speed-modulating transforms it
    delegates wholesale, so the stationary stream is untouched."""

    def __init__(self, base: TimingModel, clock: WorldClock,
                 speed_transforms: tuple):
        self.base = base
        self.clock = clock
        self.speed_transforms = speed_transforms

    @property
    def n_workers(self) -> int:
        return self.base.n_workers

    @property
    def pattern(self) -> str:
        return self.base.pattern

    def _factors(self, workers: np.ndarray) -> np.ndarray:
        f = np.ones(len(workers), dtype=np.float64)
        for tr in self.speed_transforms:
            f *= tr.speed_factors(workers, self.clock.round)
        return f

    def sample(self, worker: int) -> float:
        if not self.speed_transforms:
            return self.base.sample(worker)
        w = np.asarray([worker], dtype=np.intp)
        s = float(self.base.speeds[worker]) * float(self._factors(w)[0])
        return self.base._draw(s)

    def sample_round(self, workers) -> np.ndarray:
        if not self.speed_transforms:
            return self.base.sample_round(workers)
        workers = np.asarray(workers, dtype=np.intp)
        if workers.size == 0:
            return np.zeros(0, dtype=np.float64)
        s = self.base.speeds[workers] * self._factors(workers)
        return self.base._draw_batch(s)


class ScenarioScheduler:
    """Scheduler wrapper: advances the world clock at each round boundary
    and — when elastic transforms declare workers down — remaps fresh
    assignments onto available workers (graceful drain: the pool never
    halts, jobs just avoid absent workers).

    Policy randomness stays in the base scheduler's RNG; remapping uses a
    separate RNG consumed only when a reassignment actually happens, so
    worlds without elastic transforms (and elastic worlds outside any down
    window) replay the base policy stream untouched.
    """

    def __init__(self, base: Scheduler, clock: WorldClock,
                 availability: np.ndarray | None, remap_seed):
        self.base = base
        self.clock = clock
        self.availability = availability
        self._remap_seed = remap_seed
        self._remap_rng = np.random.default_rng(remap_seed)

    # engine-facing surface -------------------------------------------------
    @property
    def n(self) -> int:
        return self.base.n

    @property
    def wait_b(self) -> int:
        return self.base.wait_b

    @property
    def name(self) -> str:
        return f"scenario({self.base.name})"

    def concurrency(self) -> int:
        return self.base.concurrency()

    def reset(self) -> None:
        self.base.reset()
        self.clock.reset()
        self._remap_rng = np.random.default_rng(self._remap_seed)

    def _remap(self, ws: list) -> list:
        if self.availability is None:
            return ws
        r = min(self.clock.round, self.availability.shape[0] - 1)
        up = np.flatnonzero(self.availability[r] > 0)
        if up.size == 0:        # transforms guarantee this can't happen
            return ws
        up_set = set(int(w) for w in up)
        taken = set(w for w in ws if w in up_set)
        out = []
        for w in ws:
            if w in up_set:
                out.append(w)
                continue
            # prefer an available worker the round hasn't claimed yet (keeps
            # without-replacement policies without replacement)
            free = [int(u) for u in up if int(u) not in taken]
            pool = free if free else [int(u) for u in up]
            pick = int(pool[self._remap_rng.integers(len(pool))])
            taken.add(pick)
            out.append(pick)
        return out

    def initial_workers(self):
        return self._remap(list(self.base.initial_workers()))

    def next_workers(self, finished):
        self.clock.round += 1
        return self._remap(list(self.base.next_workers(finished)))


# ---------------------------------------------------------------------------
# Realisation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScenarioWorld:
    """A realised scenario: the ordinary Schedule plus the per-round
    channels that `runtime.compile_plan` folds into the RunPlan."""

    schedule: Schedule
    scenario: Scenario
    rounds: int
    #: (rounds, n) 0/1 membership, or None when no elastic transform
    availability: np.ndarray | None = None
    #: (rounds,) Zipf exponents, or None when the data law is static
    zipf_as: np.ndarray | None = None
    #: (rounds,) gradient keep-densities in (0, 1], or None
    grad_density: np.ndarray | None = None
    #: (rounds, n) per-worker loss-weight gains (NaN = poisoned receipt),
    #: or None when no fault transform injects gradient faults
    fault_gain: np.ndarray | None = None
    #: sorted round indices where the driver process is scheduled to be
    #: preempted (host-level metadata — never lowered to device), or None
    preempt_rounds: np.ndarray | None = None


def realise_world(scenario: Scenario, scheduler: Scheduler,
                  timing: TimingModel, T: int, *, seed: int = 0,
                  rounds: int | None = None) -> ScenarioWorld:
    """Wrap (scheduler, timing) in the scenario and run the exact engine.

    ``seed`` drives ONLY the scenario layer (transform trajectories and
    elastic remapping) — the base scheduler/timing keep their own seeds, so
    the identity scenario reproduces the stationary schedule bit-for-bit
    regardless of ``seed``.
    """
    if timing.n_workers != scheduler.n:
        raise ValueError("scheduler and timing model disagree on n_workers")
    b = scheduler.wait_b
    n_rounds = T // b if rounds is None else min(rounds, T // b)
    n = scheduler.n

    for i, tr in enumerate(scenario.transforms):
        tr.prepare(n, n_rounds, np.random.default_rng([seed, i]))

    avail = None
    for tr in scenario.transforms:
        a = tr.availability()
        if a is not None:
            a = a[:n_rounds]
            avail = a if avail is None else avail * a

    clock = WorldClock()
    speed_trs = tuple(t for t in scenario.transforms if t.modulates_speed)
    sched_w = ScenarioScheduler(scheduler, clock, avail, [seed, 10_007])
    timing_w = ScenarioTimingModel(timing, clock, speed_trs)
    schedule = build_schedule(sched_w, timing_w, T)

    zipf_as = None
    for tr in scenario.transforms:
        z = tr.zipf_trajectory()
        if z is not None:
            zipf_as = np.asarray(z, dtype=np.float64)[:n_rounds]  # last wins

    density = None
    for tr in scenario.transforms:
        d = tr.grad_density(schedule)
        if d is not None:
            d = np.asarray(d, dtype=np.float32)[:n_rounds]
            # composing sparsifiers: the most aggressive density wins
            density = d if density is None else np.minimum(density, d)

    gain = None
    for tr in scenario.transforms:
        g = tr.fault_gain()
        if g is not None:
            g = np.asarray(g, dtype=np.float32)[:n_rounds]
            # gains compose multiplicatively; NaN absorbs (poison wins)
            gain = g if gain is None else gain * g

    preempts = []
    for tr in scenario.transforms:
        p = tr.preempt_rounds()
        if p is not None and len(p):
            preempts.append(np.asarray(p, dtype=np.int64))
    preempt = (np.unique(np.concatenate(preempts)[
        np.concatenate(preempts) < n_rounds]) if preempts else None)
    if preempt is not None and preempt.size == 0:
        preempt = None

    return ScenarioWorld(
        schedule=schedule,
        scenario=scenario,
        rounds=n_rounds,
        availability=avail,
        zipf_as=zipf_as,
        grad_density=density,
        fault_gain=gain,
        preempt_rounds=preempt,
    )
