"""Scenario-world smoke bench: non-stationary RunPlans through the scan
executor.

One row per world (stationary baseline, straggler, elastic, and a
combined drift + data-drift + sparsify world): realise the scenario,
lower it to a ``RunPlan`` — availability, CDF-bank and grad-density
channels included — and time the WARM whole-run scan dispatch, recording
the realised τ-statistics next to the throughput.  The point is a CI
canary with two properties:

* every scenario channel compiles and runs end-to-end on every push (the
  numbers are a bonus; the row existing at all is the gate),
* rounds/s across worlds shows what the extra channels COST at dispatch
  level (the cdf gather and the per-leaf quantile are per-round device
  work; elastic/straggler are free at run time — they only reshape the
  host-side lowering).

Writes ``experiments/figs/BENCH_scenarios.json`` (``bench:
"scenarios"``).  There is no committed baseline for this payload:
``benchmarks/check_perf.py`` only gates the ``runtime_dispatch_ab`` kind
and loudly skips others, so this file is an artifact for eyeballs, not a
pass/fail gate.

    PYTHONPATH=src python -m benchmarks.perf_scenarios --quick
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
from jax.sharding import Mesh

from repro.api import ExperimentSpec, TrainJob, TrainerBackend
from repro.distributed import AsyncTrainer, AsyncConfig
from repro.optim import OptConfig
from repro.runtime import PlanExecutor, compile_plan
from repro.scenarios import tau_report

#: world name → scenario spec string ("" = identity wrap — the baseline
#: every other row is read against)
WORLDS = (
    ("stationary", ""),
    ("straggler", "straggler:k=1,factor=8,every=16,span=4"),
    ("elastic", "elastic:k=1,every=16,span=4"),
    ("drift_sparsify", "drift:period=32,amp=0.5;"
                       "data_drift:a0=1.1,a1=2.0;sparsify:frac=0.5"),
)

#: smallest step the trainer can run — the bench measures the dispatch
#: layer + per-round channel cost, not model compute
TINY = (("n_layers", 1), ("d_model", 8), ("n_heads", 1), ("n_kv_heads", 1),
        ("d_ff", 16), ("vocab", 127))


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


def run(out: str = "experiments/figs", quick: bool = False,
        rounds: int = 0, arch: str = "qwen2-0.5b") -> dict:
    os.makedirs(out, exist_ok=True)
    rounds = rounds or (64 if quick else 256)
    k = min(16, rounds)
    job = TrainJob(arch=arch, global_batch=4, seq_len=4,
                   arch_overrides=TINY)
    mesh = _mesh()
    tr = AsyncTrainer(job.make_arch(), mesh,
                      opt=OptConfig(lr=3e-3, clip_norm=1.0),
                      async_cfg=AsyncConfig(delay_rounds=1))
    tr.n_groups = 4

    entries = []
    for name, scen in WORLDS:
        spec = ExperimentSpec(scheduler="fedbuff:b=2",
                              timing="poisson:slow=6", objective=job,
                              T=rounds, n_workers=4, stepsize=3e-3, seed=0,
                              scenario=scen)
        world = TrainerBackend.world_for(spec, 4)
        plan = compile_plan(world.schedule, job, rounds=rounds, n_groups=4,
                            seed=0, availability=world.availability,
                            zipf_as=world.zipf_as,
                            grad_density=world.grad_density)
        ex = PlanExecutor(tr, plan, donate=False)
        state = tr.init_state(jax.random.PRNGKey(0))
        r = ex.run_scan(state, rounds_per_launch=k,
                        metrics="none")                    # compile
        jax.block_until_ready(jax.tree_util.tree_leaves(r.state)[0])
        t0 = time.time()
        r = ex.run_scan(state, rounds_per_launch=k, metrics="none")
        jax.block_until_ready(jax.tree_util.tree_leaves(r.state)[0])
        dt = time.time() - t0
        rep = tau_report(world.schedule, "fedbuff", scenario_spec=scen)
        entry = {
            "world": name,
            "scenario": scen,
            "rounds": rounds,
            "seconds": round(dt, 4),
            "rounds_per_s": round(rounds / dt, 2),
            "launches": r.launches,
            "tau_max": rep["global"]["tau_max"],
            "tau_avg": round(rep["global"]["tau_avg"], 4),
            "tau_c": rep["global"]["tau_c"],
            "channels": {k_: v for k_, v in plan.summary().items()
                         if k_ in ("n_cdf_phases", "sparsified")},
        }
        entries.append(entry)
        print(f"{name:<16} rounds/s={entry['rounds_per_s']:>8} "
              f"tau_max={entry['tau_max']:>3} tau_c={entry['tau_c']:>3} "
              f"channels={entry['channels']}")

    payload = {
        "bench": "scenarios",
        "backend": jax.default_backend(),
        "arch": arch,
        "rounds": rounds,
        "note": ("one warm whole-run scan per world on the SAME trainer; "
                 "rows differ only in the realised world and the RunPlan "
                 "channels it lowers to.  Absolute rounds/s is "
                 "machine-local; read rows against the stationary row of "
                 "the same run.  tau stats are the realised global "
                 "statistics of each world's schedule."),
        "entries": entries,
    }
    path = os.path.join(out, "BENCH_scenarios.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", path)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="64 rounds instead of 256")
    ap.add_argument("--rounds", type=int, default=0)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--out", default="experiments/figs")
    args = ap.parse_args()
    run(out=args.out, quick=args.quick, rounds=args.rounds, arch=args.arch)


if __name__ == "__main__":
    main()
