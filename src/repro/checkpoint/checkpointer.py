"""Host-gather checkpointing: sharded state → flat .npz + metadata.

Small-scale by design (the container is one host); at real pod scale this
would be per-shard async writes — the interface (save/restore of the full
train-state pytree keyed by flattened paths) is what the rest of the
framework depends on.  bfloat16 leaves are bit-cast to uint16 for storage
(npz has no native bf16).

Durability contract: :func:`save` is ATOMIC at the file level — both
``state.npz`` and ``meta.json`` are written to temp files in the target
directory and ``os.replace``-d into place, so a crash mid-save never
leaves a truncated file behind; the worst case (killed between the two
replaces) is a fresh ``state.npz`` next to the previous ``meta.json``,
which :func:`restore` detects via the sha256 recorded in the metadata and
refuses loudly.  :func:`verify` runs the same integrity checks without
materialising the state (what :class:`~repro.checkpoint.AsyncSnapshotter`
uses to pick the newest restorable snapshot).
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile

import jax
import numpy as np

_BF16 = "__bf16__"


class CheckpointError(RuntimeError):
    """A checkpoint is missing, partial, or corrupt — never restore from
    it silently."""


def _flatten(tree):
    out = {}
    for p, v in jax.tree_util.tree_leaves_with_path(tree):
        arr = np.asarray(jax.device_get(v))
        key = jax.tree_util.keystr(p)
        if arr.dtype.name == "bfloat16":
            out[_BF16 + key] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _replace_into(path: str, name: str, write_fn) -> str:
    """Write via ``write_fn(tmp_path)`` then atomically rename to
    ``path/name`` (same directory, so the rename never crosses a
    filesystem boundary)."""
    fd, tmp = tempfile.mkstemp(dir=path, prefix=f".{name}.", suffix=".tmp")
    os.close(fd)
    try:
        write_fn(tmp)
        os.replace(tmp, os.path.join(path, name))
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return os.path.join(path, name)


def save(path: str, state, step: int | None = None, meta: dict | None = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)

    digest = {}

    def write_state(tmp):
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        digest["sha"] = _sha256(tmp)
        digest["nbytes"] = os.path.getsize(tmp)

    # state first, meta last: meta.json names the state file's digest, so
    # a crash between the two renames leaves a detectable (sha-mismatched)
    # pair rather than a restorable-looking torn checkpoint
    _replace_into(path, "state.npz", write_state)
    info = {"step": int(step) if step is not None else None,
            "keys": sorted(flat),
            "state_sha256": digest["sha"],
            "state_nbytes": int(digest["nbytes"]),
            **(meta or {})}

    def write_meta(tmp):
        with open(tmp, "w") as f:
            json.dump(info, f, indent=1)

    _replace_into(path, "meta.json", write_meta)


def verify(path: str) -> dict:
    """Integrity-check a checkpoint directory without loading the state;
    returns the metadata dict or raises :class:`CheckpointError` with the
    specific defect (missing file, truncation, digest mismatch)."""
    meta_path = os.path.join(path, "meta.json")
    state_path = os.path.join(path, "state.npz")
    if not os.path.exists(meta_path):
        raise CheckpointError(f"{path}: meta.json is missing — not a "
                              "checkpoint, or save was interrupted")
    try:
        with open(meta_path) as f:
            info = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointError(f"{path}: meta.json is unreadable ({e}) — "
                              "corrupt checkpoint") from e
    if not os.path.exists(state_path):
        raise CheckpointError(f"{path}: state.npz is missing — corrupt or "
                              "partially deleted checkpoint")
    nbytes = info.get("state_nbytes")
    if nbytes is not None and os.path.getsize(state_path) != int(nbytes):
        raise CheckpointError(
            f"{path}: state.npz is {os.path.getsize(state_path)} bytes but "
            f"meta.json recorded {nbytes} — truncated or torn checkpoint")
    sha = info.get("state_sha256")
    if sha is not None and _sha256(state_path) != sha:
        raise CheckpointError(
            f"{path}: state.npz sha256 does not match meta.json — the "
            "state and metadata are from different saves (crash between "
            "the two atomic renames) or the file is corrupt")
    return info


def restore(path: str, like_state, shardings=None):
    """Restore into the structure of ``like_state`` (shapes must match).

    Fails loudly (:class:`CheckpointError`) on a missing, truncated or
    digest-mismatched checkpoint instead of handing back garbage."""
    import zipfile

    import ml_dtypes

    verify(path)
    state_path = os.path.join(path, "state.npz")
    try:
        data = np.load(state_path)
        files = set(data.files)
    except (zipfile.BadZipFile, ValueError, OSError) as e:
        raise CheckpointError(
            f"{path}: state.npz failed to load ({e}) — corrupt "
            "checkpoint") from e
    leaves_paths = jax.tree_util.tree_leaves_with_path(like_state)
    sh_leaves = (jax.tree_util.tree_leaves(shardings)
                 if shardings is not None else [None] * len(leaves_paths))
    new_leaves = []
    for (p, old), sh in zip(leaves_paths, sh_leaves):
        key = jax.tree_util.keystr(p)
        if _BF16 + key in files:
            arr = data[_BF16 + key].view(ml_dtypes.bfloat16)
        elif key in files:
            arr = data[key]
        else:
            raise CheckpointError(
                f"{path}: leaf {key} is absent from the checkpoint — the "
                "saved state has a different structure")
        if tuple(arr.shape) != tuple(old.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {old.shape}")
        if arr.dtype != old.dtype:
            arr = arr.astype(old.dtype)
        new_leaves.append(jax.device_put(arr, sh) if sh is not None else
                          jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(like_state)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def load_meta(path: str) -> dict:
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)
