"""AsyncTrainer: the paper's technique at trainer level (CPU, 1-device mesh).

Semantics checks mirror the theory: delayed buffer = one-round staleness,
worker masks = assignment rule, sync mode = baseline.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.configs import get_arch
from repro.core import (TimingModel, build_schedule, round_masks,
                        make_scheduler, heterogeneous_speeds)
from repro.data import DataConfig, HeterogeneousTokenPipeline
from repro.distributed import AsyncTrainer, AsyncConfig
from repro.optim import OptConfig


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _trainer(delay=1, **kw):
    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    return cfg, AsyncTrainer(cfg, _mesh(),
                             opt=OptConfig(lr=1e-2, clip_norm=1.0),
                             async_cfg=AsyncConfig(delay_rounds=delay, **kw))


def _batch(cfg, B=4, S=16, seed=0):
    pipe = HeterogeneousTokenPipeline(
        DataConfig(vocab=cfg.vocab, seq_len=S, global_batch=B, n_groups=1,
                   seed=seed))
    return {k: jnp.asarray(v) for k, v in pipe.batch(seed).items()}


def test_state_tree_matches_specs():
    cfg, tr = _trainer()
    state = tr.init_state(jax.random.PRNGKey(0))
    ab = tr.abstract_state()
    flat_s = jax.tree_util.tree_leaves(state)
    flat_a = jax.tree_util.tree_leaves(ab)
    assert len(flat_s) == len(flat_a)
    for s, a in zip(flat_s, flat_a):
        assert s.shape == a.shape and s.dtype == a.dtype


def test_loss_decreases_sync_and_async():
    for delay in (0, 1):
        cfg, tr = _trainer(delay=delay)
        state = tr.init_state(jax.random.PRNGKey(0))
        step = jax.jit(tr.train_step_fn())
        batch = _batch(cfg)
        mask = jnp.ones((tr.n_groups,))
        losses = []
        for i in range(12):
            state, m = step(state, batch, mask)
            losses.append(float(m["loss"]))
        # memorise one batch: loss must drop substantially
        assert losses[-1] < losses[1] * 0.9, (delay, losses)


def test_first_round_is_identity_with_delay():
    """With an empty buffer the first update must be a no-op on params."""
    cfg, tr = _trainer(delay=1)
    state = tr.init_state(jax.random.PRNGKey(0))
    step = jax.jit(tr.train_step_fn())
    p0 = jax.tree_util.tree_leaves(state["params"])
    state2, _ = step(state, _batch(cfg), jnp.ones((tr.n_groups,)))
    p1 = jax.tree_util.tree_leaves(state2["params"])
    for a, b in zip(p0, p1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # buffer now holds the gradient
    assert float(sum(jnp.abs(g.astype(jnp.float32)).sum()
                     for g in jax.tree_util.tree_leaves(state2["gbuf"]))) > 0


def test_delayed_buffer_shifts_updates_by_one_round():
    """Async(delay=1) applied gradients at step t+1 equal sync gradients the
    trainer computed at step t — run both side by side on identical batches
    with SGD (no momentum) and compare parameter trajectories."""
    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    mesh = _mesh()
    opt = OptConfig(name="sgd", lr=1e-2, clip_norm=None, momentum=0.0)
    tr_async = AsyncTrainer(cfg, mesh, opt=opt, async_cfg=AsyncConfig(1))
    tr_sync = AsyncTrainer(cfg, mesh, opt=opt, async_cfg=AsyncConfig(0))
    sa = tr_async.init_state(jax.random.PRNGKey(0))
    ss = tr_sync.init_state(jax.random.PRNGKey(0))
    step_a = jax.jit(tr_async.train_step_fn())
    step_s = jax.jit(tr_sync.train_step_fn())
    mask = jnp.ones((1,))
    b0 = _batch(cfg, seed=0)
    # async step 1 on b0: params unchanged, buffer ← g(x0, b0)
    sa, _ = step_a(sa, b0, mask)
    # async step 2 on anything: applies g(x0, b0) → equals sync step on b0
    sa, _ = step_a(sa, _batch(cfg, seed=1), mask)
    ss, _ = step_s(ss, b0, mask)
    for a, b in zip(jax.tree_util.tree_leaves(sa["params"]),
                    jax.tree_util.tree_leaves(ss["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


def test_worker_mask_zero_gives_zero_gradient():
    cfg, tr = _trainer(delay=0)
    state = tr.init_state(jax.random.PRNGKey(0))
    step = jax.jit(tr.train_step_fn())
    state2, m = step(state, _batch(cfg), jnp.zeros((tr.n_groups,)))
    assert float(m["grad_norm"]) == pytest.approx(0.0, abs=1e-6)


def test_masks_from_real_schedulers_drive_training():
    """End-to-end: scheduler → engine → round masks → trainer steps."""
    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    mesh = _mesh()
    n_groups = 4   # virtual groups (> mesh data size is fine: masks weight examples)
    tr = AsyncTrainer(cfg, mesh, opt=OptConfig(lr=5e-3),
                      async_cfg=AsyncConfig(delay_rounds=1))
    tr.n_groups = n_groups
    sched = make_scheduler("shuffled", n_groups, seed=0)
    tm = TimingModel(heterogeneous_speeds(n_groups), "poisson", seed=0)
    s = build_schedule(sched, tm, 16 * 1)
    masks = round_masks(s)
    state = tr.init_state(jax.random.PRNGKey(0))
    step = jax.jit(tr.train_step_fn())
    batch = _batch(cfg, B=8)
    losses = []
    for q in range(masks.shape[0]):
        state, m = step(state, batch, jnp.asarray(masks[q]))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[1]
    assert all(np.isfinite(losses))


def test_moe_arch_trains():
    cfg = get_arch("deepseek-moe-16b").reduced().with_(remat="none")
    tr = AsyncTrainer(cfg, _mesh(), opt=OptConfig(lr=1e-2),
                      async_cfg=AsyncConfig(delay_rounds=1))
    state = tr.init_state(jax.random.PRNGKey(0))
    step = jax.jit(tr.train_step_fn())
    batch = _batch(cfg)
    for i in range(6):
        state, m = step(state, batch, jnp.ones((tr.n_groups,)))
    assert np.isfinite(float(m["loss"]))
    assert float(m["aux"]) > 0
