from .base import ArchConfig, InputShape, SHAPES, smoke_shape
from .registry import ARCHS, get_arch

__all__ = ["ArchConfig", "InputShape", "SHAPES", "smoke_shape", "ARCHS", "get_arch"]
