"""Pallas kernels vs pure-jnp oracles — interpret=True shape/dtype sweeps."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.async_update import (async_update_pallas,
                                        fused_adam_pallas,
                                        fused_adam_delayed_pallas,
                                        sgd_step_pallas)
from repro.kernels.ssd_chunk import ssd_chunk_pallas


def _qkv(B, Sq, Sk, H, KV, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, D), jnp.float32).astype(dtype)
    return q, k, v


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,KV,D,bq,bk", [
    (1, 128, 128, 4, 4, 64, 64, 64),      # MHA square
    (2, 256, 256, 8, 2, 64, 128, 64),     # GQA 4:1
    (1, 96, 160, 4, 1, 32, 64, 64),       # ragged (padding path), MQA
    (1, 512, 512, 2, 2, 128, 128, 128),   # larger blocks
])
def test_flash_attention_causal(dtype, B, Sq, Sk, H, KV, D, bq, bk):
    q, k, v = _qkv(B, Sq, Sk, H, KV, D, dtype)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=bq, block_k=bk,
                                 interpret=True)
    want = ref.reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [16, 64, 1000])
def test_flash_attention_sliding_window(window):
    q, k, v = _qkv(1, 256, 256, 4, 4, 64, jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64, interpret=True)
    want = ref.reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_noncausal():
    q, k, v = _qkv(2, 128, 192, 4, 4, 64, jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=False, block_q=64,
                                 block_k=64, interpret=True)
    want = ref.reference_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_model_layer():
    """Kernel ≡ the model's chunked-jnp attention (the TPU swap-in point)."""
    from repro.models.layers import attention
    q, k, v = _qkv(1, 256, 256, 8, 4, 64, jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                 block_k=64, interpret=True)
    want = attention(q, k, v, causal=True, dense_max=64, chunk_q=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [128 * 256, 128 * 256 + 37, 1000])
def test_async_update_kernel(dtype, n):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    p = jax.random.normal(ks[0], (n,), jnp.float32).astype(dtype)
    gb = jax.random.normal(ks[1], (n,), jnp.float32).astype(dtype)
    g = jax.random.normal(ks[2], (n,), jnp.float32).astype(dtype)
    got_p, got_b = async_update_pallas(p, gb, g, lr=0.01, clip_scale=0.5,
                                       delay_scale=0.25, interpret=True)
    want_p, want_b = ref.reference_async_update(p, gb, g, lr=0.01,
                                                clip_scale=0.5,
                                                delay_scale=0.25)
    np.testing.assert_allclose(np.asarray(got_p, np.float32),
                               np.asarray(want_p, np.float32), **TOL[dtype])
    np.testing.assert_array_equal(np.asarray(got_b, np.float32),
                                  np.asarray(want_b, np.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [128 * 256 + 37, 100])
def test_sgd_step_kernel(dtype, n):
    """Swap-free SGD step: identical params-out as async_update, no buffer."""
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    p = jax.random.normal(ks[0], (n,), jnp.float32).astype(dtype)
    g = jax.random.normal(ks[1], (n,), jnp.float32).astype(dtype)
    got = sgd_step_pallas(p, g, lr=0.02, clip_scale=0.5, delay_scale=0.25,
                          interpret=True)
    want, _ = ref.reference_async_update(p, g, g, lr=0.02, clip_scale=0.5,
                                         delay_scale=0.25)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("count", [1, 100])
def test_fused_adam_kernel(dtype, count):
    n = 4096 + 17
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    p = jax.random.normal(ks[0], (n,), jnp.float32).astype(dtype)
    m = jax.random.normal(ks[1], (n,), jnp.float32) * 0.1
    v = jax.random.uniform(ks[2], (n,), jnp.float32) * 0.01
    g = jax.random.normal(ks[3], (n,), jnp.float32).astype(dtype)
    got = fused_adam_pallas(p, m, v, g, lr=1e-3, count=count, interpret=True)
    want = ref.reference_fused_adam(p, m, v, g, lr=1e-3, beta1=0.9,
                                    beta2=0.95, eps=1e-8,
                                    bc1=1 - 0.9 ** count,
                                    bc2=1 - 0.95 ** count)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5,
                                   atol=3e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [4096 + 17, 333])
def test_fused_adam_delayed_kernel(dtype, n):
    """Delayed variant: stale gbuf drives the step, fresh g lands in gbuf'."""
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    p = jax.random.normal(ks[0], (n,), jnp.float32).astype(dtype)
    m = jax.random.normal(ks[1], (n,), jnp.float32) * 0.1
    v = jax.random.uniform(ks[2], (n,), jnp.float32) * 0.01
    gb = jax.random.normal(ks[3], (n,), jnp.float32).astype(dtype)
    g = jax.random.normal(ks[4], (n,), jnp.float32).astype(dtype)
    got = fused_adam_delayed_pallas(p, m, v, gb, g, lr=1e-3, count=5,
                                    clip_scale=0.5, weight_decay=0.01,
                                    interpret=True)
    want = ref.reference_fused_adam_delayed(
        p, m, v, gb, g, lr=1e-3, beta1=0.9, beta2=0.95, eps=1e-8,
        bc1=1 - 0.9 ** 5, bc2=1 - 0.95 ** 5, clip_scale=0.5,
        weight_decay=0.01)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-6)
    for a, b in zip(got[:3], want[:3]):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)
    # the buffer swap is a pure copy: bitwise
    np.testing.assert_array_equal(np.asarray(got[3], np.float32),
                                  np.asarray(want[3], np.float32))


def test_fused_adam_delayed_ops_dispatch():
    n = 777
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    p, gb, g = (jax.random.normal(k, (n,), jnp.float32) for k in ks[:3])
    m = jnp.zeros((n,)); v = jnp.zeros((n,))
    a = ops.fused_adam_delayed(p, m, v, gb, g, lr=1e-3, interpret=True)
    b = ops.fused_adam_delayed(p, m, v, gb, g, lr=1e-3, use_kernel=False)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("c,H,P,N", [(16, 2, 32, 16), (64, 4, 64, 32)])
def test_ssd_chunk_kernel_vs_sequential(dtype, c, H, P, N):
    """Kernel intra-chunk output + state vs the sequential recurrence."""
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = (jax.random.normal(ks[0], (c, H, P), jnp.float32) * 0.5).astype(dtype)
    dt = jax.random.uniform(ks[1], (c, H), jnp.float32, 0.01, 0.2)
    A = -jax.random.uniform(ks[2], (H,), jnp.float32, 0.5, 2.0)
    B_ = jax.random.normal(ks[3], (c, N), jnp.float32) * 0.3
    C_ = jax.random.normal(jax.random.PRNGKey(5), (c, N), jnp.float32) * 0.3
    y, st = ssd_chunk_pallas(x[None, None], dt[None, None], A,
                             B_[None, None], C_[None, None], interpret=True)
    want_y, want_h = ref.reference_ssd_chunk(x, dt, A, B_, C_)
    tol = dict(rtol=4e-2, atol=4e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(y[0, 0], np.float32),
                               np.asarray(want_y, np.float32), **tol)
    # kernel emits (N, P); oracle (H, P, N)
    np.testing.assert_allclose(
        np.asarray(st[0, 0], np.float32),
        np.asarray(want_h, np.float32).transpose(0, 2, 1), **tol)


def test_ssd_chunk_matches_model_ssd():
    """Kernel composed with the inter-chunk scan ≡ layers.ssd_chunked."""
    from repro.models.layers import ssd_chunked
    B, S, H, P, N, c = 2, 128, 2, 32, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32) * 0.5
    dt = jax.random.uniform(ks[1], (B, S, H), jnp.float32, 0.01, 0.2)
    A = -jax.random.uniform(ks[2], (H,), jnp.float32, 0.5, 2.0)
    B_ = jax.random.normal(ks[3], (B, S, N), jnp.float32) * 0.3
    C_ = jax.random.normal(ks[4], (B, S, N), jnp.float32) * 0.3
    want_y, want_h = ssd_chunked(x, dt, A, B_, C_, chunk=c)

    nc = S // c
    xr = x.reshape(B, nc, c, H, P)
    dtr = dt.reshape(B, nc, c, H)
    Br = B_.reshape(B, nc, c, N)
    Cr = C_.reshape(B, nc, c, N)
    y_diag, states = ssd_chunk_pallas(xr, dtr, A, Br, Cr, interpret=True)
    # inter-chunk recurrence + offset (same composition as the model)
    la = dt.astype(jnp.float32) * A[None, None, :]
    cums = jnp.cumsum(la.reshape(B, nc, c, H), axis=2)
    chunk_decay = jnp.exp(cums[:, :, -1, :])
    st = jnp.moveaxis(states, -1, -2)                     # (B,nc,H,P,N)

    def step(h, inp):
        s, dec = inp
        return h * dec[..., None, None] + s, h

    hT, h_prev = jax.lax.scan(
        step, jnp.zeros((B, H, P, N), jnp.float32),
        (jnp.moveaxis(st, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)
    y_off = jnp.einsum("bkin,bkhpn,bkih->bkihp",
                       Cr.astype(jnp.float32), h_prev, jnp.exp(cums))
    y = (y_diag.astype(jnp.float32) + y_off).reshape(B, S, H, P)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want_y, np.float32),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(want_h),
                               rtol=1e-3, atol=1e-3)


def test_ops_wrappers_dispatch():
    q, k, v = _qkv(1, 64, 64, 2, 2, 32, jnp.float32)
    a = ops.flash_attention(q, k, v, interpret=True)
    b = ops.flash_attention(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_model_forward_with_flash_kernel_path():
    """cfg.use_flash_attention routes attention through the Pallas kernel
    (interpret on CPU) and matches the jnp path."""
    from repro.configs import get_arch
    from repro.models import init_params, forward_logits
    cfg = get_arch("qwen3-8b").reduced().with_(remat="none", n_layers=1)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, cfg.vocab)
    base, _ = forward_logits(cfg, params, {"tokens": tokens})
    flash, _ = forward_logits(cfg.with_(use_flash_attention=True), params,
                              {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(flash, np.float32),
                               np.asarray(base, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_model_forward_with_ssd_kernel_path():
    """cfg.use_ssd_kernel routes the SSD intra-chunk compute through the
    Pallas kernel and matches the jnp path."""
    from repro.configs import get_arch
    from repro.models import init_params, forward_logits
    cfg = get_arch("mamba2-370m").reduced().with_(remat="none", n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    base, _ = forward_logits(cfg, params, {"tokens": tokens})
    kern, _ = forward_logits(cfg.with_(use_ssd_kernel=True), params,
                             {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(kern, np.float32),
                               np.asarray(base, np.float32),
                               rtol=3e-2, atol=3e-2)
