"""Micro-benchmark: AsyncTrainer train_step / serve_step wall time on the
reduced configs (CPU; TPU perf comes from §Roofline, not wall clock).

Three modes:

* default      — per-arch train_step wall time → ``perf.csv`` (legacy).
* ``--ab``     — reference vs fused ``update_impl`` A/B on the SAME arch,
  batch and state → ``BENCH_trainstep.json``, PLUS a three-way
  reference / per-leaf / pooled sweep of the ISOLATED delayed server
  update → ``BENCH_update_apply.json`` (kernel-launch counts + wall
  time: the pooled path issues O(n_dtypes) launches vs the per-leaf
  path's O(n_leaves)).  On TPU the fused columns are the compiled Mosaic
  kernels (the number that matters); off-TPU they are the Pallas
  interpreter, so treat the CPU "speedup" as a correctness artifact, not
  a perf claim (the JSONs record backend + impl so nobody misreads it).
* ``--dispatch-ab`` — eager per-round dispatch loop vs the
  ``repro.runtime`` scan executor on one shared ``RunPlan`` at several
  ``rounds_per_launch`` values → ``BENCH_runtime.json`` (rounds/s +
  launch and host-sync counts; dispatch is host-side overhead, so this
  ratio is meaningful on any backend).
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import ARCHS, get_arch
from repro.data import DataConfig, HeterogeneousTokenPipeline
from repro.distributed import AsyncTrainer, AsyncConfig
from repro.optim import OptConfig, resolve_update_impl


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _batch_for(cfg, B, S):
    pipe = HeterogeneousTokenPipeline(DataConfig(cfg.vocab, S, B))
    from repro.models import batch_specs
    batch = {}
    for k, sp in batch_specs(cfg, B, S).items():
        if sp.dtype == "int32":
            batch[k] = jnp.asarray(pipe.batch(0)["tokens"][:, :sp.shape[1]])
        else:   # stubbed modality embeddings (vlm patches / audio frames)
            batch[k] = jax.random.normal(jax.random.PRNGKey(1), sp.shape,
                                         jnp.float32)
    return batch


def _time_step(tr, batch, iters):
    state = tr.init_state(jax.random.PRNGKey(0))
    step = jax.jit(tr.train_step_fn())
    mask = jnp.ones((tr.n_groups,))
    state, m = step(state, batch, mask)          # compile
    jax.block_until_ready(m["loss"])
    t0 = time.time()
    for _ in range(iters):
        state, m = step(state, batch, mask)
    jax.block_until_ready(m["loss"])
    return (time.time() - t0) / iters * 1e6, float(m["loss"])


def run(out: str = "experiments/figs", quick: bool = False):
    os.makedirs(out, exist_ok=True)
    mesh = _mesh()
    rows = []
    names = ["qwen2-0.5b", "deepseek-moe-16b", "mamba2-370m"] if quick \
        else sorted(ARCHS)
    for name in names:
        cfg = get_arch(name).reduced().with_(remat="none")
        tr = AsyncTrainer(cfg, mesh, opt=OptConfig(lr=1e-3),
                          async_cfg=AsyncConfig(delay_rounds=1))
        B, S = 2, 32
        batch = _batch_for(cfg, B, S)
        us, loss = _time_step(tr, batch, iters=5)
        rows.append({"name": f"train_step_{name}", "us_per_call": round(us, 1),
                     "derived": f"loss={loss:.3f}"})
    with open(os.path.join(out, "perf.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=["name", "us_per_call", "derived"])
        w.writeheader()
        w.writerows(rows)
    return rows


def run_ab(out: str = "experiments/figs", quick: bool = False, iters: int = 5,
           archs=None):
    """Reference-vs-fused A/B on identical (arch, state, batch) pairs.

    Writes ``BENCH_trainstep.json``: one entry per arch with
    ``reference_us`` / ``fused_us`` / ``speedup`` plus enough provenance
    (backend, effective impl, shapes) to interpret the numbers."""
    os.makedirs(out, exist_ok=True)
    mesh = _mesh()
    if archs is None:
        archs = ["qwen2-0.5b"] if quick else \
            ["qwen2-0.5b", "deepseek-moe-16b", "mamba2-370m"]
    fused_impl = resolve_update_impl("pallas")
    entries = []
    for name in archs:
        cfg = get_arch(name).reduced().with_(remat="none")
        B, S = 2, 32
        batch = _batch_for(cfg, B, S)
        entry = {"arch": name, "batch": B, "seq_len": S, "iters": iters}
        for label, impl in (("reference", "reference"), ("fused", fused_impl)):
            tr = AsyncTrainer(
                cfg, mesh,
                opt=OptConfig(lr=1e-3, update_impl=impl),
                async_cfg=AsyncConfig(delay_rounds=1))
            us, loss = _time_step(tr, batch, iters)
            entry[f"{label}_us"] = round(us, 1)
            entry[f"{label}_loss"] = round(loss, 4)
        entry["fused_impl"] = fused_impl
        entry["speedup"] = round(entry["reference_us"] / entry["fused_us"], 3)
        entries.append(entry)
        print(f"{name}: reference={entry['reference_us']:.0f}us "
              f"fused[{fused_impl}]={entry['fused_us']:.0f}us "
              f"speedup={entry['speedup']}x")
    payload = {
        "bench": "trainstep_ab",
        "backend": jax.default_backend(),
        "fused_impl": fused_impl,
        "note": ("fused==pallas_interpret means the Pallas INTERPRETER ran "
                 "(off-TPU correctness mode); speedups are only meaningful "
                 "when fused_impl == 'pallas' on a TPU backend"),
        "entries": entries,
    }
    path = os.path.join(out, "BENCH_trainstep.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", path)
    return payload


def run_update_ab(out: str = "experiments/figs", quick: bool = False,
                  iters: int = 20, archs=None):
    """Three-way sweep of the ISOLATED delayed server update (eq. 2) on one
    arch's real state tree: reference / per-leaf fused / pooled fused.

    Writes ``BENCH_update_apply.json``: per arch the wall time of each impl
    plus its pallas launch count — ``n_leaves`` kernels for the per-leaf
    path, ``n_pools`` (= number of distinct param dtypes) for the pooled
    path.  The launch-count column is the structural claim; the wall-time
    column is only a perf claim on a TPU backend."""
    import jax.random as jrandom
    from repro.models import model as M
    from repro.optim import (OptConfig, adam_init, build_layout, init_pools,
                             pool_tree, pooled_delayed_apply,
                             reference_delayed_apply, fused_delayed_apply)

    os.makedirs(out, exist_ok=True)
    if archs is None:
        archs = ["qwen2-0.5b"] if quick else \
            ["qwen2-0.5b", "deepseek-moe-16b", "mamba2-370m"]
    fused_impl = resolve_update_impl("pallas")
    interpret = fused_impl == "pallas_interpret"
    cfg_opt = OptConfig(name="adam", lr=1e-3, clip_norm=1.0)
    entries = []
    for name in archs:
        cfg = get_arch(name).reduced().with_(remat="none")
        params = M.init_params(cfg, jrandom.PRNGKey(0))
        leaves = jax.tree_util.tree_leaves(params)
        grads = jax.tree_util.tree_map(
            lambda p: jax.random.normal(jrandom.PRNGKey(1), p.shape,
                                        jnp.float32).astype(p.dtype) * 1e-2
            if p.ndim else jnp.asarray(1e-2, p.dtype), params)
        gbuf = jax.tree_util.tree_map(jnp.zeros_like, params)
        opt_state = adam_init(params)
        lay = build_layout(params, 1)
        pools = init_pools(lay, params)
        g_pools = pool_tree(lay, grads)
        count0 = jnp.zeros((), jnp.int32)

        def time_fn(fn, *args):
            o = fn(*args)                           # compile
            jax.block_until_ready(jax.tree_util.tree_leaves(o)[0])
            t0 = time.time()
            for _ in range(iters):
                o = fn(*args)
            jax.block_until_ready(jax.tree_util.tree_leaves(o)[0])
            return (time.time() - t0) / iters * 1e6

        ref_us = time_fn(
            jax.jit(lambda g, b, s, p: reference_delayed_apply(
                g, b, s, p, cfg_opt)), grads, gbuf, opt_state, params)
        leaf_us = time_fn(
            jax.jit(lambda g, b, s, p: fused_delayed_apply(
                g, b, s, p, cfg_opt, interpret=interpret)),
            grads, gbuf, opt_state, params)
        pooled_us = time_fn(
            jax.jit(lambda g, pl_, c: pooled_delayed_apply(
                g, pl_, c, cfg_opt, interpret=interpret)),
            g_pools, pools, count0)
        entry = {
            "arch": name,
            "n_leaves": len(leaves),
            "n_pools": lay.n_pools,
            "params": int(sum(int(np.prod(l.shape)) for l in leaves)),
            "launches": {"reference": 0, "per_leaf": len(leaves),
                         "pooled": lay.n_pools},
            "reference_us": round(ref_us, 1),
            "per_leaf_us": round(leaf_us, 1),
            "pooled_us": round(pooled_us, 1),
            "pooled_vs_per_leaf": round(leaf_us / pooled_us, 3),
            "iters": iters,
        }
        entries.append(entry)
        print(f"{name}: reference={ref_us:.0f}us "
              f"per_leaf[{len(leaves)} launches]={leaf_us:.0f}us "
              f"pooled[{lay.n_pools} launches]={pooled_us:.0f}us "
              f"pooled_vs_per_leaf={entry['pooled_vs_per_leaf']}x")
    payload = {
        "bench": "update_apply_three_way",
        "backend": jax.default_backend(),
        "fused_impl": fused_impl,
        "note": ("isolated delayed server update on the arch's real state "
                 "tree; 'launches' counts pallas_calls per step (the "
                 "structural O(n_leaves) → O(n_pools) claim).  Off-TPU the "
                 "fused columns run the Pallas INTERPRETER: wall-time "
                 "ratios are only perf claims on a TPU backend"),
        "entries": entries,
    }
    path = os.path.join(out, "BENCH_update_apply.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", path)
    return payload


def run_dispatch_ab(out: str = "experiments/figs", quick: bool = False,
                    rounds: int = 0, arch: str = "qwen2-0.5b",
                    save_baseline: bool = False):
    """Eager per-round loop vs scan whole-run executor on ONE plan.

    Times the WARM dispatch path — plan slicing, device batch synthesis,
    step launch, metric transport, compiled executables held in a
    ``PlanExecutor`` — and writes ``BENCH_runtime.json``.  Every row runs
    the SAME ``RunPlan`` and step function, so the delta is pure dispatch.
    Rows:

    * ``eager`` — one Python dispatch + one metric sync per ROUND,
    * ``scan``/``chunk_sync`` — the PR-4 path: K rounds per launch with a
      blocking metric readback every chunk (an ``on_step`` consumer),
    * ``scan``/``chunk`` — overlapped dispatch: chunks enqueue
      back-to-back, ONE deferred readback at the end,
    * ``scan``/``tap`` at K = rounds — whole-run single launch, metrics
      streamed per round through the io_callback tap,
    * ``scan``/``none`` at K = rounds — metrics discarded on device,
    * ``grid`` — the vmapped γ-grid lane over ``n_grid`` points vs the
      same points run sequentially (``grid_speedup`` is that ratio).

    Dispatch overhead is a host-side cost, so unlike the kernel A/Bs the
    ratios are meaningful on any backend (the JSON records the backend
    regardless).  The bench arch is deliberately small: dispatch overhead
    is a per-round constant, so the config keeps per-round compute
    comparable to it (at 100×-larger steps the same absolute win
    disappears into the compute — record, don't infer).

    ``save_baseline`` additionally writes the payload to
    ``benchmarks/BENCH_runtime.json`` — the committed baseline
    ``benchmarks/check_perf.py`` gates CI against."""
    import jax.random as jrandom
    from repro.api import ExperimentSpec, TrainJob, TrainerBackend
    from repro.runtime import PlanExecutor, compile_plan

    os.makedirs(out, exist_ok=True)
    mesh = _mesh()
    # 256 rounds even in --quick: the timed window must dwarf OS
    # scheduler jitter (compile time dominates the bench's wall clock
    # either way).  The arch is the SMALLEST step the trainer can run —
    # this bench measures the dispatch layer, and per-round compute is a
    # constant both paths pay, so shrinking it is what makes the
    # dispatch delta visible at all
    rounds = rounds or 256
    ks = [1, 8] if quick else [1, 4, 8, 16]
    grid_gammas = (3e-3, 1.5e-3, 7.5e-4, 3.75e-4)
    job = TrainJob(arch=arch, global_batch=4, seq_len=4,
                   arch_overrides=(("n_layers", 1), ("d_model", 8),
                                   ("n_heads", 1), ("n_kv_heads", 1),
                                   ("d_ff", 16), ("vocab", 127)))
    spec = ExperimentSpec(scheduler="shuffled", timing="poisson:slow=6",
                          objective=job, T=rounds, n_workers=4,
                          stepsize=3e-3, seed=0)
    cfg = job.make_arch()
    _, schedule = TrainerBackend.masks_for(spec, 4)
    plan = compile_plan(schedule, job, rounds=rounds, n_groups=4, seed=0,
                        grid_gammas=grid_gammas, base_gamma=3e-3)
    tr = AsyncTrainer(cfg, mesh, opt=OptConfig(lr=3e-3, clip_norm=1.0),
                      async_cfg=AsyncConfig(delay_rounds=1))
    tr.n_groups = 4
    ex = PlanExecutor(tr, plan, donate=False)
    # one shared initial state OUTSIDE every timed window (state init is
    # a constant that would compress the ratios); donate=False above is
    # what makes reuse sound — no launch consumes the buffers
    state0 = tr.init_state(jrandom.PRNGKey(0))

    def timed(fn):
        fn(state0)                                # compile + warm caches
        best, r = None, None
        for _ in range(3):                        # min-of-3: dispatch noise
            t0 = time.time()
            r = fn(state0)
            dt = time.time() - t0
            best = dt if best is None else min(best, dt)
        return best, r

    entries = []

    def record(runtime, mode, k, seconds, r, eager_s=None, **kw):
        e = {"runtime": runtime, "metrics": mode, "rounds_per_launch": k,
             "seconds": round(seconds, 4),
             "rounds_per_s": round(rounds / seconds, 2),
             "launches": r.launches, "host_syncs": r.host_syncs,
             "tap_events": r.tap_events, **kw}
        if eager_s is not None:
            e["speedup_vs_eager"] = round(eager_s / seconds, 3)
        entries.append(e)
        extra = "".join(f" {k_}={v}" for k_, v in kw.items())
        vs = f", {e['speedup_vs_eager']}x vs eager" if eager_s else ""
        print(f"{runtime}/{mode} K={k}: {rounds / seconds:.1f} rounds/s "
              f"({r.launches} launches, {r.host_syncs} host syncs, "
              f"{r.tap_events} taps{vs}){extra}")

    eager_s, r_e = timed(ex.run_eager)
    record("eager", "per_round", 1, eager_s, r_e)
    noop = lambda i, st, m: None
    for k in ks:
        s, r = timed(lambda st, k=k: ex.run_scan(
            st, rounds_per_launch=k, on_step=noop))
        record("scan", "chunk_sync", k, s, r, eager_s)
    chunk_s = {}
    for k in sorted({min(8, rounds), rounds}):
        s, r = timed(lambda st, k=k: ex.run_scan(st, rounds_per_launch=k))
        chunk_s[k] = s
        record("scan", "chunk", k, s, r, eager_s)
    s, r = timed(lambda st: ex.run_scan(st, rounds_per_launch=rounds,
                                        metrics="tap"))
    record("scan", "tap", rounds, s, r, eager_s)
    s, r = timed(lambda st: ex.run_scan(st, rounds_per_launch=rounds,
                                        metrics="none"))
    record("scan", "none", rounds, s, r, eager_s)

    # γ-grid lane: all points vmapped in one program vs the same points
    # run back-to-back through the (already warm) scan executor — the
    # sequential per-point time is the scan/chunk row measured above
    k_grid = min(8, rounds)
    seq_total = chunk_s[k_grid] * len(grid_gammas)
    grid_s, r_g = timed(lambda st: ex.run_grid(
        st, rounds_per_launch=k_grid))
    record("grid", "chunk", k_grid, grid_s, r_g,
           n_grid=len(grid_gammas),
           sequential_seconds=round(seq_total, 4),
           grid_speedup=round(seq_total / grid_s, 3))
    print(f"grid lane: {len(grid_gammas)} γ in {grid_s:.3f}s vs "
          f"{seq_total:.3f}s sequential "
          f"({seq_total / grid_s:.2f}x)")

    payload = {
        "bench": "runtime_dispatch_ab",
        "backend": jax.default_backend(),
        "arch": arch, "rounds": rounds,
        "note": ("same RunPlan + step function for every row; only the "
                 "dispatch/metric-transport layer differs.  host_syncs "
                 "counts blocking device→host metric readbacks; "
                 "tap_events counts io_callback rows; chunk_sync is the "
                 "per-chunk-barrier path (an on_step consumer), chunk is "
                 "overlapped dispatch with one deferred readback.  "
                 "grid_speedup = sequential wall time / vmapped-lane "
                 "wall time over n_grid stepsizes"),
        "entries": entries,
    }
    path = os.path.join(out, "BENCH_runtime.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", path)
    if save_baseline:
        base = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_runtime.json")
        with open(base, "w") as f:
            json.dump(payload, f, indent=2)
        print("wrote baseline", base)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ab", action="store_true",
                    help="reference-vs-fused update_impl A/B → "
                         "BENCH_trainstep.json + three-way update-apply "
                         "sweep → BENCH_update_apply.json")
    ap.add_argument("--dispatch-ab", action="store_true",
                    help="eager per-round loop vs scan whole-run executor "
                         "at several rounds_per_launch → BENCH_runtime.json")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=0,
                    help="dispatch A/B: rounds per timed run (0 = 256; "
                         "--quick only trims the K sweep, not the rounds)")
    ap.add_argument("--save-baseline", action="store_true",
                    help="dispatch A/B: also write the payload to "
                         "benchmarks/BENCH_runtime.json (the committed "
                         "baseline benchmarks/check_perf.py gates "
                         "against)")
    ap.add_argument("--out", default="experiments/figs")
    ap.add_argument("--archs", default=None,
                    help="comma-separated arch names (A/B mode)")
    args = ap.parse_args()
    archs = args.archs.split(",") if args.archs else None
    if args.ab:
        run_ab(out=args.out, quick=args.quick, iters=args.iters, archs=archs)
        run_update_ab(out=args.out, quick=args.quick,
                      iters=max(args.iters, 5), archs=archs)
    if args.dispatch_ab:
        run_dispatch_ab(out=args.out, quick=args.quick, rounds=args.rounds,
                        arch=(archs[0] if archs else "qwen2-0.5b"),
                        save_baseline=args.save_baseline)
    if not (args.ab or args.dispatch_ab):
        for r in run(out=args.out, quick=args.quick):
            print(r)


if __name__ == "__main__":
    main()
