"""Exact AsGrad replay: x_{t+1} = x_t − γ̃ · g_{i_t}(x_{π_t}), jittable.

Given a :class:`~repro.core.engine.Schedule` (which fixes i_t and π_t), the
optimisation itself is a `lax.scan` with a ring buffer of past iterates —
x_{π_t} is read from slot π_t mod D, D = τ_max + 1.  This is bit-exact w.r.t.
the event-driven view and runs at jit speed, which is what makes the paper's
stepsize grid-searches cheap.

``grad_fn(x, worker, key)`` is any jax-differentiable per-worker gradient
oracle (see ``repro.objectives``).  ``key`` enables stochastic gradients
(Assumption 2); pass ``stochastic=False`` for the paper's full-gradient runs
(Fig. 1 / Fig. 3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .engine import Schedule


@dataclasses.dataclass
class ReplayResult:
    x: np.ndarray                 # final iterate
    xs: Optional[np.ndarray]      # (T//log_every, d) iterate snapshots
    log_ts: Optional[np.ndarray]  # matching iteration indices
    grad_norms: Optional[np.ndarray]  # ||∇f(x)|| at the snapshots
    losses: Optional[np.ndarray]      # f(x) at the snapshots


def delay_adaptive_stepsizes(gamma: float, delays: np.ndarray, tau_c: int) -> np.ndarray:
    """[Mishchenko et al. 22 / Koloskova et al. 22]-style delay adaptivity:
    γ_t = γ · min(1, τ_C / (τ_t + 1)) — shrinks the step for very stale
    gradients, removing the τ_max dependence (Table 1, footnote b)."""
    d = np.asarray(delays, dtype=np.float64)
    return (gamma * np.minimum(1.0, tau_c / (d + 1.0))).astype(np.float32)


@partial(jax.jit, static_argnames=("grad_fn", "ring_size", "clip"))
def _replay_scan(grad_fn, x0, workers, slots, read_slots, stepsizes, keys,
                 ring_size: int, clip: Optional[float]):
    D = ring_size

    def step(carry, inp):
        x, ring = carry
        worker, slot, read_slot, gamma, key = inp
        ring = jax.lax.dynamic_update_index_in_dim(ring, x, slot, axis=0)
        x_stale = jax.lax.dynamic_index_in_dim(ring, read_slot, axis=0, keepdims=False)
        g = grad_fn(x_stale, worker, key)
        if clip is not None:
            norm = jnp.sqrt(jnp.sum(g * g))
            g = g * jnp.minimum(1.0, clip / (norm + 1e-12))
        x = x - gamma * g
        return (x, ring), x

    ring0 = jnp.zeros((D,) + x0.shape, x0.dtype)
    (xf, _), xs = jax.lax.scan(
        step, (x0, ring0), (workers, slots, read_slots, stepsizes, keys)
    )
    return xf, xs


def replay(
    schedule: Schedule,
    grad_fn: Callable,
    x0,
    stepsize,
    *,
    key: Optional[jax.Array] = None,
    clip: Optional[float] = None,
    log_every: int = 50,
    full_grad_fn: Optional[Callable] = None,
    loss_fn: Optional[Callable] = None,
) -> ReplayResult:
    """Run the schedule.  ``stepsize`` is the *server* stepsize γ; waiting
    variants apply γ/wait_b per gradient (Prop. C.2 equivalence)."""
    T = schedule.T
    D = max(schedule.tau_max() + 1, 1)
    x0 = jnp.asarray(x0)

    gam = np.asarray(stepsize, dtype=np.float32)
    if gam.ndim == 0:
        gam = np.full(T, float(gam) / schedule.wait_b, dtype=np.float32)
    else:
        gam = gam.astype(np.float32) / schedule.wait_b
    workers = jnp.asarray(schedule.workers, dtype=jnp.int32)
    slots = jnp.asarray(np.arange(T, dtype=np.int64) % D, dtype=jnp.int32)
    read_slots = jnp.asarray(schedule.assign_iters.astype(np.int64) % D, dtype=jnp.int32)
    if key is None:
        key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, T)

    xf, xs = _replay_scan(
        grad_fn, x0, workers, slots, read_slots, jnp.asarray(gam), keys, D, clip
    )
    xf = np.asarray(xf)
    idx = np.arange(0, T, log_every)
    xs_log = np.asarray(xs[idx])
    gn = ls = None
    if full_grad_fn is not None:
        gn = np.asarray(
            jax.vmap(lambda x: jnp.linalg.norm(full_grad_fn(x)))(jnp.asarray(xs_log))
        )
    if loss_fn is not None:
        ls = np.asarray(jax.vmap(loss_fn)(jnp.asarray(xs_log)))
    return ReplayResult(x=xf, xs=xs_log, log_ts=idx, grad_norms=gn, losses=ls)


def run_async_sgd(
    scheduler,
    timing,
    grad_fn,
    x0,
    stepsize,
    T: int,
    **kw,
):
    """Convenience: build the schedule and replay it."""
    from .engine import build_schedule

    sched = build_schedule(scheduler, timing, T)
    return sched, replay(sched, grad_fn, x0, stepsize, **kw)
