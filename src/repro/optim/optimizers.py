"""Optimizers from scratch (no optax in this container).

* SGD (+momentum) — the paper's algorithm, with Assumption-4 clipping.
* Adam — f32 moments regardless of param dtype; moments carry ZeRO-shardable
  logical axes identical to their parameter.
* Delay-adaptive stepsize scale (the [32]-style trick that removes τ_max).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adam"            # adam | sgd
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.0         # sgd only
    clip_norm: Optional[float] = 1.0   # Assumption 4 enforcement


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(F32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12)).astype(F32)
    return jax.tree_util.tree_map(
        lambda g: (g.astype(F32) * scale).astype(g.dtype), tree), norm


def adam_init(params):
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adam_update(grads, opt_state, params, cfg: OptConfig, lr_scale=1.0):
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    count = opt_state["count"] + 1
    b1, b2 = cfg.beta1, cfg.beta2
    c = count.astype(F32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(p, g, m, v):
        g32 = g.astype(F32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(F32)
        # cast the STEP, not the params: upcasting p to f32 lets XLA CSE the
        # convert into the FSDP all-gather, which then moves f32 weights
        # (2× HBM + 2× ICI at 314B scale)
        newp = p - (cfg.lr * lr_scale * step).astype(p.dtype)
        return newp, m, v

    out = jax.tree_util.tree_map(upd, params, grads, opt_state["m"], opt_state["v"])
    newp = jax.tree_util.tree_map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree_util.tree_map(lambda t: t[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree_util.tree_map(lambda t: t[2], out,
                               is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"m": m, "v": v, "count": count}, gnorm


def sgd_update(grads, opt_state, params, cfg: OptConfig, lr_scale=1.0):
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    if cfg.momentum:
        m = jax.tree_util.tree_map(
            lambda mo, g: cfg.momentum * mo + g.astype(F32),
            opt_state["m"], grads)
        step_tree = m
    else:
        m = opt_state["m"]
        step_tree = grads
    newp = jax.tree_util.tree_map(
        lambda p, s: p - (cfg.lr * lr_scale * s.astype(F32)).astype(p.dtype),
        params, step_tree)
    count = opt_state["count"] + 1
    return newp, {"m": m, "v": opt_state["v"], "count": count}, gnorm


def make_optimizer(cfg: OptConfig):
    if cfg.name == "adam":
        return adam_init, adam_update
    if cfg.name == "sgd":
        return adam_init, sgd_update     # same state tree (m unused w/o momentum)
    raise ValueError(cfg.name)
