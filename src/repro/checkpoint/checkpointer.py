"""Host-gather checkpointing: sharded state → flat .npz + metadata.

Small-scale by design (the container is one host); at real pod scale this
would be per-shard async writes — the interface (save/restore of the full
train-state pytree keyed by flattened paths) is what the rest of the
framework depends on.  bfloat16 leaves are bit-cast to uint16 for storage
(npz has no native bf16).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

_BF16 = "__bf16__"


def _flatten(tree):
    out = {}
    for p, v in jax.tree_util.tree_leaves_with_path(tree):
        arr = np.asarray(jax.device_get(v))
        key = jax.tree_util.keystr(p)
        if arr.dtype.name == "bfloat16":
            out[_BF16 + key] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def save(path: str, state, step: int | None = None, meta: dict | None = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)
    np.savez(os.path.join(path, "state.npz"), **flat)
    info = {"step": int(step) if step is not None else None,
            "keys": sorted(flat), **(meta or {})}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(info, f, indent=1)


def restore(path: str, like_state, shardings=None):
    """Restore into the structure of ``like_state`` (shapes must match)."""
    import ml_dtypes

    data = np.load(os.path.join(path, "state.npz"))
    leaves_paths = jax.tree_util.tree_leaves_with_path(like_state)
    sh_leaves = (jax.tree_util.tree_leaves(shardings)
                 if shardings is not None else [None] * len(leaves_paths))
    new_leaves = []
    for (p, old), sh in zip(leaves_paths, sh_leaves):
        key = jax.tree_util.keystr(p)
        if _BF16 + key in data.files:
            arr = data[_BF16 + key].view(ml_dtypes.bfloat16)
        else:
            arr = data[key]
        if tuple(arr.shape) != tuple(old.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {old.shape}")
        if arr.dtype != old.dtype:
            arr = arr.astype(old.dtype)
        new_leaves.append(jax.device_put(arr, sh) if sh is not None else
                          jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(like_state)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def load_meta(path: str) -> dict:
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)
