"""Observability overhead bench: what tracing costs the tap transport.

Tracing is only admissible if it observes the hot path without becoming
part of it.  The contract (README §Observability) is a ≤5% ceiling on
the tap transport — the executor's most host-active lane (one ordered
io_callback per round), hence the worst case for per-round instants.

The gated number is measured DIRECTLY: a timing proxy around the
Recorder accumulates the nanoseconds spent inside every tracing call
during the traced run, and::

    overhead_ratio = 1 − (time inside tracing calls / traced wall time)

must stay ≥ 0.95.  A traced-vs-untraced wall-clock A/B on the same warm
program rides along as ``wall_ab_ratio`` (median of adjacent paired
runs) for context, but it is NOT the gate: the per-round tracing cost
is ~2-3µs against a ~10% run-to-run noise floor on shared CI hosts, so
a throughput-ratio gate at 5% would be pure coin-flip — measured here
as paired-median ratios swinging 0.89-1.07 while the direct fraction
holds under 1%.

Alongside the ratio the payload carries three structural flags that
``benchmarks/check_perf.py``'s ``obs`` checker gates:

* ``trace_valid`` — the emitted ``trace.json`` (training run) and
  ``trace_serve.json`` (SlotServer run) are valid Chrome trace-event
  JSON with the expected span families (launch/tap_round, admit) —
  i.e. Perfetto would load them;
* ``metrics_valid`` — the emitted ``obs_metrics.jsonl`` passes
  ``repro.obs.schema`` validation;
* ``tap_events_match`` — the traced run streamed exactly one tap event
  per round (tracing observed the transport, it did not perturb it).

Ratios are same-machine and same-payload, so they are meaningful on any
backend.  ``--save-baseline`` writes the committed
``benchmarks/BENCH_obs.json`` the CI gate compares against (the gate's
ceiling is absolute, so the baseline is provenance, not the floor).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import numpy as np
import jax
from jax.sharding import Mesh


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


class _TimedRecorder:
    """Delegates to a real Recorder, accumulating the wall nanoseconds
    spent INSIDE each hot-path tracing call — the direct cost of
    observation, independent of host load.  Spans delegate untimed: a
    span encloses device work (launch, barrier), so timing the context
    manager would count the thing being observed, not the observing;
    span entry/exit cost is two clock reads per CHUNK, noise next to
    the per-round instants this bench exists to price."""

    def __init__(self, rec):
        self._rec = rec
        self.ns = 0

    def _timed(method):                      # noqa: N805
        def call(self, *a, **kw):
            t0 = time.perf_counter_ns()
            getattr(self._rec, method)(*a, **kw)
            self.ns += time.perf_counter_ns() - t0
        return call

    instant = _timed("instant")
    count = _timed("count")
    gauge = _timed("gauge")
    hist = _timed("hist")
    span_at = _timed("span_at")
    del _timed

    def span(self, *a, **kw):
        return self._rec.span(*a, **kw)

    def now_ns(self):
        return self._rec.now_ns()


def _validate_chrome(path: str, want_names=()) -> tuple[bool, str, int]:
    """(ok, why, n_events): structural Chrome-trace-event validation —
    the checks Perfetto's loader actually cares about."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return False, f"unreadable JSON: {e}", 0
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return False, "traceEvents missing or empty", 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            return False, f"event {i} lacks ph/name", len(events)
        if ev["ph"] == "X" and ("ts" not in ev or "dur" not in ev):
            return False, f"complete event {i} lacks ts/dur", len(events)
    names = {ev["name"] for ev in events}
    missing = [n for n in want_names if n not in names]
    if missing:
        return False, f"expected span families absent: {missing}", \
            len(events)
    return True, "", len(events)


def run_obs(out: str = "experiments/figs", quick: bool = False,
            rounds: int = 0, arch: str = "qwen2-0.5b",
            save_baseline: bool = False):
    """Traced-vs-untraced A/B on one warm plan + a traced slot-serve."""
    import jax.random as jrandom
    from repro.api import ExperimentSpec, TrainJob, TrainerBackend
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.obs import Recorder, validate_metrics_log, SchemaError
    from repro.optim import OptConfig
    from repro.runtime import PlanExecutor, compile_plan

    os.makedirs(out, exist_ok=True)
    mesh = _mesh()
    # 256 rounds as in the dispatch A/B, and the micro arch keeps
    # per-round compute small — the WORST case for relative tracing
    # cost, since the per-round instant is priced against a ~0.4ms round
    rounds = rounds or 256
    repeats = 5 if quick else 9
    job = TrainJob(arch=arch, global_batch=4, seq_len=4,
                   arch_overrides=(("n_layers", 1), ("d_model", 8),
                                   ("n_heads", 1), ("n_kv_heads", 1),
                                   ("d_ff", 16), ("vocab", 127)))
    spec = ExperimentSpec(scheduler="shuffled", timing="poisson:slow=6",
                          objective=job, T=rounds, n_workers=4,
                          stepsize=3e-3, seed=0)
    cfg = job.make_arch()
    _, schedule = TrainerBackend.masks_for(spec, 4)
    plan = compile_plan(schedule, job, rounds=rounds, n_groups=4, seed=0)
    tr = AsyncTrainer(cfg, mesh, opt=OptConfig(lr=3e-3, clip_norm=1.0),
                      async_cfg=AsyncConfig(delay_rounds=1))
    tr.n_groups = 4
    # one shared initial state outside every timed window; donate=False
    # makes reuse sound (no launch consumes the buffers)
    state0 = tr.init_state(jrandom.PRNGKey(0))

    # ONE executor, recorder toggled per run: every `run_scan` re-reads
    # `self.recorder` and rebuilds the host-side tap sink, so the traced
    # and untraced runs execute the IDENTICAL compiled program (two
    # separately jitted instances of the same code differ by several %
    # at this scale, which would swamp the measurement).  Traced runs go
    # through the timing proxy, which prices every tracing call
    # directly; untraced runs exist for the informational wall A/B.
    rec = Recorder()
    timed_rec = _TimedRecorder(rec)
    ex_obs = PlanExecutor(tr, plan, donate=False, recorder=rec)

    def once(recorder):
        ex_obs.recorder = recorder
        t0 = time.perf_counter()
        ex_obs.run_scan(state0, rounds_per_launch=rounds, metrics="tap")
        return time.perf_counter() - t0

    once(rec)                                 # compile + warm caches
    plain_s = traced_s = None
    traced_wall_ns = 0.0
    pair_ratios = []
    for _ in range(repeats):
        dt_p = once(None)
        dt_t = once(timed_rec)
        traced_wall_ns += dt_t * 1e9
        pair_ratios.append(dt_p / dt_t)
        plain_s = dt_p if plain_s is None else min(plain_s, dt_p)
        traced_s = dt_t if traced_s is None else min(traced_s, dt_t)
    ex_obs.recorder = rec

    trace_fraction = timed_rec.ns / traced_wall_ns
    ratio = 1.0 - trace_fraction
    wall_ab = statistics.median(pair_ratios)
    print(f"tap untraced: {rounds / plain_s:.1f} rounds/s   "
          f"traced: {rounds / traced_s:.1f} rounds/s   "
          f"wall A/B median={wall_ab:.3f}")
    print(f"time inside tracing calls: {timed_rec.ns / 1e6:.2f}ms of "
          f"{traced_wall_ns / 1e6:.0f}ms traced "
          f"({100 * trace_fraction:.2f}%)   overhead_ratio={ratio:.4f}")

    # story run: same plan with an async snapshotter so the exported
    # training trace carries the snapshot offer/copy/finalise spans the
    # acceptance bar asks for (outside every timed window)
    import tempfile
    from repro.checkpoint import AsyncSnapshotter
    with tempfile.TemporaryDirectory() as td:
        snap = AsyncSnapshotter(td, max(rounds // 2, 1), meta={"bench": "obs"})
        ex_obs.run_scan(state0, rounds_per_launch=max(rounds // 2, 1),
                        metrics="tap", snapshot=snap)

    counters = rec.tracer.counters()
    tap_match = counters.get("tap_events", -1) == counters.get("rounds", -2)

    trace_path = os.path.join(out, "trace.json")
    metrics_path = os.path.join(out, "obs_metrics.jsonl")
    rec.export_chrome(trace_path)
    rec.export_metrics(metrics_path)
    trace_ok, trace_why, n_events = _validate_chrome(
        trace_path, want_names=("launch", "tap_round", "barrier",
                                "snapshot_offer", "snapshot_finalise"))
    try:
        n_lines = sum(validate_metrics_log(metrics_path).values())
        metrics_ok, metrics_why = True, ""
    except SchemaError as e:
        n_lines, metrics_ok, metrics_why = 0, False, str(e)

    # traced slot-serve: the second trace the acceptance bar asks for —
    # admit/prefill spans + per-request lanes from the SlotServer driver
    serve_trace_path = os.path.join(out, "trace_serve.json")
    rec2 = Recorder()
    n_serve_events = 0
    serve_ok, serve_why = True, ""
    try:
        from repro.distributed import SlotServer, SlotConfig
        from repro.models import init_params

        max_new, plen = 8, 4
        server = SlotServer(
            cfg, mesh,
            SlotConfig(n_slots=2, ctx_len=plen + max_new,
                       steps_per_launch=4, seed=0),
            recorder=rec2)
        params = init_params(cfg, jrandom.PRNGKey(1))
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab, (4, plen)).astype(np.int32)
        server.serve(params, prompts, max_new)
        rec2.export_chrome(serve_trace_path)
        serve_ok, serve_why, n_serve_events = _validate_chrome(
            serve_trace_path,
            want_names=("admit", "prefill", "launch", "request"))
    except Exception as e:           # the bench must still report a payload
        serve_ok, serve_why = False, f"serve run failed: {e}"

    payload = {
        "bench": "obs",
        "backend": jax.default_backend(),
        "arch": arch, "rounds": rounds, "repeats": repeats,
        "untraced_rounds_per_s": round(rounds / plain_s, 2),
        "traced_rounds_per_s": round(rounds / traced_s, 2),
        "overhead_ratio": round(ratio, 4),
        "trace_fraction": round(trace_fraction, 6),
        "trace_call_ms": round(timed_rec.ns / 1e6, 3),
        "wall_ab_ratio": round(wall_ab, 4),
        "trace_valid": bool(trace_ok and serve_ok),
        "trace_events": n_events,
        "serve_trace_events": n_serve_events,
        "metrics_valid": bool(metrics_ok),
        "metrics_lines": n_lines,
        "tap_events_match": bool(tap_match),
        "note": ("one warm RunPlan/state through ONE PlanExecutor with "
                 "the Recorder toggled per run (identical compiled "
                 "program) on the tap transport, the most host-active "
                 "lane.  overhead_ratio = 1 - time-inside-tracing-calls/"
                 "traced-wall-time, measured directly by a timing proxy; "
                 "the documented ceiling is 5% (check_perf.py "
                 "--tolerance 0.05 gates it absolutely).  wall_ab_ratio "
                 "is the informational paired-median throughput ratio — "
                 "NOT gated, shared-host noise exceeds the ceiling.  "
                 "trace.json / trace_serve.json are Chrome trace-event "
                 "JSON (ui.perfetto.dev); obs_metrics.jsonl validates "
                 "via python -m repro.obs.schema"),
    }
    for flag, why in (("trace_valid", trace_why or serve_why),
                      ("metrics_valid", metrics_why)):
        if not payload[flag]:
            payload[f"{flag}_why"] = why
            print(f"WARNING: {flag} is False: {why}")
    path = os.path.join(out, "BENCH_obs.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", path)
    if save_baseline:
        base = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_obs.json")
        with open(base, "w") as f:
            json.dump(payload, f, indent=2)
        print("wrote baseline", base)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="3 timed repeats instead of 5 (rounds unchanged)")
    ap.add_argument("--rounds", type=int, default=0, help="0 = 256")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--out", default="experiments/figs")
    ap.add_argument("--save-baseline", action="store_true",
                    help="also write benchmarks/BENCH_obs.json (the "
                         "committed baseline check_perf.py reads)")
    args = ap.parse_args()
    run_obs(out=args.out, quick=args.quick, rounds=args.rounds,
            arch=args.arch, save_baseline=args.save_baseline)


if __name__ == "__main__":
    main()
