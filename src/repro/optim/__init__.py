from .optimizers import (
    adam_init, adam_update, sgd_update, global_norm, clip_by_global_norm,
    OptConfig, make_optimizer,
)

__all__ = ["adam_init", "adam_update", "sgd_update", "global_norm",
           "clip_by_global_norm", "OptConfig", "make_optimizer"]
