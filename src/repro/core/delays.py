"""Worker timing models — Section 5 / Appendix A of the paper.

Each worker ``i`` owns a positive speed parameter ``s_i``; a timing model
turns it into a per-job compute time ``r`` (in simulated seconds):

* ``fixed``:    r = s_i                       (fixed delay pattern)
* ``poisson``:  r ~ Po(s_i)                   (clamped to >= 1)
* ``normal``:   r = |N(s_i, s_i)| + 1
* ``uniform``:  r ~ Uni(0, s_i)

These are exactly the four patterns the paper benchmarks.  The simulator is
agnostic: anything with ``sample(worker) -> float`` works.
"""
from __future__ import annotations

import numpy as np

PATTERNS = ("fixed", "poisson", "normal", "uniform")


class TimingModel:
    """Samples per-job compute times for ``n`` workers.

    Parameters
    ----------
    speeds:
        array of per-worker parameters ``s_i`` (larger = slower worker).
    pattern:
        one of :data:`PATTERNS`.
    seed:
        host RNG seed (timings are host-side; they order events, they do not
        enter any jax computation).
    """

    def __init__(self, speeds, pattern: str = "fixed", seed: int = 0):
        speeds = np.asarray(speeds, dtype=np.float64)
        if np.any(speeds <= 0):
            raise ValueError("worker speed parameters must be positive")
        if pattern not in PATTERNS:
            raise ValueError(f"unknown pattern {pattern!r}; want one of {PATTERNS}")
        self.speeds = speeds
        self.pattern = pattern
        self._rng = np.random.default_rng(seed)

    @property
    def n_workers(self) -> int:
        return int(self.speeds.shape[0])

    def sample(self, worker: int) -> float:
        s = float(self.speeds[worker])
        if self.pattern == "fixed":
            r = s
        elif self.pattern == "poisson":
            r = float(self._rng.poisson(s))
            r = max(r, 1.0)
        elif self.pattern == "normal":
            r = abs(float(self._rng.normal(s, np.sqrt(s)))) + 1.0
        else:  # uniform
            r = float(self._rng.uniform(0.0, s))
            r = max(r, 1e-6)
        return r


def heterogeneous_speeds(n: int, slow_factor: float = 5.0, base: float = 1.0):
    """Linearly spread speeds in [base, base*slow_factor] — a simple
    heterogeneous-cluster profile used across benchmarks/examples."""
    return base * (1.0 + (slow_factor - 1.0) * np.arange(n) / max(n - 1, 1))
