"""Model building blocks, pure jnp — every assigned family composes these.

All functions are shape-polymorphic and jit/pjit friendly; activations are
bf16 with f32 softmax/normalisation.  Attention auto-switches to a
query-chunked streaming implementation for long sequences so prefill_32k
does not materialise (S, S) score matrices (the Pallas flash kernel in
``repro.kernels`` is the TPU-target version of the same algorithm).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# normalisation / embeddings
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    x32 = x.astype(F32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(F32)).astype(x.dtype)


def rope(x, positions, theta: float = 1e4):
    """Rotary embeddings.  x: (..., S, H, D); positions: (S,) or (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))
    ang = positions.astype(F32)[..., None] * freqs          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(Sq, Sk) additive bias from causal + sliding-window constraints."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF).astype(F32)


def _sdpa(q, k, v, bias):
    """q: (B,Sq,H,D), k/v: (B,Sk,KV,D), bias: (Sq,Sk) or (B,1,Sq,Sk).

    Operands stay bf16 with f32 accumulation (preferred_element_type) — an
    explicit .astype(F32) would materialise f32 copies of the whole k/v."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=F32)
    scores = scores / np.sqrt(D)
    if bias.ndim == 2:
        scores = scores + bias[None, None, None]
    else:
        scores = scores + bias[:, :, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=F32)
    return out.reshape(B, Sq, H, D).astype(v.dtype)


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, chunk_q: int = 512, dense_max: int = 1024):
    """Self/cross attention with GQA.  Chunked over query blocks when long."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    if max(Sq, Sk) <= dense_max or Sq < 2 * chunk_q:
        return _sdpa(q, k, v, _mask_bias(q_pos, k_pos, causal, window))

    n_chunks = Sq // chunk_q
    rem = Sq - n_chunks * chunk_q
    qc = q[:, : n_chunks * chunk_q].reshape(B, n_chunks, chunk_q, H, D)
    qc = jnp.moveaxis(qc, 1, 0)                 # (nc, B, cq, H, D)

    @jax.checkpoint  # recompute per-chunk probs in backward (O(chunk) memory)
    def chunk_attn(q_blk, i):
        qp = jnp.arange(chunk_q) + i * chunk_q + q_offset
        ok = jnp.ones((chunk_q, Sk), bool)
        if causal:
            ok &= k_pos[None, :] <= qp[:, None]
        if window is not None:
            ok &= k_pos[None, :] > qp[:, None] - window
        bias = jnp.where(ok, 0.0, NEG_INF).astype(F32)
        return _sdpa(q_blk, k, v, bias)

    def body(_, q_blk_i):
        q_blk, i = q_blk_i
        return None, chunk_attn(q_blk, i)

    _, out = jax.lax.scan(body, None, (qc, jnp.arange(n_chunks)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_chunks * chunk_q, H, D)
    if rem:
        tail = _sdpa(q[:, -rem:], k, v,
                     _mask_bias(q_pos[-rem:], k_pos, causal, window))
        out = jnp.concatenate([out, tail], axis=1)
    return out


def decode_attention(q, k_cache, v_cache, cache_positions, pos,
                     window: Optional[int] = None):
    """One-token attention vs a ring-buffer cache.

    q: (B,1,H,D); caches: (B,W,KV,D); cache_positions: (W,) int32 holding the
    absolute position stored in each slot (−1 = empty); pos: scalar int32 of
    the current token.  The current token's own k/v must already be written.

    Ragged (slot-server) variant: ``pos`` is (B,) and ``cache_positions`` is
    (B, W) — each batch row decodes at its own absolute position, so the
    validity mask is per-row.  The scalar path's op sequence is unchanged
    (the bias broadcasts identically), keeping lock-step decoding
    bit-for-bit what it was.

    The score tensor is constrained to keep the cache's ctx sharding so
    GSPMD computes a *distributed* softmax (partial max/sum + small
    all-reduce) instead of all-gathering the cache (flash-decode pattern).
    """
    from ..distributed.sharding import shard_activation

    if jnp.ndim(pos) == 1:                            # ragged: per-row pos
        valid = (cache_positions >= 0) & (cache_positions <= pos[:, None])
        if window is not None:
            valid &= cache_positions > (pos[:, None] - window)
        bias = jnp.where(valid, 0.0, NEG_INF).astype(F32)[:, None]  # (B,1=Sq,W)
    else:
        valid = (cache_positions >= 0) & (cache_positions <= pos)
        if window is not None:
            valid &= cache_positions > pos - window
        bias = jnp.where(valid, 0.0, NEG_INF).astype(F32)[None, None]  # (1,1=Sq,W)

    B, Sq, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qr, k_cache,
                        preferred_element_type=F32) / np.sqrt(D)
    scores = scores + bias[:, None, None]             # (B|1,1,1,Sq,W)
    scores = shard_activation(
        scores, ("batch", "kv_heads", None, None, "ctx"))
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    probs = (p / l).astype(v_cache.dtype)
    probs = shard_activation(
        probs, ("batch", "kv_heads", None, None, "ctx"))
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache,
                     preferred_element_type=F32)
    return out.reshape(B, Sq, H, D).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, w_down)


# ---------------------------------------------------------------------------
# Mixture of Experts (gather/scatter capacity dispatch)
# ---------------------------------------------------------------------------

def moe_router(x, w_router, top_k: int):
    """Returns (weights (T,k) f32, ids (T,k) i32, aux load-balance loss)."""
    logits = jnp.einsum("td,de->te", x.astype(F32), w_router.astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
    # Switch-style aux loss: E * Σ_e fraction_tokens_e · mean_prob_e
    E = w_router.shape[-1]
    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=F32), axis=0)
    aux = E * jnp.sum(me * fe)
    return w, ids, aux


def moe_ffn(x, w_router, w_gate, w_up, w_down, top_k: int,
            capacity_factor: float = 1.25):
    """Fine-grained top-k MoE over flattened tokens.

    x: (B,S,d);  expert weights: (E, d, f) / (E, f, d).
    Dispatch: per expert, the top-C tokens by routing weight are gathered
    (capacity C = ceil(T·k/E·cf)); overflow tokens are dropped for that
    expert (their residual passes through) — standard capacity semantics.

    Sharding: dispatch is GROUP-LOCAL — tokens are viewed as (G, T/G, d)
    where G = number of data shards; routing, capacity and gather/scatter
    all happen within a group (standard local-capacity MoE), so no
    cross-shard token gather exists.  Expert compute is expert-parallel when
    E divides the model axis (deepseek) and tensor-parallel on the expert ff
    otherwise (grok); the only cross-shard traffic is the combine reduction.
    Without this, GSPMD all-gathers the full token set per layer (≈64 GB/dev
    at grok-1 train scale).
    """
    from ..distributed.sharding import shard_activation, data_shard_count

    B, S, d = x.shape
    E = w_gate.shape[0]
    T = B * S
    G = data_shard_count()
    if T % G or (T // G) < E:
        G = 1
    TL = T // G
    xt = shard_activation(x.reshape(G, TL, d), ("batch", None, None))
    weights, ids, aux = moe_router(xt.reshape(T, d), w_router, top_k)
    weights = weights.reshape(G, TL, top_k)
    ids = ids.reshape(G, TL, top_k)

    C = int(np.ceil(TL * top_k / E * capacity_factor))
    C = min(C, TL)
    # per-token-per-expert routing weight (G, TL, E), 0 if not routed
    w_full = jnp.zeros((G, TL, E), F32)
    garange = jnp.arange(G)[:, None, None]
    w_full = w_full.at[garange, jnp.arange(TL)[None, :, None], ids].set(weights)
    # top-C tokens per expert, within each group
    gate_w, token_idx = jax.lax.top_k(w_full.transpose(0, 2, 1), C)  # (G,E,C)
    x_e = jax.vmap(lambda xg, idx: xg[idx])(xt, token_idx)            # (G,E,C,d)
    x_e = shard_activation(x_e, ("batch", "experts", None, None))
    g = jnp.einsum("gecd,edf->gecf", x_e, w_gate)
    u = jnp.einsum("gecd,edf->gecf", x_e, w_up)
    h = shard_activation(jax.nn.silu(g.astype(F32)).astype(x.dtype) * u,
                         ("batch", "experts", None, "ff"))
    y_e = jnp.einsum("gecf,efd->gecd", h, w_down)                     # (G,E,C,d)
    y_e = shard_activation(y_e, ("batch", "experts", None, None))
    y_e = y_e * gate_w[..., None].astype(y_e.dtype)
    # combine: scatter-add back to token order within each group
    def _combine(idx, ye):
        return jnp.zeros((TL, d), y_e.dtype).at[idx.reshape(-1)].add(
            ye.reshape(E * C, d))

    y = jax.vmap(_combine)(token_idx, y_e)
    y = shard_activation(y, ("batch", None, None))
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Mamba2 (state-space duality, chunked)
# ---------------------------------------------------------------------------

def _segsum(a):
    """a: (..., C).  Returns (..., C, C) with out[i,j] = Σ_{k=j+1..i} a_k for
    j < i, 0 on diagonal, −inf above (the 1-semiseparable log-decay matrix)."""
    C = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((C, C), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B_, C_, chunk: int = 128, h0=None,
                use_kernel: bool = False):
    """Chunked SSD scan (Mamba2, alg. of Dao & Gu 2024 §6).

    x:  (B, S, H, P)  — per-head inputs
    dt: (B, S, H)     — post-softplus step sizes
    A:  (H,)          — negative decay rates (A = −exp(A_log))
    B_: (B, S, N), C_: (B, S, N)  — shared across heads (n_groups=1)
    h0: optional initial state (B, H, P, N)
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, "seq must divide chunk"
    la = (dt.astype(F32) * A[None, None, :].astype(F32))       # log decay (B,S,H)

    def r(t):  # split the sequence axis into (nc, chunk)
        return t.reshape(t.shape[0], nc, chunk, *t.shape[2:])

    xc, dtc, lac = r(x), r(dt), r(la)                          # lac: (B,k,c,H)
    Bc, Cc = r(B_).astype(F32), r(C_).astype(F32)              # (B,k,c,N)
    xdt = (xc * dtc[..., None]).astype(F32)                    # (B,k,c,H,P)
    cums = jnp.cumsum(lac, axis=2)                             # (B,k,c,H)

    if use_kernel:
        # Pallas intra-chunk kernel (TPU target; interpret on CPU)
        from ..kernels.ops import ssd_chunk
        y_diag, st = ssd_chunk(xc, dtc, A, r(B_), r(C_))
        y_diag = y_diag.astype(F32)
        states = jnp.moveaxis(st, -1, -2)                      # (B,k,H,P,N)
    else:
        # --- intra-chunk (quadratic, attention-like) ---
        # einsum letters: b batch, k chunk, i/j pos-in-chunk, h head, p P, n N
        Lh = jnp.exp(_segsum(jnp.moveaxis(lac, -1, 2)))        # (B,k,H,i,j)
        scores = jnp.einsum("bkin,bkjn->bkij", Cc, Bc)         # CBᵀ, head-shared
        y_diag = jnp.einsum("bkij,bkhij,bkjhp->bkihp", scores, Lh, xdt)

        # --- chunk-final states ---
        decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)      # (B,k,c,H)
        states = jnp.einsum("bkjn,bkjhp->bkhpn", Bc,
                            xdt * decay_to_end[..., None])     # (B,k,H,P,N)

    # --- inter-chunk recurrence over k (short scan) ---
    chunk_decay = jnp.exp(cums[:, :, -1, :])                   # (B,k,H)


    def step(h, inp):
        s, dec = inp
        h_new = h * dec[..., None, None] + s
        return h_new, h

    init = jnp.zeros((Bb, H, P, N), F32) if h0 is None else h0.astype(F32)
    hT, h_prev = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                        # (B,k,H,P,N)

    # --- inter-chunk contribution ---
    decay_from_start = jnp.exp(cums)                           # (B,k,c,H)
    y_off = jnp.einsum("bkin,bkhpn,bkih->bkihp", Cc, h_prev, decay_from_start)
    y = (y_diag + y_off).reshape(Bb, S, H, P)
    return y.astype(x.dtype), hT


def ssd_decode_step(h, x_t, dt_t, A, B_t, C_t):
    """One-token SSD update.  h: (B,H,P,N); x_t: (B,H,P); dt_t: (B,H);
    B_t/C_t: (B,N).  Returns (y (B,H,P), h_new)."""
    a = jnp.exp((dt_t * A[None, :]).astype(F32))               # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", (x_t * dt_t[..., None]).astype(F32),
                     B_t.astype(F32))
    h_new = h * a[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, C_t.astype(F32))
    return y.astype(x_t.dtype), h_new


# ---------------------------------------------------------------------------
# depthwise causal conv1d (mamba front conv)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, b=None):
    """x: (B,S,D); w: (K,D) depthwise kernel; left-padded causal."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    if b is not None:
        out = out + b[None, None, :]
    return jax.nn.silu(out.astype(F32)).astype(x.dtype)


def conv1d_decode(conv_state, x_t, w, b=None):
    """conv_state: (B,K−1,D) past inputs; x_t: (B,D).  Returns (y, new_state)."""
    K = w.shape[0]
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,D)
    y = jnp.einsum("bkd,kd->bd", full, w)
    if b is not None:
        y = y + b[None, :]
    new_state = full[:, 1:, :]
    return jax.nn.silu(y.astype(F32)).astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """Token-level cross entropy, f32 accumulation.  logits (..., V)."""
    logits = logits.astype(F32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        return jnp.sum(nll * mask) / (jnp.sum(mask) + 1e-6)
    return jnp.mean(nll)
