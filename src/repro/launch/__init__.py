from .mesh import make_production_mesh, make_host_mesh, mesh_devices, PEAK_FLOPS_BF16, HBM_BW, ICI_BW

__all__ = ["make_production_mesh", "make_host_mesh", "mesh_devices",
           "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW"]
