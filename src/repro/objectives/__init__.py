from .logreg import LogRegProblem
from .synthetic import make_synthetic, make_libsvm_like
from .quadratic import QuadraticProblem

__all__ = ["LogRegProblem", "make_synthetic", "make_libsvm_like", "QuadraticProblem"]
