"""Per-architecture smoke tests: reduced variant of the same family, one
forward + one train-gradient step + one decode step on CPU; asserts output
shapes and absence of NaNs (the brief's required smoke coverage)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import (
    param_specs, init_params, n_params, n_active_params,
    forward_logits, loss_fn, init_cache, decode_step, batch_specs,
    init_tree, abstract_tree,
)
from repro.models.specs import Spec

ARCH_NAMES = sorted(ARCHS)


def _batch(cfg, B=2, S=32, key=jax.random.PRNGKey(1)):
    specs = batch_specs(cfg, B, S)
    b = {}
    for k, sp in specs.items():
        kk = jax.random.fold_in(key, hash(k) % 1000)
        if sp.dtype == "int32":
            b[k] = jax.random.randint(kk, sp.shape, 0, cfg.vocab, jnp.int32)
        else:
            b[k] = jax.random.normal(kk, sp.shape, jnp.float32)
    return b


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg = get_arch(name).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: forward_logits(cfg, p, b))(params, batch)
    B = batch["tokens"].shape[0]
    S = batch["tokens"].shape[1]
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_grad_finite(name):
    cfg = get_arch(name).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    @jax.jit
    def step(p, b):
        (l, m), g = jax.value_and_grad(
            lambda q: loss_fn(cfg, q, b), has_aux=True)(p)
        return l, g

    loss, grads = step(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    # at least some gradient signal everywhere except unused stubs
    nonzero = sum(float(jnp.abs(g.astype(jnp.float32)).sum()) > 0 for g in leaves)
    assert nonzero / len(leaves) > 0.8


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step(name):
    cfg = get_arch(name).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, ctx = 2, 16
    cache = init_cache(cfg, B, ctx)
    tok = jnp.array([1, 2], jnp.int32)

    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos, ctx))
    logits, cache = step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # a few more steps reuse the cache without shape drift
    for pos in range(1, 4):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_param_counts_match_assignment_scale():
    """Full (non-reduced) configs hit the advertised parameter scale."""
    expect = {
        "grok-1-314b": (250e9, 380e9),
        "deepseek-moe-16b": (13e9, 20e9),
        "minitron-8b": (7e9, 10e9),
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "stablelm-1.6b": (1.2e9, 2.2e9),
        "zamba2-7b": (6e9, 9e9),
        "mamba2-370m": (0.25e9, 0.5e9),
        "seamless-m4t-large-v2": (1.2e9, 2.8e9),
        "pixtral-12b": (10e9, 14e9),
        "qwen3-8b": (6.5e9, 10e9),
    }
    for name, (lo, hi) in expect.items():
        n = n_params(get_arch(name))
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    cfg = get_arch("grok-1-314b")
    act = n_active_params(cfg)
    tot = n_params(cfg)
    assert act < tot
    # top-2 of 8 experts → roughly a quarter of expert params active
    assert 0.2 * tot < act < 0.5 * tot


def test_decode_matches_prefill_dense():
    """Sequential decode of a short prompt reproduces full-forward logits."""
    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full, _ = forward_logits(cfg, params, {"tokens": tokens})
    cache = init_cache(cfg, B, S)
    step = jax.jit(lambda c, t, pos: decode_step(cfg, params, c, t, pos, S))
    outs = []
    for pos in range(S):
        lg, cache = step(cache, tokens[:, pos], jnp.int32(pos))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_prefill_ssm():
    """Same equivalence for the SSD recurrence (chunked scan vs step)."""
    cfg = get_arch("mamba2-370m").reduced().with_(remat="none")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full, _ = forward_logits(cfg, params, {"tokens": tokens})
    cache = init_cache(cfg, B, S)
    step = jax.jit(lambda c, t, pos: decode_step(cfg, params, c, t, pos, S))
    outs = []
    for pos in range(S):
        lg, cache = step(cache, tokens[:, pos], jnp.int32(pos))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_sliding_window_attention_restricts_context():
    """With window W, logits for position t only depend on tokens > t−W."""
    cfg = get_arch("qwen3-8b").reduced().with_(sliding_window=4, remat="none",
                                               n_layers=1)
    params = init_params(cfg, jax.random.PRNGKey(0))
    S = 12
    t1 = jax.random.randint(jax.random.PRNGKey(3), (1, S), 0, cfg.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 7) % cfg.vocab)   # perturb an early token
    l1, _ = forward_logits(cfg, params, {"tokens": t1})
    l2, _ = forward_logits(cfg, params, {"tokens": t2})
    # last position is > W away from position 0 → unchanged
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               rtol=1e-4, atol=1e-4)
    # position 1 IS affected
    assert not np.allclose(np.asarray(l1[0, 1]), np.asarray(l2[0, 1]), atol=1e-4)


@pytest.mark.parametrize("name", ["qwen3-8b", "mamba2-370m", "zamba2-7b",
                                  "seamless-m4t-large-v2", "deepseek-moe-16b",
                                  "pixtral-12b"])
def test_prefill_then_decode_matches_full_forward(name):
    """prefill(prompt) + decode(next tokens) ≡ forward over the whole seq."""
    from repro.models import prefill
    # capacity_factor high enough that no MoE token drops — capacity-based
    # routing otherwise differs legitimately between prompt- and step-batches
    cfg = get_arch(name).reduced().with_(remat="none", capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S)
    full, _ = forward_logits(cfg, params, batch)
    tok = batch["tokens"]
    S_dec = tok.shape[1]          # audio decoders are shorter than S
    split = max(S_dec - 6, S_dec // 2)
    pre_batch = dict(batch)
    pre_batch["tokens"] = tok[:, :split]
    S = S_dec
    last, cache = prefill(cfg, params, pre_batch, ctx_len=S)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(full[:, split - 1], np.float32),
                               rtol=5e-2, atol=5e-2)
    step = jax.jit(lambda c, t, pos: decode_step(cfg, params, c, t, pos, S))
    for pos in range(split, S):
        lg, cache = step(cache, tok[:, pos], jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(lg, np.float32),
                                   np.asarray(full[:, pos], np.float32),
                                   rtol=7e-2, atol=7e-2)
