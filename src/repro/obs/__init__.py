"""``repro.obs`` — unified tracing + metrics across the runtime.

Zero-dependency (stdlib only) observability substrate: a
:class:`Tracer` collecting host-timestamped spans / instants / metrics
at EXISTING host boundaries (never a new device sync), a per-run
:class:`Recorder` handle threaded through ``PlanExecutor``,
``SlotServer``, ``AsyncSnapshotter`` and the fault guards, a
:class:`CompileWatch` retrace sentinel generalising
``SlotServer.compile_counts``, Chrome-trace-event export (Perfetto) +
a schema-versioned JSONL metrics log, and :func:`render_summary` for
the human time-in-phase table.

    from repro.obs import Recorder, render_summary

    rec = Recorder()
    res = TrainerBackend(recorder=rec).run(spec)
    rec.export_chrome("trace.json")      # -> ui.perfetto.dev
    rec.export_metrics("metrics.jsonl")  # -> schema-validated log
    print(render_summary(res.extra["obs"], trace=res.trace))
"""
from .compile_watch import CompileWatch, RetraceError
from .recorder import Recorder
from .schema import (METRICS_SCHEMA_VERSION, SchemaError, validate_line,
                     validate_lines, validate_metrics_log)
from .summary import render_summary
from .tracer import Tracer

__all__ = [
    "CompileWatch", "RetraceError", "Recorder", "Tracer",
    "METRICS_SCHEMA_VERSION", "SchemaError", "validate_line",
    "validate_lines", "validate_metrics_log", "render_summary",
]
