"""Serving resilience (PR 10 tentpole gates).

Three acceptance gates live here:

* **clean-run no-op** — arming the retry machinery on a clean world is
  token-identical to the plain serve (and the compile counts stay at one
  trace per program).
* **SIGKILL crash-resume** — a subprocess serving with async snapshots
  (decode state + host ledger) is SIGKILLed mid-run; this process resumes
  from the newest restorable snapshot and the completed serve's token
  matrix is bitwise identical to an uninterrupted run.
* **chaos soak** — poison + driver preemption + bursty overload composed
  through the fault grammar: every request ends completed or accounted in
  exactly one degraded bucket (evictions / timeouts / shed / drained) —
  no silent loss.

Plus the mechanism units: deterministic backoff, prefix replay through
prefill, retry exhaustion, deadline=0, shed policies, graceful drain,
ledger/policy snapshot round-trips, and the ``ServeJob`` surface.
"""
import os
import signal
import subprocess
import sys
import time

import numpy as np
import jax
import pytest
from jax.sharding import Mesh

from repro.api import ExperimentSpec, ServeJob, run
from repro.api.backends import ServeBackend
from repro.checkpoint import AsyncSnapshotter
from repro.configs import get_arch
from repro.core.delays import TimingModel
from repro.distributed import (OverloadPolicy, RetryPolicy, ServePreempted,
                               SlotConfig, SlotServer)
from repro.distributed.slot_serve import _Ledger
from repro.faults import ServeFaults, realise_serve_faults
from repro.models import init_params
from repro.obs import Recorder
from repro.scenarios import tau_report, render_report

TINY = dict(n_layers=1, d_model=8, n_heads=1, n_kv_heads=1, d_ff=16,
            vocab=127)
TINY_OVR = tuple(TINY.items())


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _setup():
    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none", **TINY)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(n, plen, vocab, seed=0):
    return np.random.default_rng(seed).integers(
        0, vocab, (n, plen)).astype(np.int32)


def _server(cfg, n_slots, ctx, K=2, recorder=None, temperature=0.0):
    return SlotServer(cfg, _mesh(),
                      SlotConfig(n_slots=n_slots, ctx_len=ctx,
                                 temperature=temperature,
                                 steps_per_launch=K), recorder=recorder)


def _accounted(res, n_req):
    """Every rid lands in exactly ONE terminal bucket (full row counts as
    'completed'); returns the per-rid bucket map."""
    buckets = {}
    for rid in range(n_req):
        hits = [name for name, m in (("evicted", res.evictions),
                                     ("timed_out", res.timeouts),
                                     ("shed", res.shed),
                                     ("drained", res.drained)) if rid in m]
        if not hits:
            assert (res.tokens[rid] >= 0).all(), (
                f"rid {rid} is in no degraded bucket but its row is not a "
                f"full token row: {res.tokens[rid]}")
            buckets[rid] = "completed"
        else:
            assert len(hits) == 1, f"rid {rid} in several buckets: {hits}"
            buckets[rid] = hits[0]
    return buckets


# ---------------------------------------------------------------------------
# policies + timing registry units
# ---------------------------------------------------------------------------

def test_retry_policy_backoff_and_validation():
    rp = RetryPolicy(max_attempts=3, backoff_base=4, backoff_factor=2.0)
    assert [rp.backoff_steps(f) for f in (1, 2, 3)] == [4, 8, 16]
    assert RetryPolicy(backoff_base=0).backoff_steps(5) == 0
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="backoff_factor"):
        RetryPolicy(backoff_factor=0.5)
    with pytest.raises(ValueError, match="queue_cap"):
        OverloadPolicy(0)
    with pytest.raises(ValueError, match="shed policy"):
        OverloadPolicy(2, shed="nope")


def test_bursty_timing_pattern():
    """The bursty arrival model: near-zero gaps inside a burst, 4·s
    between bursts — same mean as the base gap; batch draws must equal
    the scalar oracle draw-for-draw."""
    s = 3.0
    tm = TimingModel(np.full(64, s), "bursty", seed=7)
    batch = tm.sample_round(np.arange(64))
    oracle = TimingModel(np.full(64, s), "bursty", seed=7)
    scalar = np.array([oracle.sample(i) for i in range(64)])
    np.testing.assert_allclose(batch, scalar)
    assert set(np.round(batch, 8)) <= {4.0 * s, 1e-6}
    assert (batch < 1e-3).any(), "no burst (near-zero gap) realised"
    assert (batch > s).any(), "no inter-burst gap realised"
    # replays bit-identically
    np.testing.assert_array_equal(
        batch, TimingModel(np.full(64, s), "bursty", seed=7)
        .sample_round(np.arange(64)))


def test_serve_fault_grammar():
    f = realise_serve_faults(
        "slot_poison:rid=1,step=4,every=0;serve_preempt:at=6,every=0",
        n_requests=4, horizon=16)
    assert f.poisons == ((1, 4),)
    assert f.preempt_steps == (6,)
    assert not f.empty
    # every>0 expands on the decode-step clock up to the horizon
    f2 = realise_serve_faults("slot_poison:rid=0,step=2,every=4",
                              n_requests=2, horizon=12)
    assert f2.poisons == ((0, 2), (0, 6), (0, 10))
    # training-lane transforms contribute no serve channels
    f3 = realise_serve_faults("nan_grad:k=1,every=4", n_requests=2,
                              horizon=8)
    assert f3.empty
    with pytest.raises(ValueError, match="rid"):
        realise_serve_faults("slot_poison:rid=-1", 2, 8)
    with pytest.raises(ValueError, match="at"):
        realise_serve_faults("serve_preempt:at=0", 2, 8)


def test_ledger_json_roundtrip():
    L = _Ledger(3, 2, [0, 1, 5])
    L.t, L.chunks, L.busy_steps = 4, 2, 7
    L.slot_rid = [1, -1]
    L.state_of = {0: "done", 1: "inflight", 2: "queued"}
    L.fin = {0: 3, 1: 6}
    L.admit_t = {0: 0, 1: 2}
    L.tries = {2: 1}
    L.emitted = {2: [5, 9]}
    L.outputs = {1: [7, 8, 9]}
    L.cur_evict = {2: 3}
    L.evict_events = [[2, 3]]
    L.evt_cursor = 1
    L.evictions, L.drain_t = {}, None
    d = L.to_json()
    L2 = _Ledger.from_json(d)
    assert L2.to_json() == d
    assert L2.in_flight == 1 and L2.done == 1
    assert L2.state_of == L.state_of and L2.emitted == L.emitted


def test_admission_policy_state_roundtrip():
    from repro.distributed import AdmissionPolicy

    a = AdmissionPolicy("shuffled", 6, seed=3)
    b = AdmissionPolicy("shuffled", 6, seed=99)     # scrambled on purpose
    arrived = set(range(6))
    first = a.pick(arrived, 0)
    a.notify_completion(first)
    b.load_state(a.state_dict())
    for _ in range(3):                              # identical continuations
        pa = a.pick(arrived, 1)
        pb = b.pick(arrived, 1)
        assert pa == pb
        if pa is not None:
            a.notify_completion(pa)
            b.notify_completion(pb)


# ---------------------------------------------------------------------------
# acceptance gate: clean-world retry no-op
# ---------------------------------------------------------------------------

def test_clean_world_retry_is_token_identical():
    cfg, params = _setup()
    n, plen, T = 3, 4, 6
    ctx = plen + T
    prompts = _prompts(n, plen, cfg.vocab)
    arr = np.array([0, 1, 3])
    plain = _server(cfg, 2, ctx).serve(params, prompts, T, arrivals=arr)
    srv = _server(cfg, 2, ctx)
    armed = srv.serve(params, prompts, T, arrivals=arr,
                      retry=RetryPolicy(max_attempts=3),
                      overload=OverloadPolicy(queue_cap=8))
    np.testing.assert_array_equal(plain.tokens, armed.tokens)
    assert armed.evictions == {} and armed.attempts == {}
    assert armed.shed == {} and armed.drained == {}
    assert armed.resumed_from is None
    assert all(v == 1 for v in srv.compile_counts().values()), (
        srv.compile_counts())


# ---------------------------------------------------------------------------
# retry mechanism
# ---------------------------------------------------------------------------

def test_poison_retry_recovers_full_row():
    """A poisoned lane retries with its emitted prefix replayed through
    prefill; under greedy decoding the recovered row equals the clean
    row exactly."""
    cfg, params = _setup()
    n, plen, T = 2, 4, 6
    ctx = plen + T
    prompts = _prompts(n, plen, cfg.vocab)
    clean = _server(cfg, 2, ctx).serve(params, prompts, T)
    res = _server(cfg, 2, ctx).serve(
        params, prompts, T,
        faults=ServeFaults(poisons=((1, 2),)),
        retry=RetryPolicy(max_attempts=2, backoff_base=2))
    np.testing.assert_array_equal(clean.tokens, res.tokens)
    assert res.attempts == {1: 1}
    assert res.evictions == {}          # recovered — not terminal
    assert _accounted(res, n) == {0: "completed", 1: "completed"}


def test_without_retry_poison_is_terminal():
    cfg, params = _setup()
    n, plen, T = 2, 4, 6
    ctx = plen + T
    prompts = _prompts(n, plen, cfg.vocab)
    res = _server(cfg, 2, ctx).serve(params, prompts, T,
                                     faults=ServeFaults(poisons=((1, 2),)))
    assert res.evictions == {1: 2}
    assert (res.tokens[1, :3] >= 0).all() and (res.tokens[1, 3:] == -1).all()
    assert (res.tokens[0] >= 0).all()


def test_retry_exhaustion_lands_in_evictions_with_attempts():
    """slot_poison every=1 fails every attempt: the request exhausts its
    budget and is accounted terminally with the attempt count."""
    cfg, params = _setup()
    n, plen, T = 1, 4, 4
    ctx = plen + T
    prompts = _prompts(n, plen, cfg.vocab)
    cells = tuple((0, s) for s in range(1, 32))          # poison steps >= 1
    res = _server(cfg, 1, ctx).serve(
        params, prompts, T, faults=ServeFaults(poisons=cells),
        retry=RetryPolicy(max_attempts=2, backoff_base=1))
    assert 0 in res.evictions
    assert res.attempts == {0: 2}
    row = res.tokens[0]
    k = int((row >= 0).sum())
    assert 0 < k < T and (row[:k] >= 0).all() and (row[k:] == -1).all(), row
    assert _accounted(res, n) == {0: "evicted"}


def test_retried_stream_reseeds_per_attempt():
    """Attempt a re-seeds the slot key with fold_in(key, a): under
    temperature sampling the retried tail is reproducible run-to-run."""
    cfg, params = _setup()
    n, plen, T = 1, 4, 6
    ctx = plen + T

    def go():
        return _server(cfg, 1, ctx, temperature=0.8).serve(
            params, _prompts(n, plen, cfg.vocab), T,
            faults=ServeFaults(poisons=((0, 2),)),
            retry=RetryPolicy(max_attempts=2, backoff_base=2))

    a, b = go(), go()
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.attempts == {0: 1} and (a.tokens[0] >= 0).all()


# ---------------------------------------------------------------------------
# deadlines + overload + drain
# ---------------------------------------------------------------------------

def test_deadline_zero_times_out_at_first_sweep():
    cfg, params = _setup()
    n, plen, T = 3, 4, 4
    ctx = plen + T
    res = _server(cfg, 1, ctx).serve(params, _prompts(n, plen, cfg.vocab),
                                     T, deadline=0)
    # one admitted at t=0; the two still queued at the next sweep (wait
    # K > 0) are immediately timed out
    assert len(res.timeouts) == 2
    assert set(res.timeouts.values()) == {2}
    assert sorted(v for r, v in enumerate(res.ttft_steps) if v < 0) == [-1, -1]
    assert _accounted(res, n)[0] == "completed"


def test_deadline_timeout_retries_with_backoff_then_completes():
    cfg, params = _setup()
    n, plen, T = 2, 4, 4
    ctx = plen + T
    prompts = _prompts(n, plen, cfg.vocab)
    clean = _server(cfg, 2, ctx).serve(params, prompts, T)
    res = _server(cfg, 1, ctx).serve(
        params, prompts, T, deadline=0,
        retry=RetryPolicy(max_attempts=3, backoff_base=2))
    assert res.timeouts == {} and res.attempts.get(1, 0) >= 1
    # greedy: the eventually-admitted stream matches the clean one
    np.testing.assert_array_equal(clean.tokens, res.tokens)
    assert _accounted(res, n) == {0: "completed", 1: "completed"}


def test_shed_policies_are_distinguishable():
    cfg, params = _setup()
    n, plen, T = 6, 4, 4
    ctx = plen + T
    prompts = _prompts(n, plen, cfg.vocab)

    def go(shed):
        return _server(cfg, 1, ctx).serve(
            params, prompts, T,
            overload=OverloadPolicy(queue_cap=2, shed=shed))

    new = go("reject-new")
    old = go("drop-oldest")
    assert len(new.shed) == 3 and len(old.shed) == 3
    assert set(new.shed) != set(old.shed)
    # reject-new drops the NEWEST eligible waiters, drop-oldest the head
    assert set(new.shed) == {3, 4, 5}
    assert set(old.shed) == {1, 2, 3}
    for res in (new, old):
        b = _accounted(res, n)
        assert sum(1 for v in b.values() if v == "completed") == 3


def test_readmission_respects_drop_oldest_shedding():
    """A retried request re-enters a bounded queue: under drop-oldest its
    later eligibility makes it the freshest waiter, so the head sheds —
    and every request is still accounted."""
    cfg, params = _setup()
    n, plen, T = 4, 4, 4
    ctx = plen + T
    res = _server(cfg, 1, ctx).serve(
        params, _prompts(n, plen, cfg.vocab), T,
        faults=ServeFaults(poisons=((0, 1), (0, 2), (0, 3), (0, 4),
                                    (0, 5), (0, 6), (0, 7))),
        retry=RetryPolicy(max_attempts=2, backoff_base=2),
        overload=OverloadPolicy(queue_cap=1, shed="drop-oldest"))
    buckets = _accounted(res, n)
    assert buckets[0] in ("evicted", "shed")     # rid 0 fails every attempt
    assert res.shed, "cap=1 on a 1-slot pool must shed someone"
    assert res.attempts.get(0, 0) >= 1


def test_graceful_drain():
    cfg, params = _setup()
    n, plen, T = 4, 4, 6
    ctx = plen + T
    rec = Recorder()
    arr = np.array([0, 0, 8, 12])
    res = _server(cfg, 1, ctx, recorder=rec).serve(
        params, _prompts(n, plen, cfg.vocab), T, arrivals=arr,
        drain_after=2)
    # rid 0 is in flight at the drain point and finishes; everyone still
    # queued (arrived or not) is cancelled and accounted
    assert (res.tokens[0] >= 0).all()
    assert set(res.drained) == {1, 2, 3}
    assert all(v == 2 for v in res.drained.values())
    names = {e["name"] for e in rec.tracer.chrome_trace()["traceEvents"]}
    assert "drain" in names and "drain_start" in names
    b = _accounted(res, n)
    assert b == {0: "completed", 1: "drained", 2: "drained", 3: "drained"}


def test_serve_job_resilience_fields_and_backend_surface():
    with pytest.raises(ValueError, match="max_retries"):
        ServeJob(max_retries=0)
    with pytest.raises(ValueError, match="max_retries"):
        ServeJob(max_retries=2)                    # needs the slot lane
    with pytest.raises(ValueError, match="queue_cap"):
        ServeJob(queue_cap=4)
    with pytest.raises(ValueError, match="queue_cap"):
        ServeJob(queue_cap=0, n_slots=2)
    with pytest.raises(ValueError, match="shed policy"):
        ServeJob(queue_cap=2, n_slots=2, shed_policy="nope")
    with pytest.raises(ValueError, match="drain_after"):
        ServeJob(drain_after=-1, n_slots=2)
    res = ServeBackend(mesh=_mesh()).run(ExperimentSpec(
        objective=ServeJob(batch=2, prompt_len=4, arch_overrides=TINY_OVR,
                           n_slots=2, n_requests=3, max_retries=2,
                           retry_backoff=2, queue_cap=4,
                           steps_per_launch=2),
        T=5, seed=0, scenario="slot_poison:rid=1,step=2,every=0"))
    assert res.extra["attempts"] == {1: 1}
    assert res.extra["evictions"] == {}            # recovered via retry
    assert (res.x >= 0).all()
    deg = res.extra["tau_report"]["degraded"]
    assert deg["attempts"] == {1: 1}
    assert "shed" in deg and "drained" in deg


def test_tau_report_degraded_render():
    lock = run(ExperimentSpec(objective=ServeJob(
        batch=2, prompt_len=4, arch_overrides=TINY_OVR, n_slots=2,
        steps_per_launch=2), T=4))
    rep = tau_report(lock.schedule, "pure", concurrency=2,
                     evictions={0: 3}, timeouts={1: 2}, shed={2: 1},
                     drained={3: 4}, attempts={0: 2})
    assert rep["degraded"]["shed"] == {2: 1}
    assert rep["degraded"]["attempts"] == {0: 2}
    txt = render_report(rep)
    assert "1 shed" in txt and "1 drained" in txt
    assert "1 retried" in txt and "2 failed attempts" in txt


# ---------------------------------------------------------------------------
# durability: snapshot / preempt / resume
# ---------------------------------------------------------------------------

def test_preempt_snapshot_resume_bitwise(tmp_path):
    """serve_preempt raises at the scheduled boundary after a forced
    snapshot offer; a resumed serve completes with a token matrix bitwise
    identical to the uninterrupted run."""
    cfg, params = _setup()
    n, plen, T = 3, 4, 6
    ctx = plen + T
    prompts = _prompts(n, plen, cfg.vocab)
    arr = np.array([0, 0, 4])
    clean = _server(cfg, 2, ctx).serve(params, prompts, T, arrivals=arr)

    srv = _server(cfg, 2, ctx)
    snapdir = str(tmp_path / "serve-snaps")
    faults = ServeFaults(preempt_steps=(4,))
    with pytest.raises(ServePreempted) as ei:
        srv.serve(params, prompts, T, arrivals=arr, faults=faults,
                  snapshot=AsyncSnapshotter(snapdir, 2, keep=3))
    assert ei.value.at == 4 and ei.value.step >= 4
    r, latest = AsyncSnapshotter.latest(snapdir)
    assert r == ei.value.step

    res = srv.serve(params, prompts, T, arrivals=arr, faults=faults,
                    resume_from=latest)
    assert res.resumed_from == r
    np.testing.assert_array_equal(clean.tokens, res.tokens)
    np.testing.assert_array_equal(clean.ttft_steps, res.ttft_steps)
    assert res.chunks == clean.chunks              # lifetime accounting


def _sigkill_child_main(snapdir):                  # pragma: no cover
    cfg, params = _setup()
    n, plen, T = 4, 4, 12
    ctx = plen + T
    prompts = _prompts(n, plen, cfg.vocab)
    srv = _server(cfg, 2, ctx, K=2)

    def throttle(rid, tok, step):                  # ~0.2 s per token: the
        time.sleep(0.2)                            # parent kills mid-serve

    srv.serve(params, prompts, T, arrivals=np.array([0, 0, 4, 8]),
              on_token=throttle,
              snapshot=AsyncSnapshotter(snapdir, 2, keep=3))
    print("FINISHED", flush=True)


def test_sigkill_serve_crash_resume_gate(tmp_path):
    """The serving durability acceptance gate: SIGKILL a subprocess
    mid-serve, resume from its newest restorable snapshot, and the
    completed token matrix is bitwise identical to an uninterrupted
    run — pre-crash tokens ride the snapshot's host ledger."""
    snapdir = str(tmp_path / "serve-crash")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (env.get("PYTHONPATH", ""),
                    os.path.dirname(os.path.abspath(__file__))) if p)
    child = subprocess.Popen(
        [sys.executable, "-c",
         "import sys; from test_resilience import _sigkill_child_main; "
         "_sigkill_child_main(sys.argv[1])", snapdir],
        env=env, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))),
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
    try:
        deadline = time.time() + 300
        found = None
        while time.time() < deadline:
            if child.poll() is not None:
                break
            found = AsyncSnapshotter.latest(snapdir)
            if found is not None:
                break
            time.sleep(0.05)
        assert found is not None, (
            "child produced no snapshot before finishing/deadline:\n"
            + child.communicate()[1])
        child.send_signal(signal.SIGKILL)
        child.wait(timeout=60)
    finally:
        if child.poll() is None:
            child.kill()
            child.wait(timeout=60)
    out = (child.stdout.read() or "") if child.stdout else ""
    assert "FINISHED" not in out, "child finished before the kill landed"

    r, latest = AsyncSnapshotter.latest(snapdir)
    assert r > 0 and r % 2 == 0                    # chunk boundary

    cfg, params = _setup()
    n, plen, T = 4, 4, 12
    ctx = plen + T
    prompts = _prompts(n, plen, cfg.vocab)
    arr = np.array([0, 0, 4, 8])
    clean = _server(cfg, 2, ctx, K=2).serve(params, prompts, T,
                                            arrivals=arr)
    res = _server(cfg, 2, ctx, K=2).serve(params, prompts, T, arrivals=arr,
                                          resume_from=latest)
    assert res.resumed_from == r
    np.testing.assert_array_equal(clean.tokens, res.tokens)
    np.testing.assert_array_equal(clean.ttft_steps, res.ttft_steps)


# ---------------------------------------------------------------------------
# acceptance gate: chaos soak
# ---------------------------------------------------------------------------

def test_chaos_soak_no_silent_loss(tmp_path):
    """Poison + driver preemption + bursty arrivals + bounded queue +
    retries, composed through the fault grammar and resumed across the
    preemption: every request is completed or accounted in exactly one
    degraded bucket, and the τ-report's degraded section agrees."""
    cfg, params = _setup()
    n, plen, T = 6, 4, 5
    ctx = plen + T
    prompts = _prompts(n, plen, cfg.vocab)
    from repro.distributed import draw_arrivals

    arr = draw_arrivals(n, "bursty:gap=2", seed=3)
    faults = realise_serve_faults(
        "slot_poison:rid=1,step=3,every=1;serve_preempt:at=8,every=0",
        n_requests=n, horizon=256, seed=3)
    assert faults.poisons and faults.preempt_steps == (8,)

    srv = _server(cfg, 2, ctx)
    snapdir = str(tmp_path / "chaos-snaps")
    resume, res, hops = None, None, 0
    while True:
        try:
            res = srv.serve(params, prompts, T, arrivals=arr,
                            faults=faults,
                            retry=RetryPolicy(max_attempts=2,
                                              backoff_base=2),
                            overload=OverloadPolicy(queue_cap=3,
                                                    shed="drop-oldest"),
                            snapshot=AsyncSnapshotter(snapdir, 2, keep=3),
                            resume_from=resume)
            break
        except ServePreempted:
            hops += 1
            assert hops <= 2, "preemption loop did not converge"
            resume = AsyncSnapshotter.latest(snapdir)[1]
    assert hops == 1 and res.resumed_from is not None

    buckets = _accounted(res, n)                   # the no-silent-loss gate
    assert buckets[1] != "completed"               # poisoned every step
    assert res.attempts.get(1, 0) >= 1
    rep = tau_report(res.schedule, "pure", concurrency=2,
                     scenario_spec="chaos", evictions=res.evictions,
                     timeouts=res.timeouts, shed=res.shed,
                     drained=res.drained, attempts=res.attempts)
    deg = rep["degraded"]
    n_degraded = sum(1 for v in buckets.values() if v != "completed")
    assert (len(deg["evictions"]) + len(deg["timeouts"])
            + len(deg["shed"]) + len(deg["drained"])) == n_degraded
    assert render_report(rep)                      # renders without error
