"""Composable world transforms — the vocabulary of non-stationary worlds.

A :class:`WorldTransform` modulates ONE aspect of a stationary
(scheduler × timing) world, keyed on the server ROUND index (the natural
clock of Algorithm 1: one aggregated update per ``wait_b`` receipts):

* timing-side (``modulates_speed``) — a multiplicative factor on the
  per-worker speed parameter ``s_i`` at the round a job *starts*
  (:class:`SpeedDrift`, :class:`Straggler`),
* membership-side — a per-round 0/1 availability table consumed both by
  the scheduler wrapper (no new jobs for down workers) and by the plan
  lowering (mask rows of down workers zeroed — the hard-drop channel)
  (:class:`ElasticWorkers`),
* data-side — a per-round Zipf exponent trajectory fed into the
  ``repro.data`` group distributions (:class:`DataDrift`),
* update-side — a per-round gradient keep-density in (0, 1] applied as
  magnitude top-k sparsification before the server update, the staleness
  remedy of Candela et al. (arXiv:1910.09466)
  (:class:`SparsifiedGrads`).

Every transform is deterministic given the realisation seed: `prepare`
receives a dedicated ``np.random.Generator`` (seeded per (scenario seed,
transform position)), precomputes its whole trajectory for the run, and the
query methods are pure table lookups.  An :class:`Identity` transform (and
any transform at neutral parameters) leaves the wrapped world bit-for-bit
identical to the unwrapped one.
"""
from __future__ import annotations

import numpy as np


class WorldTransform:
    """Base transform: neutral in every channel."""

    name = "base"
    #: True when the transform modulates per-worker compute speeds (the
    #: timing wrapper only consults these — cheap per-sample path)
    modulates_speed = False

    def prepare(self, n: int, rounds: int, rng: np.random.Generator) -> None:
        """Precompute trajectories for a run of ``rounds`` server rounds
        over ``n`` workers.  Called once per realisation."""

    # ---- timing channel ----------------------------------------------------
    def speed_factors(self, workers: np.ndarray, round_idx: int) -> np.ndarray:
        """(len(workers),) multiplicative factors on s_i at ``round_idx``
        (larger s = slower worker, so a factor > 1 is a slowdown)."""
        return np.ones(len(workers), dtype=np.float64)

    # ---- membership channel ------------------------------------------------
    def availability(self) -> np.ndarray | None:
        """(rounds, n) 0/1 table, or None when the transform never drops
        anyone."""
        return None

    # ---- data channel ------------------------------------------------------
    def zipf_trajectory(self) -> np.ndarray | None:
        """(rounds,) Zipf exponents, or None when the data law is static."""
        return None

    # ---- update channel ----------------------------------------------------
    def grad_density(self, schedule) -> np.ndarray | None:
        """(rounds,) keep-densities in (0, 1], or None.  Receives the
        REALISED schedule so densities can key on actual delays."""
        return None

    # ---- fault channels (repro.faults.transforms) --------------------------
    def fault_gain(self) -> np.ndarray | None:
        """(rounds, n) multiplicative gains on per-worker loss weights
        (NaN = poisoned receipt), or None when the transform injects no
        gradient faults."""
        return None

    def preempt_rounds(self) -> np.ndarray | None:
        """(k,) round indices at which the DRIVER process is scheduled to
        be preempted (host-level metadata, never lowered to device), or
        None."""
        return None

    # ---- serving channels (repro.faults.transforms) ------------------------
    # For serve-lane transforms ``prepare(n, rounds, rng)`` receives
    # n = n_requests and rounds = the decode-step horizon: the serving
    # clock is decode steps, not server rounds.
    def serve_poisons(self) -> np.ndarray | None:
        """(m, 2) int (rid, decode-step) cells whose decode logits the
        slot server poisons to NaN (driving the quarantine path), or None
        when the transform injects no serve faults."""
        return None

    def serve_preempt_steps(self) -> np.ndarray | None:
        """(k,) decode-step boundaries at which the SERVE driver process
        is scheduled to be preempted (host-level metadata; the chaos
        harness kills/raises there and exercises snapshot resume), or
        None."""
        return None


class Identity(WorldTransform):
    """Explicit no-op — a wrapped world with only Identity transforms must
    reproduce the stationary world bit-for-bit (the acceptance gate for
    the whole scenario layer)."""

    name = "identity"


def _windows(rounds: int, every: int, span: int):
    """Recurring windows [j·every, j·every + span), j >= 1 — round 0 stays
    clean so every world starts from the stationary regime."""
    j = 1
    while j * every < rounds:
        lo = j * every
        yield lo, min(lo + span, rounds)
        j += 1


class SpeedDrift(WorldTransform):
    """Smooth per-worker speed trajectories:
    s_i(q) = s_i · (1 + amp·sin(2π(q/period + i/n))).

    Workers drift out of phase (phase offset i/n), so the *relative* speed
    ordering — what the realised delays depend on — keeps rotating: the
    slowest worker of round 0 is mid-pack half a period later.
    """

    name = "drift"
    modulates_speed = True

    def __init__(self, period: float = 64.0, amp: float = 0.5):
        if not 0.0 <= amp < 1.0:
            raise ValueError(f"drift amp must be in [0, 1) (got {amp})")
        if period <= 0:
            raise ValueError(f"drift period must be positive (got {period})")
        self.period = float(period)
        self.amp = float(amp)

    def prepare(self, n, rounds, rng):
        q = np.arange(rounds + 1, dtype=np.float64)[:, None]
        phase = np.arange(n, dtype=np.float64)[None, :] / max(n, 1)
        self._table = 1.0 + self.amp * np.sin(
            2.0 * np.pi * (q / self.period + phase))

    def speed_factors(self, workers, round_idx):
        r = min(round_idx, self._table.shape[0] - 1)
        return self._table[r, workers]


class Straggler(WorldTransform):
    """Transient correlated slowdowns: every ``every`` rounds, ``k``
    workers (chosen per window from the realisation RNG) run ``factor``×
    slower for ``span`` rounds — the "one rack is thermally throttling"
    regime where τ_max decouples from τ_C."""

    name = "straggler"
    modulates_speed = True

    def __init__(self, k: int = 1, factor: float = 8.0, every: int = 16,
                 span: int = 4):
        if k < 1 or every < 1 or span < 1:
            raise ValueError("straggler k/every/span must be >= 1")
        if factor <= 0:
            raise ValueError(f"straggler factor must be positive (got {factor})")
        self.k = int(k)
        self.factor = float(factor)
        self.every = int(every)
        self.span = int(span)

    def prepare(self, n, rounds, rng):
        table = np.ones((rounds + 1, n), dtype=np.float64)
        k = min(self.k, n)
        for lo, hi in _windows(rounds + 1, self.every, self.span):
            hit = rng.choice(n, size=k, replace=False)
            table[lo:hi, hit] *= self.factor
        self._table = table

    def speed_factors(self, workers, round_idx):
        r = min(round_idx, self._table.shape[0] - 1)
        return self._table[r, workers]


class ElasticWorkers(WorldTransform):
    """Dropout/rejoin: every ``every`` rounds, ``k`` workers leave the pool
    for ``span`` rounds, then rejoin — n changes mid-run (the genuine
    extension beyond the paper).  Down workers receive no new jobs (the
    scheduler wrapper remaps their assignments onto available workers) and
    their residual in-flight receipts are hard-dropped on the compiled
    path (mask row zeroed via the plan's availability channel)."""

    name = "elastic"

    def __init__(self, k: int = 1, every: int = 16, span: int = 4):
        if k < 1 or every < 1 or span < 1:
            raise ValueError("elastic k/every/span must be >= 1")
        self.k = int(k)
        self.every = int(every)
        self.span = int(span)

    def prepare(self, n, rounds, rng):
        avail = np.ones((max(rounds, 1), n), dtype=np.float32)
        k = min(self.k, max(n - 1, 1))      # never drop the whole pool
        for lo, hi in _windows(max(rounds, 1), self.every, self.span):
            down = rng.choice(n, size=k, replace=False)
            avail[lo:hi, down] = 0.0
        self._avail = avail

    def availability(self):
        return self._avail


class DataDrift(WorldTransform):
    """Non-stationary data: the Zipf exponent of the group token
    distributions follows a trajectory — a linear ramp a0 → a1 over the
    run, or (with ``period``) a sinusoid oscillating between them.  The
    trajectory is quantised into a small CDF bank at plan-lowering time,
    so the compiled executor pays one extra gather per round."""

    name = "data_drift"

    def __init__(self, a0: float = 1.2, a1: float = 2.0,
                 period: float = 0.0):
        if a0 <= 0 or a1 <= 0:
            raise ValueError("data_drift exponents must be positive")
        self.a0 = float(a0)
        self.a1 = float(a1)
        self.period = float(period)

    def prepare(self, n, rounds, rng):
        q = np.arange(max(rounds, 1), dtype=np.float64)
        if self.period > 0:
            ramp = 0.5 * (1.0 - np.cos(2.0 * np.pi * q / self.period))
        else:
            ramp = q / max(rounds - 1, 1)
        self._traj = self.a0 + (self.a1 - self.a0) * ramp

    def zipf_trajectory(self):
        return self._traj


class SparsifiedGrads(WorldTransform):
    """Top-k gradient sparsification as a staleness remedy (Candela et
    al., arXiv:1910.09466): per round, only the largest-magnitude
    ``density`` fraction of each gradient leaf survives into the server
    update.  ``adaptive=1`` keys the density on the realised per-round
    mean delay — sparsify harder when staler,
    density_q = clip(1/(1+τ̄_q), frac, 1) — which is the remedy coupling
    the paper's τ-statistics make measurable."""

    name = "sparsify"

    def __init__(self, frac: float = 0.5, adaptive: int = 0):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"sparsify frac must be in (0, 1] (got {frac})")
        self.frac = float(frac)
        self.adaptive = bool(adaptive)

    def prepare(self, n, rounds, rng):
        self._rounds = max(rounds, 1)

    def grad_density(self, schedule):
        rounds = self._rounds
        if not self.adaptive:
            return np.full(rounds, self.frac, dtype=np.float32)
        b = schedule.wait_b
        n_full = min(rounds, schedule.T // b)
        d = schedule.delays[:n_full * b].astype(np.float64)
        tau = np.zeros(rounds, dtype=np.float64)
        tau[:n_full] = d.reshape(n_full, b).mean(axis=1)
        return np.clip(1.0 / (1.0 + tau), self.frac, 1.0).astype(np.float32)


#: spec-string name → transform class (the grammar's vocabulary)
TRANSFORMS = {
    cls.name: cls
    for cls in (Identity, SpeedDrift, Straggler, ElasticWorkers, DataDrift,
                SparsifiedGrads)
}
