"""``repro.runtime`` — compiled whole-run execution.

Two layers:

* :mod:`~repro.runtime.plan` lowers a realised schedule + train job into a
  device-resident :class:`RunPlan` (stacked round masks, per-round delay
  scales, folded per-round PRNG data keys, static batch-synthesis tables),
* :mod:`~repro.runtime.executor` replays the plan — ``runtime="scan"``
  runs K rounds per XLA launch with ``jax.lax.scan`` (one host sync per
  chunk), ``runtime="eager"`` is the one-launch-per-round parity oracle.

``TrainerBackend`` drives both through :func:`execute`; they are also
usable directly against any ``AsyncTrainer``::

    plan = compile_plan(schedule, job, rounds=T, n_groups=n, seed=0)
    res = execute(trainer, plan, trainer.init_state(key),
                  runtime="scan", rounds_per_launch=16)
"""
from .plan import RunPlan, compile_plan, fold_data_keys
from .executor import (METRICS, RUNTIMES, ExecResult, PlanExecutor, execute,
                       make_batch_fn, run_eager, run_scan)

__all__ = [
    "RunPlan", "compile_plan", "fold_data_keys",
    "METRICS", "RUNTIMES", "ExecResult", "PlanExecutor", "execute",
    "make_batch_fn", "run_eager", "run_scan",
]
