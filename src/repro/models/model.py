"""Model zoo composition: param specs, train forward, prefill, decode.

One code path per family (dense / moe / ssm / hybrid / audio / vlm), all
built from ``layers.py`` blocks, all scan-over-layers (stacked weights) so
the lowered HLO stays compact at 64–81 layers.

Conventions
-----------
* params are a nested dict of arrays; the same tree of :class:`Spec`
  (``param_specs``) carries shapes + logical sharding axes.
* ``batch`` is a dict: tokens (B,S) int32 [+ patches (B,P,dv) for vlm,
  frames (B,S,fd) for audio].
* decode uses ring-buffer KV caches (window = sliding_window or context
  length) and O(1) SSM states; ``cache_specs`` declares the cache tree.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from .specs import Spec, init_tree, abstract_tree, axes_tree, count_params
from . import layers as L


# ============================================================================
# parameter specs
# ============================================================================

def _attn_specs(cfg: ArchConfig, stacked: Optional[int]):
    pre = (stacked,) if stacked else ()
    ax = ("layers",) if stacked else ()
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s = {
        "norm": Spec(pre + (d,), ax + ("embed",), "ones"),
        "wq": Spec(pre + (d, H, Dh), ax + ("embed", "heads", "head"), "fan_in"),
        "wk": Spec(pre + (d, KV, Dh), ax + ("embed", "kv_heads", "head"), "fan_in"),
        "wv": Spec(pre + (d, KV, Dh), ax + ("embed", "kv_heads", "head"), "fan_in"),
        "wo": Spec(pre + (H, Dh, d), ax + ("heads", "head", "embed"), "fan_in"),
    }
    if cfg.qkv_bias:
        s["bq"] = Spec(pre + (H, Dh), ax + ("heads", "head"), "zeros")
        s["bk"] = Spec(pre + (KV, Dh), ax + ("kv_heads", "head"), "zeros")
        s["bv"] = Spec(pre + (KV, Dh), ax + ("kv_heads", "head"), "zeros")
    if cfg.qk_norm:
        s["q_norm"] = Spec(pre + (Dh,), ax + ("head",), "ones")
        s["k_norm"] = Spec(pre + (Dh,), ax + ("head",), "ones")
    return s


def _mlp_specs(cfg: ArchConfig, stacked: Optional[int], ff: int):
    pre = (stacked,) if stacked else ()
    ax = ("layers",) if stacked else ()
    d = cfg.d_model
    return {
        "norm": Spec(pre + (d,), ax + ("embed",), "ones"),
        "w_gate": Spec(pre + (d, ff), ax + ("embed", "ff"), "fan_in"),
        "w_up": Spec(pre + (d, ff), ax + ("embed", "ff"), "fan_in"),
        "w_down": Spec(pre + (ff, d), ax + ("ff", "embed"), "fan_in"),
    }


def _moe_specs(cfg: ArchConfig, stacked: int):
    pre, ax = (stacked,), ("layers",)
    d, E, fe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    s = {
        "norm": Spec(pre + (d,), ax + ("embed",), "ones"),
        "router": Spec(pre + (d, E), ax + ("embed", "experts"), "fan_in",
                       dtype="float32"),
        "w_gate": Spec(pre + (E, d, fe), ax + ("experts", "embed", "ff"), "fan_in"),
        "w_up": Spec(pre + (E, d, fe), ax + ("experts", "embed", "ff"), "fan_in"),
        "w_down": Spec(pre + (E, fe, d), ax + ("experts", "ff", "embed"), "fan_in"),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * cfg.moe_d_ff
        s["shared"] = _mlp_specs(cfg, stacked, fs)
        del s["shared"]["norm"]  # shares the moe norm
    return s


def _mamba_specs(cfg: ArchConfig, stacked: Optional[int]):
    pre = (stacked,) if stacked else ()
    ax = ("layers",) if stacked else ()
    d, di, N, Hs, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.ssm_heads, cfg.ssm_conv)
    conv_dim = di + 2 * N
    return {
        "norm": Spec(pre + (d,), ax + ("embed",), "ones"),
        "in_z": Spec(pre + (d, di), ax + ("embed", "d_inner"), "fan_in"),
        "in_x": Spec(pre + (d, di), ax + ("embed", "d_inner"), "fan_in"),
        "in_B": Spec(pre + (d, N), ax + ("embed", "state"), "fan_in"),
        "in_C": Spec(pre + (d, N), ax + ("embed", "state"), "fan_in"),
        "in_dt": Spec(pre + (d, Hs), ax + ("embed", "ssm_heads"), "fan_in"),
        "conv_w": Spec(pre + (K, conv_dim), ax + ("conv", "d_inner"), "fan_in"),
        "conv_b": Spec(pre + (conv_dim,), ax + ("d_inner",), "zeros"),
        "A_log": Spec(pre + (Hs,), ax + ("ssm_heads",), "mamba_A", dtype="float32"),
        "D": Spec(pre + (Hs,), ax + ("ssm_heads",), "ones", dtype="float32"),
        "dt_bias": Spec(pre + (Hs,), ax + ("ssm_heads",), "mamba_dt", dtype="float32"),
        "gate_norm": Spec(pre + (di,), ax + ("d_inner",), "ones"),
        "out_proj": Spec(pre + (di, d), ax + ("d_inner", "embed"), "fan_in"),
    }


def param_specs(cfg: ArchConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab
    specs: dict = {
        "embed": Spec((V, d), ("vocab", "embed"), "normal"),
        "final_norm": Spec((d,), ("embed",), "ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = Spec((d, V), ("embed", "vocab"), "fan_in")

    nl = cfg.n_layers
    if cfg.family in ("dense", "vlm"):
        specs["blocks"] = {"attn": _attn_specs(cfg, nl),
                           "mlp": _mlp_specs(cfg, nl, cfg.d_ff)}
    elif cfg.family == "moe":
        specs["blocks"] = {"attn": _attn_specs(cfg, nl),
                           "moe": _moe_specs(cfg, nl)}
    elif cfg.family == "ssm":
        specs["blocks"] = {"mamba": _mamba_specs(cfg, nl)}
    elif cfg.family == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers - g * cfg.attn_every
        specs["blocks"] = {"mamba": _mamba_specs(cfg, g * cfg.attn_every)}
        if rem:
            specs["tail"] = {"mamba": _mamba_specs(cfg, rem)}
        specs["shared_attn"] = _attn_specs(cfg, None)
        specs["shared_mlp"] = _mlp_specs(cfg, None, cfg.d_ff)
    elif cfg.family == "audio":
        specs["frontend_proj"] = Spec((cfg.frontend_dim, d), (None, "embed"), "fan_in")
        specs["enc_blocks"] = {"attn": _attn_specs(cfg, cfg.enc_layers),
                               "mlp": _mlp_specs(cfg, cfg.enc_layers, cfg.d_ff)}
        specs["enc_norm"] = Spec((d,), ("embed",), "ones")
        specs["blocks"] = {"attn": _attn_specs(cfg, nl),
                           "cross": _attn_specs(cfg, nl),
                           "mlp": _mlp_specs(cfg, nl, cfg.d_ff)}
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm":
        specs["projector"] = Spec((cfg.vision_dim, d), (None, "embed"), "fan_in")
    return specs


def init_params(cfg: ArchConfig, key) -> dict:
    return init_tree(param_specs(cfg), key)


def n_params(cfg: ArchConfig) -> int:
    return count_params(param_specs(cfg))


def n_active_params(cfg: ArchConfig) -> int:
    """Active parameters per token (MoE counts top_k + shared experts)."""
    if cfg.family != "moe":
        return n_params(cfg)
    total = count_params(param_specs(cfg))
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_layers
    inactive = (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


# ============================================================================
# block applications (sequence / train)
# ============================================================================

def _apply_attn(cfg, p, h, *, causal=True, positions=None, kv_h=None,
                window=None, return_kv=False):
    """Standard pre-norm attention block.  kv_h: cross-attention memory."""
    x = L.rms_norm(h, p["norm"], cfg.norm_eps)
    src = x if kv_h is None else kv_h
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    if kv_h is None and positions is not None:       # rope only on self-attn
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    if cfg.use_flash_attention:
        from ..kernels.ops import flash_attention
        o = flash_attention(q, k, v, causal=causal and kv_h is None,
                            window=window)
    else:
        o = L.attention(q, k, v, causal=causal and kv_h is None, window=window)
    out = h + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if return_kv:
        return out, (k, v)
    return out


def _apply_mlp(cfg, p, h):
    x = L.rms_norm(h, p["norm"], cfg.norm_eps)
    return h + L.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


def _apply_moe(cfg, p, h):
    x = L.rms_norm(h, p["norm"], cfg.norm_eps)
    y, aux = L.moe_ffn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"],
                       cfg.top_k, cfg.capacity_factor)
    if "shared" in p:
        sp = p["shared"]
        y = y + L.swiglu(x, sp["w_gate"], sp["w_up"], sp["w_down"])
    return h + y, aux


def _mamba_inner(cfg, p, x_n):
    """Projections + conv for a normalised input (B,S,d) → ssd operands."""
    di, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = jnp.einsum("bsd,de->bse", x_n, p["in_z"])
    xi = jnp.einsum("bsd,de->bse", x_n, p["in_x"])
    Bp = jnp.einsum("bsd,dn->bsn", x_n, p["in_B"])
    Cp = jnp.einsum("bsd,dn->bsn", x_n, p["in_C"])
    dt = jnp.einsum("bsd,dh->bsh", x_n, p["in_dt"])
    return z, xi, Bp, Cp, dt


def _apply_mamba(cfg, p, h, return_state=False):
    B, S, d = h.shape
    di, N, Hs, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    x_n = L.rms_norm(h, p["norm"], cfg.norm_eps)
    z, xi, Bp, Cp, dt = _mamba_inner(cfg, p, x_n)
    conv_in = jnp.concatenate([xi, Bp, Cp], axis=-1)
    conv_out = L.causal_conv1d(conv_in, p["conv_w"], p["conv_b"])
    xi, Bp, Cp = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = _shard_act(xi.reshape(B, S, Hs, P), ("batch", "seq", "ssm_heads", None))
    dt = _shard_act(dt, ("batch", "seq", "ssm_heads"))
    y, hT = L.ssd_chunked(xh, dt, A, Bp, Cp, chunk=min(cfg.ssm_chunk, S),
                          use_kernel=cfg.use_ssd_kernel)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(h.dtype)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype),
                   p["gate_norm"], cfg.norm_eps)
    out = h + jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_state:
        K = cfg.ssm_conv
        conv_state = conv_in[:, S - (K - 1):, :]
        return out, (conv_state, hT)
    return out


# ============================================================================
# stacks
# ============================================================================

def _shard_act(x, axes=None):
    """Constrain an activation's sharding (no-op outside a mesh context).

    Rank≥3 activations are named (batch, seq, ...) so a Rules variant with
    "seq" in model_priority turns on sequence parallelism (a §Perf lever);
    under the default rules "seq" maps to None — identical behaviour."""
    from ..distributed.sharding import shard_activation
    if axes is None:
        if x.ndim == 3:
            # "seq"/"act_embed" are inert under default rules (not in
            # model_priority); Rules variants opt in to sequence parallelism
            # or Megatron-style embed-sharded residuals
            axes = ("batch", "seq", "act_embed")
        elif x.ndim > 3:
            axes = ("batch", "seq") + (None,) * (x.ndim - 2)
        else:
            axes = ("batch",) + (None,) * (x.ndim - 1)
    return shard_activation(x, axes)


def _constrain_carry(out):
    """Re-pin batch sharding on rank≥2 float carries (scan drops it)."""
    def f(x):
        if hasattr(x, "ndim") and x.ndim >= 2 and jnp.issubdtype(x.dtype, jnp.floating):
            return _shard_act(x)
        return x
    return jax.tree_util.tree_map(f, out)


def _scan(fn, stacked_params, h, remat: bool):
    body = jax.checkpoint(fn) if remat else fn

    def step(carry, p):
        return _constrain_carry(body(p, carry)), None

    h, _ = jax.lax.scan(step, h, stacked_params)
    return h


def _decoder_stack(cfg, params, h, positions, *, window=None, memory=None):
    remat = cfg.remat == "full"
    blocks = params["blocks"]
    if cfg.family in ("dense", "vlm"):
        def f(p, x):
            x = _apply_attn(cfg, p["attn"], x, positions=positions, window=window)
            return _apply_mlp(cfg, p["mlp"], x)
        return _scan(f, blocks, h, remat), 0.0
    if cfg.family == "moe":
        def f(p, carry):
            x, aux = carry
            x = _apply_attn(cfg, p["attn"], x, positions=positions, window=window)
            x, a = _apply_moe(cfg, p["moe"], x)
            return (x, aux + a)
        (h, aux) = _scan(f, blocks, (h, jnp.zeros((), jnp.float32)), remat)
        return h, aux / cfg.n_layers
    if cfg.family == "ssm":
        def f(p, x):
            return _apply_mamba(cfg, p["mamba"], x)
        return _scan(f, blocks, h, remat), 0.0
    if cfg.family == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        k = cfg.attn_every
        sa, sm = params["shared_attn"], params["shared_mlp"]
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((g, k) + a.shape[1:]), blocks["mamba"])

        def group(pg, x):
            x = _apply_attn(cfg, sa, x, positions=positions, window=window)
            x = _apply_mlp(cfg, sm, x)

            def inner(pl, y):
                return _apply_mamba(cfg, pl, y)
            # remat the inner layers too: without it each group's backward
            # stores 6 layers of f32 SSD intermediates (~30 GB/dev at 7B)
            return _scan(inner, pg, x, remat)

        f = jax.checkpoint(group) if remat else group
        h, _ = jax.lax.scan(lambda c, p: (f(p, c), None), h, grouped)
        if "tail" in params:
            def inner(pl, y):
                return _apply_mamba(cfg, pl, y)
            h = _scan(inner, params["tail"]["mamba"], h, remat)
        return h, 0.0
    if cfg.family == "audio":
        def f(p, x):
            x = _apply_attn(cfg, p["attn"], x, positions=positions, window=window)
            x = _apply_attn(cfg, p["cross"], x, kv_h=memory)
            return _apply_mlp(cfg, p["mlp"], x)
        return _scan(f, blocks, h, remat), 0.0
    raise ValueError(cfg.family)


def _encoder_stack(cfg, params, frames):
    """Bidirectional encoder over stubbed frame embeddings (audio)."""
    h = jnp.einsum("bsf,fd->bsd", frames.astype(jnp.dtype(cfg.dtype)),
                   params["frontend_proj"])
    positions = jnp.arange(h.shape[1])
    remat = cfg.remat == "full"

    def f(p, x):
        x = _apply_attn(cfg, p["attn"], x, causal=False, positions=positions)
        return _apply_mlp(cfg, p["mlp"], x)

    h = _scan(f, params["enc_blocks"], h, remat)
    return L.rms_norm(h, params["enc_norm"], cfg.norm_eps)


# ============================================================================
# train / prefill forwards
# ============================================================================

def _embed(cfg, params, tokens):
    return jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))


def _unembed(cfg, params, h):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return _shard_act(logits, ("batch", "seq", "vocab"))


def _embed_input(cfg: ArchConfig, params, batch):
    """Shared train/prefill input embedding → (h, cross-attn memory|None)."""
    memory = None
    if cfg.family == "audio":
        memory = _encoder_stack(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    h = _embed(cfg, params, tokens)
    if cfg.family == "vlm":
        patches = jnp.einsum(
            "bpv,vd->bpd", batch["patches"].astype(jnp.dtype(cfg.dtype)),
            params["projector"])
        h = jnp.concatenate([patches, h[:, patches.shape[1]:]], axis=1)
    return _shard_act(h), memory


def forward_logits(cfg: ArchConfig, params, batch, window=None):
    """Full-sequence forward → (logits, aux_loss)."""
    if window is None:
        window = cfg.sliding_window
    h, memory = _embed_input(cfg, params, batch)
    positions = jnp.arange(batch["tokens"].shape[1])
    h, aux = _decoder_stack(cfg, params, h, positions, window=window,
                            memory=memory)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _unembed(cfg, params, h), aux


def loss_fn(cfg: ArchConfig, params, batch, example_weights=None,
            aux_coeff: float = 0.01, window=None):
    """Next-token CE (+ MoE aux).  ``example_weights`` (B,) implements the
    AsGrad worker-participation mask (see distributed.async_trainer)."""
    logits, aux = forward_logits(cfg, params, batch, window=window)
    labels = batch["tokens"][:, 1:]
    lg = logits[:, :-1]
    mask = jnp.ones(labels.shape, jnp.float32)
    if example_weights is not None:
        mask = mask * example_weights[:, None]
    ce = L.softmax_xent(lg, labels, mask)
    return ce + aux_coeff * aux, {"ce": ce, "aux": aux}


# ============================================================================
# prefill: forward + cache emission (feeds decode)
# ============================================================================

def _ring_from_seq(k_seq, v_seq, W: int):
    """(L,B,S,KV,D) stacked per-layer k/v → ring cache of the last W tokens,
    placed at slot = pos mod W, plus the positions buffer."""
    S = k_seq.shape[2]
    take = min(W, S)
    pos = jnp.arange(S - take, S)
    slots = jnp.mod(pos, W)
    kc = jnp.zeros(k_seq.shape[:2] + (W,) + k_seq.shape[3:], k_seq.dtype)
    vc = jnp.zeros_like(kc)
    kc = kc.at[:, :, slots].set(k_seq[:, :, -take:])
    vc = vc.at[:, :, slots].set(v_seq[:, :, -take:])
    positions = jnp.full((W,), -1, jnp.int32).at[slots].set(pos.astype(jnp.int32))
    return kc, vc, positions


def prefill(cfg: ArchConfig, params, batch, ctx_len: Optional[int] = None):
    """Process the prompt, return (last-token logits (B,V), decode cache).

    The cache tree matches ``cache_specs(cfg, B, ctx_len)``; ctx_len defaults
    to the prompt length.
    """
    window = cfg.sliding_window
    tokens = batch["tokens"]
    Bsz, S = tokens.shape
    ctx = ctx_len or S
    W = min(cfg.sliding_window or ctx, ctx)
    h, memory = _embed_input(cfg, params, batch)
    positions = jnp.arange(S)
    cache: dict = {}
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        def f(x, p):
            if fam == "moe":
                x, kv = _apply_attn(cfg, p["attn"], x, positions=positions,
                                    window=window, return_kv=True)
                x, _ = _apply_moe(cfg, p["moe"], x)
            else:
                x, kv = _apply_attn(cfg, p["attn"], x, positions=positions,
                                    window=window, return_kv=True)
                x = _apply_mlp(cfg, p["mlp"], x)
            return x, kv

        h, (ks, vs) = jax.lax.scan(f, h, params["blocks"])
        kc, vc, posbuf = _ring_from_seq(ks, vs, W)
        cache = {"self": {"k": kc, "v": vc}, "positions": posbuf}
    elif fam == "ssm":
        def f(x, p):
            x, st = _apply_mamba(cfg, p["mamba"], x, return_state=True)
            return x, st

        h, (cs, ss) = jax.lax.scan(f, h, params["blocks"])
        cache = {"ssm": {"conv": cs, "ssd": ss}}
    elif fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        k = cfg.attn_every
        sa, sm = params["shared_attn"], params["shared_mlp"]
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((g, k) + a.shape[1:]), params["blocks"]["mamba"])

        def fg(x, pg):
            x, kv = _apply_attn(cfg, sa, x, positions=positions, window=window,
                                return_kv=True)
            x = _apply_mlp(cfg, sm, x)

            def fi(y, pl):
                y, st = _apply_mamba(cfg, pl, y, return_state=True)
                return y, st

            x, st = jax.lax.scan(fi, x, pg)
            return x, (kv, st)

        h, (kvs, sts) = jax.lax.scan(fg, h, grouped)
        kc, vc, posbuf = _ring_from_seq(kvs[0], kvs[1], W)
        cs, ss = sts
        cache = {
            "attn": {"k": kc, "v": vc},
            "positions": posbuf,
            "ssm": {"conv": cs.reshape((g * k,) + cs.shape[2:]),
                    "ssd": ss.reshape((g * k,) + ss.shape[2:])},
        }
        if "tail" in params:
            def fi(y, pl):
                y, st = _apply_mamba(cfg, pl, y, return_state=True)
                return y, st

            h, (cs2, ss2) = jax.lax.scan(fi, h, params["tail"]["mamba"])
            cache["ssm_tail"] = {"conv": cs2, "ssd": ss2}
    elif fam == "audio":
        def f(x, p):
            x, kv = _apply_attn(cfg, p["attn"], x, positions=positions,
                                window=window, return_kv=True)
            # cross k/v come from the (un-normed) encoder memory — the block
            # norm applies only to the decoder stream, matching _apply_attn
            ck = jnp.einsum("bsd,dhk->bshk", memory, p["cross"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", memory, p["cross"]["wv"])
            x = _apply_attn(cfg, p["cross"], x, kv_h=memory)
            x = _apply_mlp(cfg, p["mlp"], x)
            return x, (kv, (ck, cv))

        h, (kvs, crosses) = jax.lax.scan(f, h, params["blocks"])
        kc, vc, posbuf = _ring_from_seq(kvs[0], kvs[1], W)
        cache = {"self": {"k": kc, "v": vc}, "positions": posbuf,
                 "cross_k": crosses[0], "cross_v": crosses[1]}
    else:
        raise ValueError(fam)

    h = L.rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, h)[:, 0]
    return logits, cache


# ============================================================================
# decode (serve_step)
# ============================================================================

def cache_specs(cfg: ArchConfig, batch: int, ctx_len: int, *,
                ragged: bool = False) -> dict:
    """Cache tree as Specs (shapes + logical axes) — feeds input_specs().

    ``ragged=True`` declares the slot-server cache: the positions buffer
    grows a per-row batch axis ((batch, W) instead of the shared (W,)) so
    each slot tracks its own absolute position.  Every other leaf already
    carries a batch axis and is unchanged.
    """
    W = min(cfg.sliding_window or ctx_len, ctx_len)
    KV, Dh, nl = cfg.n_kv_heads, cfg.d_head, cfg.n_layers
    dt = cfg.dtype
    pos_spec = (Spec((batch, W), ("batch", "ctx"), "zeros", "int32")
                if ragged else Spec((W,), ("ctx",), "zeros", "int32"))

    def ring(lyrs):
        return {
            "k": Spec((lyrs, batch, W, KV, Dh),
                      ("layers", "batch", "ctx", "kv_heads", "head"), "zeros", dt),
            "v": Spec((lyrs, batch, W, KV, Dh),
                      ("layers", "batch", "ctx", "kv_heads", "head"), "zeros", dt),
        }

    def ssm_states(lyrs):
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        return {
            "conv": Spec((lyrs, batch, cfg.ssm_conv - 1, conv_dim),
                         ("layers", "batch", None, "d_inner"), "zeros", dt),
            "ssd": Spec((lyrs, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                        ("layers", "batch", "ssm_heads", None, None),
                        "zeros", "float32"),
        }

    c: dict = {}
    if cfg.family in ("dense", "vlm", "moe"):
        c["self"] = ring(nl)
        c["positions"] = pos_spec
    elif cfg.family == "ssm":
        c["ssm"] = ssm_states(nl)
    elif cfg.family == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers - g * cfg.attn_every
        c["ssm"] = ssm_states(g * cfg.attn_every)
        if rem:
            c["ssm_tail"] = ssm_states(rem)
        c["attn"] = ring(g)
        c["positions"] = pos_spec
    elif cfg.family == "audio":
        c["self"] = ring(nl)
        c["positions"] = pos_spec
        c["cross_k"] = Spec((nl, batch, ctx_len, KV, Dh),
                            ("layers", "batch", "ctx", "kv_heads", "head"),
                            "zeros", dt)
        c["cross_v"] = Spec((nl, batch, ctx_len, KV, Dh),
                            ("layers", "batch", "ctx", "kv_heads", "head"),
                            "zeros", dt)
    return c


def init_cache(cfg: ArchConfig, batch: int, ctx_len: int, *,
               ragged: bool = False) -> dict:
    tree = init_tree(cache_specs(cfg, batch, ctx_len, ragged=ragged),
                     jax.random.PRNGKey(0))
    if "positions" in tree:
        tree["positions"] = tree["positions"] - 1   # −1 = empty slot
    return tree


def _decode_attn(cfg, p, h, kc, vc, cache_positions, pos, window, slot):
    """One-token attention; returns (h', new_k_slice, new_v_slice).

    ``pos``/``slot`` scalar: lock-step decoding (all rows share one
    position).  ``pos``/``slot`` (B,): ragged decoding — each row carries
    its own position, writes its own ring slot, and ``cache_positions`` is
    the per-row (B, W) buffer.
    """
    x = L.rms_norm(h, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    ragged = jnp.ndim(pos) == 1
    posv = pos[:, None] if ragged else jnp.full((1,), pos)
    q = L.rope(q, posv, cfg.rope_theta)
    k = L.rope(k, posv, cfg.rope_theta)
    if ragged:
        rows = jnp.arange(kc.shape[0])
        kc = kc.at[rows, slot].set(k[:, 0])
        vc = vc.at[rows, slot].set(v[:, 0])
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
    o = L.decode_attention(q, kc, vc, cache_positions, pos, window=window)
    return h + jnp.einsum("bshk,hkd->bsd", o, p["wo"]), kc, vc


def _decode_cross(cfg, p, h, ck, cv):
    x = L.rms_norm(h, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    o = L.attention(q, ck, cv, causal=False)
    return h + jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _decode_mamba(cfg, p, h, conv_state, ssd_state):
    B = h.shape[0]
    di, N, Hs, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    x_n = L.rms_norm(h, p["norm"], cfg.norm_eps)
    z, xi, Bp, Cp, dt = _mamba_inner(cfg, p, x_n)
    conv_in = jnp.concatenate([xi, Bp, Cp], axis=-1)[:, 0]        # (B, conv_dim)
    y_conv, conv_state = L.conv1d_decode(conv_state, conv_in, p["conv_w"], p["conv_b"])
    xi, Bp, Cp = jnp.split(y_conv, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(B, Hs, P)
    y, ssd_state = L.ssd_decode_step(ssd_state, xh, dt, A, Bp, Cp)
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(h.dtype)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype),
                   p["gate_norm"], cfg.norm_eps)
    return h + jnp.einsum("bse,ed->bsd", y, p["out_proj"]), conv_state, ssd_state


def decode_step(cfg: ArchConfig, params, cache, tokens, pos, ctx_len: int):
    """serve_step: ONE new token per sequence against the cache.

    tokens: (B,) int32; pos: scalar int32 (current absolute position) for
    lock-step decoding, or (B,) int32 per-row positions for ragged
    (slot-server) decoding against a cache built with
    ``cache_specs(..., ragged=True)`` — the positions buffer is then
    (B, W) and every row writes its own ring slot.
    Returns (logits (B, V), new_cache).
    """
    W = min(cfg.sliding_window or ctx_len, ctx_len)
    window = cfg.sliding_window
    ragged = jnp.ndim(pos) == 1
    slot = jnp.mod(pos, W)
    h = _embed(cfg, params, tokens[:, None])          # (B,1,d)
    cache = dict(cache)

    if "positions" in cache:
        if ragged:
            rows = jnp.arange(tokens.shape[0])
            cache["positions"] = cache["positions"].at[rows, slot].set(
                pos.astype(cache["positions"].dtype))
        else:
            cache["positions"] = jax.lax.dynamic_update_index_in_dim(
                cache["positions"], pos.astype(cache["positions"].dtype),
                slot, axis=0)
        cpos = cache["positions"]

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        def f(x, inp):
            p, kc, vc = inp
            x, kc, vc = _decode_attn(cfg, p["attn"], x, kc, vc, cpos, pos,
                                     window, slot)
            if fam == "moe":
                x, _ = _apply_moe(cfg, p["moe"], x)
            else:
                x = _apply_mlp(cfg, p["mlp"], x)
            return x, (kc, vc)

        h, (ks, vs) = jax.lax.scan(
            f, h, (params["blocks"], cache["self"]["k"], cache["self"]["v"]))
        cache["self"] = {"k": ks, "v": vs}
    elif fam == "ssm":
        def f(x, inp):
            p, cs, ss = inp
            x, cs, ss = _decode_mamba(cfg, p["mamba"], x, cs, ss)
            return x, (cs, ss)

        h, (cs, ss) = jax.lax.scan(
            f, h, (params["blocks"], cache["ssm"]["conv"], cache["ssm"]["ssd"]))
        cache["ssm"] = {"conv": cs, "ssd": ss}
    elif fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        k = cfg.attn_every
        sa, sm = params["shared_attn"], params["shared_mlp"]
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((g, k) + a.shape[1:]), params["blocks"]["mamba"])
        conv_g = cache["ssm"]["conv"].reshape((g, k) + cache["ssm"]["conv"].shape[1:])
        ssd_g = cache["ssm"]["ssd"].reshape((g, k) + cache["ssm"]["ssd"].shape[1:])

        def fg(x, inp):
            pg, kc, vc, csg, ssg = inp
            x, kc, vc = _decode_attn(cfg, sa, x, kc, vc, cpos, pos, window, slot)
            x = _apply_mlp(cfg, sm, x)

            def fi(y, inner):
                pl, cs, ss = inner
                y, cs, ss = _decode_mamba(cfg, pl, y, cs, ss)
                return y, (cs, ss)

            x, (csg, ssg) = jax.lax.scan(fi, x, (pg, csg, ssg))
            return x, (kc, vc, csg, ssg)

        h, (ks, vs, cs, ss) = jax.lax.scan(
            fg, h, (grouped, cache["attn"]["k"], cache["attn"]["v"], conv_g, ssd_g))
        cache["attn"] = {"k": ks, "v": vs}
        cache["ssm"] = {"conv": cs.reshape(cache["ssm"]["conv"].shape),
                        "ssd": ss.reshape(cache["ssm"]["ssd"].shape)}
        if "ssm_tail" in cache:
            def fi(y, inner):
                pl, cs2, ss2 = inner
                y, cs2, ss2 = _decode_mamba(cfg, pl, y, cs2, ss2)
                return y, (cs2, ss2)

            h, (cs2, ss2) = jax.lax.scan(
                fi, h, (params["tail"]["mamba"], cache["ssm_tail"]["conv"],
                        cache["ssm_tail"]["ssd"]))
            cache["ssm_tail"] = {"conv": cs2, "ssd": ss2}
    elif fam == "audio":
        def f(x, inp):
            p, kc, vc, ck, cv = inp
            x, kc, vc = _decode_attn(cfg, p["attn"], x, kc, vc, cpos, pos,
                                     window, slot)
            x = _decode_cross(cfg, p["cross"], x, ck, cv)
            x = _apply_mlp(cfg, p["mlp"], x)
            return x, (kc, vc)

        h, (ks, vs) = jax.lax.scan(
            f, h, (params["blocks"], cache["self"]["k"], cache["self"]["v"],
                   cache["cross_k"], cache["cross_v"]))
        cache["self"] = {"k": ks, "v": vs}
    else:
        raise ValueError(fam)

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, h)[:, 0]
    return logits, cache


# ============================================================================
# batch specs (what input_specs() builds on)
# ============================================================================

def batch_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """Train/prefill batch as Specs (logical axes drive sharding)."""
    s: dict = {}
    if cfg.family == "audio":
        s["frames"] = Spec((batch, seq, cfg.frontend_dim),
                           ("batch", "seq", None), "normal", "float32")
        s["tokens"] = Spec((batch, max(seq // cfg.dec_ratio, 8)),
                           ("batch", "seq"), "zeros", "int32")
    else:
        s["tokens"] = Spec((batch, seq), ("batch", "seq"), "zeros", "int32")
        if cfg.family == "vlm":
            npatch = min(cfg.n_patches, max(seq // 4, 4))
            s["patches"] = Spec((batch, npatch, cfg.vision_dim),
                                ("batch", "seq", None), "normal", "float32")
    return s
