"""Unit coverage for the benchmarks/check_perf.py CI gate.

The regression under test: a baseline row carrying ``grid_speedup`` whose
*current* row lacks the field used to read ``cur.get("grid_speedup",
0.0)`` and fail with a bogus ``0.000 < floor`` REGRESSION verdict — the
failure message must say the FIELD is missing, not that throughput
dropped to zero.  Plus the ``serve_slots`` kind's compare path and the
kind-dispatch rules.
"""
import importlib.util
import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCRIPT = ROOT / "benchmarks" / "check_perf.py"

_spec = importlib.util.spec_from_file_location("check_perf", SCRIPT)
check_perf = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_perf)


def _runtime_payload(*, grid_speedup=None, rounds_per_s=100.0):
    entry = {"runtime": "scan", "metrics": "chunk", "rounds_per_launch": 8,
             "rounds_per_s": rounds_per_s}
    if grid_speedup is not None:
        entry["grid_speedup"] = grid_speedup
    return {"bench": "runtime_dispatch_ab",
            "entries": [{"runtime": "eager", "metrics": "chunk",
                         "rounds_per_launch": 1, "rounds_per_s": 50.0},
                        entry]}


def _serve_payload(*, tok_per_s=40.0, occupancy=0.9, lock=100.0):
    return {"bench": "serve_slots",
            "entries": [{"mode": "lockstep", "tok_per_s": lock},
                        {"mode": "rotating", "n_slots": 2,
                         "admission": "pure", "tok_per_s": tok_per_s,
                         "occupancy": occupancy}]}


# ---------------------------------------------------------------------------
# the missing-field regression (satellite bugfix)
# ---------------------------------------------------------------------------

def test_missing_grid_speedup_reports_missing_not_zero(capsys):
    base = _runtime_payload(grid_speedup=3.0)
    cur = _runtime_payload()                 # field vanished from current
    failures = check_perf.check_runtime(cur, base, tolerance=0.3)
    assert len(failures) == 1
    assert "lacks the field" in failures[0]
    # the old bug compared 0.0 against the floor and printed "0.000 <"
    assert "0.000" not in failures[0]
    assert "MISSING" in capsys.readouterr().out


def test_present_grid_speedup_still_gated():
    base = _runtime_payload(grid_speedup=3.0)
    ok = check_perf.check_runtime(_runtime_payload(grid_speedup=2.9),
                                  base, tolerance=0.3)
    assert ok == []
    bad = check_perf.check_runtime(_runtime_payload(grid_speedup=1.0),
                                   base, tolerance=0.3)
    assert len(bad) == 1 and "grid_speedup" in bad[0]


def test_rows_returns_rows_and_eager_tuple():
    rows, eager = check_perf._rows(_runtime_payload())
    assert eager == 50.0
    assert ("scan", "chunk", 8) in rows


# ---------------------------------------------------------------------------
# the serve_slots kind
# ---------------------------------------------------------------------------

def test_serve_kind_passes_identical_payloads():
    assert check_perf.check_serve(_serve_payload(), _serve_payload(),
                                  tolerance=0.3) == []


def test_serve_kind_normalises_by_lockstep_row():
    base = _serve_payload(tok_per_s=40.0, lock=100.0)
    # half the absolute speed but the same RATIO: a slower machine, not a
    # regression
    cur = _serve_payload(tok_per_s=20.0, lock=50.0)
    assert check_perf.check_serve(cur, base, tolerance=0.3) == []
    # ratio collapse IS a regression
    bad = _serve_payload(tok_per_s=10.0, lock=100.0)
    fails = check_perf.check_serve(bad, base, tolerance=0.3)
    assert len(fails) == 1 and "tok/s" in fails[0]


def test_serve_kind_gates_occupancy_and_missing_fields():
    base = _serve_payload(occupancy=0.9)
    fails = check_perf.check_serve(_serve_payload(occupancy=0.3), base,
                                   tolerance=0.3)
    assert len(fails) == 1 and "occupancy" in fails[0]
    cur = _serve_payload()
    del cur["entries"][1]["occupancy"]
    fails = check_perf.check_serve(cur, base, tolerance=0.3)
    assert len(fails) == 1 and "lacks the field" in fails[0]


# ---------------------------------------------------------------------------
# the obs kind (absolute ceiling, like faults)
# ---------------------------------------------------------------------------

def _obs_payload(*, ratio=0.99, trace=True, metrics=True, taps=True):
    return {"bench": "obs", "overhead_ratio": ratio, "trace_valid": trace,
            "metrics_valid": metrics, "tap_events_match": taps}


def test_obs_kind_gates_absolute_ceiling():
    base = _obs_payload(ratio=0.99)
    assert check_perf.check_obs(_obs_payload(ratio=0.97), base,
                                tolerance=0.05) == []
    fails = check_perf.check_obs(_obs_payload(ratio=0.90), base,
                                 tolerance=0.05)
    assert len(fails) == 1 and "overhead_ratio" in fails[0]
    # the ceiling is absolute: a degraded committed baseline must NOT
    # grandfather a current ratio below 1 - tolerance
    fails = check_perf.check_obs(_obs_payload(ratio=0.90),
                                 _obs_payload(ratio=0.89), tolerance=0.05)
    assert len(fails) == 1


def test_obs_kind_gates_structural_flags():
    base = _obs_payload()
    for kw, name in ((dict(trace=False), "trace_valid"),
                     (dict(metrics=False), "metrics_valid"),
                     (dict(taps=False), "tap_events_match")):
        fails = check_perf.check_obs(_obs_payload(**kw), base,
                                     tolerance=0.05)
        assert len(fails) == 1 and name in fails[0]


def test_obs_kind_reports_payload_shape_change_with_file_name():
    fails = check_perf.check_obs({"bench": "obs"}, _obs_payload(),
                                 tolerance=0.05,
                                 paths=("cur_obs.json", "base_obs.json"))
    # the missing ratio no longer short-circuits: the flag rows (also
    # failing on an empty payload) are reported alongside it
    assert len(fails) == 4
    assert "overhead_ratio" in fails[0] and "cur_obs.json" in fails[0]
    assert any("trace_valid" in f for f in fails[1:])


# ---------------------------------------------------------------------------
# file names in SKIP / FAILURE messages (satellite bugfix)
# ---------------------------------------------------------------------------

def test_missing_row_failure_names_both_files():
    base = _runtime_payload()
    cur = {"bench": "runtime_dispatch_ab",
           "entries": [{"runtime": "eager", "metrics": "chunk",
                        "rounds_per_launch": 1, "rounds_per_s": 50.0}]}
    fails = check_perf.check_runtime(cur, base, tolerance=0.3,
                                     paths=("cur.json", "base.json"))
    assert len(fails) == 1
    assert "cur.json" in fails[0] and "base.json" in fails[0]


def test_rows_without_eager_names_the_file():
    with pytest.raises(SystemExit, match="weird.json"):
        check_perf._rows({"entries": []}, "weird.json")
    with pytest.raises(SystemExit, match="weird.json"):
        check_perf._serve_rows({"entries": []}, "weird.json")


def test_faults_kind_missing_ratio_is_clean_failure_not_keyerror():
    fails = check_perf.check_faults({"bench": "faults"}, {},
                                    tolerance=0.1,
                                    paths=("cur_faults.json", "b.json"))
    # every failing row of the file is reported, not just the first
    assert len(fails) == 4 and "cur_faults.json" in fails[0]
    assert any("unguarded_poisoned" in f for f in fails[1:])


# ---------------------------------------------------------------------------
# resilience kind (absolute ceiling + accounting flags)
# ---------------------------------------------------------------------------

def _resilience_payload(ratio=1.02, identical=True, accounted=True):
    return {"bench": "resilience", "retry_overhead_ratio": ratio,
            "clean_token_identical": identical, "all_accounted": accounted}


def test_resilience_kind_passes_and_ceiling_is_absolute():
    base = _resilience_payload(ratio=1.5)    # baseline never relaxes it
    assert check_perf.check_resilience(_resilience_payload(ratio=0.95),
                                       base, tolerance=0.1) == []
    fails = check_perf.check_resilience(_resilience_payload(ratio=0.85),
                                        base, tolerance=0.1)
    assert len(fails) == 1 and "retry_overhead_ratio" in fails[0]


def test_resilience_kind_gates_accounting_flags():
    base = _resilience_payload()
    for kw, name in ((dict(identical=False), "clean_token_identical"),
                     (dict(accounted=False), "all_accounted")):
        fails = check_perf.check_resilience(_resilience_payload(**kw),
                                            base, tolerance=0.1)
        assert len(fails) == 1 and name in fails[0]


def test_resilience_kind_missing_ratio_reports_all_rows():
    fails = check_perf.check_resilience({"bench": "resilience"},
                                        _resilience_payload(),
                                        tolerance=0.1,
                                        paths=("cur_r.json", "b.json"))
    assert len(fails) == 3 and "cur_r.json" in fails[0]


# ---------------------------------------------------------------------------
# kind dispatch through main()
# ---------------------------------------------------------------------------

def _run_main(tmp_path, cur, base, extra=()):
    cur_p, base_p = tmp_path / "cur.json", tmp_path / "base.json"
    cur_p.write_text(json.dumps(cur))
    base_p.write_text(json.dumps(base))
    return subprocess.run(
        [sys.executable, str(SCRIPT), str(cur_p), str(base_p), *extra],
        capture_output=True, text=True)


def test_main_accepts_serve_payload(tmp_path):
    r = _run_main(tmp_path, _serve_payload(), _serve_payload())
    assert r.returncode == 0, r.stdout + r.stderr
    assert "no dispatch-layer regression" in r.stdout


def test_main_skips_unknown_kind(tmp_path):
    r = _run_main(tmp_path, {"bench": "scenarios", "entries": []},
                  _serve_payload())
    assert r.returncode == 0
    assert "SKIP" in r.stdout


def test_main_rejects_kind_mismatch(tmp_path):
    r = _run_main(tmp_path, _serve_payload(), _runtime_payload())
    assert r.returncode != 0
    out = r.stdout + r.stderr
    assert "mismatch" in out
    # both offending files are named
    assert "cur.json" in out and "base.json" in out


def test_main_accepts_obs_payload(tmp_path):
    r = _run_main(tmp_path, _obs_payload(), _obs_payload(),
                  extra=("--tolerance", "0.05"))
    assert r.returncode == 0, r.stdout + r.stderr
    r = _run_main(tmp_path, _obs_payload(ratio=0.5), _obs_payload(),
                  extra=("--tolerance", "0.05"))
    assert r.returncode == 1
    assert "PERF REGRESSION" in r.stdout


def test_main_fails_on_serve_regression(tmp_path):
    r = _run_main(tmp_path, _serve_payload(tok_per_s=10.0),
                  _serve_payload(tok_per_s=40.0))
    assert r.returncode == 1
    assert "PERF REGRESSION" in r.stdout
