"""Serving smoke bench: lock-step oracle vs slot-based continuous batching.

One lock-step row (the normaliser) plus one slot row per configuration:
same tiny arch, same prompt stream, same per-request token budget.  Each
slot row reports decode throughput, realised slot occupancy and mean
time-to-first-token — occupancy and TTFT are deterministic functions of
the admission bookkeeping (the slot loop reads no device values), so they
double as correctness canaries, not just perf numbers.

The point is a CI canary with two properties:

* the whole slot lane (ragged decode, traced-slot admission, ordered
  io_callback tap) compiles and runs end-to-end on every push,
* tok/s normalised by the SAME run's lock-step row shows what slot
  bookkeeping COSTS at dispatch level, machine-portably.

Writes ``experiments/figs/BENCH_serve.json`` (``bench: "serve_slots"``),
gated by ``benchmarks/check_perf.py`` against the committed
``benchmarks/BENCH_serve.json`` baseline.

    PYTHONPATH=src python -m benchmarks.perf_serve --quick
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax

from repro.api import ExperimentSpec, ServeJob
from repro.api.backends import ServeBackend

#: smallest decodable arch — the bench measures the serving dispatch
#: layer (slot bookkeeping, admission, tap), not model compute
TINY = (("n_layers", 1), ("d_model", 8), ("n_heads", 1), ("n_kv_heads", 1),
        ("d_ff", 16), ("vocab", 127))

#: slot rows: (label, n_slots, n_requests, admission, arrival)
SLOT_ROWS = (
    ("static_full", 4, 4, "pure", None),          # parity shape: slots = reqs
    ("rotating", 2, 6, "pure", None),             # reqs rotate through slots
    ("poisson_shuffled", 2, 6, "shuffled", "poisson:gap=4"),
)


def run(out: str = "experiments/figs", quick: bool = False,
        steps: int = 0, arch: str = "qwen2-0.5b") -> dict:
    os.makedirs(out, exist_ok=True)
    T = steps or (16 if quick else 48)
    prompt_len = 8
    backend = ServeBackend()
    entries = []

    def serve_spec(**kw):
        return ExperimentSpec(
            objective=ServeJob(arch=arch, prompt_len=prompt_len,
                               arch_overrides=TINY, **kw), T=T, seed=0)

    # -- lock-step normaliser (warm: second run reuses the cached jit) ------
    spec = serve_spec(batch=4)
    backend.run(spec)                              # compile
    res = backend.run(spec)
    lock = {
        "mode": "lockstep",
        "batch": 4,
        "steps": T,
        "decode_seconds": round(res.extra["decode_seconds"], 4),
        "tok_per_s": round(res.extra["tok_per_s"], 2),
    }
    entries.append(lock)
    print(f"{'lockstep':<18} tok/s={lock['tok_per_s']:>9}")

    # -- slot rows ----------------------------------------------------------
    for label, n_slots, n_req, admission, arrival in SLOT_ROWS:
        spec = serve_spec(batch=4, n_slots=n_slots, n_requests=n_req,
                          admission=admission, arrival=arrival,
                          steps_per_launch=8)
        backend.run(spec)                          # compile
        res = backend.run(spec)
        rep = res.extra["tau_report"]
        entry = {
            "mode": label,
            "n_slots": n_slots,
            "n_requests": n_req,
            "admission": admission,
            "arrival": arrival,
            "steps": T,
            "decode_seconds": round(res.extra["decode_seconds"], 4),
            "tok_per_s": round(res.extra["tok_per_s"], 2),
            "occupancy": round(res.extra["occupancy"], 4),
            "ttft_mean_steps": round(
                float(np.mean(res.extra["ttft_steps"])), 2),
            "decode_steps": res.extra["decode_steps"],
            "chunks": res.extra["chunks"],
            "tau_c": rep["global"]["tau_c"],
        }
        entries.append(entry)
        print(f"{label:<18} tok/s={entry['tok_per_s']:>9} "
              f"occ={entry['occupancy']:>6} "
              f"ttft={entry['ttft_mean_steps']:>5} "
              f"tau_c={entry['tau_c']:>2}")

    payload = {
        "bench": "serve_slots",
        "backend": jax.default_backend(),
        "arch": arch,
        "steps": T,
        "prompt_len": prompt_len,
        "note": ("warm runs on a tiny arch; absolute tok/s is "
                 "machine-local — read slot rows normalised by the "
                 "lockstep row of the same run (check_perf.py does).  "
                 "occupancy and ttft are deterministic admission "
                 "bookkeeping, portable across machines."),
        "entries": entries,
    }
    path = os.path.join(out, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", path)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="16 decode steps instead of 48")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--out", default="experiments/figs")
    args = ap.parse_args()
    run(out=args.out, quick=args.quick, steps=args.steps, arch=args.arch)


if __name__ == "__main__":
    main()
