"""Ablations beyond the paper's main figures.

1. waiting-b (Alg 3/5): Prop. C.3/D.2 predict the stochastic term shrinks
   as 1/√b — measured on the exact tier across b.
2. shuffle-once vs per-cycle reshuffling (§3.2 allows both for Alg 6).
3. delay-adaptive stepsizes (Table 1 note b): pure async with γ_t =
   γ·min(1, τ_C/(τ_t+1)) vs constant γ under a heavy-tail straggler.
4. transformer-scale ordering: the AsyncTrainer (production tier) under
   pure vs shuffled masks on heterogeneous token data.
"""
from __future__ import annotations

import csv
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (TimingModel, build_schedule, replay, make_scheduler,
                        heterogeneous_speeds, delay_adaptive_stepsizes,
                        round_masks)
from repro.objectives import LogRegProblem, make_synthetic


def waiting_b_sweep(T_rounds=600, out="experiments/figs", quick=False):
    """Alg 3: larger b → smaller stochastic term (rate ∝ 1/√(Tb))."""
    n = 8
    A, b_ = make_synthetic(1.0, 1.0, n=n, m=200, d=200, seed=2)
    prob = LogRegProblem(A, b_, lam=0.1, batch_size=20)
    rows = []
    bs = (1, 2, 4, 8) if not quick else (1, 4)
    for b in bs:
        sched = make_scheduler("pure_waiting", n, b=b, seed=0)
        tm = TimingModel(heterogeneous_speeds(n, 6.0), "poisson", seed=0)
        s = build_schedule(sched, tm, T_rounds * b)
        res = replay(s, prob.grad_fn(stochastic=True), jnp.zeros(prob.d),
                     0.01, log_every=max(T_rounds * b // 20, 1),
                     full_grad_fn=prob.full_grad)
        rows.append({"ablation": "waiting_b", "b": b,
                     "final_grad_norm": float(np.mean(res.grad_norms[-3:])),
                     "tau_max": s.tau_max()})
    return rows


def shuffle_once_vs_reshuffle(T=4000, quick=False):
    n = 10
    A, b_ = make_synthetic(1.0, 1.0, n=n, m=150, d=200, seed=3)
    prob = LogRegProblem(A, b_, lam=0.1)
    rows = []
    for reshuffle in (True, False):
        from repro.core.schedulers import ShuffledAsync
        sched = ShuffledAsync(n, seed=0, reshuffle=reshuffle)
        tm = TimingModel(heterogeneous_speeds(n, 6.0), "poisson", seed=0)
        s = build_schedule(sched, tm, T if not quick else T // 4)
        res = replay(s, prob.grad_fn(), jnp.zeros(prob.d), 0.002,
                     log_every=200, full_grad_fn=prob.full_grad)
        rows.append({"ablation": "shuffle_once",
                     "mode": "reshuffle" if reshuffle else "once",
                     "final_grad_norm": float(np.mean(res.grad_norms[-3:]))})
    return rows


def delay_adaptive(T=4000, quick=False):
    """Heavy straggler: one worker 40× slower.  Delay-adaptive stepsizes
    keep the large-γ convergence without the stale-gradient blowup."""
    n = 8
    A, b_ = make_synthetic(1.0, 1.0, n=n, m=150, d=200, seed=4)
    prob = LogRegProblem(A, b_, lam=0.1)
    speeds = np.array([1.0] * (n - 1) + [40.0])
    T = T if not quick else T // 4
    rows = []
    # Measured finding (EXPERIMENTS.md §Claims): in the HETEROGENEOUS regime
    # delay-adaptive stepsizes shrink the straggler's updates to ~0, which
    # suppresses its data distribution entirely — the resulting bias hurts
    # more than the staleness it prevents.  This *supports* the paper's
    # design: balance contributions (shuffling) instead of suppressing them.
    gamma = 0.05
    for adaptive in (False, True):
        sched = make_scheduler("pure", n, seed=0)
        tm = TimingModel(speeds, "fixed", seed=0)
        s = build_schedule(sched, tm, T)
        steps = (delay_adaptive_stepsizes(gamma, s.delays, s.tau_c())
                 if adaptive else gamma)
        res = replay(s, prob.grad_fn(), jnp.zeros(prob.d), steps,
                     log_every=50, full_grad_fn=prob.full_grad)
        half = len(res.grad_norms) // 2
        rows.append({"ablation": "delay_adaptive", "adaptive": adaptive,
                     "gamma": gamma, "tau_max": s.tau_max(),
                     "final_grad_norm": float(np.mean(res.grad_norms[-3:])),
                     "worst_spike": float(np.max(res.grad_norms[half:]))})
    return rows


def transformer_ordering(steps=30, quick=False):
    """Production tier: shuffled masks beat pure masks on the reduced
    transformer with heterogeneous token data (loss after N rounds)."""
    from jax.sharding import Mesh
    from repro.configs import get_arch
    from repro.data import DataConfig, HeterogeneousTokenPipeline
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.optim import OptConfig

    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    n_groups = 4
    steps = steps if not quick else 12
    rows = []
    for alg in ("pure", "shuffled"):
        tr = AsyncTrainer(cfg, mesh, opt=OptConfig(lr=5e-3),
                          async_cfg=AsyncConfig(delay_rounds=1))
        tr.n_groups = n_groups
        sched = make_scheduler(alg, n_groups, seed=0)
        tm = TimingModel(heterogeneous_speeds(n_groups, 8.0), "poisson", seed=0)
        masks = round_masks(build_schedule(sched, tm, steps))
        pipe = HeterogeneousTokenPipeline(DataConfig(
            cfg.vocab, 32, 8, n_groups=n_groups, heterogeneity=1.0))
        state = tr.init_state(jax.random.PRNGKey(0))
        step_fn = jax.jit(tr.train_step_fn())
        losses = []
        for q in range(masks.shape[0]):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(q).items()}
            state, m = step_fn(state, batch, jnp.asarray(masks[q]))
            losses.append(float(m["loss"]))
        rows.append({"ablation": "transformer_ordering", "alg": alg,
                     "final_loss": float(np.mean(losses[-5:]))})
    return rows


def run(out="experiments/figs", quick=False):
    os.makedirs(out, exist_ok=True)
    rows = []
    rows += waiting_b_sweep(quick=quick)
    rows += shuffle_once_vs_reshuffle(quick=quick)
    rows += delay_adaptive(quick=quick)
    rows += transformer_ordering(quick=quick)
    with open(os.path.join(out, "ablations.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=sorted({k for r in rows for k in r}))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
