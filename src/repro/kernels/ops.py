"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute under ``interpret=True`` (pallas
interpreter) — set ``REPRO_KERNEL_INTERPRET=0`` on a real TPU to compile
them.  Each wrapper falls back to the pure-jnp oracle (`ref.py`) when
``use_kernel=False``, which is also what the model code uses on CPU.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .async_update import (async_update_pallas, fused_adam_pallas,
                           fused_adam_delayed_pallas)
from .ssd_chunk import ssd_chunk_pallas


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "use_kernel", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=512,
                    block_k=512, use_kernel=True, interpret=None):
    if not use_kernel:
        return ref.reference_attention(q, k, v, causal=causal, window=window)
    if interpret is None:
        interpret = _interpret_default()
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)


@partial(jax.jit, static_argnames=("lr", "clip_scale", "delay_scale",
                                   "use_kernel", "interpret"))
def async_update(params, gbuf, grads, *, lr, clip_scale=1.0, delay_scale=1.0,
                 use_kernel=True, interpret=None):
    if not use_kernel:
        return ref.reference_async_update(params, gbuf, grads, lr=lr,
                                          clip_scale=clip_scale,
                                          delay_scale=delay_scale)
    if interpret is None:
        interpret = _interpret_default()
    return async_update_pallas(params, gbuf, grads, lr=lr,
                               clip_scale=clip_scale,
                               delay_scale=delay_scale, interpret=interpret)


@partial(jax.jit, static_argnames=("lr", "beta1", "beta2", "eps", "count",
                                   "use_kernel", "interpret"))
def fused_adam(p, m, v, g, *, lr, beta1=0.9, beta2=0.95, eps=1e-8, count=1,
               use_kernel=True, interpret=None):
    if not use_kernel:
        return ref.reference_fused_adam(p, m, v, g, lr=lr, beta1=beta1,
                                        beta2=beta2, eps=eps,
                                        bc1=1 - beta1 ** count,
                                        bc2=1 - beta2 ** count)
    if interpret is None:
        interpret = _interpret_default()
    return fused_adam_pallas(p, m, v, g, lr=lr, beta1=beta1, beta2=beta2,
                             eps=eps, count=count, interpret=interpret)


@partial(jax.jit, static_argnames=("beta1", "beta2", "eps", "weight_decay",
                                   "use_kernel", "interpret"))
def fused_adam_delayed(p, m, v, gbuf, g, *, lr, beta1=0.9, beta2=0.95,
                       eps=1e-8, count=1, clip_scale=1.0, weight_decay=0.0,
                       use_kernel=True, interpret=None):
    """Delayed-buffer Adam + gbuf swap in one pass, on a single flat
    tensor.  ``lr`` / ``count`` / ``clip_scale`` are TRACED (they change
    every step — marking them static would recompile per step); the actual
    trainer hot loop goes through ``repro.optim.make_delayed_apply``, which
    calls the pallas wrapper directly, this is the standalone entry."""
    count = jnp.asarray(count)
    if not use_kernel:
        c = count.astype(jnp.float32)
        return ref.reference_fused_adam_delayed(
            p, m, v, gbuf, g, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
            bc1=1 - beta1 ** c, bc2=1 - beta2 ** c,
            clip_scale=clip_scale, weight_decay=weight_decay)
    if interpret is None:
        interpret = _interpret_default()
    return fused_adam_delayed_pallas(
        p, m, v, gbuf, g, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
        count=count, clip_scale=clip_scale, weight_decay=weight_decay,
        interpret=interpret)


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def ssd_chunk(x, dt, A, B_, C_, *, use_kernel=True, interpret=None):
    """Intra-chunk SSD (see ssd_chunk.py for shapes)."""
    if interpret is None:
        interpret = _interpret_default()
    return ssd_chunk_pallas(x, dt, A, B_, C_, interpret=interpret)
