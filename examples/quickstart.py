"""Quickstart: the AsGrad framework on the paper's own experiment.

Runs pure / random / shuffled asynchronous SGD on heterogeneous logistic
regression (Syn(1,1), §5) with poisson worker timings and prints the final
full-gradient norms — reproducing the paper's headline ordering in ~30 s.

One ``ExperimentSpec`` per algorithm; the simulator backend grid-searches
the stepsize against a single shared schedule in one batched scan.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import ExperimentSpec, grid, run
from repro.objectives import LogRegProblem, make_synthetic


def main():
    n, T = 10, 4000
    A, b = make_synthetic(1.0, 1.0, n=n, m=200, d=300, seed=0)
    prob = LogRegProblem(A, b, lam=0.1)
    print(f"heterogeneity zeta(x0) = {prob.zeta(np.zeros(prob.d)):.2f}")
    for alg in ("pure", "random", "shuffled"):
        res = run(ExperimentSpec(
            scheduler=alg,
            timing="poisson:slow=8",
            objective=prob,
            T=T,
            stepsize=grid(0.005, 0.002, 0.001),
            log_every=200,
        ))
        gn = float(np.min(res.grad_norms[-4:]))
        print(f"{alg:9s} |grad f| = {gn:.5f}  (gamma={res.gamma}, "
              f"tau_max={res.trace['tau_max']}, tau_C={res.trace['tau_c']}, "
              f"jobs min/max={res.trace['jobs_min']}/{res.trace['jobs_max']})")
    print("\nexpected: pure stalls near the zeta level; shuffled is ~10x lower.")


if __name__ == "__main__":
    main()
