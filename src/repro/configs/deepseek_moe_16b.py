"""DeepSeekMoE 16B — fine-grained 64-expert top-6 MoE + 2 shared experts.
[arXiv:2401.06066]

28L, d_model 2048, 16 heads (MHA, kv=16, d_head 128), per-expert d_ff 1408,
vocab 102400.  Deviation noted in DESIGN.md: the release uses a dense first
layer (d_ff 10944); we keep all layers MoE for a uniform scan stack.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
)
