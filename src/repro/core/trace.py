"""Empirical estimators for the paper's theory quantities (Defs 1–4).

These let EXPERIMENTS.md check the *bounds used in the proofs* against the
realised schedules — e.g. Prop. C.1 bounds ν² ≤ τ_C·τ_max·ζ²·T for pure
async; we measure the left side directly.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .engine import Schedule


def heterogeneity_zeta(per_worker_grad_fn, x, n_workers: int) -> float:
    """max_i ||∇f_i(x) − ∇f(x)|| at a point (Assumption 3 witness)."""
    gs = np.stack([np.asarray(per_worker_grad_fn(x, i)) for i in range(n_workers)])
    mean = gs.mean(axis=0)
    return float(np.max(np.linalg.norm(gs - mean, axis=-1)))


def sequence_correlation(
    schedule: Schedule,
    per_worker_grad_fn,
    xs_at_chunks,
    tau: int,
) -> np.ndarray:
    """σ²_{k,τ} (Def. 3): for each chunk k of length τ, the max over j of
    ||Σ_{t=kτ}^{kτ+j} (∇f_{i_t}(x_{kτ}) − ∇f(x_{kτ}))||².

    ``xs_at_chunks[k]`` must be the iterate at the chunk start (the replay's
    snapshot log provides these).
    """
    T = schedule.T
    n = schedule.n_workers
    n_chunks = T // tau
    out = np.zeros(n_chunks)
    for k in range(n_chunks):
        x = jnp.asarray(xs_at_chunks[k])
        gs = np.stack([np.asarray(per_worker_grad_fn(x, i)) for i in range(n)])
        gbar = gs.mean(axis=0)
        dev = gs - gbar                       # (n, d)
        idx = schedule.workers[k * tau : (k + 1) * tau]
        partial = np.cumsum(dev[idx], axis=0)  # (τ, d)
        out[k] = float(np.max(np.sum(partial * partial, axis=-1)))
    return out


def delay_variance(
    schedule: Schedule,
    per_worker_grad_fn,
    xs_all,
) -> float:
    """ν² (Def. 4): Σ_t ||Σ_{j=π_t}^{t−1} (∇f_{i_j}(x_{π_j}) − ∇f(x_{π_j}))||².

    ``xs_all[t]`` must be x_t for every t (use replay with log_every=1).
    Cost: one per-worker gradient sweep per iteration — use small T.
    """
    T = schedule.T
    n = schedule.n_workers
    devs = np.zeros((T,) + np.asarray(xs_all[0]).shape)
    for j in range(T):
        pj = int(schedule.assign_iters[j])
        x = jnp.asarray(xs_all[pj])
        gs = np.stack([np.asarray(per_worker_grad_fn(x, i)) for i in range(n)])
        devs[j] = gs[schedule.workers[j]] - gs.mean(axis=0)
    prefix = np.concatenate([np.zeros((1,) + devs.shape[1:]), np.cumsum(devs, axis=0)])
    total = 0.0
    for t in range(T):
        pt = int(schedule.assign_iters[t])
        s = prefix[t] - prefix[pt]
        total += float(np.sum(s * s))
    return total


def summarize(schedule: Schedule) -> dict:
    """One-line schedule summary (Defs 1–2 + balance)."""
    jpw = schedule.jobs_per_worker()
    return {
        "T": schedule.T,
        "tau_max": schedule.tau_max(),
        "tau_avg": round(schedule.tau_avg(), 3),
        "tau_c": schedule.tau_c(),
        "wait_b": schedule.wait_b,
        "jobs_min": int(jpw.min()),
        "jobs_max": int(jpw.max()),
        "jobs_std": round(float(jpw.std()), 3),
    }
