"""Hypothesis property tests for the Table-1 rate calculator.

``hypothesis`` is an optional ``[test]`` extra; the whole module skips
gracefully when it is absent so tier-1 stays green on minimal installs.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.theory import (
    ProblemConstants,
    pure_async,
    stepsize_pure_async,
    stepsize_random_async,
    stepsize_shuffled_async,
)

C = ProblemConstants(L=1.0, F0=1.0, sigma2=1.0, zeta2=0.5, G=2.0)


@settings(max_examples=40, deadline=None)
@given(T=st.integers(100, 10_000), tc=st.integers(1, 32), tm=st.integers(1, 64))
def test_rates_decrease_in_T(T, tc, tm):
    tm = max(tm, tc)
    r1 = pure_async(C, T, tc, tm)
    r2 = pure_async(C, 4 * T, tc, tm)
    assert r2 <= r1 + 1e-12
    assert r1 >= C.zeta2  # the ζ² floor (pure async stalls at heterogeneity)


@settings(max_examples=20, deadline=None)
@given(T=st.integers(10, 10_000))
def test_tuned_stepsizes_positive_and_bounded(T):
    g1 = stepsize_pure_async(C, T, 4, 8)
    g2 = stepsize_random_async(C, T, 4)
    g3 = stepsize_shuffled_async(C, T, 8)
    for g in (g1, g2, g3):
        assert 0 < g <= 1.0 / C.L + 1e-9
