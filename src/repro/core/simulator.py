"""Exact AsGrad replay: x_{t+1} = x_t − γ̃ · g_{i_t}(x_{π_t}), jittable.

Given a :class:`~repro.core.engine.Schedule` (which fixes i_t and π_t), the
optimisation itself is a `lax.scan` with a ring buffer of past iterates —
x_{π_t} is read from slot π_t mod D, D = τ_max + 1.  This is bit-exact w.r.t.
the event-driven view and runs at jit speed, which is what makes the paper's
stepsize grid-searches cheap.

``grad_fn(x, worker, key)`` is any jax-differentiable per-worker gradient
oracle (see ``repro.objectives``).  ``key`` enables stochastic gradients
(Assumption 2); pass ``stochastic=False`` for the paper's full-gradient runs
(Fig. 1 / Fig. 3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .engine import Schedule


@dataclasses.dataclass
class ReplayResult:
    x: np.ndarray                 # final iterate
    xs: Optional[np.ndarray]      # (T//log_every, d) iterate snapshots
    log_ts: Optional[np.ndarray]  # matching iteration indices
    grad_norms: Optional[np.ndarray]  # ||∇f(x)|| at the snapshots
    losses: Optional[np.ndarray]      # f(x) at the snapshots


def delay_adaptive_stepsizes(gamma: float, delays: np.ndarray, tau_c: int) -> np.ndarray:
    """[Mishchenko et al. 22 / Koloskova et al. 22]-style delay adaptivity:
    γ_t = γ · min(1, τ_C / (τ_t + 1)) — shrinks the step for very stale
    gradients, removing the τ_max dependence (Table 1, footnote b)."""
    d = np.asarray(delays, dtype=np.float64)
    return (gamma * np.minimum(1.0, tau_c / (d + 1.0))).astype(np.float32)


@partial(jax.jit, static_argnames=("grad_fn", "ring_size", "clip"))
def _replay_scan(grad_fn, x0, workers, slots, read_slots, stepsizes, keys,
                 ring_size: int, clip: Optional[float]):
    D = ring_size

    def step(carry, inp):
        x, ring = carry
        worker, slot, read_slot, gamma, key = inp
        ring = jax.lax.dynamic_update_index_in_dim(ring, x, slot, axis=0)
        x_stale = jax.lax.dynamic_index_in_dim(ring, read_slot, axis=0, keepdims=False)
        g = grad_fn(x_stale, worker, key)
        if clip is not None:
            norm = jnp.sqrt(jnp.sum(g * g))
            g = g * jnp.minimum(1.0, clip / (norm + 1e-12))
        x = x - gamma * g
        return (x, ring), x

    ring0 = jnp.zeros((D,) + x0.shape, x0.dtype)
    (xf, _), xs = jax.lax.scan(
        step, (x0, ring0), (workers, slots, read_slots, stepsizes, keys)
    )
    return xf, xs


@partial(jax.jit, static_argnames=("grad_fn", "ring_size", "clip", "n_grid"))
def _grid_scan(grad_fn, x0, workers, slots, read_slots, gam_mat, keys,
               ring_size: int, clip: Optional[float], n_grid: int):
    """One scan, ``n_grid`` stepsize trajectories sharing the schedule.

    The grid dimension is unrolled (it is static and small — the paper grid
    has 7 entries) rather than vmapped: each γ's gradient is evaluated with
    the exact unbatched shapes, so every trajectory is bit-identical to a
    solo :func:`_replay_scan` run.  A vmap would batch the contraction inside
    ``grad_fn`` and change the reduction order.
    """
    D = ring_size

    def one(x, ring, slot, read_slot, worker, gamma, key):
        ring = jax.lax.dynamic_update_index_in_dim(ring, x, slot, axis=0)
        x_stale = jax.lax.dynamic_index_in_dim(ring, read_slot, axis=0, keepdims=False)
        g = grad_fn(x_stale, worker, key)
        if clip is not None:
            norm = jnp.sqrt(jnp.sum(g * g))
            g = g * jnp.minimum(1.0, clip / (norm + 1e-12))
        return x - gamma * g, ring

    def step(carry, inp):
        xs, rings = carry
        worker, slot, read_slot, gams, key = inp
        new = [one(xs[i], rings[i], slot, read_slot, worker, gams[i], key)
               for i in range(n_grid)]
        xs = tuple(x for x, _ in new)
        rings = tuple(r for _, r in new)
        return (xs, rings), xs

    ring0 = jnp.zeros((D,) + x0.shape, x0.dtype)
    carry0 = (tuple(x0 for _ in range(n_grid)),
              tuple(ring0 for _ in range(n_grid)))
    (xf, _), xs = jax.lax.scan(
        step, carry0, (workers, slots, read_slots, gam_mat, keys)
    )
    return xf, xs


def _schedule_arrays(schedule: Schedule):
    """(ring size, worker/slot/read-slot device arrays) shared by replays."""
    T = schedule.T
    D = max(schedule.tau_max() + 1, 1)
    workers = jnp.asarray(schedule.workers, dtype=jnp.int32)
    slots = jnp.asarray(np.arange(T, dtype=np.int64) % D, dtype=jnp.int32)
    read_slots = jnp.asarray(schedule.assign_iters.astype(np.int64) % D,
                             dtype=jnp.int32)
    return D, workers, slots, read_slots


def replay_grid(
    schedule: Schedule,
    grad_fn: Callable,
    x0,
    stepsizes,
    *,
    key: Optional[jax.Array] = None,
    clip: Optional[float] = None,
    log_every: int = 50,
    full_grad_fn: Optional[Callable] = None,
    loss_fn: Optional[Callable] = None,
) -> list[ReplayResult]:
    """Replay one schedule under several server stepsizes in a single scan.

    The schedule is gradient-value-independent (see ``engine.py``), so a
    stepsize grid search need only build it once; this replays all γ in one
    jitted batched scan instead of a Python loop.  Returns one
    :class:`ReplayResult` per γ, each bit-identical to
    ``replay(schedule, grad_fn, x0, γ, ...)``.

    Peak memory holds all ``len(stepsizes)`` full (T, d) trajectories at
    once (vs one for the sequential loop) — fine for the paper's 7-γ grid
    at figure scale; split very large grids into chunks if that bites.
    """
    T = schedule.T
    x0 = jnp.asarray(x0)
    gammas = [np.asarray(g, dtype=np.float32) for g in stepsizes]
    gam_mat = np.stack([
        np.full(T, float(g) / schedule.wait_b, dtype=np.float32) if g.ndim == 0
        else g.astype(np.float32) / schedule.wait_b
        for g in gammas
    ], axis=1)                                   # (T, G) — scan-major
    D, workers, slots, read_slots = _schedule_arrays(schedule)
    if key is None:
        key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, T)

    xf, xs = _grid_scan(grad_fn, x0, workers, slots, read_slots,
                        jnp.asarray(gam_mat), keys, D, clip, len(gammas))
    idx = np.arange(0, T, log_every)
    out = []
    for i in range(len(gammas)):
        xs_log = np.asarray(xs[i][idx])
        gn = ls = None
        if full_grad_fn is not None:
            gn = np.asarray(jax.vmap(
                lambda x: jnp.linalg.norm(full_grad_fn(x)))(jnp.asarray(xs_log)))
        if loss_fn is not None:
            ls = np.asarray(jax.vmap(loss_fn)(jnp.asarray(xs_log)))
        out.append(ReplayResult(x=np.asarray(xf[i]), xs=xs_log, log_ts=idx,
                                grad_norms=gn, losses=ls))
    return out


def replay(
    schedule: Schedule,
    grad_fn: Callable,
    x0,
    stepsize,
    *,
    key: Optional[jax.Array] = None,
    clip: Optional[float] = None,
    log_every: int = 50,
    full_grad_fn: Optional[Callable] = None,
    loss_fn: Optional[Callable] = None,
) -> ReplayResult:
    """Run the schedule.  ``stepsize`` is the *server* stepsize γ; waiting
    variants apply γ/wait_b per gradient (Prop. C.2 equivalence)."""
    T = schedule.T
    D = max(schedule.tau_max() + 1, 1)
    x0 = jnp.asarray(x0)

    gam = np.asarray(stepsize, dtype=np.float32)
    if gam.ndim == 0:
        gam = np.full(T, float(gam) / schedule.wait_b, dtype=np.float32)
    else:
        gam = gam.astype(np.float32) / schedule.wait_b
    workers = jnp.asarray(schedule.workers, dtype=jnp.int32)
    slots = jnp.asarray(np.arange(T, dtype=np.int64) % D, dtype=jnp.int32)
    read_slots = jnp.asarray(schedule.assign_iters.astype(np.int64) % D, dtype=jnp.int32)
    if key is None:
        key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, T)

    xf, xs = _replay_scan(
        grad_fn, x0, workers, slots, read_slots, jnp.asarray(gam), keys, D, clip
    )
    xf = np.asarray(xf)
    idx = np.arange(0, T, log_every)
    xs_log = np.asarray(xs[idx])
    gn = ls = None
    if full_grad_fn is not None:
        gn = np.asarray(
            jax.vmap(lambda x: jnp.linalg.norm(full_grad_fn(x)))(jnp.asarray(xs_log))
        )
    if loss_fn is not None:
        ls = np.asarray(jax.vmap(loss_fn)(jnp.asarray(xs_log)))
    return ReplayResult(x=xf, xs=xs_log, log_ts=idx, grad_norms=gn, losses=ls)


def run_async_sgd(
    scheduler,
    timing,
    grad_fn,
    x0,
    stepsize,
    T: int,
    **kw,
):
    """Convenience: build the schedule and replay it."""
    from .engine import build_schedule

    sched = build_schedule(scheduler, timing, T)
    return sched, replay(sched, grad_fn, x0, stepsize, **kw)
