"""Job-assignment policies — the lever the AsGrad server controls (§3.1).

A :class:`Scheduler` answers two questions:

* which workers get the very first jobs (``initial_workers`` → A_1), and
* after each server model update, which workers get new jobs
  (``next_workers``).

``wait_b`` encodes the "waiting" variants (Alg 3/5): the server performs one
model update per ``b`` received gradients, all new jobs are assigned at the
round boundary α = ⌊t/b⌋·b, and the effective per-gradient stepsize is γ/b
(Prop. C.2 shows the sequential view is exactly equivalent).

Schedulers are host-side, cheap, and deterministic given their seed.  The
same objects drive both the exact discrete-event engine and the distributed
trainer's round masks, so theory-tier and production-tier orderings are
identical by construction.
"""
from __future__ import annotations

import numpy as np


class Scheduler:
    """Base class.  Subclasses override assignment behaviour."""

    #: server updates the model once per ``wait_b`` received gradients
    wait_b: int = 1
    name: str = "base"

    def __init__(self, n_workers: int, seed: int = 0):
        self.n = int(n_workers)
        self.seed = seed
        self.reset()

    # -- lifecycle ---------------------------------------------------------
    def reset(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def initial_workers(self):
        """Workers receiving jobs at x_0 (the set A_1).  Default: all."""
        return list(range(self.n))

    def next_workers(self, finished):
        """New assignments after a server update.

        ``finished``: the workers whose gradients formed the update (length
        ``wait_b``).  Returns the list of workers to assign new jobs to.
        """
        raise NotImplementedError

    # -- concurrency bound used by theory ------------------------------------
    def concurrency(self) -> int:
        """τ_C implied by this policy when all workers start busy."""
        return self.n


class PureAsync(Scheduler):
    """Alg 2: k_{t+1} = i_t — a finishing worker is immediately re-assigned
    at the freshly updated model (α_{t+1} = t+1)."""

    name = "pure"

    def next_workers(self, finished):
        return list(finished)


class PureAsyncWaiting(PureAsync):
    """Alg 3: wait for the first b workers, update once with their average,
    re-assign the same b workers at the round boundary."""

    name = "pure_waiting"

    def __init__(self, n_workers: int, b: int, seed: int = 0):
        if not 1 <= b <= n_workers:
            raise ValueError("need 1 <= b <= n_workers")
        self.wait_b = int(b)
        super().__init__(n_workers, seed)


class RandomAsync(Scheduler):
    """Alg 4 [Koloskova et al. 2022]: a fresh worker k ~ Uni[n] gets the new
    job regardless of whether it is busy (jobs queue per worker)."""

    name = "random"

    def next_workers(self, finished):
        return [int(self._rng.integers(self.n))]


class RandomAsyncWaiting(Scheduler):
    """Alg 5 (FedBuff with Q=1): wait for b, then assign to b workers sampled
    uniformly without replacement at the round boundary."""

    name = "fedbuff"

    def __init__(self, n_workers: int, b: int, seed: int = 0):
        if not 1 <= b <= n_workers:
            raise ValueError("need 1 <= b <= n_workers")
        self.wait_b = int(b)
        super().__init__(n_workers, seed)

    def next_workers(self, finished):
        return [int(w) for w in self._rng.choice(self.n, self.wait_b, replace=False)]


class ShuffledAsync(Scheduler):
    """Alg 6 [NEW in this paper]: jobs are assigned following a random
    permutation χ of workers, cycling; χ is re-sampled each cycle
    (``reshuffle=True``) or sampled once (shuffle-once)."""

    name = "shuffled"

    def __init__(self, n_workers: int, seed: int = 0, reshuffle: bool = True):
        self.reshuffle = reshuffle
        super().__init__(n_workers, seed)

    def reset(self) -> None:
        super().reset()
        self._perm = self._rng.permutation(self.n)
        self._r = 0

    def _advance(self) -> int:
        w = int(self._perm[self._r])
        self._r += 1
        if self._r == self.n:
            self._r = 0
            if self.reshuffle:
                self._perm = self._rng.permutation(self.n)
        return w

    def next_workers(self, finished):
        return [self._advance()]


class MiniBatch(Scheduler):
    """§3.2: mini-batch SGD as AsGrad — treat each data point as a client;
    the server assigns b uniform-without-replacement jobs at the same point
    and waits for all of them (τ_max = τ_C = b − 1)."""

    name = "minibatch"

    def __init__(self, n_workers: int, b: int, seed: int = 0):
        if not 1 <= b <= n_workers:
            raise ValueError("need 1 <= b <= n_workers")
        self.wait_b = int(b)
        super().__init__(n_workers, seed)

    def initial_workers(self):
        return [int(w) for w in self._rng.choice(self.n, self.wait_b, replace=False)]

    def next_workers(self, finished):
        return [int(w) for w in self._rng.choice(self.n, self.wait_b, replace=False)]

    def concurrency(self) -> int:
        return self.wait_b


class RandomReshuffling(Scheduler):
    """§3.2: single-node SGD-RR / shuffle-once.  Concurrency 1, zero delays:
    each gradient is computed at the latest model, in permutation order."""

    name = "rr"

    def __init__(self, n_workers: int, seed: int = 0, reshuffle: bool = True):
        self.reshuffle = reshuffle
        super().__init__(n_workers, seed)

    def reset(self) -> None:
        super().reset()
        self._perm = self._rng.permutation(self.n)
        self._r = 0

    def initial_workers(self):
        w = int(self._perm[self._r])
        self._r += 1
        return [w]

    def next_workers(self, finished):
        if self._r == self.n:
            self._r = 0
            if self.reshuffle:
                self._perm = self._rng.permutation(self.n)
        w = int(self._perm[self._r])
        self._r += 1
        return [w]

    def concurrency(self) -> int:
        return 1


REGISTRY = {
    cls.name: cls
    for cls in (
        PureAsync,
        PureAsyncWaiting,
        RandomAsync,
        RandomAsyncWaiting,
        ShuffledAsync,
        MiniBatch,
        RandomReshuffling,
    )
}


def make_scheduler(name: str, n_workers: int, b: int = 1, seed: int = 0, **kw):
    """Factory used by configs / CLIs."""
    cls = REGISTRY[name]
    if cls in (PureAsyncWaiting, RandomAsyncWaiting, MiniBatch):
        return cls(n_workers, b=b, seed=seed, **kw)
    return cls(n_workers, seed=seed, **kw)
