"""Zamba2-7B — Mamba2 backbone + shared attention blocks.  [arXiv:2411.15242]

81 Mamba2 layers, d_model 3584, ssm_state 64; a single *shared* attention+MLP
block (32 heads, d_head 112, d_ff 14336) is applied every 6 SSM layers
(weights re-used at every insertion; the release's per-insertion LoRA deltas
are omitted — noted in DESIGN.md).  vocab 32000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_head=112,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
)
