"""Exactness and convergence tests for the AsGrad replay (update rule (2))."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    TimingModel,
    build_schedule,
    replay,
    PureAsync,
    PureAsyncWaiting,
    MiniBatch,
    RandomReshuffling,
    ShuffledAsync,
    RandomAsync,
    delay_adaptive_stepsizes,
)
from repro.objectives import QuadraticProblem, LogRegProblem, make_synthetic


def _quad(n=6, d=5, seed=0):
    rng = np.random.default_rng(seed)
    return QuadraticProblem(rng.normal(size=(n, d)))


def test_rr_exactly_matches_classic_sgd_rr():
    """§C.3.4: AsGrad with the RR schedule IS SGD with random reshuffling.
    We replay and compare against a hand-rolled RR loop using the scheduler's
    own permutations — must match to float32 exactness."""
    prob = _quad()
    n, d = prob.n, prob.d
    gamma, epochs = 0.05, 4
    T = n * epochs

    sched = RandomReshuffling(n, seed=3)
    tm = TimingModel(np.ones(n), "fixed")
    s = build_schedule(sched, tm, T)
    x0 = jnp.zeros(d)
    res = replay(s, prob.grad_fn(), x0, gamma, log_every=1)

    # classic loop, using the same visit order the engine recorded
    x = np.zeros(d, dtype=np.float32)
    for t in range(T):
        g = np.asarray(prob.local_grad(jnp.asarray(x), int(s.workers[t])))
        x = x - gamma * g
    np.testing.assert_allclose(res.x, x, rtol=1e-5, atol=1e-6)
    assert s.tau_max() == 0


def test_minibatch_exactly_matches_minibatch_sgd():
    """Prop. C.2: b sequential AsGrad steps with γ/b at a shared stale point
    equal one mini-batch step z_{q+1} = z_q − (γ/b) Σ_{i∈B_q} ∇f_i(z_q)."""
    prob = _quad(n=12)
    b, gamma, rounds = 4, 0.07, 10
    T = b * rounds
    sched = MiniBatch(prob.n, b=b, seed=5)
    tm = TimingModel(np.linspace(1, 3, prob.n), "uniform", seed=1)
    s = build_schedule(sched, tm, T)
    res = replay(s, prob.grad_fn(), jnp.zeros(prob.d), gamma, log_every=1)

    x = np.zeros(prob.d, dtype=np.float64)
    for q in range(rounds):
        batch = s.workers[q * b:(q + 1) * b]
        g = np.mean(
            [np.asarray(prob.local_grad(jnp.asarray(x, jnp.float32), int(i))) for i in batch],
            axis=0,
        )
        x = x - gamma * g
    np.testing.assert_allclose(res.x, x, rtol=1e-4, atol=1e-5)


def test_pure_async_equal_speeds_is_cyclic_delayed_sgd():
    """Equal fixed speeds ⇒ pure async = cyclic SGD with delay n−1.
    Verify the replay against an explicit delayed-update loop."""
    prob = _quad(n=4, d=3)
    n, d = prob.n, prob.d
    gamma, T = 0.05, 40
    s = build_schedule(PureAsync(n), TimingModel(np.ones(n), "fixed"), T)
    res = replay(s, prob.grad_fn(), jnp.zeros(d), gamma, log_every=1)

    xs = [np.zeros(d, dtype=np.float64)]
    for t in range(T):
        pi = int(s.assign_iters[t])
        g = np.asarray(prob.local_grad(jnp.asarray(xs[pi], jnp.float32), int(s.workers[t])))
        xs.append(xs[-1] - gamma * g)
    np.testing.assert_allclose(res.x, xs[-1], rtol=1e-4, atol=1e-5)


def test_quadratic_convergence_to_consensus_minimum():
    """Homogeneous-speed pure async on a strongly convex quadratic must reach
    the average-of-centers minimiser (ζ > 0 but the bias term is O(γ²))."""
    prob = _quad(n=5, d=4, seed=2)
    s = build_schedule(PureAsync(prob.n), TimingModel(np.ones(prob.n), "fixed"), 4000)
    res = replay(s, prob.grad_fn(), jnp.zeros(prob.d), 0.02, log_every=100)
    np.testing.assert_allclose(res.x, prob.minimizer(), atol=0.05)


def test_replay_stochastic_reproducible():
    A, b = make_synthetic(0.5, 0.5, n=6, m=20, d=10, seed=0)
    prob = LogRegProblem(A, b, lam=0.1, batch_size=5)
    s = build_schedule(ShuffledAsync(6), TimingModel(np.arange(1, 7), "poisson"), 100)
    r1 = replay(s, prob.grad_fn(stochastic=True), jnp.zeros(10), 0.05,
                key=jax.random.PRNGKey(7), log_every=10)
    r2 = replay(s, prob.grad_fn(stochastic=True), jnp.zeros(10), 0.05,
                key=jax.random.PRNGKey(7), log_every=10)
    np.testing.assert_array_equal(r1.x, r2.x)


def test_clipping_bounds_update_norm():
    prob = _quad(n=3, d=4, seed=1)
    big = QuadraticProblem(100.0 * np.asarray(prob.c))
    s = build_schedule(PureAsync(3), TimingModel(np.ones(3), "fixed"), 10)
    clip = 1.0
    res = replay(s, big.grad_fn(), jnp.zeros(4), 1.0, clip=clip, log_every=1)
    steps = np.diff(np.concatenate([np.zeros((1, 4)), res.xs], axis=0), axis=0)
    assert np.all(np.linalg.norm(steps, axis=-1) <= clip + 1e-5)


def test_delay_adaptive_stepsizes_monotone():
    d = np.array([0, 1, 5, 100])
    g = delay_adaptive_stepsizes(0.1, d, tau_c=4)
    assert g[0] == pytest.approx(0.1)
    assert np.all(np.diff(g) <= 0)


def test_grad_norm_logging():
    prob = _quad()
    s = build_schedule(PureAsync(prob.n), TimingModel(np.ones(prob.n), "fixed"), 200)
    res = replay(s, prob.grad_fn(), jnp.zeros(prob.d), 0.05, log_every=20,
                 full_grad_fn=prob.full_grad, loss_fn=prob.loss)
    assert res.grad_norms.shape == res.log_ts.shape
    assert res.grad_norms[-1] < res.grad_norms[0]
    assert res.losses[-1] < res.losses[0]
