"""Optimizers from scratch (no optax in this container).

* SGD (+momentum) — the paper's algorithm, with Assumption-4 clipping.
* Adam — f32 moments regardless of param dtype; moments carry ZeRO-shardable
  logical axes identical to their parameter.
* Delay-adaptive stepsize scale (the [32]-style trick that removes τ_max).
* ``update_impl`` selects HOW the step executes: ``"reference"`` is the
  tree-of-elementwise jnp path; ``"pallas"`` routes every leaf through the
  fused server-update kernels in :mod:`repro.kernels.async_update` (one HBM
  pass per tile); ``"pallas_pooled"`` flattens the whole state into
  per-dtype pool buffers (see :mod:`repro.optim.pool`) so the update is ONE
  kernel per dtype instead of one per leaf; the ``*_interpret`` variants
  are the same kernels under the Pallas interpreter (CPU-correct, the CI
  parity vehicle).  Compiled impls degrade to their interpreter twin
  off-TPU — with a one-time warning — see :func:`resolve_update_impl`.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32

UPDATE_IMPLS = ("reference", "pallas", "pallas_interpret",
                "pallas_pooled", "pallas_pooled_interpret")

#: compiled impl → its interpreter twin (the off-TPU degradation target)
_INTERPRET_TWIN = {"pallas": "pallas_interpret",
                   "pallas_pooled": "pallas_pooled_interpret"}

_degrade_warned: set = set()


def resolve_update_impl(impl: str) -> str:
    """Map the requested impl to what this host can execute.

    ``"pallas"``/``"pallas_pooled"`` compile Mosaic TPU kernels; on CPU/GPU
    backends the same kernels run under the Pallas interpreter instead, so
    requesting a compiled impl off-TPU degrades to its ``*_interpret`` twin
    (identical numerics, no compile) and emits a one-time warning — an
    interpreter-speed production run should be diagnosable, not silent.
    ``"reference"``/``"*_interpret"`` pass through unchanged."""
    if impl not in UPDATE_IMPLS:
        raise ValueError(
            f"unknown update_impl {impl!r}; want one of {UPDATE_IMPLS}")
    if impl in _INTERPRET_TWIN and jax.default_backend() != "tpu":
        degraded = _INTERPRET_TWIN[impl]
        if impl not in _degrade_warned:
            _degrade_warned.add(impl)
            warnings.warn(
                f"update_impl={impl!r} needs a TPU backend; this host is "
                f"{jax.default_backend()!r}, degrading to {degraded!r} "
                "(Pallas INTERPRETER — correct numerics at interpreter "
                "speed, not a production configuration)",
                RuntimeWarning, stacklevel=2)
        return degraded
    return impl


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adam"            # adam | sgd
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.0         # sgd only
    clip_norm: Optional[float] = 1.0   # Assumption 4 enforcement
    update_impl: str = "reference"     # reference | pallas | pallas_interpret


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(F32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = clip_scale_from_norm(norm, max_norm)
    return jax.tree_util.tree_map(
        lambda g: (g.astype(F32) * scale).astype(g.dtype), tree), norm


def clip_scale_from_norm(norm, max_norm: Optional[float]) -> jax.Array:
    """The global-norm clip factor from an already-computed norm — the one
    source of truth for the formula (reference, per-leaf and pooled paths
    must agree on the epsilon or parity drifts)."""
    if not max_norm:
        return jnp.asarray(1.0, F32)
    return jnp.minimum(1.0, max_norm / (norm + 1e-12)).astype(F32)


def clip_scale_by_global_norm(tree, max_norm: Optional[float]):
    """(scale, norm) WITHOUT materialising the scaled tree — the fused path
    folds ``scale`` into the kernel's SMEM scalars instead of spending an
    extra HBM pass rescaling every leaf."""
    norm = global_norm(tree)
    return clip_scale_from_norm(norm, max_norm), norm


def _tree_unzip(out, n: int):
    """tree-of-n-tuples → n-tuple-of-trees (shared by all update impls)."""
    is_leaf = lambda x: isinstance(x, tuple)
    return tuple(
        jax.tree_util.tree_map(lambda t, i=i: t[i], out, is_leaf=is_leaf)
        for i in range(n))


def adam_init(params):
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adam_update(grads, opt_state, params, cfg: OptConfig, lr_scale=1.0):
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    count = opt_state["count"] + 1
    b1, b2 = cfg.beta1, cfg.beta2
    c = count.astype(F32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(p, g, m, v):
        g32 = g.astype(F32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(F32)
        # cast the STEP, not the params: upcasting p to f32 lets XLA CSE the
        # convert into the FSDP all-gather, which then moves f32 weights
        # (2× HBM + 2× ICI at 314B scale)
        newp = p - (cfg.lr * lr_scale * step).astype(p.dtype)
        return newp, m, v

    out = jax.tree_util.tree_map(upd, params, grads, opt_state["m"], opt_state["v"])
    newp, m, v = _tree_unzip(out, 3)
    return newp, {"m": m, "v": v, "count": count}, gnorm


def sgd_update(grads, opt_state, params, cfg: OptConfig, lr_scale=1.0):
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    if cfg.momentum:
        m = jax.tree_util.tree_map(
            lambda mo, g: cfg.momentum * mo + g.astype(F32),
            opt_state["m"], grads)
        step_tree = m
    else:
        m = opt_state["m"]
        step_tree = grads
    newp = jax.tree_util.tree_map(
        lambda p, s: p - (cfg.lr * lr_scale * s.astype(F32)).astype(p.dtype),
        params, step_tree)
    count = opt_state["count"] + 1
    return newp, {"m": m, "v": opt_state["v"], "count": count}, gnorm


# --------------------------------------------------------------------------
# fused (Pallas) execution of the same updates
# --------------------------------------------------------------------------
def fused_adam_update(grads, opt_state, params, cfg: OptConfig, lr_scale=1.0,
                      *, interpret: bool):
    """``adam_update`` semantics, executed leaf-by-leaf by the fused Pallas
    kernel: clip factor, bias corrections and weight decay ride the SMEM
    scalar block, so each leaf is ONE read-modify-write pass."""
    from ..kernels.async_update import fused_adam_pallas

    clip_scale, gnorm = clip_scale_by_global_norm(grads, cfg.clip_norm)
    count = opt_state["count"] + 1
    out = jax.tree_util.tree_map(
        lambda p, g, m, v: fused_adam_pallas(
            p, m, v, g, lr=cfg.lr * lr_scale, beta1=cfg.beta1,
            beta2=cfg.beta2, eps=cfg.eps, count=count,
            clip_scale=clip_scale, weight_decay=cfg.weight_decay,
            interpret=interpret),
        params, grads, opt_state["m"], opt_state["v"])
    newp, m, v = _tree_unzip(out, 3)
    return newp, {"m": m, "v": v, "count": count}, gnorm


def fused_sgd_update(grads, opt_state, params, cfg: OptConfig, lr_scale=1.0,
                     *, interpret: bool):
    """SGD through the swap-free ``sgd_step`` kernel; with ``cfg.momentum``
    the f32 momentum buffer rides the same HBM pass
    (``sgd_momentum_step``)."""
    clip_scale, gnorm = clip_scale_by_global_norm(grads, cfg.clip_norm)
    count = opt_state["count"] + 1
    if cfg.momentum:
        from ..kernels.async_update import sgd_momentum_step_pallas

        out = jax.tree_util.tree_map(
            lambda p, m, g: sgd_momentum_step_pallas(
                p, m, g, lr=cfg.lr, momentum=cfg.momentum,
                clip_scale=clip_scale, delay_scale=lr_scale,
                interpret=interpret),
            params, opt_state["m"], grads)
        newp, m = _tree_unzip(out, 2)
        return newp, {"m": m, "v": opt_state["v"], "count": count}, gnorm
    from ..kernels.async_update import sgd_step_pallas

    newp = jax.tree_util.tree_map(
        lambda p, g: sgd_step_pallas(
            p, g, lr=cfg.lr, clip_scale=clip_scale,
            delay_scale=lr_scale, interpret=interpret),
        params, grads)
    return newp, {"m": opt_state["m"], "v": opt_state["v"],
                  "count": count}, gnorm


# --------------------------------------------------------------------------
# delayed-buffer apply: the AsGrad server update (eq. 2) as ONE operation
# --------------------------------------------------------------------------
def reference_delayed_apply(grads, gbuf, opt_state, params, cfg: OptConfig,
                            lr_scale=1.0):
    """Apply the STALE buffer, store the fresh grads: the semantics of the
    trainer's ``delay_rounds > 0`` branch, as a reusable function.

    Returns (new_params, new_gbuf, new_opt_state, gnorm) where ``gnorm`` is
    the pre-clip norm of the APPLIED (stale) gradient."""
    update = adam_update if cfg.name == "adam" else sgd_update
    newp, new_opt, gnorm = update(gbuf, opt_state, params, cfg,
                                  lr_scale=lr_scale)
    return newp, grads, new_opt, gnorm


def fused_delayed_apply(grads, gbuf, opt_state, params, cfg: OptConfig,
                        lr_scale=1.0, *, interpret: bool):
    """The fused production path: per leaf, ONE kernel consumes the stale
    buffer, steps the parameters (+ moments for Adam) and writes the fresh
    gradient back into the buffer — the gbuf swap costs no extra HBM pass."""
    clip_scale, gnorm = clip_scale_by_global_norm(gbuf, cfg.clip_norm)
    count = opt_state["count"] + 1
    if cfg.name == "adam":
        from ..kernels.async_update import fused_adam_delayed_pallas

        out = jax.tree_util.tree_map(
            lambda p, gb, g, m, v: fused_adam_delayed_pallas(
                p, m, v, gb, g, lr=cfg.lr * lr_scale, beta1=cfg.beta1,
                beta2=cfg.beta2, eps=cfg.eps, count=count,
                clip_scale=clip_scale, weight_decay=cfg.weight_decay,
                interpret=interpret),
            params, gbuf, grads, opt_state["m"], opt_state["v"])
        newp, m, v, new_gbuf = _tree_unzip(out, 4)
        return newp, new_gbuf, {"m": m, "v": v, "count": count}, gnorm
    if cfg.momentum:
        from ..kernels.async_update import sgd_momentum_delayed_pallas

        out = jax.tree_util.tree_map(
            lambda p, m, gb, g: sgd_momentum_delayed_pallas(
                p, m, gb, g, lr=cfg.lr, momentum=cfg.momentum,
                clip_scale=clip_scale, delay_scale=lr_scale,
                interpret=interpret),
            params, opt_state["m"], gbuf, grads)
        newp, m, new_gbuf = _tree_unzip(out, 3)
        return newp, new_gbuf, {"m": m, "v": opt_state["v"],
                                "count": count}, gnorm
    from ..kernels.async_update import async_update_pallas

    out = jax.tree_util.tree_map(
        lambda p, gb, g: async_update_pallas(
            p, gb, g, lr=cfg.lr, clip_scale=clip_scale,
            delay_scale=lr_scale, interpret=interpret),
        params, gbuf, grads)
    newp, new_gbuf = _tree_unzip(out, 2)
    return newp, new_gbuf, {"m": opt_state["m"], "v": opt_state["v"],
                            "count": count}, gnorm


def make_optimizer(cfg: OptConfig):
    """(init_fn, update_fn) for ``cfg``, routed through ``cfg.update_impl``.

    All impls share the state tree and the
    ``update(grads, opt_state, params, cfg, lr_scale) → (p', state', gnorm)``
    contract; parity is gated by ``tests/test_optim_fused.py``.

    The ``pallas_pooled`` impls change the STATE LAYOUT (per-dtype pool
    buffers instead of a tree) and therefore live outside this contract:
    use :mod:`repro.optim.pool` (``AsyncTrainer`` routes there)."""
    impl = resolve_update_impl(cfg.update_impl)
    if impl.startswith("pallas_pooled"):
        raise ValueError(
            f"update_impl={cfg.update_impl!r} pools the state into per-dtype "
            "buffers and cannot serve the tree-based optimizer contract; "
            "use repro.optim.pool (AsyncTrainer does this automatically)")
    if impl == "reference":
        if cfg.name == "adam":
            return adam_init, adam_update
        if cfg.name == "sgd":
            return adam_init, sgd_update   # same state tree (m unused w/o momentum)
        raise ValueError(cfg.name)
    interpret = impl == "pallas_interpret"
    if cfg.name == "adam":
        return adam_init, partial(fused_adam_update, interpret=interpret)
    if cfg.name == "sgd":
        return adam_init, partial(fused_sgd_update, interpret=interpret)
    raise ValueError(cfg.name)


def make_delayed_apply(cfg: OptConfig):
    """The delayed-buffer server update as one callable:

        apply(grads, gbuf, opt_state, params, cfg, lr_scale)
            → (new_params, new_gbuf, new_opt_state, gnorm)

    ``"reference"`` composes clip + update + python-side buffer swap;
    the pallas impls fuse all three into the kernels.  ``pallas_pooled``
    operates on pooled state, not trees — see :mod:`repro.optim.pool`."""
    impl = resolve_update_impl(cfg.update_impl)
    if impl.startswith("pallas_pooled"):
        raise ValueError(
            f"update_impl={cfg.update_impl!r} operates on pooled state; use "
            "repro.optim.pool.pooled_delayed_apply (AsyncTrainer does this "
            "automatically)")
    if impl == "reference":
        return reference_delayed_apply
    return partial(fused_delayed_apply, interpret=impl == "pallas_interpret")
