"""Pooled optimizer state: the whole server update as ONE kernel per dtype.

The AsGrad server update (eq. 2) is a pure elementwise pass over the full
parameter/moment/buffer state, yet the per-leaf fused path launches one
``pallas_call`` per parameter leaf — dozens-to-hundreds of tiny kernels per
step for a transformer, each paying launch + HBM-stream setup cost.  This
module flattens the params/m/v/gbuf trees ONCE (at trainer init) into
per-dtype contiguous pool buffers so the entire delayed update — clip,
Adam/SGD(+momentum) step, bias corrections, weight decay, delay_scale and
the gbuf ← fresh-grads swap — executes as one ``pallas_call`` per dtype
pool, O(n_dtypes) launches instead of O(n_leaves).

Layout.  A pool is a ``(n_shards, cols)`` buffer: leaf ``l`` (padded to
``n_shards · width_l`` elements and chunked row-major) owns the column band
``[col_l, col_l + width_l)`` of every row, so row ``r`` holds shard ``r`` of
EVERY leaf.  Sharding the pool ``P(data_axes, None)`` therefore gives each
ZeRO shard a contiguous, self-contained slice of the whole state: the fused
update runs under ``shard_map`` over the mesh's data axes with zero
XLA-inserted gathers, and leaves that were too small or indivisible to
ZeRO-shard individually are sharded anyway (padding is per-leaf, ≤
``n_shards − 1`` elements).

Padding invariant.  :func:`pool_tree` zero-fills pad columns and every
kernel preserves zeros there (moments start at 0, weight decay multiplies a
0 parameter), so :func:`pooled_global_norm` is an exact global norm as a
single fused reduction per pool — no per-leaf Python-sum of reductions, no
masking.

This module is mesh-agnostic: callers pass the data-axis names explicitly
(``repro.distributed.sharding.pooled_pspec`` is the NamedSharding helper).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .optimizers import OptConfig, clip_scale_from_norm

F32 = jnp.float32


def _dtype_key(dt) -> str:
    return str(jnp.dtype(dt))


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One leaf's view into its dtype pool."""

    index: int          # position in the tree's flatten order
    path: str           # keystr (debugging / error messages)
    shape: tuple
    dtype: str          # dtype key of the POOL group (the param dtype)
    col: int            # first column in the (n_shards, cols) pool
    width: int          # columns owned = ceil(size / n_shards)
    size: int


@dataclasses.dataclass(frozen=True)
class PoolLayout:
    """tree ↔ per-dtype ``(n_shards, cols)`` pool buffers, built once.

    ``groups`` maps a dtype key ("bfloat16", "float32", ...) to the slots of
    every leaf with that dtype, in tree-flatten order; ``cols`` is each
    group's total column count.  The same layout serves params, grads and
    the f32 moments (moments pool under the PARAM's group so the kernel
    reads aligned bands, see ``pool_tree(dtype=...)``)."""

    n_shards: int
    groups: dict        # dtype key → tuple[LeafSlot, ...]
    cols: dict          # dtype key → total columns
    treedef: Any
    n_leaves: int

    @property
    def n_pools(self) -> int:
        return len(self.groups)


def build_layout(tree, n_shards: int = 1) -> PoolLayout:
    """Build the pooled layout for ``tree`` (arrays, ShapeDtypeStructs, or
    anything with ``.shape``/``.dtype``), chunked for ``n_shards`` ZeRO
    shards."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(tree)
    groups: dict = {}
    cols: dict = {}
    for index, (path, leaf) in enumerate(leaves_p):
        dk = _dtype_key(leaf.dtype)
        size = int(np.prod(leaf.shape)) if len(leaf.shape) else 1
        width = -(-size // n_shards)          # ceil
        slot = LeafSlot(index=index, path=jax.tree_util.keystr(path),
                        shape=tuple(leaf.shape), dtype=dk,
                        col=cols.get(dk, 0), width=width, size=size)
        groups.setdefault(dk, []).append(slot)
        cols[dk] = slot.col + width
    return PoolLayout(n_shards=n_shards,
                      groups={k: tuple(v) for k, v in groups.items()},
                      cols=cols, treedef=treedef, n_leaves=len(leaves_p))


def _constrain(x, sharding):
    return x if sharding is None else jax.lax.with_sharding_constraint(
        x, sharding)


def pool_tree(layout: PoolLayout, tree, dtype=None, sharding=None) -> dict:
    """tree → {dtype key: (n_shards, cols) pool}.

    ``dtype`` overrides the pool element type (f32 moments pooling under
    their param's group); ``sharding`` (a NamedSharding) is applied to every
    pool.  Pad columns are zero-filled."""
    leaves = jax.tree_util.tree_flatten(tree)[0]
    if len(leaves) != layout.n_leaves:
        raise ValueError(
            f"tree has {len(leaves)} leaves, layout expects {layout.n_leaves}")
    n = layout.n_shards
    pools = {}
    for dk, slots in layout.groups.items():
        blocks = []
        for s in slots:
            flat = jnp.ravel(leaves[s.index])
            if dtype is not None:
                flat = flat.astype(dtype)
            pad = n * s.width - s.size
            if pad:
                flat = jnp.pad(flat, (0, pad))
            blocks.append(flat.reshape(n, s.width))
        pools[dk] = _constrain(jnp.concatenate(blocks, axis=1)
                               if len(blocks) > 1 else blocks[0], sharding)
    return pools


def unpool_tree(layout: PoolLayout, pools: dict, shardings=None):
    """{dtype key: pool} → tree.  ``shardings`` (an optional matching tree of
    NamedShardings) re-constrains each leaf to its compute sharding — the
    hook XLA turns into the per-leaf FSDP-style gathers."""
    leaves: list = [None] * layout.n_leaves
    for dk, slots in layout.groups.items():
        pool = pools[dk]
        for s in slots:
            flat = pool[:, s.col:s.col + s.width].reshape(-1)
            leaves[s.index] = flat[:s.size].reshape(s.shape)
    tree = jax.tree_util.tree_unflatten(layout.treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(_constrain, tree, shardings)
    return tree


def pool_zeros(layout: PoolLayout, dtype=None, sharding=None) -> dict:
    """Zero pools (moments / delayed buffer init)."""
    return {dk: _constrain(
        jnp.zeros((layout.n_shards, layout.cols[dk]),
                  jnp.dtype(dtype) if dtype is not None else jnp.dtype(dk)),
        sharding) for dk in layout.groups}


def init_pools(layout: PoolLayout, params, delayed: bool = True,
               sharding=None) -> dict:
    """Fresh pooled optimizer state from a params tree: per dtype group
    ``{"p", "m", "v"}`` (+ a zero ``"gbuf"`` when ``delayed``) — the state
    schema every pooled consumer (trainer, benches, tests) shares."""
    p_pools = pool_tree(layout, params, sharding=sharding)
    m_pools = pool_zeros(layout, "float32", sharding=sharding)
    v_pools = pool_zeros(layout, "float32", sharding=sharding)
    b_pools = pool_zeros(layout, sharding=sharding) if delayed else None
    pools = {}
    for dk in layout.groups:
        grp = {"p": p_pools[dk], "m": m_pools[dk], "v": v_pools[dk]}
        if b_pools is not None:
            grp["gbuf"] = b_pools[dk]
        pools[dk] = grp
    return pools


def pooled_global_norm(pools: dict) -> jax.Array:
    """Global L2 norm over pool buffers: one fused reduction per pool
    (exact, because pad columns hold zeros)."""
    return jnp.sqrt(sum(jnp.sum(p.astype(F32) ** 2) for p in pools.values()))


# ---------------------------------------------------------------------------
# the fused pooled apply
# ---------------------------------------------------------------------------
def _maybe_shard_map(fn, mesh, axes, n_pool_args, n_scalar_args, n_out):
    """Wrap ``fn(pools..., scalars...)`` in shard_map over ``axes`` so each
    device updates only its local ZeRO rows (no XLA-inserted gathers).
    ``mesh=None`` or no data axes → plain call."""
    if mesh is None or not axes:
        return fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    spec = P(axes if len(axes) > 1 else axes[0], None)
    in_specs = (spec,) * n_pool_args + (P(),) * n_scalar_args
    out_specs = (spec,) * n_out if n_out > 1 else spec
    # check_rep=False: pallas_call carries no replication rule
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _block_rows(n_elems: int, interpret: bool) -> int:
    """Tile height for a pooled kernel call.

    Compiled mode keeps the kernels' default VMEM-sized pipeline tiles.
    Interpret mode emulates the grid SEQUENTIALLY with whole-array
    functional updates — cost O(grid_points · pool_size), quadratic for one
    big pool split into many tiles — so there the whole pool is ONE tile
    (grid=1, linear, and exactly what the launch-count story promises)."""
    if not interpret:
        return 256
    return max(1, -(-n_elems // 128))


def _adam_group_fns(cfg: OptConfig, interpret: bool, delayed: bool):
    from ..kernels.async_update import (fused_adam_delayed_pallas,
                                        fused_adam_pallas)

    if delayed:
        def fn(p, m, v, gb, g, clip, count, scale):
            return fused_adam_delayed_pallas(
                p, m, v, gb, g, lr=cfg.lr * scale, beta1=cfg.beta1,
                beta2=cfg.beta2, eps=cfg.eps, count=count, clip_scale=clip,
                weight_decay=cfg.weight_decay, interpret=interpret,
                block_rows=_block_rows(p.size, interpret))
        return fn, 5, 4

    def fn(p, m, v, g, clip, count, scale):
        return fused_adam_pallas(
            p, m, v, g, lr=cfg.lr * scale, beta1=cfg.beta1, beta2=cfg.beta2,
            eps=cfg.eps, count=count, clip_scale=clip,
            weight_decay=cfg.weight_decay, interpret=interpret,
            block_rows=_block_rows(p.size, interpret))
    return fn, 4, 3


def _sgd_group_fns(cfg: OptConfig, interpret: bool, delayed: bool):
    from ..kernels.async_update import (async_update_pallas, sgd_step_pallas,
                                        sgd_momentum_delayed_pallas,
                                        sgd_momentum_step_pallas)

    if cfg.momentum:
        if delayed:
            def fn(p, m, gb, g, clip, count, scale):
                return sgd_momentum_delayed_pallas(
                    p, m, gb, g, lr=cfg.lr, momentum=cfg.momentum,
                    clip_scale=clip, delay_scale=scale, interpret=interpret,
                    block_rows=_block_rows(p.size, interpret))
            return fn, 4, 3

        def fn(p, m, g, clip, count, scale):
            return sgd_momentum_step_pallas(
                p, m, g, lr=cfg.lr, momentum=cfg.momentum, clip_scale=clip,
                delay_scale=scale, interpret=interpret,
                block_rows=_block_rows(p.size, interpret))
        return fn, 3, 2

    if delayed:
        def fn(p, gb, g, clip, count, scale):
            return async_update_pallas(
                p, gb, g, lr=cfg.lr, clip_scale=clip, delay_scale=scale,
                interpret=interpret,
                block_rows=_block_rows(p.size, interpret))
        return fn, 3, 2

    def fn(p, g, clip, count, scale):
        return sgd_step_pallas(
            p, g, lr=cfg.lr, clip_scale=clip, delay_scale=scale,
            interpret=interpret, block_rows=_block_rows(p.size, interpret))
    return fn, 2, 1


def _apply_groups(grad_pools, pools, count, cfg: OptConfig, lr_scale, *,
                  delayed: bool, mesh, axes, interpret):
    """Shared body of :func:`pooled_update` / :func:`pooled_delayed_apply`."""
    if interpret is None:   # auto: compiled on TPU, interpreter elsewhere
        interpret = jax.default_backend() != "tpu"
    source = ({dk: pools[dk]["gbuf"] for dk in pools} if delayed
              else grad_pools)
    gnorm = pooled_global_norm(source)
    clip = clip_scale_from_norm(gnorm, cfg.clip_norm)
    new_count = count + 1
    scale = jnp.asarray(lr_scale, F32)

    if cfg.name == "adam":
        fn, n_in, n_out = _adam_group_fns(cfg, interpret, delayed)
    elif cfg.name == "sgd":
        fn, n_in, n_out = _sgd_group_fns(cfg, interpret, delayed)
    else:
        raise ValueError(cfg.name)
    fn = _maybe_shard_map(fn, mesh, axes, n_in, 3, n_out)

    new_pools = {}
    for dk, bufs in pools.items():
        g = grad_pools[dk]
        if cfg.name == "adam":
            args = (bufs["p"], bufs["m"], bufs["v"]) \
                + ((bufs["gbuf"],) if delayed else ()) + (g,)
            out = fn(*args, clip, new_count, scale)
            new = {"p": out[0], "m": out[1], "v": out[2]}
            if delayed:
                new["gbuf"] = out[3]
        elif cfg.momentum:
            args = (bufs["p"], bufs["m"]) \
                + ((bufs["gbuf"],) if delayed else ()) + (g,)
            out = fn(*args, clip, new_count, scale)
            new = {"p": out[0], "m": out[1], "v": bufs["v"]}
            if delayed:
                new["gbuf"] = out[2]
        else:
            args = (bufs["p"],) + ((bufs["gbuf"],) if delayed else ()) + (g,)
            out = fn(*args, clip, new_count, scale)
            out = out if isinstance(out, tuple) else (out,)
            new = {"p": out[0], "m": bufs["m"], "v": bufs["v"]}
            if delayed:
                new["gbuf"] = out[1]
        new_pools[dk] = new
    return new_pools, new_count, gnorm


def pooled_update(grad_pools, pools, count, cfg: OptConfig, lr_scale=1.0, *,
                  mesh=None, axes=(), interpret=None):
    """Synchronous pooled server update (``delay_rounds == 0``):

        pools' ← step(pools; clip·grad_pools),  one kernel per dtype pool.

    ``pools`` is ``{dtype: {"p", "m", "v"}}``; returns
    ``(new_pools, new_count, gnorm)`` with ``gnorm`` the pre-clip norm of
    the applied gradient — the pooled analogue of the
    ``make_optimizer`` update contract.  ``interpret=None`` auto-selects:
    compiled Mosaic kernels on TPU, the Pallas interpreter elsewhere."""
    return _apply_groups(grad_pools, pools, count, cfg, lr_scale,
                         delayed=False, mesh=mesh, axes=tuple(axes),
                         interpret=interpret)


def pooled_delayed_apply(grad_pools, pools, count, cfg: OptConfig,
                         lr_scale=1.0, *, mesh=None, axes=(),
                         interpret=None):
    """The delayed server update (eq. 2) over pooled state, one
    ``pallas_call`` per dtype pool:

        p', m', v' ← step(p, m, v; clip·gbuf)   (apply the STALE gradient)
        gbuf'      ← grad_pools                 (buffer the fresh one)

    ``pools`` is ``{dtype: {"p", "m", "v", "gbuf"}}``.  With ``mesh`` and
    ``axes`` (the mesh's data-axis names) the kernels run under
    ``shard_map``: each device updates only its local ZeRO rows.  Returns
    ``(new_pools, new_count, gnorm)``; ``gnorm`` is the pre-clip norm of
    the APPLIED (stale) gradient.  ``interpret=None`` auto-selects:
    compiled Mosaic kernels on TPU, the Pallas interpreter elsewhere."""
    return _apply_groups(grad_pools, pools, count, cfg, lr_scale,
                         delayed=True, mesh=mesh, axes=tuple(axes),
                         interpret=interpret)
