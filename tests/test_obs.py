"""Suite for ``repro.obs`` — the tracing + metrics layer.

The two load-bearing contracts:

* **observability never changes computed bits** — a run with a Recorder
  attached produces bit-identical state/curves to the same run without
  one (the tracer reads host boundaries that already exist; it never
  adds a device sync), and
* **the exports are real formats** — ``trace.json`` is structurally
  valid Chrome trace-event JSON (what Perfetto loads) and the JSONL
  metrics log round-trips through its own versioned schema validator.

Plus unit coverage for the Tracer primitives, the CompileWatch retrace
sentinel, and the end-to-end wiring (executor counters match ExecStats,
SlotServer trace carries the admission story, snapshot spans show the
async overlap, ``extra["obs"]`` survives RunResult JSON round-trips).
"""
import json
import pathlib
import subprocess
import sys

import numpy as np
import jax
import pytest

from repro.api import ExperimentSpec, RunResult, TrainJob, TrainerBackend
from repro.obs import (CompileWatch, METRICS_SCHEMA_VERSION, Recorder,
                       RetraceError, SchemaError, Tracer, render_summary,
                       validate_line, validate_lines, validate_metrics_log)
from repro.obs import schema as obs_schema
from repro.runtime import PlanExecutor, compile_plan

MICRO = (("n_layers", 1), ("d_model", 64), ("n_heads", 2), ("n_kv_heads", 1),
         ("d_ff", 64), ("vocab", 97))
TOL = dict(rtol=1e-5, atol=1e-7)


def _job(**kw):
    kw.setdefault("arch", "qwen2-0.5b")
    kw.setdefault("global_batch", 8)
    kw.setdefault("seq_len", 16)
    kw.setdefault("arch_overrides", MICRO)
    return TrainJob(**kw)


def _spec(job, T=6, **kw):
    return ExperimentSpec(scheduler="shuffled", timing="poisson:slow=6",
                          objective=job, T=T, n_workers=4, seed=0,
                          stepsize=3e-3, **kw)


def _trainer(job):
    from jax.sharding import Mesh
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.optim import OptConfig

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    tr = AsyncTrainer(job.make_arch(), mesh,
                      opt=OptConfig(lr=3e-3, clip_norm=job.clip_norm),
                      async_cfg=AsyncConfig(delay_rounds=job.delay_rounds))
    tr.n_groups = 4
    return tr


def _plan_for(spec, job):
    _, schedule = TrainerBackend.masks_for(spec, 4)
    return compile_plan(schedule, job, rounds=spec.T, n_groups=4,
                        seed=spec.seed)


def _assert_states_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Tracer primitives
# ---------------------------------------------------------------------------

def test_tracer_primitives_and_phase_table():
    tr = Tracer()
    with tr.span("launch", "executor", chunk=0):
        pass
    with tr.span("launch", "executor", chunk=1):
        pass
    t0 = tr.now_ns()
    tr.span_at("request", "slot0", t0, t0 + 3_000_000, rid=7)
    tr.instant("tap_round", lane="tap", round=0)
    tr.count("rounds", 5)
    tr.count("rounds", 3)
    tr.gauge("occupancy", 0.5, lane="server")
    tr.hist("ttft_steps", 1.0)
    tr.hist("ttft_steps", 3.0)

    phases = tr.phase_table()
    assert phases["launch"]["count"] == 2
    assert phases["request"]["count"] == 1
    assert phases["request"]["total_s"] == pytest.approx(0.003)
    assert tr.counters() == {"rounds": 8}
    h = tr.hist_summaries()["ttft_steps"]
    assert h["count"] == 2 and h["min"] == 1.0 and h["max"] == 3.0
    assert h["mean"] == pytest.approx(2.0)
    assert tr.wall_s > 0


def test_chrome_trace_structure():
    """The envelope Perfetto's loader accepts: M thread-name metadata per
    lane, X spans with µs ts/dur, thread-scoped instants, C counters."""
    tr = Tracer()
    with tr.span("launch", "executor", lo=0, hi=4):
        pass
    tr.instant("compile", lane="compile", fn="chunk[tap]",
               signatures=np.int64(2))       # numpy arg must degrade
    tr.gauge("gscale", 0.5, lane="faults")
    doc = tr.chrome_trace()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    ev = doc["traceEvents"]
    meta = [e for e in ev if e["ph"] == "M"]
    assert {"repro"} == {e["args"]["name"] for e in meta
                         if e["name"] == "process_name"}
    lanes = {e["args"]["name"]: e["tid"] for e in meta
             if e["name"] == "thread_name"}
    assert set(lanes) == {"executor", "compile", "faults"}
    (x,) = [e for e in ev if e["ph"] == "X"]
    assert x["name"] == "launch" and x["tid"] == lanes["executor"]
    assert x["dur"] >= 0 and x["args"] == {"lo": 0, "hi": 4}
    (i,) = [e for e in ev if e["ph"] == "i"]
    assert i["s"] == "t" and i["args"]["signatures"] == 2.0
    (c,) = [e for e in ev if e["ph"] == "C"]
    assert c["args"] == {"gscale": 0.5}
    json.dumps(doc)                          # numpy degraded, serialisable


def test_span_survives_exception():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("launch", "executor"):
            raise ValueError("boom")
    assert tr.phase_table()["launch"]["count"] == 1


# ---------------------------------------------------------------------------
# the metrics log schema
# ---------------------------------------------------------------------------

def test_metrics_log_round_trip(tmp_path):
    tr = Tracer()
    tr.count("rounds", 6)
    tr.count("launches", 2)
    tr.gauge("occupancy", 0.75, lane="server")
    tr.hist("ttft_steps", 2.0)
    path = tr.export_metrics(str(tmp_path / "m.jsonl"))
    counts = validate_metrics_log(path)
    assert counts == {"header": 1, "gauge": 1, "counter": 2, "hist": 1}
    first = json.loads(open(path).readline())
    assert first["kind"] == "header" and first["v"] == METRICS_SCHEMA_VERSION


def test_schema_rejects_bad_lines():
    ok = {"v": 1, "kind": "counter", "name": "rounds", "value": 6}
    assert validate_line(ok) == "counter"
    with pytest.raises(SchemaError, match="schema version"):
        validate_line({**ok, "v": 2})
    with pytest.raises(SchemaError, match="unknown kind"):
        validate_line({**ok, "kind": "summary"})
    with pytest.raises(SchemaError, match="missing"):
        validate_line({"v": 1, "kind": "counter", "name": "rounds"})
    # bool is an int subclass — numeric fields must still reject it
    with pytest.raises(SchemaError, match="bool"):
        validate_line({**ok, "value": True})


def test_schema_structural_rules():
    head = {"v": 1, "kind": "header", "source": "t", "wall_s": 0.1,
            "created_unix": 1.0}
    cnt = {"v": 1, "kind": "counter", "name": "r", "value": 1}
    assert validate_lines([head, cnt]) == {"header": 1, "counter": 1}
    with pytest.raises(SchemaError, match="header"):
        validate_lines([cnt])                        # no header at all
    with pytest.raises(SchemaError, match="line 1"):
        validate_lines([cnt, head])                  # header not first
    with pytest.raises(SchemaError, match="unique"):
        validate_lines([head, head])


def test_schema_cli_gate(tmp_path):
    """``python -m repro.obs.schema`` is the CI gate: exit 0 + a count
    line on a valid log, non-zero on a corrupt one."""
    tr = Tracer()
    tr.count("rounds", 1)
    good = tr.export_metrics(str(tmp_path / "good.jsonl"))
    obs_schema.main([good])                          # must not raise
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"v": 99, "kind": "counter"}\n')
    with pytest.raises(SchemaError):
        obs_schema.main([str(bad)])
    root = pathlib.Path(__file__).resolve().parent.parent
    r = subprocess.run([sys.executable, "-m", "repro.obs.schema", str(bad)],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": str(root / "src"),
                            "PATH": "/usr/bin:/bin"})
    assert r.returncode != 0


# ---------------------------------------------------------------------------
# CompileWatch: the generalised retrace sentinel
# ---------------------------------------------------------------------------

class _FakeJit:
    """Stands in for a jax.jit callable: grows a traced-signature set."""

    def __init__(self):
        self._sigs = set()

    def __call__(self, x):
        self._sigs.add(np.asarray(x).shape)
        return x

    def _cache_size(self):
        return len(self._sigs)


def test_compile_watch_records_growth():
    rec = Recorder()
    watch = CompileWatch(rec)
    fn = watch.wrap("chunk", _FakeJit())
    assert fn.__wrapped_jit__ is not None
    assert fn(np.zeros(3)) is not None               # first trace
    fn(np.zeros(3))                                  # cache hit: no event
    fn(np.zeros((2, 2)))                             # retrace
    assert watch.counts() == {"chunk": 2}
    assert rec.tracer.counters()["compiles"] == 2
    compiles = [e for e in rec.tracer.chrome_trace()["traceEvents"]
                if e.get("name") == "compile"]
    assert len(compiles) == 2
    assert compiles[-1]["args"] == {"fn": "chunk", "signatures": 2}


def test_compile_watch_steady_contract():
    watch = CompileWatch()
    fn = watch.wrap("chunk", _FakeJit())
    with pytest.raises(RetraceError, match="before mark_steady"):
        watch.check_steady()
    fn(np.zeros(3))
    assert watch.mark_steady() == {"chunk": 1}
    fn(np.zeros(3))
    watch.check_steady()                             # warm reuse: fine
    fn(np.zeros(5))                                  # steady-state retrace
    with pytest.raises(RetraceError, match=r"chunk: 1 -> 2"):
        watch.check_steady()


def test_compile_watch_unsizeable_fn_degrades():
    watch = CompileWatch()
    watch.register("plain", lambda x: x)             # no _cache_size
    assert watch.counts() == {"plain": -1}
    watch.observe()                                  # must not raise


# ---------------------------------------------------------------------------
# executor integration: parity + honest trace content
# ---------------------------------------------------------------------------

def test_scan_with_recorder_is_bit_identical_and_traced(tmp_path):
    """The acceptance bar: attaching a Recorder to the tap transport
    changes NOTHING computed (bitwise state + curves) while the trace
    tells the true dispatch story (launch spans == launches, tap_round
    instants == rounds) and both exports validate."""
    job = _job()
    spec = _spec(job, T=6)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    plain = PlanExecutor(tr, plan, donate=False).run_scan(
        tr.init_state(jax.random.PRNGKey(0)), rounds_per_launch=4,
        metrics="tap")
    rec = Recorder()
    ex = PlanExecutor(tr, plan, donate=False, recorder=rec)
    res = ex.run_scan(tr.init_state(jax.random.PRNGKey(0)),
                      rounds_per_launch=4, metrics="tap")
    _assert_states_equal(plain.state, res.state)
    for k, v in plain.metrics.items():
        np.testing.assert_array_equal(v, res.metrics[k])

    counters = rec.tracer.counters()
    assert counters["rounds"] == 6
    assert counters["launches"] == res.stats.launches == 2
    assert counters["tap_events"] == res.stats.tap_events == 6
    assert counters["host_syncs"] == res.stats.host_syncs == 0
    phases = rec.tracer.phase_table()
    assert phases["launch"]["count"] == 2
    taps = [e for e in rec.tracer.chrome_trace()["traceEvents"]
            if e.get("name") == "tap_round"]
    assert len(taps) == 6 and all(e["ph"] == "i" for e in taps)
    # the retrace sentinel saw the warm-up compiles
    assert ex.compile_counts()["chunk[tap]"] >= 1
    assert counters["compiles"] >= 1

    trace = json.load(open(rec.export_chrome(str(tmp_path / "t.json"))))
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"launch", "barrier", "tap_round"} <= names
    validate_metrics_log(rec.export_metrics(str(tmp_path / "m.jsonl")))


def test_chunk_transport_records_host_syncs():
    job = _job()
    spec = _spec(job, T=6)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    rec = Recorder()
    # an on_step forces the per-chunk readback (without it the transport
    # defers to ONE end-of-run sync — also worth asserting)
    res = PlanExecutor(tr, plan, donate=False, recorder=rec).run_scan(
        tr.init_state(jax.random.PRNGKey(0)), rounds_per_launch=3,
        metrics="chunk", on_step=lambda i, st, m: None)
    c = rec.tracer.counters()
    assert c["host_syncs"] == res.stats.host_syncs == 2
    assert rec.tracer.phase_table()["host_sync"]["count"] == 2

    rec2 = Recorder()
    res2 = PlanExecutor(tr, plan, donate=False, recorder=rec2).run_scan(
        tr.init_state(jax.random.PRNGKey(0)), rounds_per_launch=3,
        metrics="chunk")
    assert rec2.tracer.counters()["host_syncs"] == res2.stats.host_syncs == 1
    syncs = rec2.tracer.phase_table()["host_sync"]
    assert syncs["count"] == 1


def test_eager_runtime_traces_per_round():
    job = _job()
    spec = _spec(job, T=4)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    rec = Recorder()
    res = PlanExecutor(tr, plan, donate=False, recorder=rec).run_eager(
        tr.init_state(jax.random.PRNGKey(0)))
    c = rec.tracer.counters()
    assert c["rounds"] == 4
    assert c["launches"] == res.stats.launches == 4
    assert rec.tracer.phase_table()["launch"]["count"] == 4


# ---------------------------------------------------------------------------
# snapshot + server integration
# ---------------------------------------------------------------------------

def test_snapshot_spans_show_async_overlap(tmp_path):
    from repro.checkpoint import AsyncSnapshotter

    job = _job()
    spec = _spec(job, T=8)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    rec = Recorder()
    snap = AsyncSnapshotter(str(tmp_path / "snaps"), 4, meta={"arch": "t"})
    res = PlanExecutor(tr, plan, donate=False, recorder=rec).run_scan(
        tr.init_state(jax.random.PRNGKey(0)), rounds_per_launch=4,
        metrics="tap", snapshot=snap)
    assert res.stats.snapshots == 2
    c = rec.tracer.counters()
    assert c["snapshots"] == 2
    assert c["snapshot_writes"] == 2                 # drained by run end
    phases = rec.tracer.phase_table()
    assert phases["snapshot_offer"]["count"] == 2
    assert phases["snapshot_copy"]["count"] == 2
    assert phases["snapshot_finalise"]["count"] == 2


def test_slot_server_trace_tells_admission_story(tmp_path):
    from repro.configs import get_arch
    from repro.distributed import SlotConfig, SlotServer
    from repro.models import init_params
    from jax.sharding import Mesh

    cfg = get_arch("qwen2-0.5b").reduced().with_(
        remat="none", n_layers=1, d_model=8, n_heads=1, n_kv_heads=1,
        d_ff=16, vocab=127)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (5, 5)).astype(np.int32)

    rec = Recorder()
    srv = SlotServer(cfg, mesh, SlotConfig(n_slots=2, ctx_len=16,
                                           steps_per_launch=2),
                     recorder=rec)
    plain = SlotServer(cfg, mesh, SlotConfig(n_slots=2, ctx_len=16,
                                             steps_per_launch=2))
    arrivals = np.array([0, 0, 1, 3, 6])
    res = srv.serve(params, prompts, 6, admission="shuffled",
                    arrivals=arrivals)
    ref = plain.serve(params, prompts, 6, admission="shuffled",
                      arrivals=arrivals)
    np.testing.assert_array_equal(ref.tokens, res.tokens)  # obs is inert

    # the retrace gate's registry shape survived the CompileWatch move
    counts = srv.compile_counts()
    assert counts["chunk"] == 1 and counts["admit"] == 1
    assert counts["prefill[5]"] == 1
    c = rec.tracer.counters()
    assert c["requests"] == 5
    assert c["completions"] == 5
    phases = rec.tracer.phase_table()
    assert phases["admit"]["count"] == 5
    assert phases["prefill"]["count"] == 5
    assert phases["request"]["count"] == 5           # one span per rid
    trace = json.load(open(rec.export_chrome(str(tmp_path / "s.json"))))
    lanes = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"server", "slot0", "slot1"} <= lanes
    assert "ttft_steps" in rec.tracer.hist_summaries()


# ---------------------------------------------------------------------------
# the summary surface
# ---------------------------------------------------------------------------

def test_obs_summary_survives_runresult_json():
    job = _job()
    spec = _spec(job, T=6, runtime="scan", rounds_per_launch=3,
                 metrics="tap")
    rec = Recorder()
    backend = TrainerBackend(
        mesh=None, recorder=rec)
    res = backend.run(spec)
    obs = res.extra["obs"]
    assert obs["schema_version"] == METRICS_SCHEMA_VERSION
    assert obs["counters"]["rounds"] == 6
    restored = RunResult.from_json(res.to_json())
    assert restored.extra["obs"]["counters"] == obs["counters"]
    text = render_summary(restored.extra["obs"], trace=restored.trace)
    assert "launch" in text and "rounds/s" in text
    assert "tau_max" in text
    # satellite: breaker/snapshot state surfaced next to obs
    assert "tripped_round" in res.extra


def test_render_summary_handles_empty():
    assert "(no spans recorded)" in render_summary({"wall_s": 0.0})
