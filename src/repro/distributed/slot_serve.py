"""Slot-based continuous-batching serving: one compiled ragged decode loop.

The lock-step :class:`~repro.distributed.serve.Server` decodes a fixed
batch where every request starts and finishes together.  This module is
the production shape: ``n_slots`` persistent decode lanes, each carrying
its own position / activity / budget, stepped by ONE compiled program —
the serving analogue of the executor's per-round participation masks.

Design, mirroring the repo's schedule-is-value-independent thesis:

* **Device**: a chunk of ``steps_per_launch`` ragged decode steps runs as
  a ``lax.scan`` whose body calls ``models.decode_step`` with VECTOR
  ``pos`` (per-slot positions, ``cache_specs(..., ragged=True)``).
  Inactive slots freeze (token/pos/remaining held by the active mask) and
  their ring re-writes are idempotent, so masking replaces control flow —
  the program never retraces as requests come and go.  Each step streams
  ``(step, tokens, active)`` host-ward through an ordered ``io_callback``
  tap (the PR 5 idiom), so per-request consumers receive tokens while the
  device keeps decoding — the host never barriers the loop.
* **Host**: with a fixed per-request token budget there is no
  content-dependent exit, so admissions, completions, occupancy and TTFT
  are pure bookkeeping — ZERO device readbacks steer the loop.  Admission
  (which queued request fills a freed slot, at chunk boundaries) is a
  registry scheduler via :class:`~repro.distributed.admission.AdmissionPolicy`,
  and the realised trace lowers to an ordinary ``Schedule`` for
  ``scenarios.tau_report``.
* **Prefill** is folded in per admitted request: a cached batch-1 prefill
  jit produces the first token + a ctx-length cache, and a cached ``admit``
  jit writes the row into the slot cache at a *traced* slot index — one
  compile covers every admission.
* **Sampling state is per-request**, not per-pool: each slot carries its
  own PRNG key, reset at admission to ``fold_in(PRNGKey(seed), rid)`` and
  split once per decode step.  A request's sampled token stream is a pure
  function of (seed, rid, step-within-request) — independent of slot
  assignment, pool size and whatever else is decoding alongside it.
* **Degradation is masked, not crashed**: an active lane whose decode
  logits go non-finite is QUARANTINED on device (its budget zeroed, no
  token emitted) and the eviction surfaces host-side through the tap so
  the admission trace records it; queued requests whose wait exceeds a
  ``deadline`` are timed out at admission sweeps without ever occupying a
  slot.  Both degrade per-request — the pool keeps serving.

Compiled artifacts are cached on the instance (the PlanExecutor rule: a
fresh closure per call would silently recompile every run), asserted by
:meth:`SlotServer.compile_counts`.
"""
from __future__ import annotations

import dataclasses
from contextlib import nullcontext
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import model as M
from ..obs import CompileWatch
from .admission import AdmissionPolicy, AdmissionTrace, parse_admission
from .sharding import Rules, DEFAULT_RULES, sharded_trace, tree_shardings


def _span(rec, name, lane, **args):
    """Optional-recorder span (no-op without one — un-observed serves
    pay nothing on the dispatch path)."""
    return rec.span(name, lane, **args) if rec is not None else nullcontext()


@dataclasses.dataclass
class SlotConfig:
    """Knobs of the slot loop.

    ``steps_per_launch`` is the decode analogue of the executor's
    ``rounds_per_launch``: admissions land at chunk boundaries, so it
    trades admission latency against dispatch amortisation.
    """

    n_slots: int
    ctx_len: int
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0
    steps_per_launch: int = 8

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.steps_per_launch < 1:
            raise ValueError("steps_per_launch must be >= 1")


@dataclasses.dataclass
class ServeResult:
    """Per-request token matrix + the realised admission world.

    Degraded requests pad: an evicted request's ``tokens`` row holds −1
    from its quarantine step on; a timed-out request's row is all −1 and
    its ``ttft_steps`` entry is −1 (it was never admitted).
    """

    tokens: np.ndarray           # (n_requests, max_new) int32, −1 padded
    schedule: object             # repro.core.engine.Schedule of admissions
    ttft_steps: np.ndarray       # (n_requests,) admission − arrival (steps)
    occupancy: float             # mean fraction of busy slot-steps
    decode_steps: int            # launched scan steps (incl. drained tail)
    chunks: int                  # XLA launches of the chunk program
    tap_rows: int                # ordered io_callback rows delivered
    evictions: dict = dataclasses.field(default_factory=dict)
    #: rid -> decode step its lane was quarantined (non-finite logits)
    timeouts: dict = dataclasses.field(default_factory=dict)
    #: rid -> decode step its queue wait exceeded the deadline


class SlotServer:
    """Continuous-batching decode over ``n_slots`` ragged lanes."""

    def __init__(self, cfg: ArchConfig, mesh: Mesh, slots: SlotConfig,
                 rules: Rules = DEFAULT_RULES, recorder=None):
        if cfg.family in ("vlm", "audio"):
            raise NotImplementedError(
                f"slot serving admits token-only prompts; the {cfg.family!r} "
                "family needs per-request modality inputs (follow-up)")
        self.cfg, self.mesh, self.slots, self.rules = cfg, mesh, slots, rules
        self.recorder = recorder      # repro.obs.Recorder | None
        self.watch = CompileWatch(recorder)   # retrace sentinel
        self._chunk_fn = None         # cached jitted chunk program
        self._admit_fn = None         # cached jitted slot writer
        self._prefill_jits = {}       # prompt_len -> jitted batch-1 prefill
        self._tap_sink = None         # per-run host consumer of tap rows

    # ---- shardings ---------------------------------------------------------
    def param_shardings(self):
        return tree_shardings(M.param_specs(self.cfg), self.mesh, self.rules)

    def state_shardings(self):
        S = self.slots.n_slots
        cache_sh = tree_shardings(
            M.cache_specs(self.cfg, S, self.slots.ctx_len, ragged=True),
            self.mesh, self.rules)
        lane = NamedSharding(self.mesh, P(self.rules.data_axes[-1]
                                          if S > 1 else None))
        repl = NamedSharding(self.mesh, P())
        return {"cache": cache_sh, "toks": lane, "pos": lane,
                "active": lane, "remaining": lane, "keys": repl}

    # ---- state -------------------------------------------------------------
    def init_state(self) -> dict:
        """All slots empty: inactive lanes decode-and-discard until a
        request is admitted (their writes are idempotent)."""
        S = self.slots.n_slots
        state = {
            "cache": M.init_cache(self.cfg, S, self.slots.ctx_len,
                                  ragged=True),
            "toks": jnp.zeros((S,), jnp.int32),
            "pos": jnp.zeros((S,), jnp.int32),
            "active": jnp.zeros((S,), bool),
            "remaining": jnp.zeros((S,), jnp.int32),
            # (S, 2) per-slot sampling keys; placeholders until admission
            # re-seeds each slot with its request's fold_in key
            "keys": jnp.tile(jax.random.PRNGKey(self.slots.seed)[None],
                             (S, 1)),
        }
        # pin the canonical shardings up front: every producer of a state
        # tree (init / admit / chunk) must agree, or the jits re-specialise
        # on their first post-admission call
        return jax.device_put(state, self.state_shardings())

    # ---- tap ---------------------------------------------------------------
    def _emit_tap(self, idx, toks, active, quarantined):
        """Host side of the ordered io_callback (bound once so the chunk
        program stays stable; the per-run consumer swaps in via
        ``_tap_sink``)."""
        sink = self._tap_sink
        if sink is not None:
            sink(int(idx), np.asarray(toks), np.asarray(active),
                 np.asarray(quarantined))

    # ---- compiled programs -------------------------------------------------
    def chunk_fn(self):
        """Jitted ``chunk(params, state, idx0) -> state``: K ragged decode
        steps with per-step tap emission.  Compiled once; ``idx0`` is a
        traced scalar so chunk position never retraces."""
        if self._chunk_fn is not None:
            return self._chunk_fn
        from jax.experimental import io_callback

        cfg, ctx = self.cfg, self.slots.ctx_len
        temp, K = self.slots.temperature, self.slots.steps_per_launch
        emit = self._emit_tap

        def decode(params, cache, toks, pos):
            return M.decode_step(cfg, params, cache, toks, pos, ctx)

        decode = sharded_trace(decode, self.mesh, self.rules)

        def chunk(params, state, idx0):
            def round_fn(st, idx):
                logits, cache = decode(params, st["cache"], st["toks"],
                                       st["pos"])
                act = st["active"]
                # quarantine: an active lane whose logits go non-finite is
                # evicted in-mask — no token this step, budget zeroed so the
                # lane freezes (idempotent writes) until re-admission; the
                # rest of the pool is untouched
                finite = jnp.all(jnp.isfinite(logits), axis=-1)
                quar = act & ~finite
                act = act & finite
                keys = st["keys"]
                if temp > 0:
                    # per-slot streams: each lane splits its own key, so a
                    # request's samples depend only on (seed, rid, step)
                    pair = jax.vmap(jax.random.split)(keys)      # (S, 2, 2)
                    keys, subs = pair[:, 0], pair[:, 1]
                    nxt = jax.vmap(lambda k, lg: jax.random.categorical(
                        k, lg / temp))(subs, logits).astype(jnp.int32)
                else:
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                step = act.astype(jnp.int32)
                toks = jnp.where(act, nxt, st["toks"])
                rem = (st["remaining"] - step) * (~quar).astype(jnp.int32)
                # ordered: per-request consumers see tokens in decode order
                io_callback(emit, None, idx, toks, act, quar, ordered=True)
                return {"cache": cache, "toks": toks,
                        "pos": st["pos"] + step,
                        "active": act & (rem > 0), "remaining": rem,
                        "keys": keys}, None

            state, _ = jax.lax.scan(
                round_fn, state, idx0 + jnp.arange(K, dtype=jnp.int32))
            return state

        self._chunk_fn = self.watch.wrap("chunk", jax.jit(
            chunk,
            in_shardings=(self.param_shardings(), self.state_shardings(),
                          NamedSharding(self.mesh, P())),
            out_shardings=self.state_shardings(),
            donate_argnums=(1,)))
        return self._chunk_fn

    def admit_fn(self):
        """Jitted ``admit(state, pcache, slot, tok0, pos0, rem0, key)``:
        write a prefilled request into slot ``slot`` (a TRACED index — one
        compile covers every admission into any slot).  ``key`` is the
        request's own sampling key (``fold_in(PRNGKey(seed), rid)``) — it
        resets the slot's stream so sampling never leaks across the
        requests that share a lane over time."""
        if self._admit_fn is not None:
            return self._admit_fn

        def admit(state, pcache, slot, tok0, pos0, rem0, key):
            def wr(c, p):
                if c.ndim == p.ndim + 1:      # per-slot positions row
                    return jax.lax.dynamic_update_slice(
                        c, p[None].astype(c.dtype), (slot, 0))
                # every other leaf: (layers, batch=n_slots, ...) ← batch-1 row
                start = (0, slot) + (0,) * (c.ndim - 2)
                return jax.lax.dynamic_update_slice(c, p.astype(c.dtype),
                                                    start)

            return {
                "cache": jax.tree_util.tree_map(wr, state["cache"], pcache),
                "toks": state["toks"].at[slot].set(tok0),
                "pos": state["pos"].at[slot].set(pos0),
                "active": state["active"].at[slot].set(rem0 > 0),
                "remaining": state["remaining"].at[slot].set(rem0),
                "keys": state["keys"].at[slot].set(key),
            }

        self._admit_fn = self.watch.wrap("admit", jax.jit(
            admit, out_shardings=self.state_shardings(),
            donate_argnums=(0,)))
        return self._admit_fn

    def prefill_fn(self, prompt_len: int):
        """Jitted batch-1 prefill → (first token (1,), ctx-length cache);
        cached per prompt length."""
        fn = self._prefill_jits.get(prompt_len)
        if fn is None:
            cfg, ctx = self.cfg, self.slots.ctx_len

            def pf(params, tokens):
                logits, cache = M.prefill(cfg, params, {"tokens": tokens},
                                          ctx_len=ctx)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

            fn = self.watch.wrap(f"prefill[{prompt_len}]", jax.jit(pf))
            self._prefill_jits[prompt_len] = fn
        return fn

    def compile_counts(self) -> dict:
        """Traced-signature counts of the cached jits (the no-retrace
        gate: rotating requests through freed slots must keep these at 1
        per program).  Backed by the :class:`repro.obs.CompileWatch`
        retrace sentinel — with a recorder attached, every compile also
        lands as an instant in the trace."""
        return self.watch.counts()

    # ---- driver ------------------------------------------------------------
    def serve(self, params, prompts: np.ndarray, max_new: int, *,
              admission: Union[str, AdmissionPolicy] = "pure",
              arrivals: Optional[np.ndarray] = None,
              deadline: Optional[int] = None,
              on_token: Optional[Callable] = None) -> ServeResult:
        """Serve every prompt to its ``max_new``-token budget.

        prompts: (n_requests, prompt_len) int32; ``arrivals``: optional
        (n_requests,) arrival steps on the decode-step clock (see
        :func:`~repro.distributed.admission.draw_arrivals`); ``admission``:
        a policy name/compact spec or a prepared :class:`AdmissionPolicy`;
        ``deadline``: optional queue-wait budget in decode steps — a
        request still queued when ``now − arrival > deadline`` is timed
        out at the admission sweep (chunk-boundary granularity) and never
        occupies a slot; ``on_token(rid, token, step)`` fires per streamed
        token from the tap thread (token already a host int).

        The loop is steered entirely by host bookkeeping: completions are
        deterministic (``admit_step + max_new − 1``), so no device value is
        ever read to decide admission — only the final token matrix is
        assembled from the tap stream.  Quarantine evictions are the one
        DEVICE-initiated event: the host learns of them from the tap (so
        possibly chunks late), keeps the slot allocated until the original
        completion step (the frozen lane idle-decodes harmlessly), and
        records the eviction in the result + admission trace.
        """
        S, K = self.slots.n_slots, self.slots.steps_per_launch
        n_req, plen = prompts.shape
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if plen + max_new > self.slots.ctx_len:
            raise ValueError(
                f"prompt_len + max_new = {plen + max_new} exceeds "
                f"ctx_len = {self.slots.ctx_len}")
        if isinstance(admission, AdmissionPolicy):
            policy = admission
        else:
            name, b = parse_admission(admission)
            policy = AdmissionPolicy(name, n_req, b=b,
                                     seed=self.slots.seed)
        arr = (np.zeros(n_req, np.int64) if arrivals is None
               else np.asarray(arrivals, np.int64))
        if arr.shape != (n_req,):
            raise ValueError(f"arrivals must be ({n_req},); got {arr.shape}")
        if deadline is not None and deadline < 0:
            raise ValueError(f"deadline must be >= 0 (got {deadline})")

        chunk = self.chunk_fn()
        admit = self.admit_fn()
        pf = self.prefill_fn(plen)
        prompts_dev = jnp.asarray(prompts, jnp.int32)
        base_key = jax.random.PRNGKey(self.slots.seed)

        trace = AdmissionTrace(n_req, wait_b=policy.wait_b)
        state = self.init_state()
        rec = self.recorder
        slot_rid = [-1] * S
        fin: dict = {}                # rid -> completion step
        admit_t: dict = {}            # rid -> admission step
        outputs: dict = {}            # rid -> [tok0_dev, host ints...]
        step_maps: dict = {}          # chunk start -> slot_rid snapshot
        req_ns: dict = {}             # rid -> admission wall-clock ns (obs)
        tap_stats = {"rows": 0}
        mismatches: list = []
        evicted: dict = {}            # rid -> quarantine step (from tap)
        timeouts: dict = {}           # rid -> timeout step (host sweep)

        def sink(idx, toks, act, quar):
            tap_stats["rows"] += 1
            m = step_maps.get(idx - idx % K)
            if m is None:
                mismatches.append(f"step {idx}: no chunk snapshot")
                return
            for s, rid in enumerate(m):
                if bool(quar[s]):
                    if rid < 0:
                        mismatches.append(
                            f"step {idx} slot {s}: quarantine on an empty "
                            "lane")
                        continue
                    if rid not in evicted:
                        evicted[rid] = int(idx)
                        trace.evicted(rid, int(idx))
                        if rec is not None:
                            rec.instant("evict", lane="faults", rid=rid,
                                        step=int(idx))
                            rec.count("evictions")
                ev = evicted.get(rid) if rid >= 0 else None
                predicted = (rid >= 0
                             and (idx - admit_t[rid]) < max_new - 1
                             and (ev is None or idx < ev))
                if bool(act[s]) != predicted:
                    mismatches.append(
                        f"step {idx} slot {s}: device active={bool(act[s])} "
                        f"!= host-predicted {predicted}")
                    continue
                if predicted:
                    tok = int(toks[s])
                    outputs[rid].append(tok)
                    if on_token is not None:
                        on_token(rid, tok, int(idx))

        t, chunks, in_flight, done = 0, 0, 0, 0
        busy_steps = 0
        horizon = 2 * (int(arr.max(initial=0)) + n_req * max_new + K) + 4 * K
        self._tap_sink = sink
        try:
            while done < n_req:
                if t > horizon:
                    raise RuntimeError(
                        f"slot loop passed its horizon ({horizon} steps) "
                        f"with {n_req - done} requests unfinished — "
                        "admission bookkeeping is stuck")
                sweep0 = rec.now_ns() if rec is not None else 0
                # -- completions (deterministic, no readback) --------------
                freed = sorted(
                    (s for s in range(S)
                     if slot_rid[s] >= 0 and fin[slot_rid[s]] <= t),
                    key=lambda s: (fin[slot_rid[s]], s))
                for s in freed:
                    rid, slot_rid[s] = slot_rid[s], -1
                    in_flight -= 1
                    trace.completed(rid, s, fin[rid], in_flight + 1)
                    policy.notify_completion(rid)
                    done += 1
                    if rec is not None and rid in req_ns:
                        # per-request lifetime on the slot's own lane
                        rec.span_at("request", f"slot{s}", req_ns.pop(rid),
                                    rec.now_ns(), rid=rid,
                                    steps=fin[rid] - admit_t[rid] + 1)
                        rec.count("completions")
                # -- deadline timeouts (queue-wait budget) -----------------
                if deadline is not None:
                    for r in range(n_req):
                        if (r not in admit_t and r not in timeouts
                                and arr[r] <= t and t - arr[r] > deadline):
                            timeouts[r] = t
                            policy.cancel(r)
                            trace.timed_out(r, t)
                            done += 1
                            if rec is not None:
                                rec.instant("timeout", lane="server", rid=r,
                                            step=t, wait=t - int(arr[r]))
                                rec.count("timeouts")
                # -- admissions into free slots ----------------------------
                arrived = {r for r in range(n_req) if arr[r] <= t}
                free = [s for s in range(S) if slot_rid[s] < 0]
                while free:
                    rid = policy.pick(arrived, in_flight)
                    if rid is None:
                        break
                    s = free[0]
                    with _span(rec, "prefill", "server", rid=rid, plen=plen):
                        tok0, pcache = pf(params, prompts_dev[rid:rid + 1])
                    with _span(rec, "admit", "server", rid=rid, slot=s):
                        state = admit(state, pcache, s, tok0[0],
                                      jnp.int32(plen),
                                      jnp.int32(max_new - 1),
                                      jax.random.fold_in(base_key, rid))
                    outputs[rid] = [tok0]
                    admit_t[rid] = t
                    fin[rid] = t + max_new - 1
                    trace.admitted(rid, t)
                    if rec is not None:
                        rec.hist("ttft_steps", t - int(arr[rid]))
                        req_ns[rid] = rec.now_ns()
                    if max_new == 1:      # completes at admission
                        trace.completed(rid, s, t, in_flight + 1)
                        policy.notify_completion(rid)
                        done += 1
                        if rec is not None and rid in req_ns:
                            rec.span_at("request", f"slot{s}",
                                        req_ns.pop(rid), rec.now_ns(),
                                        rid=rid, steps=1)
                            rec.count("completions")
                    else:
                        slot_rid[s] = rid
                        in_flight += 1
                        free.pop(0)
                if rec is not None:
                    rec.span_at("admission_sweep", "server", sweep0,
                                rec.now_ns(), t=t)
                    rec.gauge("in_flight", in_flight, lane="server")
                    rec.gauge("occupancy", in_flight / S, lane="server")
                if done >= n_req:
                    break
                if in_flight == 0:
                    # idle pool, pending arrivals: fast-forward the clock
                    # to the next chunk boundary at/after the earliest
                    # arrival — no launch for empty air
                    nxt = min(arr[r] for r in range(n_req)
                              if r not in admit_t and r not in timeouts)
                    t = max(t + K, -(-int(nxt) // K) * K)
                    continue
                # -- one chunk launch --------------------------------------
                step_maps[t] = list(slot_rid)
                for s in range(S):
                    rid = slot_rid[s]
                    if rid >= 0:
                        busy_steps += max(0, min(t + K, fin[rid]) - t)
                with _span(rec, "launch", "server", t=t,
                           in_flight=in_flight):
                    state = chunk(params, state, jnp.int32(t))
                chunks += 1
                t += K
            with _span(rec, "barrier", "server"):
                state = jax.block_until_ready(state)
                jax.effects_barrier()
        finally:
            self._tap_sink = None

        if mismatches:
            raise RuntimeError(
                "device masks diverged from host bookkeeping:\n  "
                + "\n  ".join(mismatches[:10]))
        if tap_stats["rows"] != chunks * K:
            raise RuntimeError(
                f"serve tap delivered {tap_stats['rows']}/{chunks * K} "
                "rows — an io_callback was dropped or the run was "
                "interrupted mid-chunk")

        toks = np.full((n_req, max_new), -1, np.int32)
        for rid in range(n_req):
            if rid in timeouts:
                continue                              # never admitted: −1 row
            row = outputs[rid]
            row[0] = int(np.asarray(row[0])[0])       # deferred tok0 read
            if rid in evicted:
                if len(row) > max_new:
                    raise RuntimeError(
                        f"request {rid} streamed {len(row)} tokens past "
                        f"its {max_new} budget despite quarantine")
                toks[rid, :len(row)] = row            # −1 from eviction on
            else:
                if len(row) != max_new:
                    raise RuntimeError(
                        f"request {rid} streamed {len(row)}/{max_new} "
                        "tokens")
                toks[rid] = row
        ttft = np.array([admit_t[r] - arr[r] if r in admit_t else -1
                         for r in range(n_req)], np.int64)
        occ = busy_steps / (chunks * K * S) if chunks else 0.0
        if rec is not None:
            self.watch.observe()
            rec.count("requests", n_req)
            rec.count("serve_chunks", chunks)
            rec.count("serve_decode_steps", chunks * K)
            rec.count("serve_tap_rows", tap_stats["rows"])
            rec.gauge("occupancy_mean", float(occ), lane="server")
        return ServeResult(tokens=toks, schedule=trace.schedule(),
                           ttft_steps=ttft, occupancy=float(occ),
                           decode_steps=chunks * K, chunks=chunks,
                           tap_rows=tap_stats["rows"],
                           evictions=evicted, timeouts=timeouts)
