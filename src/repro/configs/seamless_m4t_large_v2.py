"""SeamlessM4T-large-v2 — encoder-decoder transformer backbone (multimodal).
[arXiv:2308.11596]

24 encoder + 24 decoder layers (the assigned "24L" is the published
per-stack depth), d_model 1024, 16 heads (MHA kv=16, d_head 64), d_ff 8192,
vocab 256206.  The mel-spectrogram + conv feature extractor frontend is a
STUB per the brief: input_specs() provides (B, S, frontend_dim) frame
embeddings; we own the input projection and the full enc-dec backbone.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,            # decoder depth
    enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8192,
    vocab=256206,
    frontend_dim=160,
    dec_ratio=4,
)
