"""Declarative parameter specs with logical sharding axes.

Every parameter is declared once as a :class:`Spec` — shape, logical axis
names, init rule, dtype.  From the same declaration we derive:

* materialised parameters (``init_tree``),
* abstract ShapeDtypeStructs for dry-runs (``abstract_tree``),
* NamedShardings via the logical-axis rules in ``repro.distributed.sharding``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple
    axes: tuple                    # logical axis names (or None), len == ndim
    init: str = "normal"           # normal | zeros | ones | embed | fan_in | mamba_A | mamba_dt
    dtype: str = "bfloat16"
    scale: float = 1.0             # multiplier on the init stddev

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def _fan_in(shape, axes):
    """Contraction fan-in: everything that is not an obvious output axis."""
    # convention: last axis (or the axes after 'embed'-like input dims) is out.
    if len(shape) == 1:
        return shape[0]
    return int(np.prod(shape[:-1])) if len(shape) == 2 else int(shape[0] * (shape[1] if len(shape) > 2 else 1))


def materialize(spec: Spec, key) -> jax.Array:
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "mamba_A":          # A_log with A ∈ [1, 16]
        a = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(a).astype(dt)
    if spec.init == "mamba_dt":         # dt bias: softplus^{-1} of dt ∈ [1e-3, 1e-1]
        dt0 = jnp.exp(jax.random.uniform(key, spec.shape, jnp.float32,
                                         math.log(1e-3), math.log(1e-1)))
        return (dt0 + jnp.log(-jnp.expm1(-dt0))).astype(dt)
    if spec.init == "embed":
        std = 1.0
    elif spec.init == "fan_in":
        std = 1.0 / math.sqrt(max(_fan_in(spec.shape, spec.axes), 1))
    else:  # "normal"
        std = 0.02
    x = jax.random.normal(key, spec.shape, jnp.float32) * (std * spec.scale)
    return x.astype(dt)


def init_tree(specs, key):
    """Materialise a pytree of Specs with per-leaf folded keys (deterministic
    regardless of traversal order — keys are derived from the leaf path)."""
    import zlib

    leaves = jax.tree_util.tree_leaves_with_path(specs, is_leaf=lambda x: isinstance(x, Spec))
    out = {}
    flat = {}
    for path, spec in leaves:
        name = jax.tree_util.keystr(path)
        # crc32, not hash(): Python string hashing is randomised per process,
        # which would make init non-reproducible across runs
        sub = jax.random.fold_in(key, zlib.crc32(name.encode()) % (2 ** 31))
        flat[name] = materialize(spec, sub)
    # rebuild tree
    def build(tree):
        if isinstance(tree, Spec):
            raise AssertionError
        return tree
    return jax.tree_util.tree_map_with_path(
        lambda p, s: flat[jax.tree_util.keystr(p)], specs,
        is_leaf=lambda x: isinstance(x, Spec),
    )


def abstract_tree(specs):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs, is_leaf=lambda x: isinstance(x, Spec),
    )


def axes_tree(specs):
    return jax.tree_util.tree_map(
        lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, Spec)
    )


def count_params(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, Spec)))
