"""Tests for Table-1 rate calculator + Defs 3–4 estimators vs proof bounds.

(The hypothesis property tests live in ``test_theory_property.py`` so this
module collects without the optional dependency.)
"""
import math

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    TimingModel,
    build_schedule,
    replay,
    PureAsync,
    ShuffledAsync,
    heterogeneous_speeds,
)
from repro.core.theory import (
    ProblemConstants,
    pure_async,
    pure_async_waiting,
    random_async,
    fedbuff,
    shuffled_async,
    minibatch_sgd,
    sgd_rr,
    shuffled_beats_random,
    stepsize_pure_async,
    stepsize_random_async,
    stepsize_shuffled_async,
)
from repro.core.trace import sequence_correlation, delay_variance, heterogeneity_zeta
from repro.objectives import QuadraticProblem


C = ProblemConstants(L=1.0, F0=1.0, sigma2=1.0, zeta2=0.5, G=2.0)


def test_pure_async_bg_removes_tau_max():
    """With Assumption 4 the rate is τ_max-free (Table 1 row 3)."""
    a = pure_async(C, 1000, tau_c=8, tau_max=10, bounded_grad=True)
    b = pure_async(C, 1000, tau_c=8, tau_max=10_000, bounded_grad=True)
    assert a == b


def test_waiting_improves_rate():
    """Alg 3 vs Alg 2: waiting for b shrinks every T-dependent term."""
    r1 = pure_async(C, 1000, 8, 16)
    rb = pure_async_waiting(C, 1000, 8, 16, b=8)
    assert rb < r1


def test_fedbuff_improves_with_b():
    assert fedbuff(C, 1000, 8, b=8) < fedbuff(C, 1000, 8, b=1)


def test_shuffled_vs_random_crossover():
    """Remark 1: shuffled needs fewer iterations iff ζ ≥ √n · √ε."""
    n = 100
    assert shuffled_beats_random(zeta=50.0, n=n, eps=1e-2)
    assert not shuffled_beats_random(zeta=0.1, n=n, eps=1e-2)
    # the rate comparison mirrors it in the heterogeneity-dominated regime
    hiz = ProblemConstants(L=1.0, F0=1.0, sigma2=0.0, zeta2=400.0, G=0.1)
    n, T = 10, 10_000
    assert shuffled_async(hiz, T, n) < random_async(
        ProblemConstants(L=1.0, F0=1.0, sigma2=0.0, zeta2=400.0, G=0.1), T, n
    )


def test_rr_matches_best_known_shape():
    """Prop C.4 = the Mishchenko et al. RR rate: n/T + (√n ζ/T)^{2/3}."""
    c = ProblemConstants(L=2.0, F0=3.0, sigma2=0.0, zeta2=4.0)
    n, T = 7, 5000
    expect = 2.0 * 3.0 * n / T + (2.0 * 3.0 * math.sqrt(n) * 2.0 / T) ** (2 / 3)
    assert sgd_rr(c, T, n) == pytest.approx(expect)


def test_minibatch_linear_speedup_in_b():
    r1 = minibatch_sgd(C, 1000, b=1)
    r4 = minibatch_sgd(C, 1000, b=4)
    assert r4 < r1


def test_requires_bounded_gradients():
    c = ProblemConstants(L=1.0, F0=1.0, sigma2=1.0, zeta2=0.5, G=0.0)
    with pytest.raises(ValueError):
        random_async(c, 100, 4)


# ---------------------------------------------------------------------------
# Defs 3–4 estimators vs the closed-form bounds used in the proofs
# ---------------------------------------------------------------------------

def _prob(n=6, d=4, scale=1.0, seed=0):
    rng = np.random.default_rng(seed)
    return QuadraticProblem(scale * rng.normal(size=(n, d)))


def test_sequence_correlation_bound_pure_async():
    """Prop. C.1: σ²_{k,τ} ≤ τ²ζ² for any realised order."""
    prob = _prob()
    n = prob.n
    s = build_schedule(PureAsync(n), TimingModel(heterogeneous_speeds(n), "fixed"), 120)
    res = replay(s, prob.grad_fn(), jnp.zeros(prob.d), 0.01, log_every=1)
    tau = 12
    xs_chunks = res.xs[::tau]
    sig = sequence_correlation(s, prob.per_worker_grad_fn(), xs_chunks, tau)
    zeta = heterogeneity_zeta(prob.per_worker_grad_fn(), jnp.asarray(res.xs[0]), n)
    # ζ at one point of a quadratic with equal Hessians is x-independent
    assert np.all(sig <= tau ** 2 * zeta ** 2 + 1e-4)


def test_delay_variance_bound_pure_async():
    """Prop. C.1: ν² ≤ τ_C · τ_max · ζ² · T."""
    prob = _prob()
    n = prob.n
    T = 60
    s = build_schedule(PureAsync(n), TimingModel(heterogeneous_speeds(n), "fixed"), T)
    res = replay(s, prob.grad_fn(), jnp.zeros(prob.d), 0.01, log_every=1)
    nu2 = delay_variance(s, prob.per_worker_grad_fn(), res.xs)
    zeta = heterogeneity_zeta(prob.per_worker_grad_fn(), jnp.zeros(prob.d), n)
    assert nu2 <= s.tau_c() * s.tau_max() * zeta ** 2 * T + 1e-4


def test_shuffled_lower_sequence_correlation_than_worst_case():
    """The mechanism behind Alg 6: within an epoch all workers appear once,
    so partial sums telescope — σ² stays ≤ (n/2)²-ish ζ² instead of τ²ζ²."""
    prob = _prob(scale=5.0)
    n = prob.n
    s = build_schedule(ShuffledAsync(n), TimingModel(np.ones(n), "fixed"), 10 * n)
    res = replay(s, prob.grad_fn(), jnp.zeros(prob.d), 0.005, log_every=1)
    tau = n
    sig = sequence_correlation(s, prob.per_worker_grad_fn(), res.xs[::tau], tau)
    zeta = heterogeneity_zeta(prob.per_worker_grad_fn(), jnp.zeros(prob.d), n)
    # bound n·ζ² from §D.3.3 (up to small numerical slack)
    assert np.mean(sig) <= n * zeta ** 2 + 1e-4
