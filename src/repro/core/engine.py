"""Discrete-event engine: turns (scheduler, timing model) into a *schedule*.

Key observation exploited everywhere in this repo: under Algorithm 1 the
ordering (i_t, π_t) is fully determined by worker timings and the assignment
policy — it never depends on gradient *values*.  We therefore simulate the
cluster once (host-side, cheap) to obtain the schedule, and then *replay* the
schedule through the actual optimisation (a jittable `lax.scan`, see
``simulator.py``) or through the distributed trainer (round masks).

This mirrors the paper's framing: AsGrad is "SGD with an arbitrary data
ordering plus delays" (§1, §3.1).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .types import Job, Trace, UpdateRecord
from .delays import TimingModel
from .schedulers import Scheduler


@dataclasses.dataclass
class Schedule:
    """The realised ordering of Algorithm 1.

    ``workers[t] = i_t`` and ``assign_iters[t] = π_t`` define the update rule
    x_{t+1} = x_t − γ̃ g_{i_t}(x_{π_t}) with γ̃ = γ / wait_b.
    """

    workers: np.ndarray          # (T,) int32, i_t
    assign_iters: np.ndarray     # (T,) int32, π_t
    finish_times: np.ndarray     # (T,) float64 (simulated receive instants)
    active_jobs: np.ndarray      # (T,) int32, |A_{t+1} \ R_t| before update t
    unfinished_assign_iters: np.ndarray  # (k,) int32: j for (i,j) ∈ A_{T+1}\R_T
    wait_b: int
    n_workers: int

    @property
    def T(self) -> int:
        return int(self.workers.shape[0])

    @property
    def delays(self) -> np.ndarray:
        """τ_t = t − π_t."""
        return np.arange(self.T, dtype=np.int64) - self.assign_iters

    # ---- Definitions 1 & 2 of the paper -----------------------------------
    def tau_max(self) -> int:
        tail = self.T - self.unfinished_assign_iters if len(self.unfinished_assign_iters) else np.array([0])
        m = int(self.delays.max(initial=0))
        return max(m, int(tail.max(initial=0)))

    def tau_avg(self) -> float:
        total = float(self.delays.sum()) + float((self.T - self.unfinished_assign_iters).sum())
        n_assigned = self.T + len(self.unfinished_assign_iters)
        return total / max(n_assigned, 1)

    def tau_c(self) -> int:
        return int(self.active_jobs.max(initial=0))

    def jobs_per_worker(self) -> np.ndarray:
        return np.bincount(self.workers, minlength=self.n_workers)

    def to_trace(self) -> Trace:
        recs = [
            UpdateRecord(
                t=t,
                worker=int(self.workers[t]),
                assign_iter=int(self.assign_iters[t]),
                delay=int(t - self.assign_iters[t]),
                finish_time=float(self.finish_times[t]),
                active_jobs=int(self.active_jobs[t]),
            )
            for t in range(self.T)
        ]
        unfinished = [
            Job(worker=-1, assign_iter=int(j), assign_time=0.0)
            for j in self.unfinished_assign_iters
        ]
        return Trace(records=recs, unfinished=unfinished, n_workers=self.n_workers)


def build_schedule(scheduler: Scheduler, timing: TimingModel, T: int) -> Schedule:
    """Run Algorithm 1's job bookkeeping for ``T`` received gradients.

    Jobs queue FIFO at their worker (random assignment may hand a busy worker
    a second job — §3.2 "some workers might receive new jobs without
    completing the current one").
    """
    if timing.n_workers != scheduler.n:
        raise ValueError("scheduler and timing model disagree on n_workers")
    scheduler.reset()
    n = scheduler.n
    b = scheduler.wait_b

    #  per-worker state
    queues: list[list[Job]] = [[] for _ in range(n)]
    free_at = np.zeros(n, dtype=np.float64)
    heap: list[tuple[float, int, int]] = []   # (finish_time, job_id, worker)
    jobs: dict[int, Job] = {}
    job_counter = 0
    now = 0.0

    def _start(w: int, job: Job, start: float, duration: float) -> None:
        finish = start + duration
        jobs[job.job_id] = dataclasses.replace(job, finish_time=finish)
        heapq.heappush(heap, (finish, job.job_id, w))

    def maybe_start(w: int) -> None:
        """If the worker is idle and has a queued job, start it (scalar
        path — completion-triggered starts are one at a time)."""
        if queues[w] and free_at[w] >= 0:
            job = queues[w].pop(0)
            start = max(free_at[w], job.assign_time)
            free_at[w] = -1.0  # busy marker; real free time set on completion
            _start(w, job, start, timing.sample(w))

    def assign_batch(ws, alpha: int, at: float) -> None:
        """Assign jobs to ``ws`` in order; all jobs that start NOW get
        their compute times from ONE batched ``sample_round`` call.

        Job ids increment in assignment order and the batched draws are
        bit-identical to sequential scalar draws (delays.TimingModel), so
        the realised schedule — heap tie-breaks included — matches the
        old one-``assign``-at-a-time loop exactly.
        """
        nonlocal job_counter
        starts: list[tuple[int, Job, float]] = []
        for w in ws:
            job = Job(worker=w, assign_iter=alpha, assign_time=at,
                      job_id=job_counter)
            job_counter += 1
            queues[w].append(job)
            if free_at[w] >= 0:                 # idle → starts immediately
                j = queues[w].pop(0)
                start = max(free_at[w], j.assign_time)
                free_at[w] = -1.0
                starts.append((w, j, start))
        durations = timing.sample_round([w for w, _, _ in starts])
        for (w, j, start), d in zip(starts, durations):
            _start(w, j, start, float(d))

    assign_batch(scheduler.initial_workers(), 0, 0.0)

    workers = np.empty(T, dtype=np.int32)
    assign_iters = np.empty(T, dtype=np.int32)
    finish_times = np.empty(T, dtype=np.float64)
    active = np.empty(T, dtype=np.int32)

    t = 0
    round_finished: list[int] = []
    while t < T:
        if not heap:
            raise RuntimeError(
                f"deadlock at t={t}: no running jobs (scheduler {scheduler.name})"
            )
        finish, jid, w = heapq.heappop(heap)
        job = jobs.pop(jid)
        now = finish
        # active jobs BEFORE this receipt: everything assigned minus received
        n_active = len(heap) + 1 + sum(len(q) for q in queues)
        workers[t] = w
        assign_iters[t] = job.assign_iter
        finish_times[t] = finish
        active[t] = n_active
        free_at[w] = finish
        maybe_start(w)
        round_finished.append(w)
        t += 1
        if t % b == 0:
            assign_batch(scheduler.next_workers(round_finished), t, now)
            round_finished = []

    unfinished = [j.assign_iter for j in jobs.values()]
    for q in queues:
        unfinished.extend(j.assign_iter for j in q)
    return Schedule(
        workers=workers,
        assign_iters=assign_iters,
        finish_times=finish_times,
        active_jobs=active,
        unfinished_assign_iters=np.asarray(sorted(unfinished), dtype=np.int32),
        wait_b=b,
        n_workers=n,
    )


def round_masks(schedule: Schedule, n_rounds: int | None = None) -> np.ndarray:
    """(rounds, n) 0/1 participation masks for the distributed trainer.

    Round q aggregates the ``wait_b`` receipts t ∈ [q·b, (q+1)·b); a worker
    contributing k gradients in a round gets mask weight k.
    """
    b = schedule.wait_b
    total_rounds = schedule.T // b
    if n_rounds is None:
        n_rounds = total_rounds
    n_rounds = min(n_rounds, total_rounds)
    masks = np.zeros((n_rounds, schedule.n_workers), dtype=np.float32)
    # vectorized scatter: receipt t of round q = t // b contributes +1 to
    # (q, workers[t]); np.add.at accumulates duplicate (q, w) pairs
    w = schedule.workers[:n_rounds * b]
    q = np.repeat(np.arange(n_rounds), b)
    np.add.at(masks, (q, w), 1.0)
    return masks


def lower_rounds(schedule: Schedule, n_rounds: int | None = None, *,
                 delay_rounds: int = 0, adaptive: bool = False):
    """Lower a realised :class:`Schedule` to stacked per-round arrays.

    Returns ``(masks, delay_scales)``: the ``(rounds, n)`` participation
    masks and the ``(rounds,)`` stepsize scales — the delay-adaptive rule
    from :func:`round_delay_scales` when ``adaptive``, all-ones otherwise
    (so callers always have a dense per-round γ-scale to feed the traced
    step).  This is the schedule→plan lowering primitive the
    ``repro.runtime`` executor compiles against.
    """
    masks = round_masks(schedule, n_rounds)
    rounds = masks.shape[0]
    if adaptive:
        scales = round_delay_scales(schedule, rounds,
                                    delay_rounds=delay_rounds)
    else:
        scales = np.ones(rounds, dtype=np.float32)
    return masks, scales


def round_delay_scales(schedule: Schedule, n_rounds: int | None = None,
                       delay_rounds: int = 0) -> np.ndarray:
    """(rounds,) delay-adaptive stepsize scales from the realised schedule.

    The [Koloskova et al. 22]-style rule γ_t = γ·min(1, τ_C/(τ_t+1)) at
    round granularity: the gradient APPLIED at round q is scaled by the
    rule evaluated at its effective staleness.  ``delay_rounds`` is the
    REALISED buffering depth in rounds (AsyncTrainer's single
    swapped-every-round gbuf ⇒ 1 whenever its delay branch is active): the
    gradient applied at q was RECEIVED in round q − delay_rounds (mean
    receipt delay τ̄ over its ``wait_b`` receipts) and then buffered
    ``delay_rounds`` more rounds, so
    τ_applied(q) = τ̄_{q−delay_rounds} + delay_rounds.  The first
    ``delay_rounds`` rounds apply the (gated, empty) initial buffer and get
    a neutral scale of 1.  This is the per-round ``delay_scale`` input of
    ``AsyncTrainer.train_step_fn`` — computed host-side from schedule
    metadata, applied device-side inside the fused kernels."""
    b = schedule.wait_b
    total_rounds = schedule.T // b
    if n_rounds is None:
        n_rounds = total_rounds
    n_rounds = min(n_rounds, total_rounds)
    d = schedule.delays[:n_rounds * b].astype(np.float64)
    tau_round = d.reshape(n_rounds, b).mean(axis=1)
    if delay_rounds:
        shift = min(delay_rounds, n_rounds)
        shifted = np.empty_like(tau_round)
        shifted[:shift] = 0.0                  # → scale 1 (gated rounds)
        shifted[shift:] = tau_round[:n_rounds - shift] + delay_rounds
        tau_round = shifted
    tau_c = max(schedule.tau_c(), 1)
    return np.minimum(1.0, tau_c / (tau_round + 1.0)).astype(np.float32)
