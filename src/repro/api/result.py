"""Unified result type returned by every backend."""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional

import numpy as np

#: payload-format tag for archived results; bump on breaking layout change
RESULT_JSON_VERSION = 1

#: arrays above this size archive as a (shape, dtype, ‖·‖₂) summary stub —
#: curves and masks round-trip exactly, 300M-param state trees do not
_MAX_ARRAY_ELEMS = 1 << 16


def _jsonable(v, _depth=0):
    """Best-effort JSON encoding: ndarrays → tagged dtype+list (restored as
    arrays), dataclasses → tagged field dicts, non-encodable leaves (device
    state trees, schedule objects) → a tagged ``repr`` stub."""
    if _depth > 12:
        return {"__repr__": repr(v)}
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        if v.size > _MAX_ARRAY_ELEMS:      # big state leaves: diffable stub
            try:
                l2 = float(np.linalg.norm(v.astype(np.float64).ravel()))
            except (TypeError, ValueError):
                l2 = None
            return {"__array_summary__": {
                "shape": list(v.shape), "dtype": str(v.dtype), "l2": l2}}
        return {"__ndarray__": {"dtype": str(v.dtype),
                                "data": v.tolist()}}
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return {"__dataclass__": type(v).__name__,
                "fields": {f.name: _jsonable(getattr(v, f.name), _depth + 1)
                           for f in dataclasses.fields(v)}}
    if isinstance(v, dict):
        return {str(k): _jsonable(x, _depth + 1) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x, _depth + 1) for x in v]
    try:                                   # device arrays and array-likes
        arr = np.asarray(v)
        if arr.dtype != object:
            return _jsonable(arr, _depth + 1)
    except Exception:
        pass
    return {"__repr__": repr(v)}


def _restore_grid(grid):
    """Grid keys are the γ floats; JSON stringifies them — undo that."""
    if not isinstance(grid, dict):
        return grid
    out = {}
    for k, v in grid.items():
        try:
            out[float(k)] = v
        except (TypeError, ValueError):
            out[k] = v
    return out


def _from_jsonable(v):
    if isinstance(v, dict):
        if "__ndarray__" in v:
            nd = v["__ndarray__"]
            try:
                dt = np.dtype(nd["dtype"])
            except TypeError:              # e.g. bfloat16 w/o ml_dtypes
                dt = np.float32
            return np.asarray(nd["data"], dtype=dt)
        if "__array_summary__" in v:
            return v                       # stub stays a stub
        if "__dataclass__" in v:           # restored as a plain field dict
            return {"__dataclass__": v["__dataclass__"],
                    **{k: _from_jsonable(x)
                       for k, x in v["fields"].items()}}
        if "__repr__" in v:
            return v["__repr__"]
        return {k: _from_jsonable(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_from_jsonable(x) for x in v]
    return v


@dataclasses.dataclass
class RunResult:
    """What an AsGrad run produced, backend-independent.

    ``x`` is the final iterate (simulator), the final train state tree
    (trainer), or the generated tokens (serve).  ``trace`` carries the
    realised-schedule statistics the theory bounds reference (τ_max, τ_avg,
    τ_C, job balance); ``grid`` holds the per-γ curves when a stepsize grid
    search ran.
    """

    spec: Any
    backend: str
    x: Any = None
    log_ts: Optional[np.ndarray] = None
    grad_norms: Optional[np.ndarray] = None
    losses: Optional[np.ndarray] = None
    xs: Optional[np.ndarray] = None          # iterate snapshots (simulator)
    gamma: Optional[float] = None            # the (selected) server stepsize
    grid: Optional[dict] = None              # γ → {"grad_norms", "losses", "score"}
    schedule: Any = None                     # realised Schedule, if one was built
    trace: dict = dataclasses.field(default_factory=dict)
    seconds: float = 0.0
    extra: dict = dataclasses.field(default_factory=dict)

    @property
    def final_grad_norm(self) -> Optional[float]:
        if self.grad_norms is None or not len(self.grad_norms):
            return None
        return float(self.grad_norms[-1])

    @property
    def final_loss(self) -> Optional[float]:
        if self.losses is None or not len(self.losses):
            return None
        return float(self.losses[-1])

    # ------------------------------------------------------------- archiving
    def to_json(self) -> str:
        """Archive-grade JSON: curves and grid arrays round-trip exactly
        (dtype-tagged lists), while the non-serialisable heavyweights are
        *summarised* — the realised ``schedule`` collapses to its
        statistics (T, wait_b, n_workers + the τ trace), ``spec`` to its
        field dict, and a trainer-state ``x`` to a repr stub.  The output
        is what CI artifacts and cross-PR diffs consume; see
        :meth:`from_json` for the (documented lossy) inverse."""
        sched = None
        if self.schedule is not None:
            s = self.schedule
            sched = {"T": int(s.T), "wait_b": int(s.wait_b),
                     "n_workers": int(s.n_workers),
                     "tau_max": int(s.tau_max()),
                     "tau_avg": float(s.tau_avg()),
                     "tau_c": int(s.tau_c())}
        payload = {
            "version": RESULT_JSON_VERSION,
            "backend": self.backend,
            "spec": _jsonable(self.spec),
            "x": _jsonable(self.x),
            "log_ts": _jsonable(self.log_ts),
            "grad_norms": _jsonable(self.grad_norms),
            "losses": _jsonable(self.losses),
            "xs": _jsonable(self.xs),
            "gamma": self.gamma,
            "grid": _jsonable(self.grid),
            "schedule": sched,
            "trace": _jsonable(self.trace),
            "seconds": self.seconds,
            "extra": _jsonable(self.extra),
        }
        return json.dumps(payload, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "RunResult":
        """Inverse of :meth:`to_json`.  Arrays come back as numpy arrays
        with their original dtypes; ``spec`` and ``schedule`` come back as
        the plain summary dicts the archive stored (NOT live
        ``ExperimentSpec``/``Schedule`` objects), and repr-stubbed fields
        (e.g. a trainer-state ``x``) come back as their repr strings —
        enough to diff runs across PRs, not to resume them."""
        d = json.loads(text)
        version = d.get("version")
        if version != RESULT_JSON_VERSION:
            raise ValueError(
                f"unsupported RunResult JSON version {version!r} "
                f"(this build reads {RESULT_JSON_VERSION})")
        return cls(
            spec=_from_jsonable(d["spec"]),
            backend=d["backend"],
            x=_from_jsonable(d["x"]),
            log_ts=_from_jsonable(d["log_ts"]),
            grad_norms=_from_jsonable(d["grad_norms"]),
            losses=_from_jsonable(d["losses"]),
            xs=_from_jsonable(d["xs"]),
            gamma=d["gamma"],
            grid=_restore_grid(_from_jsonable(d["grid"])),
            schedule=d["schedule"],
            trace=_from_jsonable(d["trace"]) or {},
            seconds=d["seconds"],
            extra=_from_jsonable(d["extra"]) or {},
        )
