"""Core data types shared by the exact simulator and the distributed trainer."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Job:
    """A job (i, j): worker ``i`` computes grad f_i at model iterate ``j``.

    ``assign_iter`` is the server iteration α at which the job was assigned
    (the gradient is evaluated at x_α); ``assign_time``/``finish_time`` are
    simulated wall-clock instants.
    """

    worker: int
    assign_iter: int
    assign_time: float
    finish_time: float = float("inf")
    job_id: int = -1


@dataclasses.dataclass
class UpdateRecord:
    """One server update x_{t+1} = x_t − γ g_{i_t}(x_{π_t})."""

    t: int                 # server iteration index of the update
    worker: int            # i_t
    assign_iter: int       # π_t
    delay: int             # τ_t = t − π_t
    finish_time: float     # simulated receive instant
    active_jobs: int       # |A_{t+1} \ R_t| right before the update


@dataclasses.dataclass
class Trace:
    """Everything the theory (Defs 1–4) needs, recorded by the simulator."""

    records: list                    # list[UpdateRecord]
    unfinished: list                 # list[Job] = A_{T+1} \ R_T
    n_workers: int
    grad_norm_log: list = dataclasses.field(default_factory=list)  # (t, ||∇f(x_t)||)
    loss_log: list = dataclasses.field(default_factory=list)       # (t, f(x_t))
    wallclock: float = 0.0

    @property
    def T(self) -> int:
        return len(self.records)

    def worker_sequence(self):
        return [r.worker for r in self.records]

    def delays(self):
        return [r.delay for r in self.records]


@dataclasses.dataclass
class SimResult:
    x: object                  # final iterate
    trace: Trace
    best_grad_norm: float
    final_grad_norm: float
    history: Optional[list] = None   # optional iterate snapshots [(t, x)]
