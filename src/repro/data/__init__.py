from .pipeline import DataConfig, HeterogeneousTokenPipeline, EpochShuffler

__all__ = ["DataConfig", "HeterogeneousTokenPipeline", "EpochShuffler"]
