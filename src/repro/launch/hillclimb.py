"""§Perf hillclimbing harness: hypothesis → change → re-lower → measure.

Each experiment is a named Rules/config variant applied to one
(arch × shape); the harness lowers both baseline and variant, derives the
roofline terms from the while-aware HLO cost model, and prints the deltas.
Iterations and verdicts are recorded in EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m repro.launch.hillclimb --pair grok_train
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

from ..distributed.sharding import Rules
from .dryrun import run_one
from .mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW


def terms(rec):
    hc = rec["hlo_cost"]
    return {
        "compute_s": hc["dot_flops"] / PEAK_FLOPS_BF16,
        "memory_s": hc["hbm_bytes"] / HBM_BW,
        "collective_s": hc["collective_bytes"] / ICI_BW,
        "mem_gb": rec["memory"]["peak_bytes_est"] / 1e9,
        "coll_breakdown": {k: round(v / 1e9, 2)
                           for k, v in hc["collective_breakdown"].items()},
    }


def compare(arch, shape, variants, out=None):
    """variants: list of (name, rules_or_None, extra_kwargs)."""
    results = {}
    for name, rules, kw in variants:
        rec = run_one(arch, shape, rules=rules or Rules(), **kw)
        results[name] = {"ok": rec["ok"],
                         **(terms(rec) if rec["ok"] else
                            {"error": rec.get("error")})}
        t = results[name]
        if rec["ok"]:
            print(f"  {name:28s} comp={t['compute_s']:.3f}s "
                  f"mem={t['memory_s']:.3f}s coll={t['collective_s']:.3f}s "
                  f"hbm={t['mem_gb']:.1f}GB {t['coll_breakdown']}")
        else:
            print(f"  {name:28s} FAIL {t['error'][:120]}")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=1)
    return results


PAIRS = {
    # most representative of the paper's technique + biggest model
    "grok_train": ("grok-1-314b", "train_4k"),
    # most collective-bound (expert-parallel MoE)
    "deepseek_train": ("deepseek-moe-16b", "train_4k"),
    # worst useful-compute ratio (14 unshardable heads)
    "qwen2_prefill": ("qwen2-0.5b", "prefill_32k"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(PAIRS), required=True)
    ap.add_argument("--variants", default="baseline")
    args = ap.parse_args()
    arch, shape = PAIRS[args.pair]
    print(f"== {arch} × {shape}")
    compare(arch, shape, [("baseline", None, {})],
            out=f"experiments/hillclimb_{args.pair}.json")


if __name__ == "__main__":
    main()
