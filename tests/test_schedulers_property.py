"""Hypothesis property tests for the discrete-event engine.

``hypothesis`` is an optional ``[test]`` extra; the whole module skips
gracefully when it is absent so tier-1 stays green on minimal installs.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    PATTERNS,
    TimingModel,
    build_schedule,
    heterogeneous_speeds,
    make_scheduler,
)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 12),
    b=st.integers(1, 4),
    name=st.sampled_from(["pure", "pure_waiting", "random", "fedbuff", "shuffled", "minibatch", "rr"]),
    pattern=st.sampled_from(PATTERNS),
    seed=st.integers(0, 10_000),
)
def test_property_schedule_wellformed(n, b, name, pattern, seed):
    b = min(b, n)
    sched = make_scheduler(name, n, b=b, seed=seed)
    tm = TimingModel(heterogeneous_speeds(n, slow_factor=3.0), pattern, seed=seed)
    Tq = 8 * sched.wait_b
    s = build_schedule(sched, tm, Tq)
    assert s.T == Tq
    assert np.all(s.delays >= 0)
    assert np.all(s.assign_iters >= 0)
    assert s.tau_avg() <= s.tau_max() + 1e-9
    assert s.tau_c() >= 1
    # determinism: same seed → same schedule
    sched2 = make_scheduler(name, n, b=b, seed=seed)
    tm2 = TimingModel(heterogeneous_speeds(n, slow_factor=3.0), pattern, seed=seed)
    s2 = build_schedule(sched2, tm2, Tq)
    assert np.array_equal(s.workers, s2.workers)
    assert np.array_equal(s.assign_iters, s2.assign_iters)
