"""Guard-rail configuration and the host-side divergence circuit-breaker.

Two layers of defence against faulty updates, justified by the
delay-robust analyses this repo reproduces (Koloskova et al.,
arXiv:2206.08307 — convergence survives *dropping* bad or stale
updates):

* :class:`GuardConfig` parameterises the DEVICE-side rails compiled into
  ``AsyncTrainer.step`` (no host readback, mask-style inside the scan
  body): a per-round non-finite check on the loss and the raw gradient
  norm that skips the whole apply when it fails, plus a per-worker
  health channel that backs the effective stepsize off after a bad
  receipt and recovers it multiplicatively on clean ones.

* :class:`DivergenceBreaker` is the HOST-side circuit-breaker: it
  watches the per-round loss rows streaming through the executor's tap
  lane and trips when a recent window diverges from the best window seen
  so far — the executor then stops launching further chunks
  (already-enqueued chunks drain; nothing blocks the device).

This module deliberately imports neither JAX nor any repro subpackage,
so both the trainer and the executor can depend on it without cycles.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Device-side guard rails for ``AsyncTrainer.step``.

    A round is *bad* for the workers that participated in it when the
    loss or the raw (pre-clip, pre-sparsify-aware) gradient norm is
    non-finite, or — with ``spike_norm`` set — when the raw norm exceeds
    that threshold.  Non-finite rounds skip the apply entirely: the
    gradients are zeroed before they can reach the optimizer moments or
    the delay buffer, and every state leaf except the step counter and
    the guard health keeps its previous value.  Spiky-but-finite rounds
    still apply (clipping already bounds them) but charge the
    participants' health.

    Health h_i ∈ [min_scale, 1] per worker: participants of a bad round
    take ``h_i *= backoff``; participants of a clean round recover
    ``h_i = min(1, h_i * recover)``.  The round's update is scaled by
    the participation-weighted mean health, so a worker that keeps
    sending garbage fades toward ``min_scale`` influence instead of
    poisoning γ for everyone.
    """

    backoff: float = 0.5
    recover: float = 1.25
    min_scale: float = 0.1
    #: raw grad-norm threshold counting as a (finite) fault for health
    #: purposes; None disables the spike check
    spike_norm: Optional[float] = None

    def __post_init__(self):
        if not 0.0 < self.backoff < 1.0:
            raise ValueError(f"backoff must be in (0, 1) (got {self.backoff})")
        if self.recover < 1.0:
            raise ValueError(f"recover must be >= 1 (got {self.recover})")
        if not 0.0 < self.min_scale <= 1.0:
            raise ValueError(
                f"min_scale must be in (0, 1] (got {self.min_scale})")
        if self.spike_norm is not None and self.spike_norm <= 0:
            raise ValueError(
                f"spike_norm must be positive (got {self.spike_norm})")


class DivergenceBreaker:
    """Windowed divergence circuit-breaker fed from the tap lane.

    Maintains a sliding window of the last ``window`` *finite* losses
    and the best — lowest — window mean seen so far.  Once at least one
    full window has been observed, a current window mean exceeding
    ``factor × best`` trips the breaker; the first observed round at or
    past the trip is recorded in :attr:`tripped_round`.

    A NON-FINITE loss trips immediately: NaN compares false against
    ``factor × best``, so folding it into the window would leave a
    NaN-only divergence undetected forever.  (The device-side skip guard
    still drops the round's update; the breaker's job is to stop
    LAUNCHING — a run whose loss went NaN has nothing left to compute.)

    ``observe`` is called from the executor's ordered tap callback, so
    rounds arrive in order; the executor polls :attr:`tripped` before
    launching each chunk and stops the launch loop once tripped —
    chunks already on the device stream drain normally (barrier-free).
    """

    def __init__(self, window: int = 8, factor: float = 10.0):
        if window < 1:
            raise ValueError(f"window must be >= 1 (got {window})")
        if factor <= 1.0:
            raise ValueError(f"factor must be > 1 (got {factor})")
        self.window = int(window)
        self.factor = float(factor)
        self.tripped_round: Optional[int] = None
        self._recent: deque = deque(maxlen=self.window)
        self._best: Optional[float] = None

    @property
    def tripped(self) -> bool:
        return self.tripped_round is not None

    def observe(self, round_idx: int, loss: float) -> bool:
        """Feed one per-round loss; returns True when (already) tripped."""
        if self.tripped:
            return True
        loss = float(loss)
        if loss != loss or loss in (float("inf"), float("-inf")):
            # NaN/inf never exceeds factor×best by comparison — trip NOW
            self.tripped_round = int(round_idx)
            return True
        self._recent.append(loss)
        if len(self._recent) < self.window:
            return False
        mean = sum(self._recent) / self.window
        if self._best is not None and mean > self.factor * self._best:
            self.tripped_round = int(round_idx)
            return True
        self._best = mean if self._best is None else min(self._best, mean)
        return False
