"""Slot-based continuous-batching server (tentpole gates).

The two acceptance gates live here:

* **parity** — all slots filled, no arrivals, greedy: the slot lane must
  reproduce lock-step ``Server.generate`` token-for-token (the lock-step
  driver is the oracle; the ragged decode path is a strict superset).
* **no retrace on admission** — requests rotating through freed slots
  must leave the chunk/admit compile counts at one trace per program
  (the whole point of masking over control flow).

Plus the admission layer as a unit: policy parsing, arrival draws, the
scheduler-registry remap, the trace → ``Schedule`` lowering, and the
ordered-tap streaming contract.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.api import ExperimentSpec, ServeJob, run
from repro.api.backends import ServeBackend
from repro.configs import get_arch
from repro.distributed import (AdmissionPolicy, AdmissionTrace, Server,
                               ServeConfig, SlotConfig, SlotServer,
                               draw_arrivals, parse_admission)
from repro.models import init_params, model as M
from repro.scenarios import tau_report

TINY = dict(n_layers=1, d_model=8, n_heads=1, n_kv_heads=1, d_ff=16,
            vocab=127)
TINY_OVR = tuple(TINY.items())


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _setup(arch="qwen2-0.5b", **tiny):
    cfg = get_arch(arch).reduced().with_(remat="none", **(tiny or TINY))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(n, plen, vocab, seed=0):
    return np.random.default_rng(seed).integers(
        0, vocab, (n, plen)).astype(np.int32)


def _lockstep_tokens(cfg, params, prompts, T, ctx, temperature=0.0):
    """The oracle: eager prefill + lock-step generate (backend flow)."""
    srv = Server(cfg, _mesh(), ServeConfig(batch=prompts.shape[0],
                                           ctx_len=ctx,
                                           temperature=temperature))
    logits, cache = M.prefill(cfg, params, {"tokens": jnp.asarray(prompts)},
                              ctx_len=ctx)
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    gen = srv.generate(params, np.asarray(tok0), T - 1,
                       start_pos=prompts.shape[1], cache=cache)
    return np.concatenate([np.asarray(tok0)[:, None], gen], axis=1)


# ---------------------------------------------------------------------------
# acceptance gates
# ---------------------------------------------------------------------------

def test_slot_lane_parity_bit_for_bit():
    """Full static batch, no arrivals, greedy ⇒ identical to lock-step."""
    cfg, params = _setup()
    B, plen, T = 3, 5, 6
    ctx = plen + T
    prompts = _prompts(B, plen, cfg.vocab)
    ref = _lockstep_tokens(cfg, params, prompts, T, ctx)
    srv = SlotServer(cfg, _mesh(), SlotConfig(n_slots=B, ctx_len=ctx,
                                              steps_per_launch=2))
    res = srv.serve(params, prompts, T)
    np.testing.assert_array_equal(ref, res.tokens)
    assert res.tokens.dtype == np.int32


def test_admission_does_not_retrace():
    """More requests than slots: every program stays at ONE traced
    signature while requests rotate through freed slots."""
    cfg, params = _setup()
    srv = SlotServer(cfg, _mesh(), SlotConfig(n_slots=2, ctx_len=16,
                                              steps_per_launch=2))
    prompts = _prompts(7, 5, cfg.vocab)
    arrivals = np.array([0, 0, 1, 3, 6, 9, 9])
    res = srv.serve(params, prompts, 6, admission="shuffled",
                    arrivals=arrivals)
    counts = srv.compile_counts()
    assert counts["chunk"] == 1, counts
    assert counts["admit"] == 1, counts
    assert counts["prefill[5]"] == 1, counts
    assert res.tokens.shape == (7, 6)
    # a second serve on the same instance reuses every compile
    srv.serve(params, prompts, 6, admission="pure")
    assert srv.compile_counts() == counts


def test_slot_serve_tap_streams_every_token():
    """The ordered io_callback tap delivers each post-admission token to
    its consumer, in per-request decode order, matching the result."""
    cfg, params = _setup()
    srv = SlotServer(cfg, _mesh(), SlotConfig(n_slots=2, ctx_len=16,
                                              steps_per_launch=2))
    prompts = _prompts(4, 5, cfg.vocab)
    streamed: dict = {}
    steps: dict = {}
    res = srv.serve(params, prompts, 5,
                    on_token=lambda rid, tok, step:
                    (streamed.setdefault(rid, []).append(tok),
                     steps.setdefault(rid, []).append(step)))
    for rid in range(4):
        # tokens[0] is the prefill token (emitted at admission, not
        # through the decode tap); the tap carries the remaining T-1
        np.testing.assert_array_equal(streamed[rid], res.tokens[rid, 1:])
        assert steps[rid] == sorted(steps[rid])
    assert res.tap_rows == res.decode_steps == res.chunks * 2


# ---------------------------------------------------------------------------
# serving worlds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("admission", ["pure", "random", "shuffled",
                                       "fedbuff:b=2"])
def test_every_policy_serves_every_request_once(admission):
    cfg, params = _setup()
    srv = SlotServer(cfg, _mesh(), SlotConfig(n_slots=2, ctx_len=16,
                                              steps_per_launch=2))
    prompts = _prompts(5, 5, cfg.vocab, seed=3)
    res = srv.serve(params, prompts, 4, admission=admission)
    assert res.tokens.shape == (5, 4)
    sched = res.schedule
    assert sorted(sched.workers.tolist()) == list(range(5))
    # realised serving concurrency can never exceed the slot count
    assert sched.tau_c() <= 2 + 1      # +1: completion-instant overlap
    rep = tau_report(sched, parse_admission(admission)[0], concurrency=2)
    assert rep["n_workers"] == 5


def test_arrivals_shift_admissions():
    """Late arrivals cannot be admitted before they arrive; with an idle
    pool the clock fast-forwards instead of launching empty chunks."""
    cfg, params = _setup()
    srv = SlotServer(cfg, _mesh(), SlotConfig(n_slots=2, ctx_len=24,
                                              steps_per_launch=2))
    prompts = _prompts(3, 5, cfg.vocab)
    arrivals = np.array([0, 12, 12])
    res = srv.serve(params, prompts, 4, arrivals=arrivals)
    admit = {int(w): int(a) for w, a in
             zip(res.schedule.workers,
                 np.asarray(res.ttft_steps) + arrivals)}
    assert admit[0] == 0
    assert admit[1] >= 12 and admit[2] >= 12
    assert np.all(res.ttft_steps >= 0)
    # request 0 finishes at step 3; steps 4..11 have an empty pool — the
    # loop must skip them rather than decode empty air
    assert res.decode_steps < 12 + 2 * 4


def test_single_token_budget_completes_at_admission():
    """max_new == 1: the prefill token IS the request; slots never occupy."""
    cfg, params = _setup()
    srv = SlotServer(cfg, _mesh(), SlotConfig(n_slots=2, ctx_len=16,
                                              steps_per_launch=2))
    prompts = _prompts(3, 5, cfg.vocab)
    res = srv.serve(params, prompts, 1)
    assert res.tokens.shape == (3, 1)
    assert res.chunks == 0 and res.tap_rows == 0
    ref = _lockstep_tokens(cfg, params, prompts, 1, 16)[:, :1]
    np.testing.assert_array_equal(ref, res.tokens)


def test_slot_server_rejects_budget_overflow_and_bad_families():
    cfg, params = _setup()
    srv = SlotServer(cfg, _mesh(), SlotConfig(n_slots=1, ctx_len=8))
    with pytest.raises(ValueError, match="exceeds"):
        srv.serve(params, _prompts(1, 5, cfg.vocab), 4)
    vlm = get_arch("pixtral-12b").reduced()
    with pytest.raises(NotImplementedError, match="vlm"):
        SlotServer(vlm, _mesh(), SlotConfig(n_slots=1, ctx_len=8))


# ---------------------------------------------------------------------------
# backend wiring
# ---------------------------------------------------------------------------

def test_backend_slot_route_matches_lockstep_route():
    """n_slots == batch, no arrivals ⇒ the two ServeBackend routes emit
    identical token matrices (same prompt stream by construction)."""
    lock = run(ExperimentSpec(objective=ServeJob(
        batch=3, prompt_len=5, arch_overrides=TINY_OVR), T=6))
    slot = run(ExperimentSpec(objective=ServeJob(
        batch=3, prompt_len=5, arch_overrides=TINY_OVR, n_slots=3,
        steps_per_launch=2), T=6))
    np.testing.assert_array_equal(lock.x, slot.x)
    assert slot.backend == "serve"
    assert slot.schedule is not None
    assert slot.extra["tau_report"]["global"]["tau_c"] <= 3 + 1
    assert 0 < slot.extra["occupancy"] <= 1


def test_backend_slot_route_with_arrivals_and_fedbuff():
    res = ServeBackend(mesh=_mesh()).run(ExperimentSpec(
        objective=ServeJob(batch=2, prompt_len=5, arch_overrides=TINY_OVR,
                           n_slots=2, n_requests=5,
                           admission="fedbuff:b=2",
                           arrival="poisson:gap=3", steps_per_launch=2),
        T=5, seed=2))
    assert res.x.shape == (5, 5)
    assert res.extra["n_slots"] == 2
    assert res.extra["ttft_steps"].shape == (5,)
    assert res.extra["tau_report"]["policy"] == "fedbuff"
    assert len(res.extra["arrivals"]) == 5


def test_serve_job_validates_slot_fields():
    with pytest.raises(ValueError, match="admission"):
        ServeJob(admission="nope")
    with pytest.raises(ValueError, match="arrival"):
        ServeJob(arrival="nope:gap=2")
    with pytest.raises(ValueError, match="n_slots"):
        ServeJob(n_slots=0)
    with pytest.raises(ValueError, match="steps_per_launch"):
        ServeJob(steps_per_launch=0)


# ---------------------------------------------------------------------------
# degradation: per-slot sampling, quarantine, deadlines
# ---------------------------------------------------------------------------

def test_sampled_streams_independent_of_pool_width():
    """Per-request PRNG: each request's sampled token stream is a pure
    function of (server seed, request id) — folding the slot out of the
    key — so the SAME requests decode the SAME tokens whether the pool
    has 1 slot or 2, and distinct requests get distinct streams."""
    cfg, params = _setup()
    prompts = _prompts(4, 5, cfg.vocab, seed=7)
    res = {}
    for n_slots in (1, 2):
        srv = SlotServer(cfg, _mesh(), SlotConfig(
            n_slots=n_slots, ctx_len=16, steps_per_launch=2,
            temperature=0.8, seed=11))
        res[n_slots] = srv.serve(params, prompts, 6)
        counts = srv.compile_counts()
        assert counts["chunk"] == 1 and counts["admit"] == 1, counts
    np.testing.assert_array_equal(res[1].tokens, res[2].tokens)
    # independence: no two requests share a stream (keys fold in the rid)
    toks = res[2].tokens
    for a in range(4):
        for b in range(a + 1, 4):
            assert not np.array_equal(toks[a, 1:], toks[b, 1:]), (a, b)
    # a different server seed moves the streams
    srv3 = SlotServer(cfg, _mesh(), SlotConfig(
        n_slots=2, ctx_len=16, steps_per_launch=2, temperature=0.8,
        seed=12))
    assert not np.array_equal(srv3.serve(params, prompts, 6).tokens, toks)


def test_quarantine_evicts_nonfinite_lanes():
    """Slots whose logits go non-finite are quarantined in-mask: the lane
    freezes, the request is marked evicted (its unfilled token budget is
    ``-1``), and the degradation surfaces in the trace and tau_report."""
    cfg, params = _setup()
    # poison the params: every forward produces NaN logits
    bad = jax.tree_util.tree_map(lambda x: jnp.full_like(x, np.nan), params)
    srv = SlotServer(cfg, _mesh(), SlotConfig(n_slots=2, ctx_len=16,
                                              steps_per_launch=2))
    prompts = _prompts(3, 5, cfg.vocab)
    res = srv.serve(bad, prompts, 5, arrivals=np.array([0, 0, 4]))
    assert sorted(res.evictions) == [0, 1, 2]     # every lane quarantined
    assert res.timeouts == {}
    # decode tokens after the eviction step are the -1 sentinel
    assert np.all(res.tokens[:, 1:] == -1)
    assert res.tokens.shape == (3, 5)
    rep = tau_report(res.schedule, "pure", evictions=res.evictions,
                     timeouts=res.timeouts)
    assert rep["degraded"]["evictions"] == {
        int(k): int(v) for k, v in res.evictions.items()}
    from repro.scenarios import render_report
    assert "evicted (quarantine)" in render_report(rep)
    # a healthy pool on the same instance: no evictions, compile reused
    ok = srv.serve(params, prompts, 5)
    assert ok.evictions == {} and np.all(ok.tokens >= 0)
    assert srv.compile_counts()["chunk"] == 1


def test_deadline_times_out_queued_requests():
    """Requests whose queue wait exceeds the deadline are cancelled at an
    admission sweep: never admitted, tokens all ``-1``, ttft ``-1``, and
    the remaining requests still serve to completion."""
    cfg, params = _setup()
    srv = SlotServer(cfg, _mesh(), SlotConfig(n_slots=1, ctx_len=16,
                                              steps_per_launch=2))
    prompts = _prompts(4, 5, cfg.vocab)
    res = srv.serve(params, prompts, 4, deadline=2)
    assert res.timeouts, "a 1-slot pool at deadline=2 must shed load"
    assert res.evictions == {}
    served = sorted(set(range(4)) - set(res.timeouts))
    assert served, "the head of the queue must still be served"
    for r in res.timeouts:
        assert np.all(res.tokens[r] == -1)
        assert res.ttft_steps[r] == -1
    for r in served:
        assert np.all(res.tokens[r] >= 0)
        assert res.ttft_steps[r] >= 0
    # the Schedule rows cover exactly the served requests
    assert sorted(res.schedule.workers.tolist()) == served
    rep = tau_report(res.schedule, "pure", evictions=res.evictions,
                     timeouts=res.timeouts)
    assert rep["degraded"]["timeouts"] == {
        int(k): int(v) for k, v in res.timeouts.items()}
    from repro.scenarios import render_report
    assert "timed out" in render_report(rep)
    with pytest.raises(ValueError, match="deadline"):
        srv.serve(params, prompts, 4, deadline=-1)


def test_serve_job_deadline_validation_and_backend_surface():
    with pytest.raises(ValueError, match="deadline"):
        ServeJob(deadline=-1, n_slots=2)
    with pytest.raises(ValueError, match="deadline"):
        ServeJob(deadline=4)                      # needs the slot lane
    res = ServeBackend(mesh=_mesh()).run(ExperimentSpec(
        objective=ServeJob(batch=2, prompt_len=5, arch_overrides=TINY_OVR,
                           n_slots=1, n_requests=3, deadline=1,
                           steps_per_launch=2),
        T=4, seed=0))
    assert res.extra["timeouts"], "deadline=1 on a 1-slot pool must shed"
    assert res.extra["evictions"] == {}
    deg = res.extra["tau_report"]["degraded"]
    assert deg["timeouts"] == res.extra["timeouts"]


# ---------------------------------------------------------------------------
# admission layer units
# ---------------------------------------------------------------------------

def test_parse_admission():
    assert parse_admission("pure") == ("pure", 1)
    assert parse_admission("fedbuff:b=3") == ("fedbuff", 3)
    with pytest.raises(ValueError, match="unknown admission policy"):
        parse_admission("nope")
    with pytest.raises(ValueError, match="only b="):
        parse_admission("pure:k=2")


def test_draw_arrivals():
    assert np.array_equal(draw_arrivals(4, None), np.zeros(4))
    arr = draw_arrivals(6, "fixed:gap=3")
    assert arr[0] == 0
    assert np.array_equal(np.diff(arr), np.full(5, 3))
    pois = draw_arrivals(6, "poisson:gap=4", seed=1)
    assert pois[0] == 0 and np.all(np.diff(pois) >= 0)
    assert not np.array_equal(pois, draw_arrivals(6, "poisson:gap=4", seed=2))
    with pytest.raises(ValueError, match="unknown arrival pattern"):
        draw_arrivals(2, "zipf:gap=2")


def test_admission_policy_pure_is_fifo():
    pol = AdmissionPolicy("pure", 4)
    arrived = {0, 1, 2, 3}
    order = [pol.pick(arrived, 0) for _ in range(4)]
    assert sorted(order) == [0, 1, 2, 3]
    assert pol.pick(arrived, 0) is None     # queue drained
    assert pol.n_queued == 0


def test_admission_policy_respects_arrivals():
    pol = AdmissionPolicy("pure", 3)
    assert pol.pick(set(), 0) is None       # nothing arrived yet
    got = pol.pick({2}, 0)
    assert got == 2                          # remap lands on the arrival


def test_admission_policy_fedbuff_buffers_then_flushes():
    pol = AdmissionPolicy("fedbuff", 6, b=2, seed=0)
    arrived = set(range(6))
    # initial proposals cover every request — drain the queue through them
    first = [pol.pick(arrived, 1) for _ in range(6)]
    assert sorted(first) == list(range(6))
    assert pol.pick(arrived, 1) is None      # queue drained
    # completions buffer until b of them land, then a batch of proposals
    pol.notify_completion(first[0])
    assert not pol._proposals
    pol.notify_completion(first[1])
    assert len(pol._proposals) == 2          # fedbuff batch of b

    # the flush guard: proposals withheld + idle pool must still progress
    pol2 = AdmissionPolicy("fedbuff", 4, b=2, seed=0)
    pol2._proposals.clear()                  # simulate a withheld batch
    assert pol2.pick({0, 1}, in_flight=1) is None   # work in flight: wait
    assert pol2.pick({0, 1}, in_flight=0) == 0      # idle pool: FIFO flush


def test_admission_trace_lowers_to_schedule():
    tr = AdmissionTrace(3, wait_b=1)
    tr.admitted(0, 0)
    tr.admitted(1, 0)
    tr.completed(0, 0, 4, 2)
    tr.admitted(2, 4)
    tr.completed(1, 1, 6, 2)
    tr.completed(2, 0, 8, 1)
    s = tr.schedule()
    assert s.workers.tolist() == [0, 1, 2]
    assert s.assign_iters.tolist() == [0, 0, 1]   # rid 2 admitted after 1 done
    assert s.finish_times.tolist() == [4.0, 6.0, 8.0]
    assert s.active_jobs.tolist() == [2, 2, 1]
    assert s.n_workers == 3
    assert np.all(s.delays >= 0)
    rep = tau_report(s, "pure")
    assert rep["T"] == 3
