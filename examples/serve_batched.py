"""Batched serving example: prefill a batch of prompts, then decode with the
ring-buffer KV cache through the Server driver.

  PYTHONPATH=src python examples/serve_batched.py [--arch qwen2-0.5b]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_arch
from repro.distributed import Server, ServeConfig
from repro.models import init_params, prefill, decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced().with_(remat="none")
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    ctx = args.prompt_len + args.gen
    server = Server(cfg, mesh, ServeConfig(batch=args.batch, ctx_len=ctx,
                                           temperature=0.8))

    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    last, cache = prefill(cfg, params, {"tokens": jnp.asarray(prompts)},
                          ctx_len=ctx)
    print(f"prefill {args.batch}x{args.prompt_len} in {time.time()-t0:.2f}s")

    # continue decoding from the prefilled cache
    step = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q, ctx))
    toks = jnp.argmax(last, axis=-1).astype(jnp.int32)
    out = [np.asarray(toks)]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = step(params, cache, toks, jnp.int32(args.prompt_len + i))
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(np.asarray(toks))
    dt = time.time() - t0
    gen = np.stack(out, axis=1)
    print(f"decoded {gen.shape} in {dt:.2f}s "
          f"({args.batch*(args.gen-1)/max(dt,1e-9):.1f} tok/s)")
    print("sample:", gen[0][:12])


if __name__ == "__main__":
    main()
