"""Rates report: realised τ-statistics per scenario window vs theory.

The paper's thesis is that convergence is governed by the REALISED delay
statistics (Definitions 1 & 2: τ_max, τ_avg; Definition of concurrency:
τ_C).  A non-stationary world makes those statistics time-varying, so the
report slices the realised schedule into receipt windows, recomputes the
statistics per window, and evaluates the matching Table-1 rate
(:mod:`repro.core.theory`) at the window's constants — showing exactly
when (e.g. inside a straggler window) the predicted bound degrades.

The GLOBAL row calls the Schedule's own ``tau_max/tau_avg/tau_c`` methods,
so for a stationary world the report reproduces the existing statistics
exactly — no parallel reimplementation to drift out of sync.  The
Koloskova sanity relations (τ_avg ≤ τ_C; τ_C ≤ scheduler concurrency) are
checked on the global row.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.engine import Schedule
from ..core.theory import RATES, ProblemConstants

#: unit-scale default constants; G=1 so bounded-gradient rates are defined
DEFAULT_CONSTANTS = ProblemConstants(L=1.0, F0=1.0, sigma2=1.0, zeta2=0.0,
                                     G=1.0)


def predicted_rate(policy: str, c: ProblemConstants, *, T: int, tau_c: int,
                   tau_max: int, b: int, n: int) -> float:
    """Evaluate the Table-1 rate for ``policy`` at the given schedule
    constants (dispatching each row's own signature)."""
    fn = RATES[policy]
    tau_c = max(int(tau_c), 1)
    tau_max = max(int(tau_max), 1)
    T = max(int(T), 1)
    if policy == "pure":
        return fn(c, T, tau_c, tau_max, bounded_grad=c.G > 0)
    if policy == "pure_waiting":
        return fn(c, T, tau_c, tau_max, b, bounded_grad=c.G > 0)
    if policy == "random":
        return fn(c, T, tau_c)
    if policy == "fedbuff":
        return fn(c, T, tau_c, b)
    if policy in ("shuffled", "rr"):
        return fn(c, T, n)
    if policy == "minibatch":
        return fn(c, T, b)
    raise KeyError(policy)


@dataclasses.dataclass(frozen=True)
class WindowStats:
    """Realised delay statistics over receipts t ∈ [lo, hi)."""

    lo: int
    hi: int
    tau_max: int
    tau_avg: float
    tau_c: int
    rate: float | None = None


def window_stats(schedule: Schedule, n_windows: int = 4) -> list:
    """Slice the schedule into ``n_windows`` equal receipt windows.

    Window statistics use the same quantities as the global methods
    (delays t − π_t; active jobs before each receipt) restricted to the
    window; unfinished-job corrections only apply to the final global
    statistics and are intentionally excluded here.
    """
    T = schedule.T
    n_windows = max(1, min(int(n_windows), T)) if T else 1
    edges = np.linspace(0, T, n_windows + 1).astype(int)
    out = []
    for lo, hi in zip(edges[:-1], edges[1:]):
        if hi <= lo:
            continue
        d = schedule.delays[lo:hi]
        a = schedule.active_jobs[lo:hi]
        out.append(WindowStats(
            lo=int(lo), hi=int(hi),
            tau_max=int(d.max(initial=0)),
            tau_avg=float(d.mean()) if hi > lo else 0.0,
            tau_c=int(a.max(initial=0)),
        ))
    return out


def tau_report(schedule: Schedule, policy: str, *, n_windows: int = 4,
               constants: ProblemConstants | None = None,
               concurrency: int | None = None,
               scenario_spec: str = "",
               evictions: dict | None = None,
               timeouts: dict | None = None,
               shed: dict | None = None,
               drained: dict | None = None,
               attempts: dict | None = None) -> dict:
    """Full report dict: global stats + per-window stats, each with the
    matching Table-1 rate, plus the Koloskova sanity relations.

    ``evictions`` / ``timeouts`` / ``shed`` / ``drained`` are the serving
    lane's degradation maps (rid → decode step, from
    :class:`~repro.distributed.admission.AdmissionTrace`) and ``attempts``
    the retry ledger (rid → failed attempts consumed): passed through
    under ``"degraded"`` so the rendered report accounts every request the
    pool quarantined, timed out, shed, drained or retried — the
    no-silent-loss audit trail the chaos suite checks."""
    c = constants or DEFAULT_CONSTANTS
    b = schedule.wait_b
    n = schedule.n_workers
    g_tau_max = schedule.tau_max()
    g_tau_avg = schedule.tau_avg()
    g_tau_c = schedule.tau_c()
    windows = []
    for w in window_stats(schedule, n_windows):
        rate = predicted_rate(policy, c, T=w.hi - w.lo, tau_c=w.tau_c,
                              tau_max=w.tau_max, b=b, n=n)
        windows.append(dataclasses.replace(w, rate=rate))
    return {
        "policy": policy,
        "scenario": scenario_spec,
        "T": schedule.T,
        "wait_b": b,
        "n_workers": n,
        "global": {
            "tau_max": g_tau_max,
            "tau_avg": g_tau_avg,
            "tau_c": g_tau_c,
            "rate": predicted_rate(policy, c, T=schedule.T, tau_c=g_tau_c,
                                   tau_max=g_tau_max, b=b, n=n),
        },
        "windows": windows,
        "degraded": {
            "evictions": {int(k): int(v)
                          for k, v in (evictions or {}).items()},
            "timeouts": {int(k): int(v)
                         for k, v in (timeouts or {}).items()},
            "shed": {int(k): int(v) for k, v in (shed or {}).items()},
            "drained": {int(k): int(v)
                        for k, v in (drained or {}).items()},
            "attempts": {int(k): int(v)
                         for k, v in (attempts or {}).items()},
        },
        "koloskova": {
            # τ_avg ≤ τ_C always (Koloskova et al. 22, restated §3.1)
            "tau_avg_le_tau_c": bool(g_tau_avg <= g_tau_c + 1e-9),
            # τ_C ≤ policy concurrency when the policy bounds it
            "tau_c_le_concurrency": (
                None if concurrency is None else bool(g_tau_c <= concurrency)),
        },
    }


def render_report(report: dict) -> str:
    """Plain-text table for the CLI (`launch/train --tau-report`)."""
    lines = []
    head = f"τ-report · policy={report['policy']}"
    if report.get("scenario"):
        head += f" · scenario={report['scenario']!r}"
    head += (f" · T={report['T']} b={report['wait_b']}"
             f" n={report['n_workers']}")
    lines.append(head)
    lines.append(f"{'window':>16} {'tau_max':>8} {'tau_avg':>8} "
                 f"{'tau_c':>6} {'rate':>12}")
    g = report["global"]
    lines.append(f"{'global':>16} {g['tau_max']:>8d} {g['tau_avg']:>8.2f} "
                 f"{g['tau_c']:>6d} {g['rate']:>12.4g}")
    for w in report["windows"]:
        span = f"[{w.lo},{w.hi})"
        lines.append(f"{span:>16} {w.tau_max:>8d} {w.tau_avg:>8.2f} "
                     f"{w.tau_c:>6d} {w.rate:>12.4g}")
    deg = report.get("degraded") or {}
    ev, to = deg.get("evictions") or {}, deg.get("timeouts") or {}
    sh, dr = deg.get("shed") or {}, deg.get("drained") or {}
    at = deg.get("attempts") or {}
    if ev or to or sh or dr or at:
        line = (f"degraded: {len(ev)} evicted "
                f"(quarantine) · {len(to)} timed out")
        if sh or dr:
            line += f" · {len(sh)} shed · {len(dr)} drained"
        if at:
            line += (f" · {len(at)} retried "
                     f"({sum(at.values())} failed attempts)")
        lines.append(line)
    k = report["koloskova"]
    checks = [f"tau_avg<=tau_c: {'ok' if k['tau_avg_le_tau_c'] else 'VIOLATED'}"]
    if k["tau_c_le_concurrency"] is not None:
        checks.append("tau_c<=concurrency: "
                      + ("ok" if k["tau_c_le_concurrency"] else "VIOLATED"))
    lines.append("  ".join(checks))
    return "\n".join(lines)
