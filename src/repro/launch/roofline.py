"""Roofline analysis from the dry-run artifacts (deliverable g).

For each (arch × shape) on the single-pod mesh, derive the three roofline
terms from the while-aware HLO cost model (per-device quantities — the
partitioned module IS the per-device program):

    compute_term    = dot_flops / PEAK_FLOPS_BF16          [s]
    memory_term     = hbm_bytes / HBM_BW                   [s]
    collective_term = collective_bytes / ICI_BW            [s]
                      (per-device operand bytes through one link-equivalent)

plus MODEL_FLOPS (6·N·D dense, 6·N_active·D MoE; 2·N·D for pure-forward
prefill; N·2·D_batch for one decode token) and the usefulness ratio
MODEL_FLOPS / (dot_flops × chips) — low ratios expose replicated compute
(e.g. qwen2's 14 unshardable heads) and remat overhead.

Usage: python -m repro.launch.roofline [--dir experiments/dryrun] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from ..configs import ARCHS, SHAPES, get_arch
from ..models import n_params, n_active_params
from .mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW


def model_flops(arch: str, shape_name: str) -> float:
    """Useful (algorithmic) FLOPs for the whole step, all chips."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n_act = n_active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "audio":
            tokens = shape.global_batch * (shape.seq_len
                                           + shape.seq_len // cfg.dec_ratio)
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "audio":
            tokens = shape.global_batch * (shape.seq_len
                                           + shape.seq_len // cfg.dec_ratio)
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch


def _suggest(dom: str, rec: dict) -> str:
    arch, shape = rec["arch"], rec["shape"]
    if dom == "collective":
        return ("reduce collective volume: wider model-parallel tiles / "
                "bf16 collectives / overlap FSDP all-gathers with compute")
    if dom == "memory":
        if rec["kind"] == "decode":
            return ("decode is cache-bandwidth-bound: shrink/quantise the KV "
                    "cache or raise batch to amortise weight reads")
        return "fuse elementwise chains and cut remat recompute traffic"
    return ("compute-bound: raise MFU via larger matmul tiles; if the "
            "usefulness ratio is low, fix sharding to remove replicated work")


def analyze_record(rec: dict, chips: int) -> dict:
    hc = rec["hlo_cost"]
    compute_t = hc["dot_flops"] / PEAK_FLOPS_BF16
    memory_t = hc["hbm_bytes"] / HBM_BW
    coll_t = hc["collective_bytes"] / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(hc["dot_flops"] * chips, 1.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "family": rec["family"],
        "compute_s": compute_t, "memory_s": memory_t, "collective_s": coll_t,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_total": hc["dot_flops"] * chips,
        "useful_ratio": useful,
        "mem_gb_per_dev": rec["memory"]["peak_bytes_est"] / 1e9,
        "suggestion": _suggest(dom, rec),
    }


def load_table(dirname: str, mesh: str = "sp") -> list:
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, f"*_{mesh}.json"))):
        rec = json.load(open(f))
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "error": rec.get("error", "?")})
            continue
        rows.append(analyze_record(rec, rec["n_devices"]))
    return rows


def render_markdown(rows: list) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful | GB/dev |\n|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | FAIL: {r['error'][:40]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.3f} | "
            f"{r['mem_gb_per_dev']:.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="sp", choices=["sp", "mp"])
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = load_table(args.dir, args.mesh)
    print(render_markdown(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
