"""Fused AsGrad server-update kernels (Pallas TPU).

The paper's hot loop is the server update x_{t+1} = x_t − γ g_{i_t}(x_{π_t})
(eq. 2).  In the production tier the stale gradient lives in the delayed
buffer; a naive implementation reads p, gbuf, g and writes p', gbuf' in
FIVE separate HBM passes (sub + copy + clip-scale).  These kernels fuse the
whole update into ONE pass per tile:

* ``async_update``: p' = p − (lr·delay_scale·clip_scale)·gbuf; gbuf' = g.
* ``fused_adam``:   full Adam step (m, v updates + parameter step) with the
  delayed gradient, f32 moments, bf16-safe parameter update.

Tiling: flat parameter tensors are viewed as (rows, LANE) with LANE=128
(the TPU lane width); BlockSpec tiles (block_rows, 128) keep each operand
slab in VMEM.  Scalars (lr·scales, bias corrections) arrive via a small
SMEM block, the standard scalar-plumbing pattern.

Validated under interpret=True against ``ref.reference_async_update`` /
``ref.reference_fused_adam``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
F32 = jnp.float32


def _pad_to_tiles(x, block_rows):
    n = x.size
    per_tile = block_rows * LANE
    tiles = pl.cdiv(n, per_tile)
    padded = tiles * per_tile
    flat = jnp.ravel(x)
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(tiles * block_rows, LANE), tiles


def _async_update_kernel(scal_ref, p_ref, gbuf_ref, g_ref, p_out, gbuf_out):
    eff = scal_ref[0]
    p = p_ref[...]
    stale = gbuf_ref[...].astype(F32)
    p_out[...] = (p.astype(F32) - eff * stale).astype(p_out.dtype)
    gbuf_out[...] = g_ref[...].astype(gbuf_out.dtype)


def async_update_pallas(params, gbuf, grads, *, lr, clip_scale=1.0,
                        delay_scale=1.0, block_rows=256, interpret=False):
    """Fused delayed-gradient apply on one flat tensor.

    params/gbuf/grads: same shape & dtype.  Returns (p', gbuf')."""
    assert params.shape == gbuf.shape == grads.shape
    shape, dtype = params.shape, params.dtype
    p2, tiles = _pad_to_tiles(params, block_rows)
    b2, _ = _pad_to_tiles(gbuf, block_rows)
    g2, _ = _pad_to_tiles(grads, block_rows)
    eff = jnp.asarray([lr * clip_scale * delay_scale], F32)

    p_new, gbuf_new = pl.pallas_call(
        _async_update_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p2.shape, dtype),
            jax.ShapeDtypeStruct(b2.shape, grads.dtype),
        ],
        interpret=interpret,
    )(eff, p2, b2, g2)
    n = params.size
    return (p_new.ravel()[:n].reshape(shape),
            gbuf_new.ravel()[:n].reshape(shape))


def _fused_adam_kernel(scal_ref, p_ref, m_ref, v_ref, g_ref,
                       p_out, m_out, v_out, *, beta1, beta2, eps):
    lr = scal_ref[0]
    bc1 = scal_ref[1]
    bc2 = scal_ref[2]
    g = g_ref[...].astype(F32)
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    p_out[...] = (p_ref[...].astype(F32)
                  - lr * step).astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


def fused_adam_pallas(p, m, v, g, *, lr, beta1=0.9, beta2=0.95, eps=1e-8,
                      count=1, block_rows=256, interpret=False):
    """One fused Adam step on a flat tensor; m/v f32.  Returns (p', m', v')."""
    shape, dtype = p.shape, p.dtype
    p2, tiles = _pad_to_tiles(p, block_rows)
    m2, _ = _pad_to_tiles(m.astype(F32), block_rows)
    v2, _ = _pad_to_tiles(v.astype(F32), block_rows)
    g2, _ = _pad_to_tiles(g, block_rows)
    bc1 = 1.0 - beta1 ** count
    bc2 = 1.0 - beta2 ** count
    scal = jnp.asarray([lr, bc1, bc2], F32)

    kern = functools.partial(_fused_adam_kernel, beta1=beta1, beta2=beta2,
                             eps=eps)
    p_new, m_new, v_new = pl.pallas_call(
        kern,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p2.shape, dtype),
            jax.ShapeDtypeStruct(m2.shape, F32),
            jax.ShapeDtypeStruct(v2.shape, F32),
        ],
        interpret=interpret,
    )(scal, p2, m2, v2, g2)
    n = p.size
    return (p_new.ravel()[:n].reshape(shape),
            m_new.ravel()[:n].reshape(shape),
            v_new.ravel()[:n].reshape(shape))
