"""Checkpointer round-trip + heterogeneous data pipeline properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.checkpoint import save, restore, load_meta
from repro.configs import get_arch
from repro.data import DataConfig, HeterogeneousTokenPipeline, EpochShuffler
from repro.distributed import AsyncTrainer, AsyncConfig
from repro.optim import OptConfig


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_arch("qwen2-0.5b").reduced()
    tr = AsyncTrainer(cfg, Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                                ("data", "model")),
                      opt=OptConfig(), async_cfg=AsyncConfig(1))
    state = tr.init_state(jax.random.PRNGKey(0))
    save(str(tmp_path / "ck"), state, step=7, meta={"arch": cfg.name})
    like = jax.tree_util.tree_map(jnp.zeros_like, state)
    restored = restore(str(tmp_path / "ck"), like)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    meta = load_meta(str(tmp_path / "ck"))
    assert meta["step"] == 7 and meta["arch"] == cfg.name


def test_checkpoint_shape_mismatch_raises(tmp_path):
    state = {"w": jnp.ones((3, 3))}
    save(str(tmp_path / "ck"), state)
    with pytest.raises(ValueError):
        restore(str(tmp_path / "ck"), {"w": jnp.ones((2, 3))})


def test_pipeline_heterogeneity_measurable():
    """Different groups draw measurably different token marginals; zero
    heterogeneity gives identical marginals."""
    dc = DataConfig(vocab=64, seq_len=128, global_batch=8, n_groups=4,
                    heterogeneity=1.0, seed=0)
    pipe = HeterogeneousTokenPipeline(dc)
    b = pipe.batch(0)["tokens"]
    assert b.shape == (8, 128) and b.dtype == np.int32
    per = 8 // 4
    hists = [np.bincount(b[g * per:(g + 1) * per].ravel(), minlength=64)
             for g in range(4)]
    tv = max(np.abs(hists[0] / hists[0].sum() - h / h.sum()).sum()
             for h in hists[1:])
    assert tv > 0.05
    hom = HeterogeneousTokenPipeline(
        DataConfig(vocab=64, seq_len=128, global_batch=8, n_groups=4,
                   heterogeneity=0.0, seed=0))
    bh = hom.batch(0)["tokens"]
    hh = [np.bincount(bh[g * per:(g + 1) * per].ravel(), minlength=64)
          for g in range(4)]
    tvh = max(np.abs(hh[0] / hh[0].sum() - h / h.sum()).sum() for h in hh[1:])
    assert tvh < tv


def test_pipeline_deterministic():
    dc = DataConfig(vocab=32, seq_len=16, global_batch=4, n_groups=2, seed=3)
    b1 = HeterogeneousTokenPipeline(dc).batch(5)["tokens"]
    b2 = HeterogeneousTokenPipeline(dc).batch(5)["tokens"]
    np.testing.assert_array_equal(b1, b2)


def test_epoch_shuffler_covers_every_epoch():
    sh = EpochShuffler(10, seed=0, reshuffle=True)
    for _ in range(5):
        idx = sh.next_indices(10)
        assert sorted(idx.tolist()) == list(range(10))
    once = EpochShuffler(10, seed=0, reshuffle=False)
    e1 = once.next_indices(10)
    e2 = once.next_indices(10)
    np.testing.assert_array_equal(e1, e2)
