"""Figure 1: pure vs random vs shuffled async SGD, full local gradients,
w7a / phishing (generated stand-ins), four delay patterns.

Claim validated: pure async stalls near the heterogeneity level ζ; random
escapes it; shuffled reaches ~10× smaller gradient norm and is the best.
"""
from __future__ import annotations

import csv
import os

import numpy as np

from repro.core import PATTERNS
from repro.objectives import LogRegProblem, make_libsvm_like
from .common import run_alg, ALGS


def run(T: int = 3000, out: str = "experiments/figs", quick: bool = False):
    os.makedirs(out, exist_ok=True)
    rows = []
    datasets = ("w7a", "phishing") if not quick else ("phishing",)
    patterns = PATTERNS if not quick else ("fixed", "poisson")
    for ds in datasets:
        A, b = make_libsvm_like(ds, n=10, seed=0)
        prob = LogRegProblem(A, b, lam=0.1)
        zeta = prob.zeta(np.zeros(prob.d))
        for pattern in patterns:
            finals = {}
            for alg in ALGS:
                gamma, ts, gns, secs = run_alg(prob, alg, pattern, T)
                finals[alg] = float(np.min(gns[-3:]))
                rows.append({"dataset": ds, "pattern": pattern, "alg": alg,
                             "gamma": gamma, "final_grad_norm": finals[alg],
                             "zeta": zeta, "seconds": round(secs, 1)})
                for t, g in zip(ts, gns):
                    pass  # curves optionally dumped below
                np.savez(os.path.join(out, f"fig1_{ds}_{pattern}_{alg}.npz"),
                         ts=ts, grad_norms=gns, gamma=gamma)
            # the paper's ordering
            ok = finals["shuffled"] <= finals["random"] * 1.5 and \
                finals["random"] <= finals["pure"]
            rows[-1]["ordering_ok"] = ok
    with open(os.path.join(out, "fig1.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=sorted({k for r in rows for k in r}))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
