"""Golden parity suite for the ``repro.runtime`` whole-run executor.

The load-bearing guarantee: the scan executor is the SAME run as the eager
per-round loop — same plan, same device-synthesised batches, same step
function — only the dispatch differs.  Curves must therefore agree within
the documented FMA-contraction tolerances (tests/test_optim_fused.py:
XLA may contract multiply-adds differently when the step is compiled
inside a ``lax.scan`` body than when compiled standalone; bitwise f32
equality is NOT attainable, rtol=1e-5 + small atol is the contract).

Covered here:

* plan lowering (masks/scales/keys shapes, resume-stable key folding),
* scan-vs-eager curve parity across (scheduler × update_impl ×
  delay-adaptive) combos, including the sync (delay_rounds=0) baseline,
* chunk-boundary edge cases: ``rounds_per_launch`` of 1, ``rounds``, and a
  ragged ``rounds % K != 0`` split, plus ``on_step`` barrier semantics,
* checkpoint-resume at a chunk boundary (pooled state) ≡ uninterrupted,
* ``TrainerBackend`` wiring (spec/constructor runtime resolution), and
* an 8-virtual-device pooled ZeRO-sharded scan run (subprocess
  self-bootstrap on single-device hosts, mirroring
  tests/test_pool_multidevice.py).
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import ExperimentSpec, RunResult, TrainJob, TrainerBackend
from repro.core import lower_rounds, round_delay_scales, round_masks
from repro.runtime import (METRICS, RunPlan, compile_plan, execute,
                           fold_data_keys, make_batch_fn, run_eager,
                           run_scan)

MULTI = jax.device_count() >= 8

#: micro transformer: jit/compile dominates CPU test wall time, so shrink
#: the per-step math to noise and spend the budget on dispatch coverage
MICRO = (("n_layers", 1), ("d_model", 64), ("n_heads", 2), ("n_kv_heads", 1),
         ("d_ff", 64), ("vocab", 97))

TOL = dict(rtol=1e-5, atol=1e-7)


def _job(**kw):
    kw.setdefault("arch", "qwen2-0.5b")
    kw.setdefault("global_batch", 8)
    kw.setdefault("seq_len", 16)
    kw.setdefault("arch_overrides", MICRO)
    return TrainJob(**kw)


def _spec(job, scheduler="shuffled", T=6, adaptive=False, **kw):
    stepsize = f"delay_adaptive:{3e-3}" if adaptive else 3e-3
    return ExperimentSpec(scheduler=scheduler, timing="poisson:slow=6",
                          objective=job, T=T, n_workers=4,
                          stepsize=stepsize, seed=0, **kw)


def _trainer(job, mesh=None):
    from jax.sharding import Mesh
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.optim import OptConfig

    if mesh is None:
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
    tr = AsyncTrainer(
        job.make_arch(), mesh,
        opt=OptConfig(lr=3e-3, clip_norm=job.clip_norm,
                      update_impl=job.update_impl),
        async_cfg=AsyncConfig(delay_rounds=job.delay_rounds))
    tr.n_groups = 4
    return tr


def _plan_for(spec, job):
    _, schedule = TrainerBackend.masks_for(spec, 4)
    return compile_plan(schedule, job, rounds=spec.T, n_groups=4,
                        seed=spec.seed,
                        adaptive=spec.stepsize.kind == "delay_adaptive")


@pytest.mark.skipif(MULTI, reason="already on a multi-device host")
def test_multidevice_suite_in_subprocess():
    """Single-device hosts: run this file under 8 virtual CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-k", "multidevice"],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, \
        f"8-device runtime suite failed:\n{r.stdout}\n{r.stderr}"
    assert " passed" in r.stdout


# ---------------------------------------------------------------------------
# plan lowering
# ---------------------------------------------------------------------------
def test_lower_rounds_matches_components():
    spec = _spec(_job(), scheduler="fedbuff:b=2", T=10)
    _, schedule = TrainerBackend.masks_for(spec, 4)
    masks, ones = lower_rounds(schedule, 10)
    np.testing.assert_array_equal(masks, round_masks(schedule, 10))
    np.testing.assert_array_equal(ones, np.ones(10, np.float32))
    m2, scales = lower_rounds(schedule, 10, delay_rounds=1, adaptive=True)
    np.testing.assert_array_equal(m2, masks)
    np.testing.assert_array_equal(
        scales, round_delay_scales(schedule, 10, delay_rounds=1))


def test_compile_plan_shapes_and_validation():
    job = _job()
    spec = _spec(job, T=7)
    plan = _plan_for(spec, job)
    assert plan.rounds == 7 and plan.n_groups == 4
    assert plan.masks.shape == (7, 4)
    assert plan.delay_scales.shape == (7,)
    assert plan.data_keys.shape == (7, 2)
    assert plan.vocab == 97                      # MICRO override
    assert plan.group_perms.shape == (4, 97)
    assert np.all(np.diff(plan.token_cdf) >= 0)
    assert abs(plan.token_cdf[-1] - 1.0) < 1e-5
    # not adaptive → neutral scales
    np.testing.assert_array_equal(plan.delay_scales, np.ones(7, np.float32))
    with pytest.raises(ValueError, match="rounds"):
        RunPlan(masks=plan.masks, delay_scales=plan.delay_scales[:3],
                data_keys=plan.data_keys, token_cdf=plan.token_cdf,
                group_perms=plan.group_perms, global_batch=8, seq_len=16,
                seed=0)
    with pytest.raises(ValueError, match="divide"):
        RunPlan(masks=plan.masks, delay_scales=plan.delay_scales,
                data_keys=plan.data_keys, token_cdf=plan.token_cdf,
                group_perms=plan.group_perms, global_batch=9, seq_len=16,
                seed=0)


def test_fold_data_keys_resume_stable():
    """Key at round q must not depend on the horizon — that is what makes
    a resumed run regenerate the identical batch stream."""
    k10, k4 = fold_data_keys(3, 10), fold_data_keys(3, 4)
    np.testing.assert_array_equal(k10[:4], k4)
    assert not np.array_equal(fold_data_keys(4, 4), k4)      # seed matters
    assert len({tuple(k) for k in k10}) == 10                # distinct rounds


def test_device_batch_synthesis_is_grouped_and_deterministic():
    job = _job()
    plan = _plan_for(_spec(job, T=3), job)
    batch_of = make_batch_fn(plan, job.make_arch())
    b0 = batch_of(jnp.asarray(plan.data_keys[0]))
    b0b = batch_of(jnp.asarray(plan.data_keys[0]))
    b1 = batch_of(jnp.asarray(plan.data_keys[1]))
    toks = np.asarray(b0["tokens"])
    assert toks.shape == (8, 16) and toks.dtype == np.int32
    assert toks.min() >= 0 and toks.max() < plan.vocab
    np.testing.assert_array_equal(toks, np.asarray(b0b["tokens"]))
    assert not np.array_equal(toks, np.asarray(b1["tokens"]))


# ---------------------------------------------------------------------------
# golden scan-vs-eager parity (scheduler × update_impl × delay-adaptive)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheduler,impl,adaptive,delay_rounds", [
    ("shuffled", "reference", False, 1),
    ("fedbuff:b=2", "reference", True, 1),
    ("pure", "reference", False, 0),                  # sync baseline
    ("random", "pallas_interpret", False, 1),
    ("shuffled", "pallas_pooled_interpret", True, 1),
])
def test_scan_matches_eager(scheduler, impl, adaptive, delay_rounds):
    job = _job(update_impl=impl, delay_rounds=delay_rounds)
    spec = _spec(job, scheduler=scheduler, T=6, adaptive=adaptive)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    r_e = run_eager(tr, plan, tr.init_state(jax.random.PRNGKey(0)))
    r_s = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=4)               # ragged: 4 + 2
    assert r_e.launches == 12 and r_e.host_syncs == 6   # batch jit + step jit
    assert r_s.launches == 2 and r_s.host_syncs == 2
    for k in METRICS:
        np.testing.assert_allclose(r_s.metrics[k], r_e.metrics[k], **TOL,
                                   err_msg=f"metric {k}")
    if adaptive:        # the adaptive lowering actually ran (the rule may
        assert plan.adaptive     # still saturate at 1 for short horizons)
        assert np.all(plan.delay_scales <= 1.0)


# ---------------------------------------------------------------------------
# chunk-boundary edge cases + on_step barrier semantics
# ---------------------------------------------------------------------------
def test_chunk_boundary_edge_cases():
    """K=1 (degenerate eager), K=rounds (one launch), ragged K — all the
    same curves; on_step fires once per round, at chunk boundaries, in
    order."""
    job = _job()
    spec = _spec(job, T=5)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    base = run_eager(tr, plan, tr.init_state(jax.random.PRNGKey(0)))
    for k, launches in ((1, 5), (3, 2), (5, 1)):
        seen = []
        r = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                     rounds_per_launch=k,
                     on_step=lambda i, st, m: seen.append((i, m["loss"])))
        assert r.launches == launches and r.host_syncs == launches
        assert [i for i, _ in seen] == list(range(5))
        np.testing.assert_allclose([l for _, l in seen],
                                   base.metrics["loss"], **TOL)
        for name in METRICS:
            np.testing.assert_allclose(r.metrics[name], base.metrics[name],
                                       **TOL, err_msg=f"K={k} {name}")


def test_neutral_plan_honors_trainer_static_delay_rule():
    """A NON-adaptive plan must not override the trainer's own static
    ``AsyncConfig(delay_adaptive=True)`` 1/(1+delay) rule with an explicit
    all-ones scale — the executor calls the 3-arg step, the trainer's
    config stays in charge, and scan still matches eager."""
    from jax.sharding import Mesh
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.optim import OptConfig

    job = _job()
    spec = _spec(job, T=4)
    plan = _plan_for(spec, job)
    assert not plan.adaptive
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    tr_static = AsyncTrainer(
        job.make_arch(), mesh,
        opt=OptConfig(lr=3e-3, clip_norm=job.clip_norm),
        async_cfg=AsyncConfig(delay_rounds=1, delay_adaptive=True))
    tr_static.n_groups = 4
    r_e = run_eager(tr_static, plan,
                    tr_static.init_state(jax.random.PRNGKey(0)))
    r_s = run_scan(tr_static, plan,
                   tr_static.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=2)
    for k in METRICS:
        np.testing.assert_allclose(r_s.metrics[k], r_e.metrics[k], **TOL)
    # and the halved stepsize actually bit: curves diverge from the plain
    # (delay_adaptive=False) trainer once the first buffered grad applies
    plain = run_eager(_trainer(job), plan,
                      tr_static.init_state(jax.random.PRNGKey(0)))
    assert not np.allclose(plain.metrics["loss"][2:],
                           r_e.metrics["loss"][2:], rtol=1e-6)


def test_execute_dispatch_and_unknown_runtime():
    job = _job()
    plan = _plan_for(_spec(job, T=2), job)
    tr = _trainer(job)
    r = execute(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                runtime="scan", rounds_per_launch=2)
    assert r.launches == 1
    with pytest.raises(ValueError, match="unknown runtime"):
        execute(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                runtime="vectorized")


# ---------------------------------------------------------------------------
# checkpoint-resume parity at a chunk boundary (pooled state)
# ---------------------------------------------------------------------------
def test_checkpoint_resume_parity_pooled(tmp_path):
    """Save at a chunk boundary via repro.checkpoint, restore (pooled
    pools + scalars), finish — loss/grad-norm curves must match an
    uninterrupted run within the FMA tolerances."""
    from repro import checkpoint

    job = _job(update_impl="pallas_pooled_interpret")
    spec = _spec(job, T=6)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    assert tr.pooled

    full = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                    rounds_per_launch=3)

    ckpt = str(tmp_path / "ckpt")
    saved = {}

    def barrier(i, state, m):
        if i == 2:                  # chunk boundary: state is post-round-3
            checkpoint.save(ckpt, state, step=i + 1)
            saved["step"] = i + 1

    first = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                     rounds_per_launch=3, on_step=barrier)
    assert saved["step"] == 3
    for k in METRICS:
        np.testing.assert_allclose(first.metrics[k], full.metrics[k], **TOL)

    restored = checkpoint.restore(ckpt, tr.abstract_state(),
                                  shardings=tr.state_shardings())
    assert int(restored["step"]) == 3
    tail = run_scan(tr, plan, restored, rounds_per_launch=3, start_round=3)
    for k in ("loss", "grad_norm"):
        np.testing.assert_allclose(tail.metrics[k], full.metrics[k][3:],
                                   **TOL, err_msg=f"resumed {k}")


# ---------------------------------------------------------------------------
# TrainerBackend wiring
# ---------------------------------------------------------------------------
def test_backend_runtime_resolution():
    be = TrainerBackend()
    assert be.resolve_runtime(_spec(_job())) == ("scan", 8)
    assert be.resolve_runtime(_spec(_job(), runtime="eager",
                                    rounds_per_launch=3)) == ("eager", 3)
    assert TrainerBackend(runtime="eager", rounds_per_launch=2) \
        .resolve_runtime(_spec(_job(), runtime="scan")) == ("eager", 2)
    with pytest.raises(ValueError, match="unknown runtime"):
        _spec(_job(), runtime="vectorized")
    with pytest.raises(ValueError, match="rounds_per_launch"):
        _spec(_job(), rounds_per_launch=0)


def test_backend_scan_eager_parity_and_result_roundtrip():
    """End-to-end through ``repro.api``: default scan ≡ eager oracle, the
    RunResult records the dispatch provenance, and the archived JSON
    round-trips the curves exactly."""
    job = _job()
    spec = _spec(job, T=4, rounds_per_launch=2)
    res_s = TrainerBackend().run(spec)
    res_e = TrainerBackend(runtime="eager").run(spec)
    assert res_s.extra["runtime"] == "scan"
    assert res_s.extra["rounds_per_launch"] == 2
    assert res_s.extra["launches"] == 2 and res_s.extra["host_syncs"] == 2
    assert res_e.extra["runtime"] == "eager"
    assert res_e.extra["launches"] == 8 and res_e.extra["host_syncs"] == 4
    np.testing.assert_allclose(res_s.losses, res_e.losses, **TOL)
    np.testing.assert_allclose(res_s.grad_norms, res_e.grad_norms, **TOL)
    assert len(res_s.extra["metrics"]) == 4

    r2 = RunResult.from_json(res_s.to_json())
    np.testing.assert_array_equal(r2.losses, res_s.losses)
    np.testing.assert_array_equal(r2.grad_norms, res_s.grad_norms)
    assert r2.backend == "trainer"
    assert r2.extra["runtime"] == "scan"
    assert r2.schedule["tau_max"] == res_s.schedule.tau_max()


# ---------------------------------------------------------------------------
# 8-virtual-device pooled scan run (ZeRO-sharded pools under shard_map)
# ---------------------------------------------------------------------------
@pytest.mark.skipif(not MULTI, reason="needs >= 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_scan_pooled_multidevice_parity():
    """Scan executor on a 4-data × 2-model mesh with pooled ZeRO-sharded
    state ≡ the eager oracle on the same mesh, and the carried pools keep
    their sharding across chunk launches (donation must not silently
    replicate)."""
    from repro.launch.mesh import _make_mesh
    from repro.distributed import pooled_pspec
    from jax.sharding import NamedSharding

    mesh = _make_mesh((4, 2), ("data", "model"))
    job = _job(update_impl="pallas_pooled_interpret")
    spec = _spec(job, T=4)
    plan = _plan_for(spec, job)
    tr = _trainer(job, mesh=mesh)
    assert tr.pool_layout.n_shards == 4

    r_e = run_eager(tr, plan, tr.init_state(jax.random.PRNGKey(0)))
    r_s = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=2)
    for k in METRICS:
        np.testing.assert_allclose(r_s.metrics[k], r_e.metrics[k], **TOL,
                                   err_msg=f"metric {k}")
    want = NamedSharding(mesh, pooled_pspec(mesh))
    for dk, grp in r_s.state["pools"].items():
        for name, buf in grp.items():
            assert buf.sharding.is_equivalent_to(want, buf.ndim), \
                f"pool {dk}/{name} lost ZeRO sharding: {buf.sharding}"
