"""Hypothesis property test: the sharding rules never produce an illegal
PartitionSpec.

``hypothesis`` is an optional ``[test]`` extra; the whole module skips
gracefully when it is absent so tier-1 stays green on minimal installs.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.distributed.sharding import (
    Rules, DEFAULT_RULES, logical_pspec, zero_pspec,
)

from test_sharding import FakeMesh

_NAMES = [None, "batch", "seq", "embed", "heads", "kv_heads", "ff", "vocab",
          "experts", "layers", "ctx", "d_inner", "ssm_heads", "capacity",
          "act_embed", "head", "state", "conv"]


@settings(max_examples=120, deadline=None)
@given(
    dims=st.lists(st.integers(1, 4096), min_size=1, max_size=5),
    names=st.lists(st.sampled_from(_NAMES), min_size=1, max_size=5),
    data=st.sampled_from([1, 2, 4, 16]),
    model=st.sampled_from([1, 2, 8, 16]),
    pod=st.sampled_from([0, 2]),
    zero=st.booleans(),
    seq_rules=st.booleans(),
)
def test_property_pspec_legal(dims, names, data, model, pod, zero, seq_rules):
    n = min(len(dims), len(names))
    dims, names = tuple(dims[:n]), tuple(names[:n])
    shape = {"data": data, "model": model}
    if pod:
        shape = {"pod": pod, **shape}
    mesh = FakeMesh(shape)
    rules = DEFAULT_RULES
    if seq_rules:
        rules = Rules(model_priority=DEFAULT_RULES.model_priority + ("seq",))
    spec = logical_pspec(names, dims, mesh, rules)
    if zero:
        spec = zero_pspec(names, dims, mesh, spec, rules)
    used = []
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            assert a in mesh.axis_names          # only real mesh axes
            assert a not in used                 # each mesh axis used once
            used.append(a)
            total *= mesh.shape[a]
        assert dims[i] % total == 0, (dims, names, spec)  # always divisible
