"""End-to-end behaviour tests for the whole system.

These cross the tier boundary: the same scheduler object drives both the
exact simulator and the distributed trainer, and the paper's headline claim
must emerge from the full pipeline, not just from unit parts.
"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_arch
from repro.core import (TimingModel, build_schedule, replay, round_masks,
                        make_scheduler, heterogeneous_speeds)
from repro.data import DataConfig, HeterogeneousTokenPipeline
from repro.distributed import AsyncTrainer, AsyncConfig
from repro.objectives import LogRegProblem, make_synthetic
from repro.optim import OptConfig


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_paper_headline_claim_end_to_end():
    """Pure async stalls at the heterogeneity level; shuffled reaches a
    many-times-smaller gradient norm — the paper's Fig.-1 story through the
    full engine→replay pipeline with tuned stepsizes."""
    n, T = 10, 3000
    A, b = make_synthetic(1.0, 1.0, n=n, m=120, d=120, seed=0)
    prob = LogRegProblem(A, b, lam=0.1)
    finals = {}
    for alg in ("pure", "shuffled"):
        best = np.inf
        for gamma in (0.005, 0.002, 0.001):
            s = build_schedule(make_scheduler(alg, n, seed=0),
                               TimingModel(heterogeneous_speeds(n, 8.0),
                                           "poisson", seed=0), T)
            res = replay(s, prob.grad_fn(), jnp.zeros(prob.d), gamma,
                         log_every=300, full_grad_fn=prob.full_grad)
            best = min(best, float(np.min(res.grad_norms[-3:])))
        finals[alg] = best
    assert finals["shuffled"] < finals["pure"] / 3.0, finals


def test_scheduler_identity_across_tiers():
    """The ordering the distributed trainer consumes (round masks) is the
    SAME realised schedule the exact simulator replays — worker for worker."""
    n, b, rounds = 6, 2, 20
    sched = make_scheduler("fedbuff", n, b=b, seed=1)
    tm = TimingModel(heterogeneous_speeds(n, 4.0), "normal", seed=1)
    s = build_schedule(sched, tm, rounds * b)
    masks = round_masks(s)
    # reconstruct per-round contributors from the raw schedule
    for q in range(rounds):
        contributors = sorted(s.workers[q * b:(q + 1) * b].tolist())
        from_mask = sorted(
            w for w in range(n) for _ in range(int(masks[q, w])))
        assert contributors == from_mask


def test_full_training_pipeline_with_scheduler_masks():
    """schedule → masks → AsyncTrainer steps → loss drops (transformer)."""
    cfg = get_arch("qwen3-8b").reduced().with_(remat="none")
    tr = AsyncTrainer(cfg, _mesh(), opt=OptConfig(lr=5e-3),
                      async_cfg=AsyncConfig(delay_rounds=1))
    n_groups = 4
    tr.n_groups = n_groups
    sched = make_scheduler("shuffled", n_groups, seed=0)
    tm = TimingModel(heterogeneous_speeds(n_groups, 5.0), "poisson", seed=0)
    masks = round_masks(build_schedule(sched, tm, 14))
    pipe = HeterogeneousTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=32, global_batch=8, n_groups=n_groups,
        heterogeneity=1.0))
    state = tr.init_state(jax.random.PRNGKey(0))
    step = jax.jit(tr.train_step_fn())
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    losses = []
    for q in range(masks.shape[0]):
        state, m = step(state, batch, jnp.asarray(masks[q]))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[1] and np.isfinite(losses).all()


def test_microbatch_accumulation_matches_single_batch():
    """Gradient accumulation (k microbatches) ≡ one full batch for SGD."""
    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    mesh = _mesh()
    opt = OptConfig(name="sgd", lr=1e-2, clip_norm=None)
    tr1 = AsyncTrainer(cfg, mesh, opt=opt,
                       async_cfg=AsyncConfig(delay_rounds=0, microbatches=1))
    tr4 = AsyncTrainer(cfg, mesh, opt=opt,
                       async_cfg=AsyncConfig(delay_rounds=0, microbatches=4))
    s1 = tr1.init_state(jax.random.PRNGKey(0))
    s4 = tr4.init_state(jax.random.PRNGKey(0))
    pipe = HeterogeneousTokenPipeline(DataConfig(cfg.vocab, 16, 8))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    mask = jnp.ones((1,))
    s1, m1 = jax.jit(tr1.train_step_fn())(s1, batch, mask)
    s4, m4 = jax.jit(tr4.train_step_fn())(s4, batch, mask)
    for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                    jax.tree_util.tree_leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-3)


def test_checkpoint_resume_continues_training():
    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    tr = AsyncTrainer(cfg, _mesh(), opt=OptConfig(lr=1e-2),
                      async_cfg=AsyncConfig(delay_rounds=1))
    from repro import checkpoint
    import tempfile, os
    pipe = HeterogeneousTokenPipeline(DataConfig(cfg.vocab, 16, 4))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    mask = jnp.ones((1,))
    step = jax.jit(tr.train_step_fn())
    state = tr.init_state(jax.random.PRNGKey(0))
    for _ in range(3):
        state, m = step(state, batch, mask)
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(os.path.join(d, "ck"), state, step=3)
        like = jax.tree_util.tree_map(jnp.zeros_like, state)
        restored = checkpoint.restore(os.path.join(d, "ck"), like)
    state2, m2 = step(restored, batch, mask)
    state1, m1 = step(state, batch, mask)
    assert float(m1["loss"]) == float(m2["loss"])
