"""Worker timing models — Section 5 / Appendix A of the paper.

Each worker ``i`` owns a positive speed parameter ``s_i``; a timing model
turns it into a per-job compute time ``r`` (in simulated seconds):

* ``fixed``:    r = s_i                       (fixed delay pattern)
* ``poisson``:  r ~ Po(s_i)                   (clamped to >= 1)
* ``normal``:   r = |N(mean s_i, variance s_i)| + 1
                (i.e. std = sqrt(s_i); mean and variance both equal s_i,
                matching the Poisson pattern's first two moments)
* ``uniform``:  r ~ Uni(0, s_i)
* ``bursty``:   r = 4·s_i w.p. 1/4, else ~0 — same mean s_i as the
                others, but draws cluster: runs of near-zero gaps
                (geometric, mean length 4) separated by 4·s_i lulls.
                As an ARRIVAL pattern (``draw_arrivals``) this yields
                burst traffic — batches of simultaneous requests — the
                overload-shedding worst case.

The first four are exactly the patterns the paper benchmarks; ``bursty``
is the serving lane's addition.  The simulator is
agnostic: anything with ``sample(worker) -> float`` works.  Non-stationary
worlds (drifting speeds, stragglers, elastic pools) wrap these stationary
models — see :mod:`repro.scenarios`; the wrappers reuse :meth:`_draw` on a
modulated speed so an identity wrap consumes the RNG stream bit-for-bit
identically.
"""
from __future__ import annotations

import numpy as np

PATTERNS = ("fixed", "poisson", "normal", "uniform", "bursty")


class TimingModel:
    """Samples per-job compute times for ``n`` workers.

    Parameters
    ----------
    speeds:
        array of per-worker parameters ``s_i`` (larger = slower worker).
    pattern:
        one of :data:`PATTERNS`.
    seed:
        host RNG seed (timings are host-side; they order events, they do not
        enter any jax computation).
    """

    def __init__(self, speeds, pattern: str = "fixed", seed: int = 0):
        speeds = np.asarray(speeds, dtype=np.float64)
        if np.any(speeds <= 0):
            raise ValueError("worker speed parameters must be positive")
        if pattern not in PATTERNS:
            raise ValueError(f"unknown pattern {pattern!r}; want one of {PATTERNS}")
        self.speeds = speeds
        self.pattern = pattern
        self._rng = np.random.default_rng(seed)

    @property
    def n_workers(self) -> int:
        return int(self.speeds.shape[0])

    # ------------------------------------------------------------------ draws
    def _draw(self, s: float) -> float:
        """One compute-time draw at speed parameter ``s`` — the single
        place distribution semantics live (scalar oracle; wrappers feed a
        modulated ``s`` through the same RNG stream)."""
        if self.pattern == "fixed":
            r = s
        elif self.pattern == "poisson":
            r = float(self._rng.poisson(s))
            r = max(r, 1.0)
        elif self.pattern == "normal":
            # mean s, variance s (std = sqrt(s)) — see module docstring
            r = abs(float(self._rng.normal(s, np.sqrt(s)))) + 1.0
        elif self.pattern == "uniform":
            r = float(self._rng.uniform(0.0, s))
            r = max(r, 1e-6)
        else:  # bursty: one uniform decides lull (p=1/4) vs in-burst (~0)
            r = 4.0 * s if float(self._rng.random()) < 0.25 else 1e-6
        return r

    def _draw_batch(self, s: np.ndarray) -> np.ndarray:
        """Vectorised :meth:`_draw`: one RNG call for the whole batch.

        numpy ``Generator`` fills array requests element-by-element from
        the same bit stream as repeated scalar calls, so the batched draws
        are bit-identical to a ``[_draw(x) for x in s]`` loop — the scalar
        path stays the test oracle (tests/test_scenarios.py pins this)."""
        s = np.asarray(s, dtype=np.float64)
        if self.pattern == "fixed":
            return s.copy()
        if self.pattern == "poisson":
            return np.maximum(self._rng.poisson(s).astype(np.float64), 1.0)
        if self.pattern == "normal":
            return np.abs(self._rng.normal(s, np.sqrt(s))) + 1.0
        if self.pattern == "uniform":
            return np.maximum(self._rng.uniform(0.0, s), 1e-6)
        # bursty: Generator.random(shape) consumes the same doubles as the
        # scalar loop, so the batch stays bit-identical to the oracle
        u = self._rng.random(s.shape)
        return np.where(u < 0.25, 4.0 * s, 1e-6)

    # ------------------------------------------------------------- public API
    def sample(self, worker: int) -> float:
        return self._draw(float(self.speeds[worker]))

    def sample_round(self, workers) -> np.ndarray:
        """Batched per-job compute times for a round's worth of job starts.

        ``workers`` is a sequence of worker indices (duplicates allowed —
        a waiting round can start several jobs on distinct workers, and
        the engine batches all simultaneous starts into ONE RNG call).
        Returns ``(len(workers),)`` float64 draws, bit-identical to
        calling :meth:`sample` once per worker in order.
        """
        workers = np.asarray(workers, dtype=np.intp)
        if workers.size == 0:
            return np.zeros(0, dtype=np.float64)
        return self._draw_batch(self.speeds[workers])


def heterogeneous_speeds(n: int, slow_factor: float = 5.0, base: float = 1.0):
    """Linearly spread speeds in [base, base*slow_factor] — a simple
    heterogeneous-cluster profile used across benchmarks/examples."""
    return base * (1.0 + (slow_factor - 1.0) * np.arange(n) / max(n - 1, 1))
