"""Full Section-5 reproduction driver: runs the fig1/fig2/fig3/table1
benchmarks at paper-scale grids and writes experiments/figs/*.csv.

  PYTHONPATH=src python examples/paper_reproduction.py [--quick]
"""
import argparse
import sys

sys.path.insert(0, ".")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    from benchmarks import fig1_fullgrad, fig2_stochastic, fig3_grid, \
        table1_rates
    for mod in (fig1_fullgrad, fig2_stochastic, fig3_grid, table1_rates):
        print(f"== {mod.__name__}")
        for row in mod.run(quick=args.quick):
            print(row)


if __name__ == "__main__":
    main()
