"""Pixtral-12B — Pixtral-ViT frontend + Mistral-Nemo decoder backbone.
[hf:mistralai/Pixtral-12B-2409]

40L, d_model 5120, 32 heads (GQA kv=8, d_head 128), d_ff 14336,
vocab 131072.  The ViT vision encoder + projector input is a STUB per the
brief: input_specs() provides (B, n_patches, vision_dim) patch embeddings;
we own the projector and the decoder.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=131072,
    n_patches=1024,
    vision_dim=1024,
    rope_theta=1e6,
)
