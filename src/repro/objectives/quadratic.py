"""Analytic quadratic objective — exact closed forms for unit tests.

f_i(x) = ½ (x − c_i)ᵀ H_i (x − c_i);  ∇f_i(x) = H_i (x − c_i).
With identical H_i = I the AsGrad replay admits a hand-computable
trajectory, which the tests exploit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class QuadraticProblem:
    def __init__(self, centers, hessians=None):
        self.c = jnp.asarray(centers, dtype=jnp.float32)     # (n, d)
        self.n, self.d = self.c.shape
        if hessians is None:
            hessians = np.stack([np.eye(self.d)] * self.n)
        self.H = jnp.asarray(hessians, dtype=jnp.float32)    # (n, d, d)

    def local_grad(self, x, worker):
        return self.H[worker] @ (x - self.c[worker])

    def full_grad(self, x):
        return jnp.mean(jax.vmap(lambda H, c: H @ (x - c))(self.H, self.c), axis=0)

    def loss(self, x):
        r = x[None, :] - self.c
        return 0.5 * jnp.mean(jnp.einsum("nd,ndk,nk->n", r, self.H, r))

    def grad_fn(self, stochastic: bool = False):
        return lambda x, w, key: self.local_grad(x, w)

    def per_worker_grad_fn(self):
        return lambda x, w: self.local_grad(x, w)

    def minimizer(self):
        Hbar = np.mean(np.asarray(self.H), axis=0)
        rhs = np.mean(np.einsum("ndk,nk->nd", np.asarray(self.H), np.asarray(self.c)), axis=0)
        return np.linalg.solve(Hbar, rhs)
