"""Table 1: empirical schedules vs the theory quantities the proofs bound.

For each algorithm we (a) run the spec through the simulator backend
(``repro.api``), (b) measure τ_C/τ_max/τ_avg and the Defs-3/4 quantities
ν², σ²_{k,τ} on a quadratic oracle, (c) check them against the closed-form
bounds used in the special-case proofs (Props. C.1/C.2/C.4, D.1/D.3), and
(d) evaluate the Table-1 rate value at the realised constants.
"""
from __future__ import annotations

import csv
import os

import numpy as np
import jax.numpy as jnp

from repro.api import ExperimentSpec, SimulatorBackend
from repro.core.theory import ProblemConstants, RATES
from repro.core.trace import (sequence_correlation, delay_variance,
                              heterogeneity_zeta)
from repro.objectives import QuadraticProblem


def run(out: str = "experiments/figs", T: int = 96, n: int = 8, quick=False):
    os.makedirs(out, exist_ok=True)
    rng = np.random.default_rng(0)
    prob = QuadraticProblem(rng.normal(size=(n, 6)))
    zeta = heterogeneity_zeta(prob.per_worker_grad_fn(), jnp.zeros(6), n)
    c = ProblemConstants(L=1.0, F0=float(prob.loss(jnp.zeros(6))),
                         sigma2=0.0, zeta2=zeta ** 2, G=5.0)
    rows = []
    algs = ["pure", "pure_waiting", "random", "fedbuff", "shuffled",
            "minibatch", "rr"]
    if quick:
        algs = ["pure", "shuffled", "rr"]
    backend = SimulatorBackend()
    for alg in algs:
        b = 4 if alg in ("pure_waiting", "fedbuff", "minibatch") else 1
        spec = ExperimentSpec(
            scheduler=f"{alg}:b={b}" if b > 1 else alg,
            timing="poisson:slow=4",
            objective=prob, T=T, n_workers=n,
            stepsize=0.02, log_every=1, seed=0)
        res = backend.run(spec)
        s = res.schedule
        tau = max(n, 8)
        sig = sequence_correlation(s, prob.per_worker_grad_fn(),
                                   res.xs[::tau], tau)
        nu2 = delay_variance(s, prob.per_worker_grad_fn(), res.xs)
        tc, tmax = s.tau_c(), s.tau_max()
        # the generic proof bounds
        sigma_bound = tau ** 2 * zeta ** 2
        nu_bound = max(tc * tmax, 1) * zeta ** 2 * T
        rate_fn = RATES[alg]
        if alg in ("pure", "pure_waiting"):
            rate = rate_fn(c, T, tc, tmax, b=b, bounded_grad=True) \
                if alg == "pure_waiting" else rate_fn(c, T, tc, tmax,
                                                      bounded_grad=True)
        elif alg == "random":
            rate = rate_fn(c, T, tc)
        elif alg == "fedbuff":
            rate = rate_fn(c, T, tc, b=b)
        elif alg in ("shuffled", "rr"):
            rate = rate_fn(c, T, n)
        else:
            rate = rate_fn(c, T, b=b)
        rows.append({
            "alg": alg, "b": b, "tau_c": tc, "tau_max": tmax,
            "tau_avg": res.trace["tau_avg"],
            "sigma2_mean": float(np.mean(sig)),
            "sigma2_bound": sigma_bound,
            "sigma2_ok": bool(np.all(sig <= sigma_bound + 1e-6)),
            "nu2": nu2, "nu2_bound": nu_bound,
            "nu2_ok": bool(nu2 <= nu_bound + 1e-6),
            "table1_rate": rate,
        })
    with open(os.path.join(out, "table1.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=sorted({k for r in rows for k in r}))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
