from .pipeline import (DataConfig, HeterogeneousTokenPipeline, EpochShuffler,
                       zipf_pmf)

__all__ = ["DataConfig", "HeterogeneousTokenPipeline", "EpochShuffler",
           "zipf_pmf"]
