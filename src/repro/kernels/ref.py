"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def reference_attention(q, k, v, *, causal=True, window=None):
    """Naive softmax attention.  q: (B,Sq,H,D); k/v: (B,Sk,KV,D)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, D).astype(F32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k.astype(F32)) / math.sqrt(D)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(F32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def reference_async_update(params, gbuf, grads, *, lr, clip_scale, delay_scale):
    """Server update (eq. 2), fused semantics:
        p'    = p − lr·delay_scale·clip_scale·gbuf   (apply the STALE grad)
        gbuf' = grads                                (buffer the fresh grad)
    All flat f32/bf16 arrays of identical shape."""
    eff = lr * delay_scale * clip_scale
    p_new = (params.astype(F32) - eff * gbuf.astype(F32)).astype(params.dtype)
    return p_new, grads


def reference_fused_adam(p, m, v, g, *, lr, beta1, beta2, eps, bc1, bc2,
                         clip_scale=1.0, weight_decay=0.0):
    """One fused Adam step on flat arrays; moments f32."""
    g32 = clip_scale * g.astype(F32)
    m_new = beta1 * m + (1 - beta1) * g32
    v_new = beta2 * v + (1 - beta2) * g32 * g32
    step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    step = step + weight_decay * p.astype(F32)
    p_new = p - (lr * step).astype(p.dtype)
    return p_new, m_new, v_new


def reference_fused_adam_delayed(p, m, v, gbuf, g, *, lr, beta1, beta2, eps,
                                 bc1, bc2, clip_scale=1.0, weight_decay=0.0):
    """Delayed-buffer Adam: the stale gbuf drives the step, the fresh g is
    buffered.  Returns (p', m', v', gbuf')."""
    p_new, m_new, v_new = reference_fused_adam(
        p, m, v, gbuf, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
        bc1=bc1, bc2=bc2, clip_scale=clip_scale, weight_decay=weight_decay)
    return p_new, m_new, v_new, g


def reference_sgd_momentum(p, m, g, *, lr, momentum, clip_scale=1.0,
                           delay_scale=1.0):
    """Fused heavy-ball step on flat arrays; m f32.  Returns (p', m')."""
    m_new = momentum * m + clip_scale * g.astype(F32)
    p_new = (p.astype(F32) - (lr * delay_scale) * m_new).astype(p.dtype)
    return p_new, m_new


def reference_sgd_momentum_delayed(p, m, gbuf, g, *, lr, momentum,
                                   clip_scale=1.0, delay_scale=1.0):
    """Delayed-buffer heavy-ball: stale gbuf drives the step, fresh g is
    buffered.  Returns (p', m', gbuf')."""
    p_new, m_new = reference_sgd_momentum(
        p, m, gbuf, lr=lr, momentum=momentum, clip_scale=clip_scale,
        delay_scale=delay_scale)
    return p_new, m_new, g


def reference_ssd_chunk(x, dt, A, B_, C_):
    """Single-chunk SSD (sequential recurrence oracle).

    x: (c, H, P); dt: (c, H); A: (H,); B_/C_: (c, N).
    Returns (y (c,H,P), h_final (H,P,N)) with h0 = 0.
    """
    c, H, P = x.shape
    N = B_.shape[-1]
    h = jnp.zeros((H, P, N), F32)
    ys = []
    for t in range(c):
        a = jnp.exp(dt[t].astype(F32) * A.astype(F32))          # (H,)
        upd = jnp.einsum("hp,n->hpn", (x[t] * dt[t][:, None]).astype(F32),
                         B_[t].astype(F32))
        h = h * a[:, None, None] + upd
        ys.append(jnp.einsum("hpn,n->hp", h, C_[t].astype(F32)))
    return jnp.stack(ys).astype(x.dtype), h
