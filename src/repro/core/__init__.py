"""AsGrad core: the paper's algorithmic framework (Algorithm 1).

Schedule-first architecture: a discrete-event engine realises the job
ordering (i_t, π_t); an exact jittable replay executes the updates; the same
schedulers drive the distributed trainer's round masks.
"""
from .delays import TimingModel, PATTERNS, heterogeneous_speeds
from .schedulers import (
    Scheduler,
    PureAsync,
    PureAsyncWaiting,
    RandomAsync,
    RandomAsyncWaiting,
    ShuffledAsync,
    MiniBatch,
    RandomReshuffling,
    make_scheduler,
    REGISTRY,
)
from .engine import (Schedule, build_schedule, lower_rounds, round_masks,
                     round_delay_scales)
from .simulator import (replay, replay_grid, run_async_sgd,
                        delay_adaptive_stepsizes, ReplayResult)
from . import theory, trace

__all__ = [
    "TimingModel", "PATTERNS", "heterogeneous_speeds",
    "Scheduler", "PureAsync", "PureAsyncWaiting", "RandomAsync",
    "RandomAsyncWaiting", "ShuffledAsync", "MiniBatch", "RandomReshuffling",
    "make_scheduler", "REGISTRY",
    "Schedule", "build_schedule", "lower_rounds", "round_masks",
    "round_delay_scales",
    "replay", "replay_grid", "run_async_sgd", "delay_adaptive_stepsizes",
    "ReplayResult",
    "theory", "trace",
]
