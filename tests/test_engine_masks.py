"""round_masks (vectorized scatter) and round_delay_scales metadata.

``round_masks`` used to be an O(T) nested Python loop; it is now one
``np.add.at`` scatter.  The loop stays here as the oracle.
"""
import numpy as np
import pytest

from repro.core import (PATTERNS, REGISTRY, TimingModel, build_schedule,
                        heterogeneous_speeds, make_scheduler, round_masks,
                        round_delay_scales)


def _loop_round_masks(schedule, n_rounds=None):
    """The pre-vectorization implementation, kept verbatim as oracle."""
    b = schedule.wait_b
    total_rounds = schedule.T // b
    if n_rounds is None:
        n_rounds = total_rounds
    n_rounds = min(n_rounds, total_rounds)
    masks = np.zeros((n_rounds, schedule.n_workers), dtype=np.float32)
    for q in range(n_rounds):
        for t in range(q * b, (q + 1) * b):
            masks[q, schedule.workers[t]] += 1.0
    return masks


def _random_schedule(seed, n=7, T=60):
    rng = np.random.default_rng(seed)
    name = rng.choice(sorted(REGISTRY))
    pattern = rng.choice(PATTERNS)
    b = int(rng.integers(1, 4)) if name in ("pure_waiting", "fedbuff",
                                            "minibatch") else 1
    sched = make_scheduler(name, n, b=b, seed=seed)
    timing = TimingModel(heterogeneous_speeds(n, slow_factor=5.0), pattern,
                         seed=seed)
    return build_schedule(sched, timing, T)


@pytest.mark.parametrize("seed", range(8))
def test_round_masks_scatter_equals_loop(seed):
    s = _random_schedule(seed)
    np.testing.assert_array_equal(round_masks(s), _loop_round_masks(s))
    # truncated variant too (n_rounds < total and > total)
    np.testing.assert_array_equal(round_masks(s, 5), _loop_round_masks(s, 5))
    np.testing.assert_array_equal(round_masks(s, 10 ** 6),
                                  _loop_round_masks(s, 10 ** 6))


def test_round_masks_duplicate_receipts_accumulate():
    """A worker delivering k gradients in one round must get weight k (the
    scatter must ACCUMULATE duplicate (round, worker) pairs, the classic
    np.add.at-vs-fancy-indexing trap)."""
    s = _random_schedule(3, n=3, T=40)
    masks = round_masks(s)
    assert masks.sum() == masks.shape[0] * s.wait_b
    # with 3 workers and concurrency, some round repeats a worker eventually
    loop = _loop_round_masks(s)
    assert loop.max() == masks.max()


def test_round_delay_scales_bounds_and_values():
    s = _random_schedule(1)
    scales = round_delay_scales(s)
    rounds = s.T // s.wait_b
    assert scales.shape == (rounds,)
    assert scales.dtype == np.float32
    assert np.all(scales > 0) and np.all(scales <= 1.0)
    # spot-check the rule: scale_q = min(1, tau_c / (mean delay_q + 1))
    tau_c = max(s.tau_c(), 1)
    d = s.delays[: rounds * s.wait_b].reshape(rounds, s.wait_b).mean(axis=1)
    np.testing.assert_allclose(
        scales, np.minimum(1.0, tau_c / (d + 1.0)).astype(np.float32))


def test_round_delay_scales_shift_matches_applied_gradient():
    """With a delay_rounds-deep buffer, round q applies the gradient
    RECEIVED at round q − delay_rounds (buffered delay_rounds more rounds):
    the scale must follow that gradient, not round q's receipts."""
    s = _random_schedule(2)
    rounds = s.T // s.wait_b
    base = round_delay_scales(s)                      # receipt-time taus
    shifted = round_delay_scales(s, delay_rounds=1)
    assert shifted.shape == (rounds,)
    # gated first round: neutral full step
    assert shifted[0] == 1.0
    tau_c = max(s.tau_c(), 1)
    d = s.delays[: rounds * s.wait_b].reshape(rounds, s.wait_b).mean(axis=1)
    want = np.minimum(1.0, tau_c / (d[:-1] + 1.0 + 1.0)).astype(np.float32)
    np.testing.assert_allclose(shifted[1:], want)
    # and it is genuinely a shift, not a relabel of the unshifted rule
    if rounds > 2 and not np.allclose(d[:-1], d[1:]):
        assert not np.array_equal(shifted[1:], base[1:])


def test_round_delay_scales_zero_delay_is_full_step():
    """SGD-RR realises zero delays → every round runs at full γ."""
    sched = make_scheduler("rr", 6, seed=0)
    timing = TimingModel(heterogeneous_speeds(6), "fixed", seed=0)
    s = build_schedule(sched, timing, 18)
    assert np.all(round_delay_scales(s) == 1.0)
