"""Training launcher: --arch × --scheduler × mesh → trainer backend.

The production entry point, a thin CLI over ``repro.api``: flags build one
``ExperimentSpec`` + ``TrainJob`` and hand it to ``TrainerBackend``.  On
real hardware the mesh comes from ``make_production_mesh``; on this
container ``--host-mesh`` uses whatever devices exist (the reduced configs
train end-to-end on CPU).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --host-mesh --steps 20 --scheduler shuffled --pattern poisson
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-sized variant of the arch family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--scheduler", default="shuffled",
                    choices=["pure", "pure_waiting", "random", "fedbuff",
                             "shuffled"])
    ap.add_argument("--wait-b", type=int, default=1)
    ap.add_argument("--pattern", default="poisson")
    ap.add_argument("--n-groups", type=int, default=0,
                    help="worker groups (0 = data-axis size)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--delay-rounds", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--update-impl", default="reference",
                    choices=["reference", "pallas", "pallas_interpret",
                             "pallas_pooled", "pallas_pooled_interpret"],
                    help="server-update execution: the reference elementwise "
                         "path, fused per-leaf Pallas kernels ('pallas'), or "
                         "the pooled-state path ('pallas_pooled': whole "
                         "state in per-dtype pool buffers, ONE kernel per "
                         "dtype under shard_map over the data axes); "
                         "compiled impls degrade to *_interpret off-TPU "
                         "with a warning")
    ap.add_argument("--delay-adaptive", action="store_true",
                    help="per-round stepsize scale from the schedule's "
                         "delay metadata (removes the tau_max dependence)")
    ap.add_argument("--runtime", default="scan", choices=["scan", "eager"],
                    help="dispatch layer: 'scan' compiles "
                         "--rounds-per-launch rounds into ONE XLA launch "
                         "(host sync once per chunk); 'eager' launches one "
                         "round at a time (the parity oracle)")
    ap.add_argument("--rounds-per-launch", type=int, default=8,
                    help="scan runtime: rounds per XLA launch; on_step "
                         "logging and --ckpt-every barriers fire at these "
                         "chunk boundaries")
    ap.add_argument("--metrics", default="chunk",
                    choices=["chunk", "tap", "none"],
                    help="scan metric transport: 'chunk' reads curves "
                         "back at chunk boundaries (--ckpt-every barriers "
                         "work); 'tap' streams every round through a "
                         "device-side io_callback (live logging at any "
                         "--rounds-per-launch); 'none' discards metrics "
                         "on device (fastest, final state only).  On "
                         "'tap'/'none' use --snapshot-every for periodic "
                         "checkpoints — barrier-free, so the transports "
                         "keep their speed")
    ap.add_argument("--scenario", default=None,
                    help="non-stationary world spec (repro.scenarios "
                         "grammar), e.g. 'straggler:k=2,factor=8;"
                         "elastic:every=32,span=8', "
                         "'data_drift:a0=1.2,a1=2.0;sparsify:frac=0.5' or "
                         "a fault world like 'nan_grad:k=1,every=32;"
                         "worker_crash:at=64,span=16' (pair with "
                         "--guards); omit for the stationary world")
    ap.add_argument("--tau-report", action="store_true",
                    help="print the windowed tau-statistics report "
                         "(realised tau_max/tau_avg/tau_C per window vs "
                         "the core.theory Table-1 rate) after the run")
    ap.add_argument("--sync", action="store_true")
    ap.add_argument("--host-mesh", action="store_true",
                    help="use this host's devices instead of the 16x16 pod")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--auto-rules", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="barrier-free durability (scan runtime, any "
                         "--metrics): offer an async device snapshot of "
                         "the carry every N rounds (chunk-boundary "
                         "granularity — align with --rounds-per-launch), "
                         "finalised to atomic checkpoints under "
                         "<--ckpt>/round-XXXXXXXX with no mid-run host "
                         "barrier; a killed run resumes from the newest "
                         "restorable snapshot")
    ap.add_argument("--guards", action="store_true",
                    help="arm the trainer's non-finite guard rails: "
                         "rounds with non-finite loss/grads are skipped "
                         "in-mask (the apply is gated, never the scan), "
                         "offending workers' effective stepsize backs off "
                         "and recovers (repro.faults.GuardConfig defaults)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace-event JSON of the run "
                         "(launch/host_sync/tap/snapshot/compile spans) — "
                         "load it at ui.perfetto.dev or chrome://tracing")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the schema-versioned JSONL metrics log "
                         "(counters/gauges/histograms; validate with "
                         "python -m repro.obs.schema PATH)")
    ap.add_argument("--obs-summary", action="store_true",
                    help="print the observability summary table "
                         "(time-in-phase, throughput, counters) after "
                         "the run")
    ap.add_argument("--heterogeneity", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..api import ExperimentSpec, TrainJob, TrainerBackend
    from ..configs import get_arch
    from ..distributed import DEFAULT_RULES, auto_rules
    from ..models import n_params
    from .. import checkpoint
    from .mesh import make_production_mesh, make_host_mesh

    mesh = make_host_mesh() if args.host_mesh else \
        make_production_mesh(multi_pod=args.multi_pod)
    job = TrainJob(
        arch=args.arch, reduced=args.reduced,
        remat="none" if args.reduced else None,
        global_batch=args.global_batch, seq_len=args.seq_len,
        heterogeneity=args.heterogeneity,
        delay_rounds=0 if args.sync else args.delay_rounds,
        microbatches=args.microbatches,
        update_impl=args.update_impl,
        guards=args.guards)
    cfg = job.make_arch()
    rules = auto_rules(cfg, mesh.shape.get("model", 1)) if args.auto_rules \
        else DEFAULT_RULES

    scheduler = args.scheduler if args.wait_b == 1 \
        else f"{args.scheduler}:b={args.wait_b}"
    stepsize = f"delay_adaptive:{args.lr}" if args.delay_adaptive else args.lr
    spec = ExperimentSpec(
        scheduler=scheduler, timing=f"{args.pattern}:slow=6",
        objective=job, T=args.steps, n_workers=args.n_groups or None,
        stepsize=stepsize, seed=args.seed, runtime=args.runtime,
        rounds_per_launch=args.rounds_per_launch, metrics=args.metrics,
        scenario=args.scenario)

    print(f"arch={cfg.name} params={n_params(cfg)/1e6:.1f}M "
          f"mesh={dict(mesh.shape)} groups={args.n_groups or 'auto'} "
          f"scheduler={args.scheduler} b={args.wait_b} "
          f"delay={0 if args.sync else args.delay_rounds} "
          f"update_impl={args.update_impl} runtime={args.runtime}"
          + (f" K={args.rounds_per_launch} metrics={args.metrics}"
             if args.runtime == "scan" else "")
          + (f" scenario={args.scenario!r}" if args.scenario else ""))

    if (args.runtime == "scan" and args.ckpt and args.ckpt_every
            and args.ckpt_every % args.rounds_per_launch):
        print(f"warning: --ckpt-every={args.ckpt_every} is not a multiple "
              f"of --rounds-per-launch={args.rounds_per_launch}; scan "
              f"checkpoints hold the END-of-chunk state, so off-boundary "
              f"saves are mislabelled — align the two for exact resume")
    if (args.runtime == "scan" and args.metrics != "chunk"
            and args.ckpt and args.ckpt_every):
        print(f"warning: --metrics={args.metrics} never materialises "
              f"mid-run state on host, so --ckpt-every barriers cannot "
              f"fire; use --snapshot-every for barrier-free periodic "
              f"checkpoints on this transport")

    snapshot = None
    if args.snapshot_every:
        if args.runtime != "scan":
            ap.error("--snapshot-every is a scan-runtime knob")
        if not args.ckpt:
            ap.error("--snapshot-every needs --ckpt (snapshot directory)")
        snapshot = checkpoint.AsyncSnapshotter(
            args.ckpt, args.snapshot_every, meta={"arch": cfg.name})

    def on_step(i, state, m):
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={m['loss']:.4f} "
                  f"|g|={m['grad_norm']:.3f} "
                  f"part={m['participation']:.2f}", flush=True)
        # the tap transport streams values only (state is None there)
        if state is not None and args.ckpt and args.ckpt_every \
                and (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt, state, step=i + 1,
                            meta={"arch": cfg.name})

    recorder = None
    if args.trace_out or args.metrics_out or args.obs_summary:
        from ..obs import Recorder
        recorder = Recorder()

    # only the scan runtime honours --metrics; eager keeps its per-round
    # callbacks (the executor rejects on_step solely for scan + "none")
    strip_on_step = args.metrics == "none" and args.runtime == "scan"
    backend = TrainerBackend(
        mesh=mesh, rules=rules,
        on_step=None if strip_on_step else on_step,
        snapshot=snapshot, recorder=recorder)
    res = backend.run(spec)
    final = "n/a" if res.losses is None else f"{res.losses[-1]:.4f}"
    tripped = res.extra.get("tripped_round")
    print(f"done in {res.seconds:.1f}s  final loss={final}  "
          f"tau_max={res.trace['tau_max']}  "
          f"launches={res.extra['launches']} "
          f"host_syncs={res.extra['host_syncs']} "
          f"tap_events={res.extra['tap_events']}"
          + (f" snapshots={res.extra['snapshots']}"
             if args.snapshot_every else "")
          + (f"  BREAKER TRIPPED at round {tripped}"
             if tripped is not None else ""))
    if recorder is not None:
        if args.trace_out:
            print("chrome trace:", recorder.export_chrome(args.trace_out))
        if args.metrics_out:
            print("metrics log:", recorder.export_metrics(args.metrics_out))
        if args.obs_summary:
            from ..obs import render_summary
            print(render_summary(res.extra["obs"], trace=res.trace))
    if args.tau_report:
        from ..scenarios import render_report, tau_report
        print(render_report(tau_report(
            res.schedule, args.scheduler,
            concurrency=spec.make_scheduler(
                res.extra["n_groups"]).concurrency(),
            scenario_spec=args.scenario or "")))
    if args.ckpt:
        checkpoint.save(args.ckpt, res.x, step=args.steps,
                        meta={"arch": cfg.name})
        print("final checkpoint:", args.ckpt)


if __name__ == "__main__":
    main()
