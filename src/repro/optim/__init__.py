from .optimizers import (
    adam_init, adam_update, sgd_update, global_norm, clip_by_global_norm,
    clip_scale_by_global_norm, clip_scale_from_norm, OptConfig,
    make_optimizer, make_delayed_apply,
    reference_delayed_apply, fused_delayed_apply, fused_adam_update,
    fused_sgd_update, resolve_update_impl, UPDATE_IMPLS,
)
from .pool import (
    LeafSlot, PoolLayout, build_layout, init_pools, pool_tree, unpool_tree,
    pool_zeros, pooled_global_norm, pooled_update, pooled_delayed_apply,
)

__all__ = ["adam_init", "adam_update", "sgd_update", "global_norm",
           "clip_by_global_norm", "clip_scale_by_global_norm",
           "clip_scale_from_norm", "OptConfig",
           "make_optimizer", "make_delayed_apply", "reference_delayed_apply",
           "fused_delayed_apply", "fused_adam_update", "fused_sgd_update",
           "resolve_update_impl", "UPDATE_IMPLS",
           "LeafSlot", "PoolLayout", "build_layout", "init_pools", "pool_tree",
           "unpool_tree", "pool_zeros", "pooled_global_norm",
           "pooled_update", "pooled_delayed_apply"]
