"""The :class:`Recorder` handle threaded through the runtime.

One ``Recorder`` per run is what the instrumented components accept
(``PlanExecutor``, ``SlotServer``, ``AsyncSnapshotter``, the backends,
``launch/train``): it owns a :class:`~repro.obs.tracer.Tracer`,
delegates the span/instant/metric primitives to it, and adds the
end-of-run :meth:`summary` dict that rides ``RunResult.extra["obs"]``
through serialization (plain scalars only — it must survive
``RunResult.to_json`` round-trips).

Every instrumented call site guards with ``if recorder is not None`` —
an un-observed run pays literally zero (no null-object dispatch on the
tap hot path).
"""
from __future__ import annotations

from .schema import METRICS_SCHEMA_VERSION
from .tracer import Tracer


class Recorder:
    """Per-run observability handle: a Tracer plus summary assembly."""

    def __init__(self, tracer: Tracer = None):
        self.tracer = tracer if tracer is not None else Tracer()

    # -------------------------------------------------- tracer delegation
    def span(self, name, lane="main", **args):
        return self.tracer.span(name, lane, **args)

    def span_at(self, name, lane, start_ns, end_ns, **args):
        self.tracer.span_at(name, lane, start_ns, end_ns, **args)

    def instant(self, name, lane="main", **args):
        self.tracer.instant(name, lane, **args)

    def count(self, name, inc=1):
        self.tracer.count(name, inc)

    def gauge(self, name, value, lane="main"):
        self.tracer.gauge(name, value, lane)

    def hist(self, name, value):
        self.tracer.hist(name, value)

    def now_ns(self):
        return self.tracer.now_ns()

    def export_chrome(self, path: str) -> str:
        return self.tracer.export_chrome(path)

    def export_metrics(self, path: str) -> str:
        return self.tracer.export_metrics(path)

    # ----------------------------------------------------------- summary
    def summary(self, **extra) -> dict:
        """The machine-readable run summary (``RunResult.extra["obs"]``).

        ``phases`` is the span time-in-phase table, ``counters`` the
        final cumulative counts, ``hists`` the histogram summaries —
        everything :func:`repro.obs.render_summary` needs to print the
        human table, and the measurement substrate the ROADMAP's
        self-tuning item consumes.  ``extra`` keys (e.g. ``rounds``,
        ``tau_max``) merge in at the top level.
        """
        out = {
            "schema_version": METRICS_SCHEMA_VERSION,
            "wall_s": round(self.tracer.wall_s, 6),
            "phases": self.tracer.phase_table(),
            "counters": self.tracer.counters(),
            "hists": self.tracer.hist_summaries(),
        }
        out.update(extra)
        return out
