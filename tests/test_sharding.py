"""Sharding rules: pure PartitionSpec logic (no multi-device needed)."""
import numpy as np
import jax
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import (
    Rules, DEFAULT_RULES, logical_pspec, zero_pspec, tree_pspecs,
    bytes_per_device,
)
from repro.models import param_specs, cache_specs, batch_specs
from repro.configs import get_arch


class FakeMesh:
    """Duck-typed mesh: only .shape / .axis_names are consulted by the rules."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


M1 = FakeMesh({"data": 16, "model": 16})
M2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_heads_get_model_axis():
    # qwen3: 32 heads over model=16 → heads sharded
    p = logical_pspec(("embed", "heads", "head"), (4096, 32, 128), M1)
    assert p == P(None, "model", None)


def test_kv_fallback_to_embed():
    # grok wk: kv=8 does not divide 16 → embed picks up the model axis
    p = logical_pspec(("embed", "kv_heads", "head"), (6144, 8, 128), M1)
    assert p == P("model", None, None)


def test_experts_fallback_to_ff():
    # grok experts: E=8 fails, per-expert ff 32768 divides → ff sharded
    p = logical_pspec(("layers", "experts", "embed", "ff"),
                      (64, 8, 6144, 32768), M1)
    assert p == P(None, None, None, "model")
    # deepseek: E=64 divides → expert-parallel
    p = logical_pspec(("layers", "experts", "embed", "ff"),
                      (28, 64, 2048, 1408), M1)
    assert p == P(None, "model", None, None)


def test_batch_over_pod_and_data():
    p = logical_pspec(("batch", "seq"), (256, 4096), M2)
    assert p == P(("pod", "data"), None)
    # batch=1 (long_500k) → replicated
    p = logical_pspec(("batch", "seq"), (1, 524288), M2)
    assert p == P(None, None)
    # batch=32 on 2×16 pods divides → both axes
    p = logical_pspec(("batch", "seq"), (32, 32768), M2)
    assert p == P(("pod", "data"), None)


def test_kv_cache_ctx_sharding_when_kv_heads_fail():
    # grok decode cache: kv=8 fails → ctx dim takes the model axis
    p = logical_pspec(("layers", "batch", "ctx", "kv_heads", "head"),
                      (64, 128, 32768, 8, 128), M1)
    assert p == P(None, "data", "model", None, None)


def test_zero_shards_opt_state_over_data():
    """FSDP shards a *tensor* dim (embed), never the layers dim — a
    layers-sharded stack would force whole-stack all-gathers (see Rules)."""
    axes = ("layers", "experts", "embed", "ff")
    shape = (64, 8, 6144, 32768)
    base = logical_pspec(axes, shape, M1)
    z = zero_pspec(axes, shape, M1, base)
    assert z == P(None, None, "data", "model")


def test_zero_noop_when_data_axis_taken():
    axes = ("batch", "embed")
    shape = (256, 512)
    base = logical_pspec(axes, shape, M1)
    z = zero_pspec(axes, shape, M1, base)
    assert z == base


@pytest.mark.parametrize("name", ["grok-1-314b", "qwen3-8b", "mamba2-370m",
                                  "zamba2-7b", "seamless-m4t-large-v2"])
def test_param_tree_pspecs_cover_all_leaves(name):
    cfg = get_arch(name)
    specs = param_specs(cfg)
    ps = tree_pspecs(specs, M1)
    leaves = jax.tree_util.tree_leaves(ps, is_leaf=lambda x: isinstance(x, P))
    assert leaves and all(isinstance(l, P) for l in leaves)


def test_bytes_per_device_fits_v5e_train():
    """Analytic memory: grok-1 train state (bf16 params + f32 m,v ZeRO over
    data) must land under the 16 GB/chip HBM of v5e on the 16×16 mesh."""
    from repro.distributed import AsyncTrainer, AsyncConfig
    cfg = get_arch("grok-1-314b")
    tr = AsyncTrainer.__new__(AsyncTrainer)   # only need state_specs
    tr.cfg = cfg
    tr.async_cfg = AsyncConfig(delay_rounds=1)
    specs = tr.state_specs()
    total = (bytes_per_device(specs["params"], M1, zero=True)
             + bytes_per_device(specs["opt"]["m"], M1, zero=True)
             + bytes_per_device(specs["opt"]["v"], M1, zero=True)
             + bytes_per_device(specs["gbuf"], M1, zero=True))
    assert total < 16e9, f"{total/1e9:.1f} GB/chip"


def test_custom_rules_change_assignment():
    rules = Rules(model_priority=("ff", "heads"))
    p = logical_pspec(("embed", "heads", "head"), (4096, 32, 128), M1, rules)
    assert p == P(None, "model", None)
    p2 = logical_pspec(("embed", "ff"), (4096, 12288), M1, rules)
    assert p2 == P(None, "model")


# (The hypothesis property test lives in ``test_sharding_property.py`` so
# this module collects without the optional dependency.)
