"""Retrace sentinel: watch cached jits for steady-state recompilation.

The repo's compiled drivers (``PlanExecutor``, ``SlotServer``) live and
die by ONE rule: the jitted programs are cached on the instance and must
never re-trace once warm — a silent retrace turns a 5.6× dispatch win
into a recompile-per-run regression (found twice already: the fresh-
closure tiler in PR 5, the fresh ``jax.jit`` per ``Server.generate`` in
PR 7).  :class:`CompileWatch` generalises the ``SlotServer.compile_counts``
gate those PRs hand-rolled:

* :meth:`wrap` wraps any cached jit; after each call the traced-signature
  count (``fn._cache_size()``) is compared to the last seen value and
  every growth is recorded as a ``compile`` trace instant (plus a
  ``compiles`` counter) on the attached recorder — compile events land in
  the trace next to the launch that triggered them.
* :meth:`counts` is the machine-readable registry snapshot (the old
  ``compile_counts()`` shape).
* :meth:`mark_steady` / :meth:`check_steady` assert the zero-steady-state-
  retrace contract: snapshot the counts once warm, then any later growth
  raises :class:`RetraceError` naming the offending program.

The per-call overhead is one ``_cache_size()`` read (a host-side dict
``len``) at boundaries that already dispatch an XLA program — nothing on
the device path.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional


class RetraceError(RuntimeError):
    """A watched jit re-traced after :meth:`CompileWatch.mark_steady`."""


def _cache_size(fn) -> int:
    sizer = getattr(fn, "_cache_size", None)
    return int(sizer()) if sizer is not None else -1


class CompileWatch:
    """Registry of cached jits + their traced-signature counts."""

    def __init__(self, recorder=None, lane: str = "compile"):
        self.recorder = recorder
        self.lane = lane
        self._fns: dict = {}       # name -> the underlying jitted fn
        self._seen: dict = {}      # name -> last observed signature count
        self._steady: Optional[dict] = None

    def register(self, name: str, fn) -> None:
        """Track ``fn`` without wrapping (counts/steady checks only)."""
        self._fns[name] = fn
        self._seen.setdefault(name, _cache_size(fn))

    def wrap(self, name: str, fn) -> Callable:
        """Track ``fn`` AND return a call-through wrapper that records a
        ``compile`` instant whenever a call grew the traced-signature
        count (i.e. this call paid a trace+compile)."""
        self.register(name, fn)

        @functools.wraps(fn)
        def wrapped(*args, **kw):
            out = fn(*args, **kw)
            self._note(name)
            return out

        wrapped.__wrapped_jit__ = fn
        return wrapped

    def _note(self, name: str) -> None:
        now = _cache_size(self._fns[name])
        last = self._seen.get(name, 0)
        if now > last:
            self._seen[name] = now
            rec = self.recorder
            if rec is not None:
                rec.instant("compile", lane=self.lane, fn=name,
                            signatures=now)
                rec.count("compiles", now - max(last, 0))

    def observe(self) -> dict:
        """Re-read every registered fn (for jits called outside their
        wrappers) and record instants for any growth; returns counts."""
        for name in self._fns:
            self._note(name)
        return self.counts()

    def counts(self) -> dict:
        """``{name: traced-signature count}`` for every registered jit."""
        return {name: _cache_size(fn) for name, fn in self._fns.items()}

    # ------------------------------------------------------- steady contract
    def mark_steady(self) -> dict:
        """Snapshot the current counts as the allowed steady state (call
        once the driver is warm — after the first full run, which may
        legitimately trace e.g. a ragged-tail chunk length)."""
        self._steady = self.counts()
        return dict(self._steady)

    def check_steady(self) -> None:
        """Raise :class:`RetraceError` if any watched jit traced a new
        signature since :meth:`mark_steady`."""
        if self._steady is None:
            raise RetraceError(
                "check_steady() before mark_steady(): nothing to compare "
                "against")
        grown = {name: (self._steady.get(name, 0), now)
                 for name, now in self.counts().items()
                 if now > self._steady.get(name, 0)}
        if grown:
            detail = ", ".join(f"{n}: {a} -> {b}"
                               for n, (a, b) in sorted(grown.items()))
            raise RetraceError(
                f"steady-state retrace detected ({detail}) — a cached "
                "program specialised on something that varies per call")
