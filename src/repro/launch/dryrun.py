import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/init: 512 placeholder host devices let
#   jax.make_mesh build the production meshes on this CPU-only container.

DOC = """Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

For each combination this builds the real train/prefill/serve step with the
production shardings, lowers it with ShapeDtypeStruct stand-ins (no
allocation), compiles it, and records:

* ``memory_analysis``  — per-device argument/temp/output bytes (proves fit),
* ``cost_analysis``    — XLA's module-level flops/bytes (loop bodies counted
  once; kept for reference),
* ``hlo_cost``         — our while-aware dot-flops / HBM-traffic /
  collective-bytes model (see hlo_cost.py) — feeds §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--out experiments/dryrun]
"""
__doc__ = DOC

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_arch
from ..configs.base import ArchConfig, InputShape
from ..distributed import (AsyncTrainer, AsyncConfig, Rules, DEFAULT_RULES,
                           tree_shardings)
from ..models import model as M
from ..models.specs import abstract_tree
from ..optim import OptConfig
from . import hlo_cost
from .mesh import make_production_mesh, mesh_devices

LONG_WINDOW = 8192   # SWA engaged for full-attention archs on long_500k


def arch_for_shape(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """long_500k requires sub-quadratic attention: SSM/hybrid run natively;
    every other family gets the sliding-window variant (DESIGN.md §5)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return cfg.with_(sliding_window=LONG_WINDOW)
    return cfg


def _with_sharding(tree_specs, mesh, rules, zero=False):
    ab = abstract_tree(tree_specs)
    sh = tree_shardings(tree_specs, mesh, rules, zero=zero)
    return jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s), ab, sh)


def input_specs(cfg: ArchConfig, shape: InputShape, mesh, rules=DEFAULT_RULES):
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, zero
    allocation) for every model input of this (arch, shape)."""
    if shape.kind == "train":
        tr = AsyncTrainer(cfg, mesh, opt=OptConfig(),
                          async_cfg=AsyncConfig(delay_rounds=1), rules=rules)
        state = _with_sharding(tr.state_specs(), mesh, rules)
        # params/gbuf/opt get their exact shardings from the trainer
        sh = tr.state_shardings()
        state = jax.tree_util.tree_map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            abstract_tree(tr.state_specs()), sh)
        batch = _with_sharding(M.batch_specs(cfg, shape.global_batch, shape.seq_len),
                               mesh, rules)
        mask = jax.ShapeDtypeStruct((tr.n_groups,), jnp.float32,
                                    sharding=NamedSharding(mesh, P()))
        return {"state": state, "batch": batch, "mask": mask}
    # params 2D-sharded (model x data) for serving too: 314B bf16 does not
    # fit HBM tensor-parallel-only; XLA all-gathers per layer (costed in hlo)
    params = _with_sharding(M.param_specs(cfg), mesh, rules, zero=True)
    if shape.kind == "prefill":
        batch = _with_sharding(M.batch_specs(cfg, shape.global_batch, shape.seq_len),
                               mesh, rules)
        return {"params": params, "batch": batch}
    # decode
    cache = _with_sharding(M.cache_specs(cfg, shape.global_batch, shape.seq_len),
                           mesh, rules)
    tok_spec = (P(tuple(a for a in rules.data_axes if a in mesh.axis_names))
                if shape.global_batch % max(
                    1, int(np.prod([mesh.shape[a] for a in rules.data_axes
                                    if a in mesh.axis_names]))) == 0
                and shape.global_batch > 1 else P(None))
    tokens = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32,
                                  sharding=NamedSharding(mesh, tok_spec))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return {"params": params, "cache": cache, "tokens": tokens, "pos": pos}


def build_step(cfg: ArchConfig, shape: InputShape, mesh, rules=DEFAULT_RULES,
               microbatches: int = 1):
    """→ (jitted fn, kwargs of ShapeDtypeStructs)."""
    from ..distributed.sharding import sharded_trace

    specs = input_specs(cfg, shape, mesh, rules)
    if shape.kind == "train":
        tr = AsyncTrainer(cfg, mesh, opt=OptConfig(),
                          async_cfg=AsyncConfig(delay_rounds=1,
                                                microbatches=microbatches),
                          rules=rules)
        state_sh = tr.state_shardings()
        fn = jax.jit(tr.train_step_fn(), donate_argnums=(0,),
                     out_shardings=(state_sh, None))
        return fn, (specs["state"], specs["batch"], specs["mask"])
    if shape.kind == "prefill":
        def pre(params, batch):
            return M.prefill(cfg, params, batch, ctx_len=shape.seq_len)
        return jax.jit(sharded_trace(pre, mesh, rules)), \
            (specs["params"], specs["batch"])

    def serve(params, cache, tokens, pos):
        return M.decode_step(cfg, params, cache, tokens, pos, shape.seq_len)
    cache_sh = tree_shardings(M.cache_specs(cfg, shape.global_batch,
                                            shape.seq_len), mesh, rules)
    return jax.jit(sharded_trace(serve, mesh, rules), donate_argnums=(1,),
                   out_shardings=(None, cache_sh)), \
        (specs["params"], specs["cache"], specs["tokens"], specs["pos"])


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            rules: Rules = DEFAULT_RULES, verbose: bool = True,
            microbatches: int = 1, auto: bool = False) -> dict:
    shape = SHAPES[shape_name]
    cfg = arch_for_shape(get_arch(arch), shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    if auto:
        from ..distributed.sharding import auto_rules
        rules = auto_rules(cfg, mesh.shape["model"])
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh_devices(mesh),
        "family": cfg.family, "kind": shape.kind,
        "sliding_window": cfg.sliding_window,
        "ok": False,
    }
    try:
        t0 = time.time()
        fn, args = build_step(cfg, shape, mesh, rules,
                              microbatches=microbatches)
        lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(ma.argument_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  + ma.output_size_in_bytes
                                  - ma.alias_size_in_bytes),
        }
        # analytic per-device state bytes from the Spec tree (exact; the
        # CPU backend's temp numbers include f32 upcasts of bf16 dot
        # operands that a TPU would not materialise)
        from ..distributed.sharding import bytes_per_device
        if shape.kind == "train":
            tr = AsyncTrainer(cfg, mesh, opt=OptConfig(),
                              async_cfg=AsyncConfig(delay_rounds=1), rules=rules)
            sp = tr.state_specs()
            rec["analytic_state_bytes"] = (
                bytes_per_device(sp["params"], mesh, rules, zero=True)
                + bytes_per_device(sp["opt"]["m"], mesh, rules, zero=True)
                + bytes_per_device(sp["opt"]["v"], mesh, rules, zero=True)
                + bytes_per_device(sp["gbuf"], mesh, rules, zero=True))
        else:
            rec["analytic_state_bytes"] = bytes_per_device(
                M.param_specs(cfg), mesh, rules, zero=True)
            if shape.kind == "decode":
                rec["analytic_state_bytes"] += bytes_per_device(
                    M.cache_specs(cfg, shape.global_batch, shape.seq_len),
                    mesh, rules)
        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {k: float(ca[k]) for k in ("flops", "bytes accessed")
                                if k in ca}
        t2 = time.time()
        rec["hlo_cost"] = hlo_cost.analyze(compiled.as_text()).as_dict()
        rec["analyze_s"] = round(time.time() - t2, 2)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — a dry-run failure IS the signal
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if verbose:
        status = "OK " if rec["ok"] else "FAIL"
        extra = ""
        if rec["ok"]:
            gb = rec["memory"]["peak_bytes_est"] / 1e9
            extra = (f"mem={gb:.2f}GB/dev flops={rec['hlo_cost']['dot_flops']:.3g} "
                     f"coll={rec['hlo_cost']['collective_bytes']:.3g}B "
                     f"lower={rec['lower_s']}s compile={rec['compile_s']}s")
        else:
            extra = rec["error"][:160]
        print(f"[{status}] {arch:24s} {shape_name:12s} {rec['mesh']:8s} {extra}",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) on both meshes")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--auto-rules", action="store_true",
                    help="per-arch optimized sharding rules (beyond-paper)")
    ap.add_argument("--suffix", default="")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))
    n_ok = 0
    for a, s, mp in combos:
        rec = run_one(a, s, multi_pod=mp, auto=args.auto_rules)
        n_ok += rec["ok"]
        tag = f"{a}_{s}_{'mp' if mp else 'sp'}{args.suffix}.json"
        with open(os.path.join(args.out, tag), "w") as f:
            json.dump(rec, f, indent=1)
    print(f"\n{n_ok}/{len(combos)} combinations lowered + compiled OK")
    if n_ok < len(combos):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
