"""grok-1 (314B) — 8-expert top-2 MoE.  [hf:xai-org/grok-1]

64L, d_model 6144, 48 heads (GQA kv=8, d_head 128), expert d_ff 32768,
vocab 131072.  All layers are MoE (no shared experts), per the release.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=32768,
    vocab=131072,
    n_experts=8,
    n_shared_experts=0,
    top_k=2,
    moe_d_ff=32768,
    rope_theta=1e4,
)
