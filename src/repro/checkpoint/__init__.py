from .checkpointer import save, restore, load_meta

__all__ = ["save", "restore", "load_meta"]
