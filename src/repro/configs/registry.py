"""Architecture registry: --arch <id> resolution."""
from .base import ArchConfig, InputShape, SHAPES, smoke_shape
from .grok_1_314b import CONFIG as grok_1_314b
from .deepseek_moe_16b import CONFIG as deepseek_moe_16b
from .minitron_8b import CONFIG as minitron_8b
from .qwen2_0_5b import CONFIG as qwen2_0_5b
from .stablelm_1_6b import CONFIG as stablelm_1_6b
from .zamba2_7b import CONFIG as zamba2_7b
from .mamba2_370m import CONFIG as mamba2_370m
from .seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2
from .pixtral_12b import CONFIG as pixtral_12b
from .qwen3_8b import CONFIG as qwen3_8b

ARCHS = {
    c.name: c
    for c in (
        grok_1_314b,
        deepseek_moe_16b,
        minitron_8b,
        qwen2_0_5b,
        stablelm_1_6b,
        zamba2_7b,
        mamba2_370m,
        seamless_m4t_large_v2,
        pixtral_12b,
        qwen3_8b,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]
