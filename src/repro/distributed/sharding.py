"""Logical-axis sharding rules with divisibility fallback.

Every tensor in the system (params, optimizer state, activations, caches,
batches) carries logical axis names (see ``models.specs.Spec``).  Rules map
logical names to mesh axes; a candidate that does not divide the dimension
is skipped rather than erroring (e.g. grok-1's 8 KV heads on a 16-way model
axis fall through to the next candidate).  At most one tensor dim gets each
mesh axis; priority order decides who wins — and is itself a perf lever
(§Perf iterates on it).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    """model_priority: logical names that want the tensor-parallel axis, in
    decreasing priority.  batch_names: names sharded over the data axes."""

    model_priority: tuple = (
        "experts", "heads", "kv_heads", "ctx", "d_inner", "ssm_heads",
        "ff", "vocab", "embed",
    )
    batch_names: tuple = ("batch", "capacity")
    data_axes: tuple = ("pod", "data")      # outer-to-inner data parallelism
    model_axis: str = "model"
    # ZeRO/FSDP: additionally shard params + optimizer state over the data
    # axes on the first divisible *tensor* dim that is still replicated.
    # Deliberately NOT the "layers" dim: slicing a layers-sharded stack at a
    # dynamic index makes GSPMD hoist a whole-stack all-gather out of the
    # scan (f32-converted on top, on backends that upcast bf16 dots) —
    # sharding a tensor dim instead yields small per-layer gathers inside
    # the loop, which is the standard 2D FSDP×TP schedule.
    zero_names: tuple = ("embed", "ff", "heads", "kv_heads", "d_inner",
                         "vocab", "experts", "ssm_heads", "ctx")


DEFAULT_RULES = Rules()

# ---------------------------------------------------------------------------
# activation sharding constraints (scan carries lose their sharding without
# explicit with_sharding_constraint — 40 GB of replicated logits otherwise)
# ---------------------------------------------------------------------------
import contextvars

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_activation_sharding", default=None)


class activation_sharding:
    """Context manager enabling with_sharding_constraint inside model code.

    Model code calls :func:`shard_activation` with logical axes; outside this
    context (plain CPU tests) it is a no-op.
    """

    def __init__(self, mesh, rules=None):
        self.mesh = mesh
        self.rules = rules or DEFAULT_RULES

    def __enter__(self):
        self._tok = _ACT_CTX.set((self.mesh, self.rules))
        return self

    def __exit__(self, *exc):
        _ACT_CTX.reset(self._tok)
        return False


def shard_activation(x, axes):
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_pspec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


SEQ_PARALLEL_RULES = Rules(
    model_priority=DEFAULT_RULES.model_priority + ("seq",))


def auto_rules(cfg, model_axis_size: int = 16) -> Rules:
    """Beyond-paper optimisation (§Perf): pick the sharding rules per arch.

    Architectures whose attention heads cannot shard across the model axis
    (qwen2's 14 heads, seamless' 16 MHA heads at kv=16, ...) replicate their
    attention compute model_axis-fold under the default rules; sequence
    parallelism removes that (measured 13× compute / 12.9× HBM on
    qwen2-0.5b × prefill_32k).  For archs with shardable heads (grok,
    qwen3, ...) seq-parallel k/v gathers cost more than the all-reduces they
    replace (measured +23% collectives on grok-1), so they keep the default.
    """
    heads_ok = cfg.n_heads and cfg.n_heads % model_axis_size == 0
    ssm_ok = cfg.ssm_state and cfg.ssm_heads % model_axis_size == 0
    if heads_ok or (cfg.family == "ssm" and ssm_ok):
        return DEFAULT_RULES
    return SEQ_PARALLEL_RULES


def data_shard_count() -> int:
    """Number of data-parallel shards in the active activation context
    (1 outside any context) — used by group-local MoE dispatch."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return 1
    mesh, rules = ctx
    n = 1
    for a in rules.data_axes:
        if a in mesh.axis_names:
            n *= _mesh_size(mesh, a)
    return n


def sharded_trace(fn, mesh, rules=None):
    """Wrap a step function so activation constraints apply while tracing."""
    def wrapped(*a, **k):
        with activation_sharding(mesh, rules):
            return fn(*a, **k)
    return wrapped


def _mesh_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def logical_pspec(axes, shape, mesh: Mesh, rules: Rules = DEFAULT_RULES) -> P:
    """Build a PartitionSpec for one tensor from its logical axes."""
    if axes is None:
        return P()
    assignment: list = [None] * len(axes)
    used: set = set()

    # 1) batch dims over the data axes (pod × data if both divide); each
    #    mesh axis is consumed at most once even if several dims are
    #    batch-named
    for i, ax in enumerate(axes):
        if ax in rules.batch_names:
            present = [a for a in rules.data_axes
                       if a in mesh.axis_names and a not in used]
            if not present:
                continue
            prod = math.prod(_mesh_size(mesh, a) for a in present)
            if shape[i] % prod == 0:
                assignment[i] = tuple(present) if len(present) > 1 else present[0]
                used.update(present)
            else:
                for a in reversed(present):       # try inner axis alone
                    if shape[i] % _mesh_size(mesh, a) == 0:
                        assignment[i] = a
                        used.add(a)
                        break

    # 2) one dim gets the model axis, by priority, if divisible
    msz = _mesh_size(mesh, rules.model_axis)
    if rules.model_axis in mesh.axis_names and msz > 1:
        for name in rules.model_priority:
            if rules.model_axis in used:
                break
            for i, ax in enumerate(axes):
                if ax == name and assignment[i] is None and shape[i] % msz == 0 \
                        and shape[i] >= msz:
                    assignment[i] = rules.model_axis
                    used.add(rules.model_axis)
                    break
    return P(*assignment)


def zero_pspec(axes, shape, mesh: Mesh, base: P,
               rules: Rules = DEFAULT_RULES) -> P:
    """Optimizer-state sharding: param spec + data-axis sharding on the first
    still-replicated dim named in ``zero_names`` (ZeRO-1 style)."""
    present = [a for a in rules.data_axes if a in mesh.axis_names]
    if not present:
        return base
    spec = list(base) + [None] * (len(shape) - len(base))
    used = {a for s in spec if s is not None
            for a in (s if isinstance(s, tuple) else (s,))}
    free = [a for a in present if a not in used]
    if not free:
        return base
    prod = math.prod(_mesh_size(mesh, a) for a in free)
    for name in rules.zero_names:
        for i, ax in enumerate(axes or ()):
            if ax == name and spec[i] is None and shape[i] % prod == 0 \
                    and shape[i] >= prod:
                spec[i] = tuple(free) if len(free) > 1 else free[0]
                return P(*spec)
    return base


def pool_axes(mesh: Mesh, rules: Rules = DEFAULT_RULES) -> tuple:
    """The mesh data axes a pooled state buffer shards over (the ZeRO
    domain), in rules order."""
    return tuple(a for a in rules.data_axes if a in mesh.axis_names)


def pool_shard_count(mesh: Mesh, rules: Rules = DEFAULT_RULES) -> int:
    """Row count of the pooled ``(n_shards, cols)`` buffers: one row per
    ZeRO shard (1 on data-parallel-free meshes)."""
    return int(np.prod([mesh.shape[a] for a in pool_axes(mesh, rules)],
                       dtype=int)) or 1


def pooled_pspec(mesh: Mesh, rules: Rules = DEFAULT_RULES) -> P:
    """PartitionSpec of a pooled ``(n_shards, cols)`` state buffer: rows
    over the data axes (each device owns its ZeRO shard of EVERY leaf),
    columns unsharded.  Replicated over the model axis — pooling trades the
    per-leaf 2D model×data sharding for O(n_dtypes) kernel launches; see
    the README for when to pick which."""
    axes = pool_axes(mesh, rules)
    if not axes:
        return P(None, None)
    return P(axes if len(axes) > 1 else axes[0], None)


def tree_pspecs(spec_tree, mesh: Mesh, rules: Rules = DEFAULT_RULES,
                zero: bool = False):
    """Map a Spec tree → PartitionSpec tree."""
    from ..models.specs import Spec

    def one(s: Spec):
        base = logical_pspec(s.axes, s.shape, mesh, rules)
        if zero:
            base = zero_pspec(s.axes, s.shape, mesh, base, rules)
        return base

    return jax.tree_util.tree_map(one, spec_tree,
                                  is_leaf=lambda x: isinstance(x, Spec))


def tree_shardings(spec_tree, mesh: Mesh, rules: Rules = DEFAULT_RULES,
                   zero: bool = False):
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p),
        tree_pspecs(spec_tree, mesh, rules, zero),
        is_leaf=lambda x: isinstance(x, P),
    )


def bytes_per_device(spec_tree, mesh: Mesh, rules: Rules = DEFAULT_RULES,
                     zero: bool = False) -> int:
    """Analytic per-device bytes of a Spec tree under the rules (used by the
    dry-run report alongside XLA's memory_analysis)."""
    from ..models.specs import Spec
    import jax.numpy as jnp

    total = 0
    for s in jax.tree_util.tree_leaves(spec_tree,
                                       is_leaf=lambda x: isinstance(x, Spec)):
        p = logical_pspec(s.axes, s.shape, mesh, rules)
        if zero:
            p = zero_pspec(s.axes, s.shape, mesh, p, rules)
        shards = 1
        for e in p:
            for a in (e if isinstance(e, tuple) else (e,)) if e else ():
                shards *= _mesh_size(mesh, a)
        total += int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize // shards
    return total
