"""Launch path: HLO cost model unit tests + a real dry-run in a subprocess
(the 512-device XLA flag must be set before jax init, hence the subprocess)."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.hlo_cost import analyze, parse_module, _split_instr

HLO = """\
HloModule test

%region_body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot), replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%region_cond (q: (s32[], f32[8,16])) -> pred[] {
  %q = (s32[], f32[8,16]) parameter(0)
  %j = s32[] get-tuple-element(%q), index=0
  %lim = s32[] constant(5)
  ROOT %lt = pred[] compare(%j, %lim), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,16]) tuple(%zero, %a)
  %wh = (s32[], f32[8,16]) while(%tup), condition=%region_cond, body=%region_body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_split_instr_handles_tuples_and_comments():
    got = _split_instr("  %wh.1 = (s32[], /*index=1*/f32[2,3]{1,0}) "
                       "while(%tup), condition=%c, body=%b")
    assert got is not None
    name, ty, opcode, operands, attrs = got
    assert name == "wh.1" and opcode == "while"
    assert "condition=%c" in attrs and "body=%b" in attrs
    got2 = _split_instr("  %ar = f32[4]{0} all-reduce(%x), "
                        "replica_groups=[4,2]<=[2,4]T(1,0), to_apply=%add")
    assert got2[2] == "all-reduce"
    assert "to_apply=%add" in got2[4]


def test_analyze_counts_loop_trips():
    r = analyze(HLO)
    assert r.n_while == 1 and r.unknown_trip_loops == 0
    # dot: 2*8*16*16 = 4096 flops × 5 trips
    assert r.dot_flops == 5 * 2 * 8 * 16 * 16
    # all-reduce operand: 8*16*4 bytes × 5 trips
    assert r.collective_bytes == 5 * 8 * 16 * 4
    assert r.collective_breakdown["all-reduce"] == r.collective_bytes


def test_parse_module_symbol_table():
    comps, entry, symbols = parse_module(HLO)
    assert entry == "main"
    assert "region_body" in comps and "region_cond" in comps
    assert symbols["dot"].startswith("f32[8,16]")


@pytest.mark.slow
def test_dryrun_subprocess_end_to_end(tmp_path):
    """Lower+compile one real (arch × shape × production-mesh) combo."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-0.5b",
         "--shape", "decode_32k", "--out", out],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec = json.load(open(os.path.join(out, "qwen2-0.5b_decode_32k_sp.json")))
    assert rec["ok"]
    assert rec["n_devices"] == 256
    assert rec["hlo_cost"]["dot_flops"] > 0
    assert rec["memory"]["peak_bytes_est"] < 16e9


def test_roofline_analysis_on_existing_records():
    """If the sweep artifacts exist, every single-pod record must be ok and
    produce finite roofline terms."""
    d = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                     "experiments", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("no dry-run artifacts yet")
    from repro.launch.roofline import load_table
    rows = load_table(d, "sp")
    assert rows
    for r in rows:
        assert "error" not in r, r
        assert r["compute_s"] > 0 and r["memory_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
