"""Zero-dependency tracing + metrics core.

One :class:`Tracer` per run collects three event families, all host-side
and all timestamped with ``time.perf_counter_ns()`` at EXISTING host
boundaries (chunk edges, io_callback sinks, admission sweeps) — tracing
never introduces a device sync:

* **spans** — named intervals (``launch``, ``host_sync``, ``admit``,
  ``snapshot_finalise``, ...) grouped into *lanes* (one Perfetto track
  per lane: executor / tap / snapshot / server / faults / per-slot).
* **instants** — point events (``tap_round``, ``guard_skip``, ``evict``,
  ``compile``, ``breaker_trip``).
* **metrics** — cumulative counters (``launches``, ``tap_events``),
  timestamped gauges (``occupancy``, ``gscale``) and histograms
  (``ttft_steps``, ``chunk_seconds``) that export to a JSONL log with a
  versioned schema (:mod:`repro.obs.schema`).

Exports:

* :meth:`Tracer.chrome_trace` / :meth:`Tracer.export_chrome` — Chrome
  trace-event JSON (the ``{"traceEvents": [...]}`` envelope), loadable
  in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.
* :meth:`Tracer.metrics_lines` / :meth:`Tracer.export_metrics` — the
  JSONL metrics log (one schema-versioned JSON object per line).

Thread safety: the executor's tap sink and the slot server's token tap
fire from io_callback threads while the driver thread records launch
spans, so every mutation takes ``self._lock`` — the critical section is
one list append, which is what keeps the hot-path overhead inside the
documented ≤5% tap-transport budget (``benchmarks/perf_obs.py``).
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager


def _json_safe(v):
    """Span/instant args must survive json.dumps: numpy scalars and other
    exotica degrade to float/repr instead of blowing up the export."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return repr(v)


class Tracer:
    """Collects spans / instants / metrics; exports Chrome trace + JSONL."""

    def __init__(self):
        self._t0 = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._spans = []        # (name, lane, start_ns, dur_ns, args|None)
        self._instants = []     # (name, lane, ts_ns, args|None)
        self._counters = {}     # name -> cumulative value
        self._gauges = []       # (ts_ns, lane, name, value)
        self._hists = {}        # name -> [values]
        self._lanes = {}        # lane name -> tid (stable, first-seen order)

    # ------------------------------------------------------------------ time
    def now_ns(self) -> int:
        """Monotonic nanoseconds since this tracer was created (the trace
        clock origin); pair with :meth:`span_at` for lifetimes that start
        and end at different host boundaries."""
        return time.perf_counter_ns() - self._t0

    @property
    def wall_s(self) -> float:
        return self.now_ns() / 1e9

    def _tid(self, lane: str) -> int:
        tid = self._lanes.get(lane)
        if tid is None:
            tid = self._lanes[lane] = len(self._lanes)
        return tid

    # ----------------------------------------------------------------- spans
    @contextmanager
    def span(self, name: str, lane: str = "main", **args):
        """Record the enclosed block as a complete ('X') trace event."""
        start = time.perf_counter_ns()
        try:
            yield
        finally:
            end = time.perf_counter_ns()
            with self._lock:
                self._tid(lane)
                self._spans.append(
                    (name, lane, start - self._t0, end - start,
                     args or None))

    def span_at(self, name: str, lane: str, start_ns: int, end_ns: int,
                **args) -> None:
        """Record a span whose endpoints were captured earlier with
        :meth:`now_ns` (e.g. a request's admit→completion lifetime)."""
        with self._lock:
            self._tid(lane)
            self._spans.append(
                (name, lane, int(start_ns), int(end_ns - start_ns),
                 args or None))

    def instant(self, name: str, lane: str = "main", **args) -> None:
        # the tap hot path: one of these per round — inline the clock
        # read and lane registration instead of delegating
        ts = time.perf_counter_ns() - self._t0
        with self._lock:
            if lane not in self._lanes:
                self._lanes[lane] = len(self._lanes)
            self._instants.append((name, lane, ts, args or None))

    # --------------------------------------------------------------- metrics
    def count(self, name: str, inc: int = 1) -> None:
        """Bump a cumulative counter (exported once, as its final value)."""
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + inc

    def gauge(self, name: str, value: float, lane: str = "main") -> None:
        """Record a timestamped point sample (also a Chrome 'C' event, so
        Perfetto draws the time series)."""
        ts = self.now_ns()
        with self._lock:
            self._tid(lane)
            self._gauges.append((ts, lane, name, float(value)))

    def hist(self, name: str, value: float) -> None:
        """Accumulate one histogram sample (exported as a summary line)."""
        with self._lock:
            self._hists.setdefault(name, []).append(float(value))

    # -------------------------------------------------------------- snapshots
    def counters(self) -> dict:
        with self._lock:
            return dict(self._counters)

    def phase_table(self) -> dict:
        """Aggregate spans by name: where the host-visible wall time went.

        ``{name: {"count": n, "total_s": s, "mean_ms": m}}`` — the
        time-in-phase breakdown :func:`repro.obs.render_summary` renders.
        Lanes run concurrently (a request span overlaps the launch spans
        that decode it), so totals are per-phase occupancy, not a
        partition of wall time.
        """
        with self._lock:
            spans = list(self._spans)
        out = {}
        for name, _lane, _start, dur, _args in spans:
            e = out.setdefault(name, {"count": 0, "total_s": 0.0})
            e["count"] += 1
            e["total_s"] += dur / 1e9
        for e in out.values():
            e["total_s"] = round(e["total_s"], 6)
            e["mean_ms"] = round(e["total_s"] * 1e3 / e["count"], 4)
        return out

    def hist_summaries(self) -> dict:
        with self._lock:
            hists = {k: list(v) for k, v in self._hists.items()}
        out = {}
        for name, vals in hists.items():
            vs = sorted(vals)
            n = len(vs)
            out[name] = {
                "count": n,
                "min": vs[0], "max": vs[-1],
                "mean": round(sum(vs) / n, 6),
                "p50": vs[n // 2],
                "p95": vs[min(n - 1, int(0.95 * n))],
            }
        return out

    # ------------------------------------------------------- chrome export
    def chrome_trace(self) -> dict:
        """The Chrome trace-event envelope (JSON-ready dict).

        Spans are 'X' (complete) events, instants 'i' (thread-scoped),
        gauges 'C' (counter) events; lanes become named threads of one
        ``repro`` process via 'M' metadata events.  Timestamps are
        microseconds on the tracer's monotonic clock.
        """
        with self._lock:
            spans = list(self._spans)
            instants = list(self._instants)
            gauges = list(self._gauges)
            lanes = dict(self._lanes)
        ev = [{"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
               "args": {"name": "repro"}}]
        for lane, tid in lanes.items():
            ev.append({"ph": "M", "name": "thread_name", "pid": 0,
                       "tid": tid, "args": {"name": lane}})
        for name, lane, start, dur, args in spans:
            e = {"ph": "X", "name": name, "cat": lane, "pid": 0,
                 "tid": lanes.get(lane, 0), "ts": start / 1e3,
                 "dur": max(dur, 0) / 1e3}
            if args:
                e["args"] = {k: _json_safe(v) for k, v in args.items()}
            ev.append(e)
        for name, lane, ts, args in instants:
            e = {"ph": "i", "name": name, "cat": lane, "pid": 0,
                 "tid": lanes.get(lane, 0), "ts": ts / 1e3, "s": "t"}
            if args:
                e["args"] = {k: _json_safe(v) for k, v in args.items()}
            ev.append(e)
        for ts, lane, name, value in gauges:
            ev.append({"ph": "C", "name": name, "cat": lane, "pid": 0,
                       "tid": lanes.get(lane, 0), "ts": ts / 1e3,
                       "args": {name: value}})
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path

    # -------------------------------------------------------- metrics export
    def metrics_lines(self) -> list:
        """The JSONL metrics log as a list of dicts (see
        :mod:`repro.obs.schema` for the per-line contract): one header,
        the chronological gauge samples, then final counter values and
        histogram summaries."""
        from .schema import METRICS_SCHEMA_VERSION as V

        lines = [{"v": V, "kind": "header", "source": "repro.obs",
                  "wall_s": round(self.wall_s, 6),
                  "created_unix": time.time()}]
        with self._lock:
            gauges = list(self._gauges)
            counters = dict(self._counters)
        for ts, lane, name, value in gauges:
            lines.append({"v": V, "kind": "gauge", "t_us": ts / 1e3,
                          "lane": lane, "name": name, "value": value})
        for name, value in sorted(counters.items()):
            lines.append({"v": V, "kind": "counter", "name": name,
                          "value": value})
        for name, summ in sorted(self.hist_summaries().items()):
            lines.append({"v": V, "kind": "hist", "name": name, **summ})
        return lines

    def export_metrics(self, path: str) -> str:
        with open(path, "w") as f:
            for line in self.metrics_lines():
                f.write(json.dumps(line) + "\n")
        return path
