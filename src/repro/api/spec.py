"""Declarative experiment specs: one frozen dataclass per AsGrad run.

Compact spec strings keep configs one-line:

* scheduler — ``"name[:k=v,...]"`` over :data:`repro.core.REGISTRY`, e.g.
  ``"pure"``, ``"fedbuff:b=4"``, ``"shuffled:reshuffle=0"``.
* timing — ``"pattern[:k=v,...]"`` over :data:`repro.core.PATTERNS`, e.g.
  ``"poisson:slow=8"`` (workers linearly spread over [1, slow] compute time).
* stepsize — a float (constant γ), a sequence (grid-searched γ), a
  :class:`StepsizePolicy`, or a string ``"constant:0.01"`` /
  ``"grid:0.005,0.002"`` / ``"delay_adaptive:0.05"``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import numpy as np

from ..core import (TimingModel, build_schedule, heterogeneous_speeds,
                    make_scheduler)
from ..core.engine import Schedule
from ..core.schedulers import REGISTRY


def _parse_kv(text: str) -> dict:
    """``"b=4,reshuffle=0"`` → ``{"b": 4, "reshuffle": 0}`` (numbers coerced)."""
    out: dict[str, Any] = {}
    if not text:
        return out
    for item in text.split(","):
        if "=" not in item:
            raise ValueError(f"malformed spec option {item!r} (want key=value)")
        k, v = item.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def parse_compact(spec: str) -> tuple[str, dict]:
    """``"name:k=v,k=v"`` → ``(name, kwargs)``."""
    name, _, rest = spec.partition(":")
    return name, _parse_kv(rest)


@dataclasses.dataclass(frozen=True)
class StepsizePolicy:
    """How the server stepsize γ is chosen.

    * ``constant`` — one replay at ``gammas[0]``.
    * ``grid`` — all of ``gammas`` replayed against one shared schedule (a
      single batched scan on the simulator backend); the paper's selection
      protocol (best tail grad-norm with small fluctuations) picks a winner.
    * ``delay_adaptive`` — γ_t = γ·min(1, τ_C/(τ_t+1)), the [Koloskova et
      al. 22]-style stepsize that removes the τ_max dependence (Table 1
      note b).
    """

    kind: str = "constant"          # constant | grid | delay_adaptive
    gammas: tuple = (0.01,)

    KINDS = ("constant", "grid", "delay_adaptive")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown stepsize kind {self.kind!r}")
        object.__setattr__(self, "gammas",
                           tuple(float(g) for g in self.gammas))
        if not self.gammas:
            raise ValueError("stepsize policy needs at least one gamma")

    @property
    def gamma(self) -> float:
        return self.gammas[0]

    @classmethod
    def coerce(cls, value) -> "StepsizePolicy":
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            kind, _, rest = value.partition(":")
            gammas = tuple(float(g) for g in rest.split(",") if g)
            return cls(kind, gammas or (0.01,))
        if isinstance(value, (int, float)):
            return cls("constant", (float(value),))
        if isinstance(value, (tuple, list, np.ndarray)):
            return cls("grid", tuple(float(g) for g in value))
        raise TypeError(f"cannot coerce {value!r} to a StepsizePolicy")


def constant(gamma: float) -> StepsizePolicy:
    return StepsizePolicy("constant", (gamma,))


def grid(*gammas: float) -> StepsizePolicy:
    return StepsizePolicy("grid", tuple(gammas))


def delay_adaptive(gamma: float) -> StepsizePolicy:
    return StepsizePolicy("delay_adaptive", (gamma,))


@dataclasses.dataclass(frozen=True)
class TrainJob:
    """Objective for the trainer backend: arch + data for ``AsyncTrainer``.

    ``ExperimentSpec.T`` counts server *rounds* here (one aggregated model
    update per round); the schedule realises ``T·wait_b`` gradient receipts.
    """

    arch: str = "qwen2-0.5b"
    reduced: bool = True
    remat: Optional[str] = "none"
    arch_overrides: tuple = ()          # ((field, value), ...)
    global_batch: int = 8
    seq_len: int = 64
    heterogeneity: float = 1.0
    delay_rounds: int = 1               # 0 = synchronous baseline
    microbatches: int = 1
    opt: str = "adam"
    clip_norm: Optional[float] = 1.0
    #: how the server update executes: "reference" (tree of elementwise
    #: jnp ops) | "pallas" (fused per-leaf TPU kernels) |
    #: "pallas_pooled" (whole state flattened into per-dtype pool buffers,
    #: ONE kernel per dtype under shard_map — see repro.optim.pool) |
    #: the "*_interpret" twins (same kernels, Pallas interpreter; compiled
    #: impls degrade to these off-TPU with a one-time warning)
    update_impl: str = "reference"
    #: guard rails (:class:`repro.faults.GuardConfig` defaults): per-round
    #: non-finite detection skips the apply in-mask, a per-worker health
    #: channel backs off repeat offenders' effective γ and recovers it on
    #: clean rounds — the runtime survives injected ``fault:`` channels
    guards: bool = False

    def make_arch(self):
        from ..configs import get_arch
        cfg = get_arch(self.arch)
        if self.reduced:
            cfg = cfg.reduced()
        if self.remat is not None:
            cfg = cfg.with_(remat=self.remat)
        if self.arch_overrides:
            cfg = cfg.with_(**dict(self.arch_overrides))
        return cfg


@dataclasses.dataclass(frozen=True)
class ServeJob:
    """Objective for the serve backend: batched greedy/temperature decoding.

    ``ExperimentSpec.T`` counts decode steps (per-request token budget).

    Two serving modes share this job:

    * lock-step (default, ``n_slots=None``) — a fixed batch decodes in
      unison through :class:`repro.distributed.Server`; scheduler/timing
      fields are unused.
    * continuous batching (``n_slots`` set) — ``n_requests`` requests flow
      through ``n_slots`` persistent decode lanes
      (:class:`repro.distributed.SlotServer`); ``admission`` picks which
      queued request fills a freed slot (scheduler-registry compact spec,
      e.g. ``"pure"`` / ``"fedbuff:b=2"``) and ``arrival`` draws
      inter-arrival gaps from the timing registry
      (``"pattern[:gap=G]"``, e.g. ``"poisson:gap=4"``; ``None`` = all
      requests queued at step 0).
    """

    arch: str = "qwen2-0.5b"
    reduced: bool = True
    batch: int = 4
    prompt_len: int = 12
    temperature: float = 0.0
    arch_overrides: tuple = ()          # ((field, value), ...)
    n_slots: Optional[int] = None       # set → continuous-batching lane
    n_requests: Optional[int] = None    # default: batch
    admission: str = "pure"             # scheduler-registry compact spec
    arrival: Optional[str] = None       # timing-registry "pattern[:gap=G]"
    steps_per_launch: int = 8           # decode steps per chunk launch
    #: queue-wait budget in decode steps (slot lane only): a request still
    #: queued past it is timed out at the admission sweep, never admitted,
    #: and surfaced in the result's timeout map / τ-report
    deadline: Optional[int] = None
    #: retry budget (slot lane only): total admission attempts per request
    #: (1 = detect-and-discard); > 1 re-queues evicted/timed-out requests
    #: with exponential backoff ``retry_backoff · 2^(failures−1)`` steps
    max_retries: int = 1
    retry_backoff: int = 4              # backoff base, in decode steps
    #: bounded admission queue (slot lane only): eligible waiters beyond
    #: the cap are shed under ``shed_policy``
    queue_cap: Optional[int] = None
    shed_policy: str = "reject-new"     # "reject-new" | "drop-oldest"
    #: graceful drain (slot lane only): stop admitting at this decode
    #: step, finish in-flight lanes, cancel (account) the rest
    drain_after: Optional[int] = None

    def __post_init__(self):
        if self.n_slots is not None and self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.steps_per_launch < 1:
            raise ValueError("steps_per_launch must be >= 1")
        for knob, val in (("deadline", self.deadline),
                          ("queue_cap", self.queue_cap),
                          ("drain_after", self.drain_after)):
            if val is not None and self.n_slots is None:
                raise ValueError(
                    f"{knob} is a slot-lane knob; set n_slots as well")
        if self.deadline is not None and self.deadline < 0:
            raise ValueError("deadline must be >= 0")
        if self.drain_after is not None and self.drain_after < 0:
            raise ValueError("drain_after must be >= 0")
        if self.max_retries < 1:
            raise ValueError("max_retries must be >= 1 (1 = no retry)")
        if self.max_retries > 1 and self.n_slots is None:
            raise ValueError(
                "max_retries is a slot-lane knob; set n_slots as well")
        if self.retry_backoff < 0:
            raise ValueError("retry_backoff must be >= 0")
        # constructing the policies validates queue_cap/shed_policy too
        from ..distributed.slot_serve import OverloadPolicy
        if self.queue_cap is not None:
            OverloadPolicy(self.queue_cap, self.shed_policy)
        elif self.shed_policy not in ("reject-new", "drop-oldest"):
            raise ValueError(
                f"unknown shed policy {self.shed_policy!r}")
        from ..distributed.admission import parse_admission
        parse_admission(self.admission)     # fail fast on grammar errors
        if self.arrival:
            from ..distributed.admission import draw_arrivals
            draw_arrivals(1, self.arrival)

    def make_arch(self):
        from ..configs import get_arch
        cfg = get_arch(self.arch)
        if self.reduced:
            cfg = cfg.reduced().with_(remat="none")
        if self.arch_overrides:
            cfg = cfg.with_(**dict(self.arch_overrides))
        return cfg


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One AsGrad experiment, declaratively.

    Field → paper notation (Algorithm 1):

    * ``scheduler`` — the job-assignment policy producing the ordering
      (i_t, π_t); ``wait_b`` variants update once per b received gradients.
    * ``timing`` — worker compute-time distribution; with the scheduler it
      fully determines the realised delays τ_t = t − π_t.
    * ``T`` — horizon: gradient receipts on the simulator backend, server
      rounds on the trainer backend, decode steps on the serve backend.
    * ``stepsize`` — the server stepsize γ (policy, see
      :class:`StepsizePolicy`); waiting variants apply γ/b per gradient.
    * ``objective`` — the functions f_i: a problem object exposing
      ``grad_fn``/``full_grad`` (simulator), a :class:`TrainJob` (trainer),
      or a :class:`ServeJob` (serve).
    * ``runtime`` — how the trainer backend dispatches rounds:
      ``"scan"`` (compiled whole-run executor, ``rounds_per_launch``
      rounds per XLA launch — the default) or ``"eager"`` (one launch +
      one host sync per round; the parity oracle).  ``None`` defers to the
      backend's own default; simulator/serve backends ignore both fields,
      so one spec object still describes any tier.
    * ``metrics`` — how scan-runtime metrics reach the host: ``"chunk"``
      (read back at chunk boundaries — the default; ``on_step`` sees the
      end-of-chunk state, checkpoint barriers work), ``"tap"`` (streamed
      per round through a device-side io_callback — live logging at any
      ``rounds_per_launch``, but ``on_step`` receives ``state=None``) or
      ``"none"`` (discarded on device — fastest, no curves).  ``None``
      defers to the backend default; ignored by the eager runtime and the
      other tiers.
    * ``scenario`` — optional non-stationary world spec
      (:mod:`repro.scenarios` grammar, e.g.
      ``"straggler:k=2,factor=8;elastic:every=32"``): the scheduler and
      timing model are wrapped in the scenario's transforms before the
      schedule is realised.  Schedule-level transforms (drift, straggler,
      elastic) affect every backend that realises a schedule; the data
      (``data_drift``) and update (``sparsify``) channels lower into the
      trainer backend's ``RunPlan`` only.  ``None`` (the default) takes
      the plain stationary path; ``""`` is the identity scenario
      (wrapped path, bit-identical schedule — the parity gate).
    """

    RUNTIMES = (None, "scan", "eager")
    METRIC_MODES = (None, "chunk", "tap", "none")

    scheduler: str = "pure"
    timing: str = "fixed:slow=5"
    objective: Any = None
    T: int = 1000
    n_workers: Optional[int] = None     # default: objective.n
    stepsize: Any = 0.01                # coerced to StepsizePolicy
    stochastic: bool = False
    clip: Optional[float] = None
    log_every: int = 100
    speeds: Optional[tuple] = None      # explicit per-worker speeds override
    seed: int = 0
    runtime: Optional[str] = None       # None → backend default ("scan")
    rounds_per_launch: int = 8          # scan runtime: K rounds per launch
    metrics: Optional[str] = None       # None → backend default ("chunk")
    scenario: Optional[str] = None      # None → stationary world

    def __post_init__(self):
        object.__setattr__(self, "stepsize",
                           StepsizePolicy.coerce(self.stepsize))
        if self.runtime not in self.RUNTIMES:
            raise ValueError(
                f"unknown runtime {self.runtime!r}; want one of "
                f"{[r for r in self.RUNTIMES if r]} (or None)")
        if self.metrics not in self.METRIC_MODES:
            raise ValueError(
                f"unknown metrics mode {self.metrics!r}; want one of "
                f"{[m for m in self.METRIC_MODES if m]} (or None)")
        if self.rounds_per_launch < 1:
            raise ValueError("rounds_per_launch must be >= 1")
        if self.speeds is not None:
            object.__setattr__(self, "speeds",
                               tuple(float(s) for s in self.speeds))
        name, _ = parse_compact(self.scheduler)
        if name not in REGISTRY:
            raise ValueError(
                f"unknown scheduler {name!r}; want one of {sorted(REGISTRY)}")
        if self.scenario is not None:
            from ..scenarios import parse_scenario
            parse_scenario(self.scenario)   # fail fast on grammar errors

    # ---- resolved pieces ---------------------------------------------------
    @property
    def n(self) -> int:
        if self.n_workers is not None:
            return int(self.n_workers)
        n = getattr(self.objective, "n", None)
        if n is None:
            raise ValueError(
                "n_workers not set and objective does not define .n")
        return int(n)

    def make_scheduler(self, n: Optional[int] = None):
        name, kw = parse_compact(self.scheduler)
        b = int(kw.pop("b", 1))
        return make_scheduler(name, n or self.n, b=b, seed=self.seed, **kw)

    def make_timing(self, n: Optional[int] = None) -> TimingModel:
        pattern, kw = parse_compact(self.timing)
        n = n or self.n
        slow = float(kw.pop("slow", 5.0))
        base = float(kw.pop("base", 1.0))
        if kw:
            raise ValueError(f"unknown timing options {sorted(kw)}")
        if self.speeds is not None:    # explicit profile overrides slow/base
            if len(self.speeds) != n:
                raise ValueError("speeds length must equal n_workers")
            speeds = np.asarray(self.speeds)
        else:
            speeds = heterogeneous_speeds(n, slow_factor=slow, base=base)
        return TimingModel(speeds, pattern, seed=self.seed)

    def make_scenario(self):
        """The parsed :class:`repro.scenarios.Scenario` (empty when the
        spec has none — the identity scenario)."""
        from ..scenarios import parse_scenario
        return parse_scenario(self.scenario or "")

    def build_world(self, T: Optional[int] = None,
                    n: Optional[int] = None):
        """Realise the (possibly non-stationary) world for this spec:
        the scenario-wrapped schedule plus the per-round channels
        (availability / zipf trajectory / grad density) the trainer
        backend folds into the :class:`repro.runtime.RunPlan`.  With no
        scenario this is the identity wrap — same schedule bit-for-bit as
        :meth:`build_schedule`."""
        from ..scenarios import realise_world
        sched = self.make_scheduler(n)
        return realise_world(self.make_scenario(), sched,
                             self.make_timing(n), T or self.T,
                             seed=self.seed)

    def build_schedule(self, T: Optional[int] = None,
                       n: Optional[int] = None) -> Schedule:
        """Realise the ordering (i_t, π_t) for this spec (through the
        scenario wrap when one is set)."""
        if self.scenario is not None:
            return self.build_world(T, n).schedule
        sched = self.make_scheduler(n)
        return build_schedule(sched, self.make_timing(n), T or self.T)
