"""Deterministic fault transforms — injectable failure as a scenario.

Faults are scheduled world transforms, exactly like stragglers or
elastic membership (Maranjyan's optimal-scheduling line, arXiv:2601.02523,
treats worker failure as a first-class scheduled event): each one
precomputes its whole trajectory from the realisation seed in
``prepare``, lowers into ``RunPlan`` channels, and therefore replays
bit-for-bit under scan ≡ eager.

Channels:

* ``fault_gain`` — a (rounds, n) multiplicative gain on each worker's
  received contribution: the participation-weighted mean gain scales the
  round's post-normalisation loss and gradients (scaling example weights
  alone would cancel in the CE's weight normalisation).  ``1.0`` is
  neutral; :class:`CorruptReceipt` plants a huge finite gain (an
  inflated, garbage receipt — spikes the loss/norm, exercising clipping,
  the spike check and the breaker); :class:`NanGrad` plants ``NaN``
  (poisons the loss/gradients of every round that worker participates
  in — exercises the non-finite skip guard).  Gains of non-participating
  workers are ignored (the gate forces them to 1 before the mean).
* ``availability`` — :class:`WorkerCrash` reuses the elastic membership
  channel for a one-off scheduled crash window (vs. elastic's recurring
  dropout/rejoin), optionally permanent.
* ``preempt_rounds`` — :class:`HostPreempt` is host-level metadata, not
  a device channel: the rounds at which the *driver process* should be
  killed.  Tests and the crash-resume gate read it to schedule SIGKILL;
  the compiled program never sees it.

Serving-lane faults run on the DECODE-STEP clock instead of the round
clock (``prepare`` receives ``n = n_requests`` and ``rounds = horizon``
in decode steps):

* ``serve_poisons`` — :class:`SlotPoison` names (rid, decode-step) cells
  whose logits the slot server forces to NaN before its finite check:
  the lane quarantines exactly there, deterministically, driving the
  retry/re-admission path end-to-end.
* ``serve_preempt_steps`` — :class:`ServePreempt` is the serve driver's
  ``host_preempt``: decode-step boundaries where the driver dies.  The
  in-process harness raises ``ServePreempted`` there (after forcing a
  snapshot offer); the SIGKILL gate kills a real subprocess.

:func:`realise_serve_faults` lowers any scenario spec string to a
:class:`ServeFaults` bundle (non-serve transforms contribute nothing),
which ``SlotServer.serve(faults=...)`` consumes.

Grammar (same ``name:k=v,...`` spec strings as every other transform)::

    nan_grad:k=1,every=16,span=1
    corrupt_receipt:k=1,scale=1e4,every=16,span=1
    worker_crash:k=1,at=16,span=16,permanent=1
    host_preempt:at=32
    slot_poison:rid=1,step=4,every=0
    serve_preempt:at=16,every=0

Importing this module registers the names into
``repro.scenarios.TRANSFORMS`` (``repro.scenarios`` imports it, so any
path that can parse a spec string already knows them).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..scenarios.transforms import TRANSFORMS, WorldTransform, _windows


class NanGrad(WorldTransform):
    """Poisoned receipts: every ``every`` rounds, ``k`` workers (chosen
    per window from the realisation RNG) return non-finite gradients for
    ``span`` rounds.  Without guards the first hit permanently NaNs the
    params; with guards those rounds are skipped and health backs off."""

    name = "nan_grad"

    def __init__(self, k: int = 1, every: int = 16, span: int = 1):
        if k < 1 or every < 1 or span < 1:
            raise ValueError("nan_grad k/every/span must be >= 1")
        self.k = int(k)
        self.every = int(every)
        self.span = int(span)

    def prepare(self, n, rounds, rng):
        gain = np.ones((max(rounds, 1), n), dtype=np.float32)
        k = min(self.k, n)
        for lo, hi in _windows(max(rounds, 1), self.every, self.span):
            hit = rng.choice(n, size=k, replace=False)
            gain[lo:hi, hit] = np.nan
        self._gain = gain

    def fault_gain(self):
        return self._gain


class CorruptReceipt(WorldTransform):
    """Garbage-but-finite receipts: flagged (round, worker) cells scale
    that worker's loss contribution by ``scale`` — an inflated gradient
    that stays finite, so it passes the non-finite guard but spikes the
    loss/norm (exercising clipping, the spike check, and the breaker)."""

    name = "corrupt_receipt"

    def __init__(self, k: int = 1, scale: float = 1e4, every: int = 16,
                 span: int = 1):
        if k < 1 or every < 1 or span < 1:
            raise ValueError("corrupt_receipt k/every/span must be >= 1")
        if not np.isfinite(scale) or scale <= 0 or scale == 1.0:
            raise ValueError(
                f"corrupt_receipt scale must be finite, positive and != 1 "
                f"(got {scale}); use nan_grad for non-finite faults")
        self.k = int(k)
        self.scale = float(scale)
        self.every = int(every)
        self.span = int(span)

    def prepare(self, n, rounds, rng):
        gain = np.ones((max(rounds, 1), n), dtype=np.float32)
        k = min(self.k, n)
        for lo, hi in _windows(max(rounds, 1), self.every, self.span):
            hit = rng.choice(n, size=k, replace=False)
            gain[lo:hi, hit] = self.scale
        self._gain = gain

    def fault_gain(self):
        return self._gain


class WorkerCrash(WorldTransform):
    """One-off scheduled crash: ``k`` workers (chosen from the
    realisation RNG) go down at round ``at`` for ``span`` rounds — or for
    the rest of the run with ``permanent=1`` — via the same availability
    channel elastic membership uses (scheduler remap + hard mask drop).
    Never takes down the whole pool."""

    name = "worker_crash"

    def __init__(self, k: int = 1, at: int = 16, span: int = 16,
                 permanent: int = 0):
        if k < 1 or at < 1 or span < 1:
            raise ValueError("worker_crash k/at/span must be >= 1 "
                             "(round 0 stays clean)")
        self.k = int(k)
        self.at = int(at)
        self.span = int(span)
        self.permanent = bool(permanent)

    def prepare(self, n, rounds, rng):
        avail = np.ones((max(rounds, 1), n), dtype=np.float32)
        k = min(self.k, max(n - 1, 1))      # never crash the whole pool
        down = rng.choice(n, size=k, replace=False)
        lo = self.at
        hi = avail.shape[0] if self.permanent else min(self.at + self.span,
                                                       avail.shape[0])
        if lo < avail.shape[0]:
            avail[lo:hi, down] = 0.0
        self._avail = avail

    def availability(self):
        return self._avail


class HostPreempt(WorldTransform):
    """Scheduled preemption of the DRIVER process at round ``at`` (and
    every ``every`` rounds after, when ``every > 0``).  Pure host-level
    metadata surfaced as ``ScenarioWorld.preempt_rounds`` — harnesses use
    it to SIGKILL the process mid-run and then exercise snapshot resume;
    the device program is unaffected."""

    name = "host_preempt"

    def __init__(self, at: int = 32, every: int = 0):
        if at < 1:
            raise ValueError(f"host_preempt at must be >= 1 (got {at})")
        if every < 0:
            raise ValueError(f"host_preempt every must be >= 0 (got {every})")
        self.at = int(at)
        self.every = int(every)

    def prepare(self, n, rounds, rng):
        rounds = max(rounds, 1)
        pts = [self.at]
        if self.every > 0:
            nxt = self.at + self.every
            while nxt < rounds:
                pts.append(nxt)
                nxt += self.every
        self._rounds = np.asarray([p for p in pts if p < rounds],
                                  dtype=np.int64)

    def preempt_rounds(self):
        return self._rounds


class SlotPoison(WorldTransform):
    """Deterministic serve-lane poisoning: request ``rid``'s decode
    logits go NaN at decode step ``step`` (and every ``every`` steps
    after, when ``every > 0``) — IF the request occupies a slot then.
    The device quarantines the lane in-mask; with retries enabled the
    host re-admits with backoff, so this transform is the unit driver of
    the whole recovery path.  A request poisoned at ``every=1`` fails on
    every attempt — the retry-exhaustion worst case."""

    name = "slot_poison"

    def __init__(self, rid: int = 0, step: int = 1, every: int = 0):
        if rid < 0:
            raise ValueError(f"slot_poison rid must be >= 0 (got {rid})")
        if step < 0:
            raise ValueError(f"slot_poison step must be >= 0 (got {step})")
        if every < 0:
            raise ValueError(f"slot_poison every must be >= 0 (got {every})")
        self.rid = int(rid)
        self.step = int(step)
        self.every = int(every)

    def prepare(self, n, rounds, rng):
        horizon = max(rounds, self.step + 1)
        rid = min(self.rid, max(n - 1, 0))    # clamp to the request set
        steps = ([self.step] if self.every == 0
                 else list(range(self.step, horizon, self.every)))
        self._cells = np.array([(rid, s) for s in steps], dtype=np.int64)

    def serve_poisons(self):
        return self._cells


class ServePreempt(WorldTransform):
    """Scheduled preemption of the SERVE driver at decode-step boundary
    ``at`` (and every ``every`` steps after, when ``every > 0``) — the
    decode-clock sibling of :class:`HostPreempt`.  Pure host metadata:
    the slot server force-offers a snapshot and raises
    ``ServePreempted`` at the first chunk boundary past each point;
    harnesses catch it and resume from the snapshot directory."""

    name = "serve_preempt"

    def __init__(self, at: int = 8, every: int = 0):
        if at < 1:
            raise ValueError(f"serve_preempt at must be >= 1 (got {at})")
        if every < 0:
            raise ValueError(
                f"serve_preempt every must be >= 0 (got {every})")
        self.at = int(at)
        self.every = int(every)

    def prepare(self, n, rounds, rng):
        horizon = max(rounds, 1)
        pts = [self.at]
        if self.every > 0:
            nxt = self.at + self.every
            while nxt < horizon:
                pts.append(nxt)
                nxt += self.every
        self._steps = np.asarray([p for p in pts if p < horizon],
                                 dtype=np.int64)

    def serve_preempt_steps(self):
        return self._steps


@dataclasses.dataclass(frozen=True)
class ServeFaults:
    """Realised serve-fault plan on the decode-step clock.

    ``poisons`` is a tuple of (rid, decode-step) cells (absolute steps);
    ``preempt_steps`` the driver-kill boundaries.  Plain data — the slot
    server consumes it structurally, keeping ``repro.distributed`` free
    of a ``repro.faults`` import."""

    poisons: tuple = ()
    preempt_steps: tuple = ()

    @property
    def empty(self) -> bool:
        return not self.poisons and not self.preempt_steps


def realise_serve_faults(spec, n_requests: int, horizon: int,
                         seed: int = 0) -> ServeFaults:
    """Lower a scenario spec (string or parsed ``Scenario``) to the
    serve-fault channels, with the standard per-(seed, position)
    realisation RNGs.  Transforms without serve channels contribute
    nothing — a training-fault spec realises as an empty bundle."""
    from ..scenarios.scenario import parse_scenario

    scen = parse_scenario(spec) if isinstance(spec, str) else spec
    poisons, preempts = set(), set()
    for i, tr in enumerate(scen.transforms):
        tr.prepare(int(n_requests), int(horizon),
                   np.random.default_rng([seed, i]))
        cells = tr.serve_poisons()
        if cells is not None:
            poisons.update((int(r), int(s)) for r, s in np.asarray(cells))
        steps = tr.serve_preempt_steps()
        if steps is not None:
            preempts.update(int(s) for s in np.asarray(steps))
    return ServeFaults(poisons=tuple(sorted(poisons)),
                       preempt_steps=tuple(sorted(preempts)))


FAULT_TRANSFORMS = {
    cls.name: cls
    for cls in (NanGrad, CorruptReceipt, WorkerCrash, HostPreempt,
                SlotPoison, ServePreempt)
}

# register into the shared grammar vocabulary (dict mutated in place, so
# every module holding a reference to TRANSFORMS sees the fault names)
TRANSFORMS.update(FAULT_TRANSFORMS)
