"""Versioned schema of the JSONL metrics log (and its validator).

Every line of a ``Tracer.export_metrics`` log is a standalone JSON
object tagged ``"v": METRICS_SCHEMA_VERSION`` — consumers (the CI
schema gate, the future self-tuning cache) validate per line and can
skip kinds they predate.  Line kinds:

* ``header`` — exactly one, first: ``{"v", "kind", "source",
  "wall_s", "created_unix"}``.
* ``gauge`` — a timestamped point sample: ``{"v", "kind", "t_us",
  "lane", "name", "value"}`` (``t_us``: microseconds on the tracer's
  monotonic clock).
* ``counter`` — a final cumulative value: ``{"v", "kind", "name",
  "value"}``.
* ``hist`` — a histogram summary: ``{"v", "kind", "name", "count",
  "min", "max", "mean", "p50", "p95"}``.

The validator is hand-rolled (this package is zero-dependency by
contract — no jsonschema): required keys, types, and the
header-first/header-once structural rules.  Run it as a module to gate
a file in CI::

    python -m repro.obs.schema experiments/figs/obs_metrics.jsonl
"""
from __future__ import annotations

import json

#: bump on any breaking change to the line layouts above
METRICS_SCHEMA_VERSION = 1

_NUM = (int, float)
#: kind -> {field: required types}; bool is an int subclass, so numeric
#: fields explicitly reject it
_FIELDS = {
    "header": {"source": str, "wall_s": _NUM, "created_unix": _NUM},
    "gauge": {"t_us": _NUM, "lane": str, "name": str, "value": _NUM},
    "counter": {"name": str, "value": _NUM},
    "hist": {"name": str, "count": int, "min": _NUM, "max": _NUM,
             "mean": _NUM, "p50": _NUM, "p95": _NUM},
}


class SchemaError(ValueError):
    """A metrics log line violated the versioned schema."""


def validate_line(obj: dict, lineno: int = 0) -> str:
    """Validate one parsed line; returns its kind, raises SchemaError."""
    where = f"line {lineno}: " if lineno else ""
    if not isinstance(obj, dict):
        raise SchemaError(f"{where}expected a JSON object, got "
                          f"{type(obj).__name__}")
    v = obj.get("v")
    if v != METRICS_SCHEMA_VERSION:
        raise SchemaError(
            f"{where}schema version {v!r} != {METRICS_SCHEMA_VERSION} "
            "(this build validates only its own version)")
    kind = obj.get("kind")
    if kind not in _FIELDS:
        raise SchemaError(
            f"{where}unknown kind {kind!r}; want one of {sorted(_FIELDS)}")
    for field, types in _FIELDS[kind].items():
        if field not in obj:
            raise SchemaError(f"{where}{kind} line missing {field!r}")
        val = obj[field]
        if isinstance(val, bool) or not isinstance(val, types):
            raise SchemaError(
                f"{where}{kind}.{field} has type {type(val).__name__}, "
                f"want {types}")
    return kind


def validate_lines(lines) -> dict:
    """Validate a parsed log (iterable of dicts): per-line schema plus
    the structural rules (header exactly once, first).  Returns the
    per-kind line counts."""
    counts: dict = {}
    for i, obj in enumerate(lines, start=1):
        kind = validate_line(obj, i)
        if kind == "header" and i != 1:
            raise SchemaError(f"line {i}: header must be line 1 and unique")
        counts[kind] = counts.get(kind, 0) + 1
    if counts.get("header", 0) != 1:
        raise SchemaError(
            f"log has {counts.get('header', 0)} header lines, want exactly 1")
    return counts


def validate_metrics_log(path: str) -> dict:
    """Parse + validate a JSONL metrics file; returns per-kind counts."""
    parsed = []
    with open(path) as f:
        for i, raw in enumerate(f, start=1):
            raw = raw.strip()
            if not raw:
                raise SchemaError(f"line {i}: blank line in JSONL log")
            try:
                parsed.append(json.loads(raw))
            except json.JSONDecodeError as e:
                raise SchemaError(f"line {i}: not valid JSON: {e}") from e
    return validate_lines(parsed)


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(
        description="validate a repro.obs JSONL metrics log")
    ap.add_argument("path", help="metrics .jsonl file to validate")
    args = ap.parse_args(argv)
    counts = validate_metrics_log(args.path)
    total = sum(counts.values())
    print(f"{args.path}: {total} lines valid against metrics schema "
          f"v{METRICS_SCHEMA_VERSION} "
          f"({', '.join(f'{k}={v}' for k, v in sorted(counts.items()))})")


if __name__ == "__main__":
    main()
