"""repro.faults — deterministic fault injection and guard rails.

Three pieces, one per execution tier:

* :mod:`transforms` — scheduled fault transforms (``nan_grad``,
  ``corrupt_receipt``, ``worker_crash``, ``host_preempt``) lowering into
  ``RunPlan`` channels through the ordinary scenario grammar, so
  injected faults replay bit-for-bit under scan ≡ eager.
* :class:`GuardConfig` — device-side non-finite skip guard and
  per-worker health backoff compiled into ``AsyncTrainer.step``.
* :class:`DivergenceBreaker` — host-side windowed circuit-breaker fed
  from the executor's tap lane.

Durability (the async tap-mode snapshotter) lives in
``repro.checkpoint.snapshot`` — faults make it necessary; the
checkpoint package owns the format.
"""
from .guards import DivergenceBreaker, GuardConfig
from .transforms import (
    FAULT_TRANSFORMS,
    CorruptReceipt,
    HostPreempt,
    NanGrad,
    ServeFaults,
    ServePreempt,
    SlotPoison,
    WorkerCrash,
    realise_serve_faults,
)

__all__ = [
    "GuardConfig",
    "DivergenceBreaker",
    "FAULT_TRANSFORMS",
    "NanGrad",
    "CorruptReceipt",
    "WorkerCrash",
    "HostPreempt",
    "SlotPoison",
    "ServePreempt",
    "ServeFaults",
    "realise_serve_faults",
]
