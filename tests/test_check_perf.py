"""Unit coverage for the benchmarks/check_perf.py CI gate.

The regression under test: a baseline row carrying ``grid_speedup`` whose
*current* row lacks the field used to read ``cur.get("grid_speedup",
0.0)`` and fail with a bogus ``0.000 < floor`` REGRESSION verdict — the
failure message must say the FIELD is missing, not that throughput
dropped to zero.  Plus the ``serve_slots`` kind's compare path and the
kind-dispatch rules.
"""
import importlib.util
import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCRIPT = ROOT / "benchmarks" / "check_perf.py"

_spec = importlib.util.spec_from_file_location("check_perf", SCRIPT)
check_perf = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_perf)


def _runtime_payload(*, grid_speedup=None, rounds_per_s=100.0):
    entry = {"runtime": "scan", "metrics": "chunk", "rounds_per_launch": 8,
             "rounds_per_s": rounds_per_s}
    if grid_speedup is not None:
        entry["grid_speedup"] = grid_speedup
    return {"bench": "runtime_dispatch_ab",
            "entries": [{"runtime": "eager", "metrics": "chunk",
                         "rounds_per_launch": 1, "rounds_per_s": 50.0},
                        entry]}


def _serve_payload(*, tok_per_s=40.0, occupancy=0.9, lock=100.0):
    return {"bench": "serve_slots",
            "entries": [{"mode": "lockstep", "tok_per_s": lock},
                        {"mode": "rotating", "n_slots": 2,
                         "admission": "pure", "tok_per_s": tok_per_s,
                         "occupancy": occupancy}]}


# ---------------------------------------------------------------------------
# the missing-field regression (satellite bugfix)
# ---------------------------------------------------------------------------

def test_missing_grid_speedup_reports_missing_not_zero(capsys):
    base = _runtime_payload(grid_speedup=3.0)
    cur = _runtime_payload()                 # field vanished from current
    failures = check_perf.check_runtime(cur, base, tolerance=0.3)
    assert len(failures) == 1
    assert "lacks the field" in failures[0]
    # the old bug compared 0.0 against the floor and printed "0.000 <"
    assert "0.000" not in failures[0]
    assert "MISSING" in capsys.readouterr().out


def test_present_grid_speedup_still_gated():
    base = _runtime_payload(grid_speedup=3.0)
    ok = check_perf.check_runtime(_runtime_payload(grid_speedup=2.9),
                                  base, tolerance=0.3)
    assert ok == []
    bad = check_perf.check_runtime(_runtime_payload(grid_speedup=1.0),
                                   base, tolerance=0.3)
    assert len(bad) == 1 and "grid_speedup" in bad[0]


def test_rows_returns_rows_and_eager_tuple():
    rows, eager = check_perf._rows(_runtime_payload())
    assert eager == 50.0
    assert ("scan", "chunk", 8) in rows


# ---------------------------------------------------------------------------
# the serve_slots kind
# ---------------------------------------------------------------------------

def test_serve_kind_passes_identical_payloads():
    assert check_perf.check_serve(_serve_payload(), _serve_payload(),
                                  tolerance=0.3) == []


def test_serve_kind_normalises_by_lockstep_row():
    base = _serve_payload(tok_per_s=40.0, lock=100.0)
    # half the absolute speed but the same RATIO: a slower machine, not a
    # regression
    cur = _serve_payload(tok_per_s=20.0, lock=50.0)
    assert check_perf.check_serve(cur, base, tolerance=0.3) == []
    # ratio collapse IS a regression
    bad = _serve_payload(tok_per_s=10.0, lock=100.0)
    fails = check_perf.check_serve(bad, base, tolerance=0.3)
    assert len(fails) == 1 and "tok/s" in fails[0]


def test_serve_kind_gates_occupancy_and_missing_fields():
    base = _serve_payload(occupancy=0.9)
    fails = check_perf.check_serve(_serve_payload(occupancy=0.3), base,
                                   tolerance=0.3)
    assert len(fails) == 1 and "occupancy" in fails[0]
    cur = _serve_payload()
    del cur["entries"][1]["occupancy"]
    fails = check_perf.check_serve(cur, base, tolerance=0.3)
    assert len(fails) == 1 and "lacks the field" in fails[0]


# ---------------------------------------------------------------------------
# kind dispatch through main()
# ---------------------------------------------------------------------------

def _run_main(tmp_path, cur, base, extra=()):
    cur_p, base_p = tmp_path / "cur.json", tmp_path / "base.json"
    cur_p.write_text(json.dumps(cur))
    base_p.write_text(json.dumps(base))
    return subprocess.run(
        [sys.executable, str(SCRIPT), str(cur_p), str(base_p), *extra],
        capture_output=True, text=True)


def test_main_accepts_serve_payload(tmp_path):
    r = _run_main(tmp_path, _serve_payload(), _serve_payload())
    assert r.returncode == 0, r.stdout + r.stderr
    assert "no dispatch-layer regression" in r.stdout


def test_main_skips_unknown_kind(tmp_path):
    r = _run_main(tmp_path, {"bench": "scenarios", "entries": []},
                  _serve_payload())
    assert r.returncode == 0
    assert "SKIP" in r.stdout


def test_main_rejects_kind_mismatch(tmp_path):
    r = _run_main(tmp_path, _serve_payload(), _runtime_payload())
    assert r.returncode != 0
    assert "mismatch" in r.stdout + r.stderr


def test_main_fails_on_serve_regression(tmp_path):
    r = _run_main(tmp_path, _serve_payload(tok_per_s=10.0),
                  _serve_payload(tok_per_s=40.0))
    assert r.returncode == 1
    assert "PERF REGRESSION" in r.stdout
