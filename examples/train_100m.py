"""End-to-end driver: train a ~100M-parameter qwen2-family model with the
AsGrad async trainer on heterogeneous data for a few hundred steps.

Presets:
  --preset smoke   tiny model, 20 steps   (runs anywhere, CI-sized)
  --preset 100m    ~100M params, 300 steps (the deliverable run; sized for a
                   real accelerator — on this CPU container use smoke)

  PYTHONPATH=src python examples/train_100m.py --preset smoke \
      --scheduler shuffled --pattern poisson
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_arch
from repro.core import TimingModel, build_schedule, round_masks, \
    make_scheduler, heterogeneous_speeds
from repro.data import DataConfig, HeterogeneousTokenPipeline
from repro.distributed import AsyncTrainer, AsyncConfig
from repro.optim import OptConfig
from repro import checkpoint


def build(preset: str):
    base = get_arch("qwen2-0.5b")
    if preset == "smoke":
        cfg = base.reduced().with_(remat="none")
        steps, B, S, n_groups = 20, 8, 64, 4
    else:  # ~100M active params
        cfg = base.with_(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                         d_head=64, d_ff=2048, vocab=32768,
                         tie_embeddings=True)
        steps, B, S, n_groups = 300, 32, 512, 8
    return cfg, steps, B, S, n_groups


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--scheduler", default="shuffled",
                    choices=["pure", "random", "shuffled", "fedbuff"])
    ap.add_argument("--pattern", default="poisson")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--sync", action="store_true",
                    help="synchronous baseline (delay_rounds=0)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg, steps, B, S, n_groups = build(args.preset)
    from repro.models import n_params
    print(f"arch={cfg.name}-derived  params={n_params(cfg)/1e6:.1f}M  "
          f"steps={steps}  batch={B}x{S}  groups={n_groups}")

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    tr = AsyncTrainer(cfg, mesh, opt=OptConfig(lr=args.lr, clip_norm=1.0),
                      async_cfg=AsyncConfig(
                          delay_rounds=0 if args.sync else 1))
    tr.n_groups = n_groups

    sched = make_scheduler(args.scheduler, n_groups,
                           b=max(n_groups // 2, 1), seed=0)
    tm = TimingModel(heterogeneous_speeds(n_groups, 6.0), args.pattern, seed=0)
    schedule = build_schedule(sched, tm, steps * sched.wait_b)
    masks = round_masks(schedule)

    pipe = HeterogeneousTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=S, global_batch=B, n_groups=n_groups,
        heterogeneity=1.0))
    state = tr.init_state(jax.random.PRNGKey(0))
    step = jax.jit(tr.train_step_fn())

    t0 = time.time()
    for i in range(min(steps, masks.shape[0])):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        state, m = step(state, batch, jnp.asarray(masks[i]))
        if i % max(steps // 10, 1) == 0 or i == steps - 1:
            print(f"step {i:4d}  loss={float(m['loss']):.4f}  "
                  f"|g|={float(m['grad_norm']):.3f}  "
                  f"part={float(m['participation']):.2f}  "
                  f"{(time.time()-t0):.1f}s")
    if args.ckpt:
        checkpoint.save(args.ckpt, state, step=steps, meta={"arch": cfg.name})
        print("checkpoint saved to", args.ckpt)


if __name__ == "__main__":
    main()
