"""CI gate: fail on a dispatch-layer perf regression vs the committed
baseline (``benchmarks/BENCH_runtime.json`` / ``benchmarks/BENCH_serve.json``).

Absolute rounds/s across heterogeneous CI hosts is pure noise — a GitHub
runner and the laptop that wrote the baseline differ by far more than any
real regression.  What IS machine-portable is each row's throughput
normalised by the SAME payload's reference row — the eager row for the
``runtime_dispatch_ab`` kind, the lock-step serving row for the
``serve_slots`` kind: that ratio isolates the dispatch/metric-transport
layer (launch amortisation, readback barriers, tap overhead, slot-loop
bookkeeping) from raw core speed, which is exactly what these benches
exist to track.  The gate fails when any subject row's normalised
throughput (or the grid lane's ``grid_speedup``, or the slot lane's
``occupancy``) drops more than ``--tolerance`` (default 30%) below the
baseline's.  The ``faults`` and ``obs`` kinds instead gate an ABSOLUTE
same-machine ratio (guarded/unguarded, traced/untraced) against a
documented ceiling — see their checkers.

Any other payload kind (e.g. the ``scenarios`` smoke bench, or a future
kind this script predates) is SKIPPED loudly with exit 0 — an
artifact-only bench must never fail CI just because the gate doesn't know
how to read it.  A missing file skips the same way (benches run under
``if: always()``, so an earlier failed step may legitimately leave no
payload behind).

Usage::

    python benchmarks/check_perf.py experiments/figs/BENCH_runtime.json \
        benchmarks/BENCH_runtime.json --tolerance 0.3
    python benchmarks/check_perf.py experiments/figs/BENCH_serve.json \
        benchmarks/BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _rows(payload: dict, path: str = "<payload>") -> tuple[dict, float]:
    """(runtime, metrics, K) -> entry, plus the eager rounds/s."""
    eager = [e for e in payload["entries"] if e["runtime"] == "eager"]
    if not eager:
        raise SystemExit(
            f"bench file {path!r} has no eager row to normalise against")
    rows = {(e["runtime"], e.get("metrics", "chunk"),
             e["rounds_per_launch"]): e
            for e in payload["entries"]}
    return rows, float(eager[0]["rounds_per_s"])


def check_runtime(current: dict, baseline: dict, tolerance: float,
                  paths=("<current>", "<baseline>")) -> list:
    cur_rows, cur_eager = _rows(current, paths[0])
    base_rows, base_eager = _rows(baseline, paths[1])
    failures = []
    print(f"{'row':<28} {'base':>8} {'now':>8} {'floor':>8}  verdict")
    for key, base in sorted(base_rows.items(), key=str):
        if key[0] == "eager":
            continue                      # the normaliser, not a subject
        cur = cur_rows.get(key)
        if cur is None:
            failures.append(
                f"{key}: present in baseline {paths[1]!r} but missing "
                f"from current payload {paths[0]!r}")
            print(f"{str(key):<28} {'':>8} {'':>8} {'':>8}  MISSING")
            continue
        base_n = float(base["rounds_per_s"]) / base_eager
        cur_n = float(cur["rounds_per_s"]) / cur_eager
        floor = base_n * (1.0 - tolerance)
        ok = cur_n >= floor
        print(f"{str(key):<28} {base_n:>8.3f} {cur_n:>8.3f} "
              f"{floor:>8.3f}  {'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(
                f"{key}: normalised rounds/s {cur_n:.3f} < floor "
                f"{floor:.3f} (baseline {base_n:.3f}, "
                f"tolerance {tolerance:.0%})")
        if "grid_speedup" in base:
            if "grid_speedup" not in cur:
                # a vanished field is a bench-shape change, not a 0.000
                # throughput — report it as such instead of a bogus floor
                # comparison
                failures.append(
                    f"{key}: baseline has grid_speedup but the current "
                    "row lacks the field")
                print(f"{'  grid_speedup':<28} "
                      f"{float(base['grid_speedup']):>8.3f} {'':>8} "
                      f"{'':>8}  MISSING")
                continue
            g_base = float(base["grid_speedup"])
            g_cur = float(cur["grid_speedup"])
            g_floor = g_base * (1.0 - tolerance)
            g_ok = g_cur >= g_floor
            print(f"{'  grid_speedup':<28} {g_base:>8.3f} {g_cur:>8.3f} "
                  f"{g_floor:>8.3f}  {'ok' if g_ok else 'REGRESSION'}")
            if not g_ok:
                failures.append(
                    f"{key}: grid_speedup {g_cur:.3f} < floor "
                    f"{g_floor:.3f}")
    return failures


def _serve_rows(payload: dict, path: str = "<payload>") -> tuple[dict, float]:
    """mode-key -> entry, plus the lock-step tok/s normaliser."""
    lock = [e for e in payload["entries"] if e["mode"] == "lockstep"]
    if not lock:
        raise SystemExit(
            f"bench file {path!r} has no lockstep row to normalise against")
    rows = {}
    for e in payload["entries"]:
        key = (e["mode"] if e["mode"] == "lockstep"
               else (e["mode"], e["n_slots"], e.get("admission", "pure")))
        rows[key] = e
    return rows, float(lock[0]["tok_per_s"])


def check_serve(current: dict, baseline: dict, tolerance: float,
                paths=("<current>", "<baseline>")) -> list:
    """Slot-serving gate: tok/s normalised by the same run's lock-step
    row (machine-portable), plus the realised slot occupancy — that one
    is a deterministic function of the admission bookkeeping, so a drop
    means the slot loop is leaving lanes idle, not that the host is slow."""
    cur_rows, cur_lock = _serve_rows(current, paths[0])
    base_rows, base_lock = _serve_rows(baseline, paths[1])
    failures = []
    print(f"{'row':<34} {'base':>8} {'now':>8} {'floor':>8}  verdict")
    for key, base in sorted(base_rows.items(), key=str):
        if key == "lockstep":
            continue                      # the normaliser, not a subject
        cur = cur_rows.get(key)
        if cur is None:
            failures.append(
                f"{key}: present in baseline {paths[1]!r} but missing "
                f"from current payload {paths[0]!r}")
            print(f"{str(key):<34} {'':>8} {'':>8} {'':>8}  MISSING")
            continue
        base_n = float(base["tok_per_s"]) / base_lock
        cur_n = float(cur["tok_per_s"]) / cur_lock
        floor = base_n * (1.0 - tolerance)
        ok = cur_n >= floor
        print(f"{str(key):<34} {base_n:>8.3f} {cur_n:>8.3f} "
              f"{floor:>8.3f}  {'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(
                f"{key}: normalised tok/s {cur_n:.3f} < floor "
                f"{floor:.3f} (baseline {base_n:.3f}, "
                f"tolerance {tolerance:.0%})")
        if "occupancy" in base:
            if "occupancy" not in cur:
                failures.append(
                    f"{key}: baseline has occupancy but the current row "
                    "lacks the field")
                print(f"{'  occupancy':<34} "
                      f"{float(base['occupancy']):>8.3f} {'':>8} "
                      f"{'':>8}  MISSING")
                continue
            o_base = float(base["occupancy"])
            o_cur = float(cur["occupancy"])
            o_floor = o_base * (1.0 - tolerance)
            o_ok = o_cur >= o_floor
            print(f"{'  occupancy':<34} {o_base:>8.3f} {o_cur:>8.3f} "
                  f"{o_floor:>8.3f}  {'ok' if o_ok else 'REGRESSION'}")
            if not o_ok:
                failures.append(
                    f"{key}: occupancy {o_cur:.3f} < floor {o_floor:.3f}")
    return failures


def check_faults(current: dict, baseline: dict, tolerance: float,
                 paths=("<current>", "<baseline>")) -> list:
    """Fault-injection gate: the ceiling is ABSOLUTE, not baseline-relative.

    The payload's ``guard_overhead_ratio`` (guarded / unguarded rounds/s
    on the same plan, state and machine) is already machine-portable, and
    the guard's documented contract is a ≤10% overhead ceiling — so CI
    passes ``--tolerance 0.1`` and the gate fails when the CURRENT ratio
    drops below ``1 − tolerance``, regardless of what the committed
    baseline measured.  The two smoke flags are gated the same way: the
    unguarded run must actually end poisoned (else the fault channel went
    dead and the overhead number is meaningless) and the guarded run must
    end finite with every poisoned round skipped."""
    failures = []
    if "guard_overhead_ratio" not in current:
        # keep checking the remaining rows — a missing field must not
        # hide whatever ELSE regressed in the same payload
        failures.append(
            f"current bench file {paths[0]!r} has kind 'faults' but no "
            "guard_overhead_ratio field — the bench payload shape changed "
            "under the gate")
        print(f"{'guard_overhead_ratio':<28} {'':>8} {'':>8} {'':>8}  "
              "MISSING")
    else:
        ratio = float(current["guard_overhead_ratio"])
        floor = 1.0 - tolerance
        base_ratio = float(baseline.get("guard_overhead_ratio", 0.0))
        print(f"{'guard_overhead_ratio':<28} {base_ratio:>8.3f} "
              f"{ratio:>8.3f} {floor:>8.3f}  "
              f"{'ok' if ratio >= floor else 'REGRESSION'}")
        if ratio < floor:
            failures.append(
                f"guard_overhead_ratio {ratio:.3f} < floor {floor:.3f} — "
                f"the guard costs more than {tolerance:.0%} of unguarded "
                "scan throughput")
    for flag, why in (
            ("unguarded_poisoned",
             "the injected faults no longer poison an unguarded run — the "
             "fault channel is dead end-to-end"),
            ("guarded_final_finite",
             "the guard let non-finite values reach the final params")):
        ok = bool(current.get(flag, False))
        print(f"{flag:<28} {'':>8} {str(ok):>8} {'True':>8}  "
              f"{'ok' if ok else 'FAILED'}")
        if not ok:
            failures.append(f"{flag} is False: {why}")
    skipped = int(current.get("guarded_skipped_rounds", -1))
    poisoned = int(current.get("poisoned_rounds", -2))
    ok = skipped == poisoned and poisoned > 0
    print(f"{'skipped == poisoned':<28} {'':>8} {skipped:>8} {poisoned:>8}  "
          f"{'ok' if ok else 'FAILED'}")
    if not ok:
        failures.append(
            f"guarded run skipped {skipped} rounds but the plan poisons "
            f"{poisoned} participating rounds — the guard is skipping the "
            "wrong rounds (or the world realised no faults)")
    return failures


def check_obs(current: dict, baseline: dict, tolerance: float,
              paths=("<current>", "<baseline>")) -> list:
    """Observability-overhead gate: the ceiling is ABSOLUTE, like the
    faults gate.  The payload's ``overhead_ratio`` (traced / untraced
    rounds/s on the same plan, state and machine — the tap transport with
    a live Recorder attached vs without one) is machine-portable, and the
    tracing contract is a ≤5% ceiling — CI passes ``--tolerance 0.05``
    and the gate fails when the CURRENT ratio drops below
    ``1 − tolerance`` regardless of the committed baseline.  The
    structural flags are gated too: the emitted Chrome trace and JSONL
    metrics log must have validated, and the traced run must have
    streamed exactly one tap event per round (tracing must observe the
    transport, not perturb it)."""
    failures = []
    if "overhead_ratio" not in current:
        # as in check_faults: record and continue so secondary failures
        # in the same payload still surface
        failures.append(
            f"current bench file {paths[0]!r} has kind 'obs' but no "
            "overhead_ratio field — the bench payload shape changed "
            "under the gate")
        print(f"{'overhead_ratio':<28} {'':>8} {'':>8} {'':>8}  MISSING")
    else:
        ratio = float(current["overhead_ratio"])
        floor = 1.0 - tolerance
        base_ratio = float(baseline.get("overhead_ratio", 0.0))
        print(f"{'overhead_ratio':<28} {base_ratio:>8.3f} {ratio:>8.3f} "
              f"{floor:>8.3f}  {'ok' if ratio >= floor else 'REGRESSION'}")
        if ratio < floor:
            failures.append(
                f"overhead_ratio {ratio:.3f} < floor {floor:.3f} — "
                f"tracing costs more than {tolerance:.0%} of untraced tap "
                f"throughput (current file {paths[0]!r})")
    for flag, why in (
            ("trace_valid",
             "the emitted trace.json is not valid Chrome trace-event "
             "JSON — Perfetto would reject it"),
            ("metrics_valid",
             "the emitted JSONL metrics log failed schema validation"),
            ("tap_events_match",
             "the traced run's tap_events != rounds — tracing perturbed "
             "the tap transport it was supposed to observe")):
        ok = bool(current.get(flag, False))
        print(f"{flag:<28} {'':>8} {str(ok):>8} {'True':>8}  "
              f"{'ok' if ok else 'FAILED'}")
        if not ok:
            failures.append(f"{flag} is False: {why}")
    return failures


def check_resilience(current: dict, baseline: dict, tolerance: float,
                     paths=("<current>", "<baseline>")) -> list:
    """Serving-resilience gate: absolute ceiling, like faults/obs.

    ``retry_overhead_ratio`` is (retry-machinery-armed clean serve tok/s)
    / (plain clean serve tok/s) on the same machine — the documented
    contract is that arming retries on a clean world costs ≤10%, so CI
    passes ``--tolerance 0.1`` and the gate fails below ``1 − tolerance``
    regardless of the committed baseline.  The flags pin the two
    correctness halves: the armed clean run must be TOKEN-IDENTICAL to
    the plain one (retry machinery is a no-op until a failure happens)
    and the chaos run must account every request (completed or in a
    degraded bucket — no silent loss)."""
    failures = []
    if "retry_overhead_ratio" not in current:
        failures.append(
            f"current bench file {paths[0]!r} has kind 'resilience' but "
            "no retry_overhead_ratio field — the bench payload shape "
            "changed under the gate")
        print(f"{'retry_overhead_ratio':<28} {'':>8} {'':>8} {'':>8}  "
              "MISSING")
    else:
        ratio = float(current["retry_overhead_ratio"])
        floor = 1.0 - tolerance
        base_ratio = float(baseline.get("retry_overhead_ratio", 0.0))
        print(f"{'retry_overhead_ratio':<28} {base_ratio:>8.3f} "
              f"{ratio:>8.3f} {floor:>8.3f}  "
              f"{'ok' if ratio >= floor else 'REGRESSION'}")
        if ratio < floor:
            failures.append(
                f"retry_overhead_ratio {ratio:.3f} < floor {floor:.3f} — "
                f"arming retries costs more than {tolerance:.0%} of clean "
                "slot-serve throughput")
    for flag, why in (
            ("clean_token_identical",
             "a clean serve with retries armed emitted different tokens "
             "than the plain serve — the retry machinery is not a no-op "
             "on the clean path"),
            ("all_accounted",
             "the chaos run lost requests: some rid is neither completed "
             "nor in evictions/timeouts/shed/drained — silent loss")):
        ok = bool(current.get(flag, False))
        print(f"{flag:<28} {'':>8} {str(ok):>8} {'True':>8}  "
              f"{'ok' if ok else 'FAILED'}")
        if not ok:
            failures.append(f"{flag} is False: {why}")
    return failures


#: bench kinds this gate knows how to compare (payload "bench" field)
CHECKERS = {
    "runtime_dispatch_ab": check_runtime,
    "serve_slots": check_serve,
    "faults": check_faults,
    "obs": check_obs,
    "resilience": check_resilience,
}
KNOWN_KINDS = set(CHECKERS)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="freshly produced bench JSON")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.3,
                    help="allowed fractional drop in normalised throughput "
                         "(default 0.3 = 30%%)")
    args = ap.parse_args()
    payloads = {}
    for label, path in (("current", args.current),
                        ("baseline", args.baseline)):
        if not os.path.exists(path):
            print(f"SKIP: {label} bench file {path!r} does not exist — "
                  "nothing to gate (not a failure: benches run under "
                  "if: always(), so an earlier failed step may have left "
                  "no payload)")
            return
        with open(path) as f:
            payloads[label] = json.load(f)
    kinds = {label: payload.get("bench", "<missing>")
             for label, payload in payloads.items()}
    for label, kind in kinds.items():
        if kind not in KNOWN_KINDS:
            print(f"SKIP: {label} bench file {getattr(args, label)!r} has "
                  f"kind {kind!r}, which this gate cannot compare (known: "
                  f"{sorted(KNOWN_KINDS)}) — treating as artifact-only, "
                  "not a failure")
            return
    if kinds["current"] != kinds["baseline"]:
        raise SystemExit(
            f"bench kind mismatch: current file {args.current!r} is "
            f"{kinds['current']!r} but baseline file {args.baseline!r} is "
            f"{kinds['baseline']!r} — not comparable")
    failures = CHECKERS[kinds["current"]](
        payloads["current"], payloads["baseline"], args.tolerance,
        paths=(args.current, args.baseline))
    if failures:
        print("\nPERF REGRESSION vs committed baseline:")
        for msg in failures:
            print(" -", msg)
        sys.exit(1)
    print("\nno dispatch-layer regression "
          f"(tolerance {args.tolerance:.0%})")


if __name__ == "__main__":
    main()
