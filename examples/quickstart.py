"""Quickstart: the AsGrad framework on the paper's own experiment.

Runs pure / random / shuffled asynchronous SGD on heterogeneous logistic
regression (Syn(1,1), §5) with poisson worker timings and prints the final
full-gradient norms — reproducing the paper's headline ordering in ~30 s.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (TimingModel, build_schedule, replay, make_scheduler,
                        heterogeneous_speeds, trace)
from repro.objectives import LogRegProblem, make_synthetic


def main():
    n, T = 10, 4000
    A, b = make_synthetic(1.0, 1.0, n=n, m=200, d=300, seed=0)
    prob = LogRegProblem(A, b, lam=0.1)
    print(f"heterogeneity zeta(x0) = {prob.zeta(np.zeros(prob.d)):.2f}")
    speeds = heterogeneous_speeds(n, slow_factor=8.0)
    for alg in ("pure", "random", "shuffled"):
        best = (np.inf, None, None)
        for gamma in (0.005, 0.002, 0.001):
            sched = make_scheduler(alg, n, seed=0)
            tm = TimingModel(speeds, "poisson", seed=0)
            s = build_schedule(sched, tm, T)
            res = replay(s, prob.grad_fn(), jnp.zeros(prob.d), gamma,
                         log_every=200, full_grad_fn=prob.full_grad)
            gn = float(np.min(res.grad_norms[-4:]))
            if gn < best[0]:
                best = (gn, gamma, trace.summarize(s))
        gn, gamma, summ = best
        print(f"{alg:9s} |grad f| = {gn:.5f}  (gamma={gamma}, "
              f"tau_max={summ['tau_max']}, tau_C={summ['tau_c']}, "
              f"jobs min/max={summ['jobs_min']}/{summ['jobs_max']})")
    print("\nexpected: pure stalls near the zeta level; shuffled is ~10x lower.")


if __name__ == "__main__":
    main()
