"""Checkpointer round-trip + atomic-save durability + data pipeline.

The durability half pins the crash contract: saves are atomic (temp file
+ ``os.replace``, state first, metadata last), so any observable
checkpoint directory is either fully verifiable or detectably torn —
``verify``/``restore`` must fail LOUDLY on truncation, digest mismatch,
or missing halves, and the async snapshotter must skip such directories
when picking a resume point."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.checkpoint import (AsyncSnapshotter, CheckpointError, load_meta,
                              restore, save, verify)
from repro.configs import get_arch
from repro.data import DataConfig, HeterogeneousTokenPipeline, EpochShuffler
from repro.distributed import AsyncTrainer, AsyncConfig
from repro.optim import OptConfig


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_arch("qwen2-0.5b").reduced()
    tr = AsyncTrainer(cfg, Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                                ("data", "model")),
                      opt=OptConfig(), async_cfg=AsyncConfig(1))
    state = tr.init_state(jax.random.PRNGKey(0))
    save(str(tmp_path / "ck"), state, step=7, meta={"arch": cfg.name})
    like = jax.tree_util.tree_map(jnp.zeros_like, state)
    restored = restore(str(tmp_path / "ck"), like)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    meta = load_meta(str(tmp_path / "ck"))
    assert meta["step"] == 7 and meta["arch"] == cfg.name


def test_checkpoint_shape_mismatch_raises(tmp_path):
    state = {"w": jnp.ones((3, 3))}
    save(str(tmp_path / "ck"), state)
    with pytest.raises(ValueError):
        restore(str(tmp_path / "ck"), {"w": jnp.ones((2, 3))})


def test_checkpoint_save_is_atomic_and_verifiable(tmp_path):
    """The save leaves exactly {state.npz, meta.json} (no temp litter),
    meta records the state file's digest, and verify() passes."""
    ck = str(tmp_path / "ck")
    save(ck, {"w": jnp.arange(6.0).reshape(2, 3),
              "b": jnp.ones((4,), jnp.bfloat16)}, step=3)
    assert sorted(os.listdir(ck)) == ["meta.json", "state.npz"]
    info = verify(ck)
    assert info["step"] == 3
    assert info["state_nbytes"] == os.path.getsize(
        os.path.join(ck, "state.npz"))
    assert len(info["state_sha256"]) == 64
    assert len(info["keys"]) == 2


def test_checkpoint_truncated_state_fails_loudly(tmp_path):
    ck = str(tmp_path / "ck")
    save(ck, {"w": jnp.ones((32, 32))})
    sp = os.path.join(ck, "state.npz")
    with open(sp, "r+b") as f:
        f.truncate(os.path.getsize(sp) // 2)
    with pytest.raises(CheckpointError, match="truncated|torn"):
        verify(ck)
    with pytest.raises(CheckpointError):
        restore(ck, {"w": jnp.ones((32, 32))})


def test_checkpoint_digest_mismatch_fails_loudly(tmp_path):
    """Same-size corruption (a flipped byte — or a crash between the two
    atomic renames pairing a fresh state with stale metadata) is caught
    by the sha256, not the size check."""
    ck = str(tmp_path / "ck")
    save(ck, {"w": jnp.ones((32, 32))})
    sp = os.path.join(ck, "state.npz")
    with open(sp, "r+b") as f:
        f.seek(os.path.getsize(sp) - 100)
        byte = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(CheckpointError, match="sha256"):
        verify(ck)
    with pytest.raises(CheckpointError, match="sha256"):
        restore(ck, {"w": jnp.ones((32, 32))})


def test_checkpoint_missing_halves_fail_loudly(tmp_path):
    ck = str(tmp_path / "ck")
    save(ck, {"w": jnp.ones(3)})
    os.remove(os.path.join(ck, "meta.json"))
    with pytest.raises(CheckpointError, match="meta.json"):
        verify(ck)
    save(ck, {"w": jnp.ones(3)})
    os.remove(os.path.join(ck, "state.npz"))
    with pytest.raises(CheckpointError, match="state.npz"):
        verify(ck)
    # a leaf absent from the archive is a structure mismatch, not garbage
    save(ck, {"w": jnp.ones(3)})
    with pytest.raises(CheckpointError, match="absent"):
        restore(ck, {"w": jnp.ones(3), "extra": jnp.ones(2)})


def test_snapshotter_latest_skips_corrupt_dirs(tmp_path):
    """Crash recovery: the newest snapshot directory may be the one torn
    by the crash — latest() must fall back to the newest RESTORABLE one
    (and ignore non-snapshot directory names entirely)."""
    root = str(tmp_path / "snaps")
    save(os.path.join(root, "round-00000004"), {"w": jnp.ones(3)}, step=4)
    save(os.path.join(root, "round-00000008"), {"w": jnp.ones(3)}, step=8)
    os.makedirs(os.path.join(root, "not-a-round"))
    r, d = AsyncSnapshotter.latest(root)
    assert r == 8 and d.endswith("round-00000008")
    # tear the newest: truncate its state file
    sp = os.path.join(root, "round-00000008", "state.npz")
    with open(sp, "r+b") as f:
        f.truncate(10)
    r, d = AsyncSnapshotter.latest(root)
    assert r == 4 and d.endswith("round-00000004")
    # tear both → nothing restorable
    os.remove(os.path.join(root, "round-00000004", "meta.json"))
    assert AsyncSnapshotter.latest(root) is None


def test_pipeline_heterogeneity_measurable():
    """Different groups draw measurably different token marginals; zero
    heterogeneity gives identical marginals."""
    dc = DataConfig(vocab=64, seq_len=128, global_batch=8, n_groups=4,
                    heterogeneity=1.0, seed=0)
    pipe = HeterogeneousTokenPipeline(dc)
    b = pipe.batch(0)["tokens"]
    assert b.shape == (8, 128) and b.dtype == np.int32
    per = 8 // 4
    hists = [np.bincount(b[g * per:(g + 1) * per].ravel(), minlength=64)
             for g in range(4)]
    tv = max(np.abs(hists[0] / hists[0].sum() - h / h.sum()).sum()
             for h in hists[1:])
    assert tv > 0.05
    hom = HeterogeneousTokenPipeline(
        DataConfig(vocab=64, seq_len=128, global_batch=8, n_groups=4,
                   heterogeneity=0.0, seed=0))
    bh = hom.batch(0)["tokens"]
    hh = [np.bincount(bh[g * per:(g + 1) * per].ravel(), minlength=64)
          for g in range(4)]
    tvh = max(np.abs(hh[0] / hh[0].sum() - h / h.sum()).sum() for h in hh[1:])
    assert tvh < tv


def test_pipeline_deterministic():
    dc = DataConfig(vocab=32, seq_len=16, global_batch=4, n_groups=2, seed=3)
    b1 = HeterogeneousTokenPipeline(dc).batch(5)["tokens"]
    b2 = HeterogeneousTokenPipeline(dc).batch(5)["tokens"]
    np.testing.assert_array_equal(b1, b2)


def test_epoch_shuffler_covers_every_epoch():
    sh = EpochShuffler(10, seed=0, reshuffle=True)
    for _ in range(5):
        idx = sh.next_indices(10)
        assert sorted(idx.tolist()) == list(range(10))
    once = EpochShuffler(10, seed=0, reshuffle=False)
    e1 = once.next_indices(10)
    e2 = once.next_indices(10)
    np.testing.assert_array_equal(e1, e2)
