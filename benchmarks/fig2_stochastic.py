"""Figure 2: stochastic gradients on Syn(α, β), poisson delays, batch m/10.

Claim validated: same ordering as Fig. 1 under gradient noise; shuffled
finds the lowest-error stationary point across heterogeneity levels.
"""
from __future__ import annotations

import csv
import os

import numpy as np

from repro.objectives import LogRegProblem, make_synthetic
from .common import run_alg, ALGS


def run(T: int = 3000, out: str = "experiments/figs", quick: bool = False):
    os.makedirs(out, exist_ok=True)
    levels = ((0.5, 0.5), (1.0, 1.0), (1.5, 1.5)) if not quick else ((1.0, 1.0),)
    rows = []
    for (a, b_) in levels:
        A, b = make_synthetic(a, b_, n=10, m=200, d=300, seed=0)
        prob = LogRegProblem(A, b, lam=0.1, batch_size=20)   # m/10
        for alg in ALGS:
            gamma, ts, gns, secs = run_alg(prob, alg, "poisson", T,
                                           stochastic=True)
            rows.append({"alpha": a, "beta": b_, "alg": alg, "gamma": gamma,
                         "final_grad_norm": float(np.min(gns[-3:])),
                         "seconds": round(secs, 1)})
            np.savez(os.path.join(out, f"fig2_syn{a}_{b_}_{alg}.npz"),
                     ts=ts, grad_norms=gns, gamma=gamma)
    with open(os.path.join(out, "fig2.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=sorted({k for r in rows for k in r}))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
