from .sharding import (Rules, DEFAULT_RULES, SEQ_PARALLEL_RULES, auto_rules,
                       logical_pspec, zero_pspec, tree_pspecs, tree_shardings,
                       bytes_per_device, pool_axes, pool_shard_count,
                       pooled_pspec)
from .async_trainer import AsyncTrainer, AsyncConfig
from .serve import Server, ServeConfig
from .slot_serve import (SlotServer, SlotConfig, ServeResult, RetryPolicy,
                         OverloadPolicy, ServePreempted, SHED_POLICIES)
from .admission import (AdmissionPolicy, AdmissionTrace, draw_arrivals,
                        parse_admission)

__all__ = ["Rules", "DEFAULT_RULES", "SEQ_PARALLEL_RULES", "auto_rules", "logical_pspec", "zero_pspec",
           "tree_pspecs", "tree_shardings", "bytes_per_device",
           "pool_axes", "pool_shard_count", "pooled_pspec",
           "AsyncTrainer", "AsyncConfig", "Server", "ServeConfig",
           "SlotServer", "SlotConfig", "ServeResult", "RetryPolicy",
           "OverloadPolicy", "ServePreempted", "SHED_POLICIES",
           "AdmissionPolicy", "AdmissionTrace", "draw_arrivals",
           "parse_admission"]
