"""Schedule → device-resident execution plan.

A realised :class:`repro.core.engine.Schedule` is host-side numpy: per
*receipt* arrays of workers and assignment iterates.  The trainer consumes
it one ROUND at a time — participation mask, stepsize scale, data batch —
and the eager dispatch loop used to rebuild each of those on host every
round, forcing a host↔device round trip per round.

:func:`compile_plan` lowers the whole run ONCE into a :class:`RunPlan`:
stacked per-round arrays (masks, delay scales, folded PRNG data keys) plus
the static tables device-side batch synthesis needs (the Zipf inverse-CDF
and the per-group vocab permutations of
:class:`repro.data.HeterogeneousTokenPipeline`).  Everything in the plan is
gradient-value-independent — the same observation that makes the exact
simulator possible (engine.py docstring) makes the whole run compilable:
``lax.scan`` can replay plan slices with zero host involvement.

The plan is runtime-neutral: the scan executor scans it K rounds per XLA
launch, the eager oracle indexes it one round at a time.  Both synthesise
batches on device from the SAME per-round keys, which is what makes
eager-vs-scan parity a meaningful gate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core import lower_rounds
from ..core.engine import Schedule


@dataclasses.dataclass(frozen=True)
class RunPlan:
    """Device-ready lowering of one training run.

    Per-round stacked arrays (row ``q`` drives round ``q``):

    * ``masks`` — ``(rounds, n_groups)`` f32 participation masks,
    * ``delay_scales`` — ``(rounds,)`` f32 per-round γ-scales (all ones
      unless the spec's stepsize policy is delay-adaptive),
    * ``data_keys`` — ``(rounds, 2)`` uint32 PRNG keys,
      ``fold_in(PRNGKey(seed), q)``: the whole data stream in one array.

    Static data-synthesis tables (host-computed once, device-resident for
    the run):

    * ``token_cdf`` — ``(vocab,)`` f32 cumulative Zipf pmf (inverse-CDF
      sampling via ``searchsorted``),
    * ``group_perms`` — ``(n_groups, vocab)`` int32 group-specific vocab
      permutations (the heterogeneity ζ² knob).

    ``grid_scales`` is the optional γ-axis: ``(n_grid, rounds)`` f32
    per-round stepsize scales, one row per grid point
    (``γ_g/γ_base × delay_scales``) — what the executor's vmapped
    :meth:`~repro.runtime.PlanExecutor.run_grid` lane scans over.  The
    ordering, masks and data keys are γ-independent, so one plan serves
    the whole grid.

    Scenario channels (``repro.scenarios`` worlds; all optional, all
    ``None`` for a stationary plan):

    * elastic membership has NO channel of its own — the availability
      table is folded into ``masks`` at compile time (a down worker's mask
      entry is zeroed, hard-dropping its residual in-flight receipts),
    * ``cdf_bank``/``cdf_index`` — drifting data law: ``(n_phases,
      vocab)`` f32 cumulative Zipf pmfs and the ``(rounds,)`` int32 row
      index per round (the trajectory quantised to ≤ ``n_phases``
      levels); round q samples tokens from ``cdf_bank[cdf_index[q]]``,
    * ``grad_density`` — ``(rounds,)`` f32 keep-densities in (0, 1]:
      per-leaf magnitude top-k gradient sparsification applied inside the
      train step (1.0 ⇒ exact no-op),
    * ``fault_gain`` — ``(rounds, n_groups)`` f32 per-worker loss-weight
      gains from the fault transforms (``repro.faults``): 1.0 neutral,
      huge-but-finite = corrupted receipt, NaN = poisoned receipt.  Only
      participating workers' gains matter (the mask zeroes the rest).
    """

    masks: np.ndarray
    delay_scales: np.ndarray
    data_keys: np.ndarray
    token_cdf: np.ndarray
    group_perms: np.ndarray
    global_batch: int
    seq_len: int
    seed: int
    adaptive: bool = False
    grid_scales: Optional[np.ndarray] = None
    cdf_bank: Optional[np.ndarray] = None
    cdf_index: Optional[np.ndarray] = None
    grad_density: Optional[np.ndarray] = None
    fault_gain: Optional[np.ndarray] = None

    @property
    def rounds(self) -> int:
        return int(self.masks.shape[0])

    @property
    def n_groups(self) -> int:
        return int(self.masks.shape[1])

    @property
    def vocab(self) -> int:
        return int(self.token_cdf.shape[0])

    @property
    def n_grid(self) -> int:
        """Grid points on the γ-axis (0 when the plan has none)."""
        return 0 if self.grid_scales is None \
            else int(self.grid_scales.shape[0])

    def __post_init__(self):
        if self.masks.shape[0] != self.delay_scales.shape[0] or \
                self.masks.shape[0] != self.data_keys.shape[0]:
            raise ValueError(
                f"per-round arrays disagree on rounds: masks "
                f"{self.masks.shape}, delay_scales {self.delay_scales.shape},"
                f" data_keys {self.data_keys.shape}")
        if self.group_perms.shape != (self.n_groups, self.vocab):
            raise ValueError(
                f"group_perms {self.group_perms.shape} != "
                f"(n_groups={self.n_groups}, vocab={self.vocab})")
        if self.global_batch % self.n_groups:
            raise ValueError(
                f"the {self.n_groups} groups must divide "
                f"global_batch={self.global_batch}")
        if self.grid_scales is not None and (
                self.grid_scales.ndim != 2
                or self.grid_scales.shape[1] != self.masks.shape[0]
                or not self.grid_scales.shape[0]):
            raise ValueError(
                f"grid_scales must be (n_grid >= 1, rounds="
                f"{self.masks.shape[0]}); got "
                f"{self.grid_scales.shape}")
        if (self.cdf_bank is None) != (self.cdf_index is None):
            raise ValueError("cdf_bank and cdf_index must be set together")
        if self.cdf_bank is not None:
            if self.cdf_bank.ndim != 2 or \
                    self.cdf_bank.shape[1] != self.vocab:
                raise ValueError(
                    f"cdf_bank must be (n_phases, vocab={self.vocab}); got "
                    f"{self.cdf_bank.shape}")
            if self.cdf_index.shape != (self.rounds,):
                raise ValueError(
                    f"cdf_index must be (rounds={self.rounds},); got "
                    f"{self.cdf_index.shape}")
            if self.cdf_index.min(initial=0) < 0 or \
                    self.cdf_index.max(initial=0) >= self.cdf_bank.shape[0]:
                raise ValueError("cdf_index out of cdf_bank range")
        if self.grad_density is not None:
            if self.grad_density.shape != (self.rounds,):
                raise ValueError(
                    f"grad_density must be (rounds={self.rounds},); got "
                    f"{self.grad_density.shape}")
            if np.any(self.grad_density <= 0) or \
                    np.any(self.grad_density > 1):
                raise ValueError("grad_density values must be in (0, 1]")
        if self.fault_gain is not None:
            if self.fault_gain.shape != (self.rounds, self.n_groups):
                raise ValueError(
                    f"fault_gain must be (rounds={self.rounds}, "
                    f"n_groups={self.n_groups}); got {self.fault_gain.shape}")
            # NaN compares False everywhere, so this only rejects real zeros
            if np.any(self.fault_gain == 0):
                raise ValueError(
                    "fault_gain must not contain zeros — drop workers via "
                    "the availability channel, not a zero gain")

    # ------------------------------------------------------------------ views
    def device_slices(self, lo: int = 0, hi: Optional[int] = None):
        """``(masks, data_keys, delay_scales)`` rows ``[lo, hi)`` as device
        arrays — the xs of one ``lax.scan`` launch."""
        import jax.numpy as jnp

        hi = self.rounds if hi is None else hi
        return (jnp.asarray(self.masks[lo:hi]),
                jnp.asarray(self.data_keys[lo:hi]),
                jnp.asarray(self.delay_scales[lo:hi]))

    def grid_slice(self, lo: int = 0, hi: Optional[int] = None):
        """``(n_grid, hi-lo)`` per-γ scale columns for one chunk launch."""
        import jax.numpy as jnp

        if self.grid_scales is None:
            raise ValueError("plan has no γ-axis (grid_scales is None)")
        hi = self.rounds if hi is None else hi
        return jnp.asarray(self.grid_scales[:, lo:hi])

    def summary(self) -> dict:
        return {"rounds": self.rounds, "n_groups": self.n_groups,
                "vocab": self.vocab, "global_batch": self.global_batch,
                "seq_len": self.seq_len, "seed": self.seed,
                "adaptive": self.adaptive, "n_grid": self.n_grid,
                "n_cdf_phases": (0 if self.cdf_bank is None
                                 else int(self.cdf_bank.shape[0])),
                "sparsified": self.grad_density is not None,
                "faulted": self.fault_gain is not None}


def fold_data_keys(seed: int, rounds: int) -> np.ndarray:
    """``(rounds, 2)`` uint32 — round q's batch key is
    ``fold_in(PRNGKey(seed), q)``; a pure function of (seed, q), so a run
    resumed at any round boundary regenerates the identical stream."""
    import jax

    key = jax.random.PRNGKey(seed)
    keys = jax.vmap(lambda q: jax.random.fold_in(key, q))(
        np.arange(rounds, dtype=np.uint32))
    if jax.dtypes.issubdtype(keys.dtype, jax.dtypes.prng_key):  # typed keys
        keys = jax.random.key_data(keys)
    return np.asarray(keys, dtype=np.uint32)


def quantize_zipf_trajectory(zipf_as: np.ndarray, vocab: int,
                             n_phases: int = 8):
    """Quantise a per-round Zipf-exponent trajectory into a CDF bank.

    Returns ``(cdf_bank (n_phases', vocab) f32, cdf_index (rounds,)
    int32)`` with ``n_phases' <= n_phases`` distinct levels (nearest-level
    rounding on a linear grid between the trajectory's extremes; a
    constant trajectory collapses to one phase).  Each bank row is the
    cumulative :func:`repro.data.zipf_pmf` at that exponent — the same
    inverse-CDF table a stationary plan at that exponent would carry.
    """
    from ..data import zipf_pmf

    z = np.asarray(zipf_as, dtype=np.float64)
    if z.ndim != 1 or not z.size:
        raise ValueError("zipf_as must be a non-empty 1-D trajectory")
    if np.any(z <= 0):
        raise ValueError("zipf exponents must be positive")
    lo, hi = float(z.min()), float(z.max())
    if hi - lo < 1e-12:
        levels = np.asarray([lo])
    else:
        levels = np.linspace(lo, hi, max(int(n_phases), 2))
    idx = np.argmin(np.abs(z[:, None] - levels[None, :]), axis=1)
    used = np.unique(idx)
    remap = np.zeros(len(levels), dtype=np.int32)
    remap[used] = np.arange(len(used), dtype=np.int32)
    bank = np.stack([np.cumsum(zipf_pmf(vocab, levels[u])) for u in used])
    return bank.astype(np.float32), remap[idx].astype(np.int32)


def compile_plan(schedule: Schedule, job, *, rounds: Optional[int] = None,
                 n_groups: Optional[int] = None, seed: int = 0,
                 adaptive: bool = False,
                 grid_gammas: Optional[Sequence[float]] = None,
                 base_gamma: Optional[float] = None,
                 availability: Optional[np.ndarray] = None,
                 zipf_as: Optional[np.ndarray] = None,
                 grad_density: Optional[np.ndarray] = None,
                 fault_gain: Optional[np.ndarray] = None,
                 n_cdf_phases: int = 8) -> RunPlan:
    """Lower ``(schedule, job)`` to a :class:`RunPlan`.

    ``job`` is a :class:`repro.api.TrainJob` (anything exposing
    ``make_arch()``, ``global_batch``, ``seq_len``, ``heterogeneity`` and
    ``delay_rounds`` works).  ``adaptive`` applies the [Koloskova et
    al. 22]-style per-round scale from the schedule's delay metadata; the
    realised buffering depth is 1 round whenever ``delay_rounds > 0``
    (AsyncTrainer's single swapped-every-round gbuf — see
    :func:`repro.core.round_delay_scales`).

    ``grid_gammas`` adds the γ-axis: one ``grid_scales`` row per grid
    point, ``γ_g / base_gamma`` (default ``base_gamma = grid_gammas[0]``,
    the lr the executing trainer was built with) times the per-round
    scales — the optimizer applies ``lr · scale`` everywhere, so scaling
    the scale IS running at γ_g.  Every row folds the whole stepsize
    policy in, so the grid lane always calls the explicit 4-arg step.

    Scenario channels (typically from a realised
    :class:`repro.scenarios.ScenarioWorld`; the runtime stays
    scenario-agnostic — these are plain per-round arrays):

    * ``availability`` — ``(rounds', n)`` 0/1 membership, multiplied into
      the participation masks (elastic hard-drop),
    * ``zipf_as`` — ``(rounds',)`` Zipf-exponent trajectory, quantised via
      :func:`quantize_zipf_trajectory` into ``cdf_bank``/``cdf_index``,
    * ``grad_density`` — ``(rounds',)`` keep-densities in (0, 1],
    * ``fault_gain`` — ``(rounds', n)`` per-worker loss-weight gains
      (``repro.faults``; NaN = poisoned receipt).

    Shorter channels than the plan's rounds are padded with their neutral
    value (all-up / last exponent / density 1 / gain 1).
    """
    from ..data import DataConfig, HeterogeneousTokenPipeline

    n = n_groups if n_groups is not None else schedule.n_workers
    masks, scales = lower_rounds(
        schedule, rounds,
        delay_rounds=1 if getattr(job, "delay_rounds", 0) > 0 else 0,
        adaptive=adaptive)
    R = masks.shape[0]
    if availability is not None:
        avail = np.asarray(availability, dtype=np.float32)
        if avail.ndim != 2 or avail.shape[1] != masks.shape[1]:
            raise ValueError(
                f"availability must be (rounds, n_workers="
                f"{masks.shape[1]}); got {avail.shape}")
        if avail.shape[0] < R:
            avail = np.concatenate(
                [avail, np.ones((R - avail.shape[0], avail.shape[1]),
                                np.float32)])
        masks = masks * avail[:R]
    cfg = job.make_arch()          # built once: vocab probe + pipeline share it
    cdf_bank = cdf_index = None
    if zipf_as is not None:
        z = np.asarray(zipf_as, dtype=np.float64)
        if z.shape[0] < R:
            z = np.concatenate([z, np.full(R - z.shape[0], z[-1])])
        cdf_bank, cdf_index = quantize_zipf_trajectory(
            z[:R], cfg.vocab, n_cdf_phases)
    density = None
    if grad_density is not None:
        density = np.asarray(grad_density, dtype=np.float32)
        if density.shape[0] < R:
            density = np.concatenate(
                [density, np.ones(R - density.shape[0], np.float32)])
        density = density[:R]
    gain = None
    if fault_gain is not None:
        gain = np.asarray(fault_gain, dtype=np.float32)
        if gain.ndim != 2 or gain.shape[1] != masks.shape[1]:
            raise ValueError(
                f"fault_gain must be (rounds, n_workers="
                f"{masks.shape[1]}); got {gain.shape}")
        if gain.shape[0] < R:
            gain = np.concatenate(
                [gain, np.ones((R - gain.shape[0], gain.shape[1]),
                               np.float32)])
        gain = gain[:R]
    grid_scales = None
    if grid_gammas is not None:
        g = np.asarray([float(x) for x in grid_gammas], np.float32)
        if g.ndim != 1 or not g.size:
            raise ValueError("grid_gammas must be a non-empty 1-D sequence")
        base = np.float32(base_gamma if base_gamma is not None else g[0])
        grid_scales = ((g / base)[:, None]
                       * scales[None, :]).astype(np.float32)
    pipe = HeterogeneousTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=job.seq_len, global_batch=job.global_batch,
        n_groups=n, heterogeneity=job.heterogeneity, seed=seed))
    return RunPlan(
        masks=masks.astype(np.float32),
        delay_scales=scales.astype(np.float32),
        data_keys=fold_data_keys(seed, masks.shape[0]),
        token_cdf=np.cumsum(pipe.pmf).astype(np.float32),
        group_perms=np.stack(pipe.perms).astype(np.int32),
        global_batch=job.global_batch, seq_len=job.seq_len,
        seed=seed, adaptive=adaptive, grid_scales=grid_scales,
        cdf_bank=cdf_bank, cdf_index=cdf_index, grad_density=density,
        fault_gain=gain)
