"""Barrier-free durability: donated-pipeline-safe async device snapshots.

The scan executor's fast paths (``metrics="tap"|"none"``) never
materialise the mid-run state on host — that is exactly why they are
fast, and exactly why they had no durability.  :class:`AsyncSnapshotter`
closes the gap without reintroducing barriers:

1. ``offer(round, state)`` dispatches a cached NON-donating jitted
   device copy of the carry.  The copy is enqueued on the device stream
   *before* the next chunk launch donates the carry's buffers, and
   devices execute in dispatch order, so the snapshot reads consistent
   data no matter how far ahead the host races.
2. Every leaf of the copy starts a ``copy_to_host_async`` transfer and
   the pair is parked in a two-deep pending queue (double buffer).
3. Offering the NEXT snapshot finalises the previous one: by then its
   transfer has had a whole snapshot cadence to complete, so the numpy
   materialisation inside :func:`repro.checkpoint.save` is (near) free,
   and the write itself is the ordinary ATOMIC checkpoint save.

The device pipeline therefore never drains mid-run: the host only ever
waits for data the device finished a cadence ago.  A SIGKILL at any
point loses at most the two pending snapshots; everything older is an
atomically-written, sha-verified checkpoint directory that
:meth:`AsyncSnapshotter.latest` will find and
:func:`repro.checkpoint.restore` will load — and because snapshots land
on chunk boundaries and the plan's data keys are pure functions of
(seed, round), a resumed run is bit-for-bit the uninterrupted one.
"""
from __future__ import annotations

import os
import re
import shutil
from collections import deque
from typing import Optional

from . import checkpointer

_ROUND_DIR = re.compile(r"^round-(\d{8})$")


class AsyncSnapshotter:
    """Periodic async snapshots of a scan run's carried state.

    ``every`` is the cadence knob in ROUNDS: a chunk boundary ``hi`` is
    due when ``hi % every == 0`` (plus the final boundary).  Boundaries
    are the only offer points, so pick ``every`` as a multiple of
    ``rounds_per_launch`` to get exactly the cadence you asked for —
    other values snapshot at the boundaries the modulo happens to hit.

    ``keep`` bounds disk: only the newest ``keep`` snapshot directories
    survive pruning (the crash-recovery window).
    """

    def __init__(self, path: str, every: int, *, keep: int = 2,
                 meta: Optional[dict] = None, recorder=None):
        if every < 1:
            raise ValueError(f"snapshot cadence must be >= 1 (got {every})")
        if keep < 1:
            raise ValueError(f"keep must be >= 1 (got {keep})")
        self.path = str(path)
        self.every = int(every)
        self.keep = int(keep)
        self._meta = dict(meta or {})
        self.recorder = recorder            # repro.obs.Recorder | None
        self._copy_jit = None
        self._pending: deque = deque()      # (round, on-device copy)
        self._written: list = []            # (round, dirname), ascending

    # ------------------------------------------------------------- schedule
    def due(self, round_i: int, total_rounds: int) -> bool:
        """Is the chunk boundary ``round_i`` a snapshot point?"""
        return round_i % self.every == 0 or round_i >= total_rounds

    # --------------------------------------------------------------- offers
    def offer(self, round_i: int, state, meta: Optional[dict] = None) -> None:
        """Snapshot the carry at round ``round_i`` without blocking on it.

        Dispatches the device copy + async host fetch and returns; the
        PREVIOUS pending snapshot (whose fetch has been in flight since
        the last offer) is finalised to disk on the way out, keeping at
        most one snapshot in flight (the double buffer).  ``meta`` is
        per-offer metadata merged into the saved ``meta.json`` — the slot
        server rides its host-side ledger (queue, rid→slot map, emitted
        tokens, retry/backoff state) here so a crash-resume restores the
        DRIVER, not just the device carry."""
        import jax

        if self._copy_jit is None:
            import jax.numpy as jnp

            # non-donating identity copy: output buffers are fresh (no
            # donation means XLA cannot alias them to the inputs), so the
            # next chunk donating the carry cannot clobber the snapshot
            self._copy_jit = jax.jit(
                lambda s: jax.tree_util.tree_map(jnp.copy, s))
        rec = self.recorder
        if rec is None:
            snap = self._copy_jit(state)
        else:
            with rec.span("snapshot_copy", "snapshot", round=int(round_i)):
                snap = self._copy_jit(state)
        for leaf in jax.tree_util.tree_leaves(snap):
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()
        self._pending.append((int(round_i), snap, dict(meta or {})))
        while len(self._pending) > 1:
            self._write_oldest()

    def drain(self) -> Optional[int]:
        """Flush every pending snapshot to disk (end of run); returns the
        newest written round, or None when nothing was ever offered."""
        while self._pending:
            self._write_oldest()
        return self._written[-1][0] if self._written else None

    # ---------------------------------------------------------------- disk
    def round_dir(self, round_i: int) -> str:
        return os.path.join(self.path, f"round-{round_i:08d}")

    def _write_oldest(self) -> None:
        r, snap, extra = self._pending.popleft()
        rec = self.recorder
        meta = {**self._meta, **extra, "round": r, "kind": "snapshot"}
        if rec is None:
            checkpointer.save(self.round_dir(r), snap, step=r, meta=meta)
        else:
            # in the trace this span sits a whole cadence AFTER the
            # snapshot_offer/snapshot_copy of the same round — the
            # visible proof the two-deep async window overlaps compute
            with rec.span("snapshot_finalise", "snapshot", round=r):
                checkpointer.save(self.round_dir(r), snap, step=r, meta=meta)
            rec.count("snapshot_writes")
        self._written.append((r, self.round_dir(r)))
        self._prune()

    def _prune(self) -> None:
        while len(self._written) > self.keep:
            _, old = self._written.pop(0)
            shutil.rmtree(old, ignore_errors=True)

    @staticmethod
    def latest(path: str) -> Optional[tuple]:
        """Newest RESTORABLE snapshot under ``path`` as ``(round,
        dirname)``, or None.  Directories that fail the checkpoint
        integrity check (e.g. a save torn by the crash being recovered
        from) are skipped — that is the whole point of keeping more than
        one."""
        if not os.path.isdir(path):
            return None
        rounds = []
        for name in os.listdir(path):
            m = _ROUND_DIR.match(name)
            if m:
                rounds.append((int(m.group(1)), os.path.join(path, name)))
        for r, dirname in sorted(rounds, reverse=True):
            try:
                checkpointer.verify(dirname)
            except checkpointer.CheckpointError:
                continue
            return r, dirname
        return None
