"""Request admission as an asynchronous-scheduler scenario.

The AsGrad observation — the ordering (i_t, π_t) never depends on gradient
*values* — has an exact serving analogue: with a fixed per-request token
budget (no content-dependent EOS), which queued request fills a freed slot
never depends on the *tokens* being decoded.  Admission is therefore a pure
host-side bookkeeping problem, and the existing scheduler registry
(``pure`` / ``random`` / ``shuffled`` / ``fedbuff`` …) already models it:
"worker i finishes and gets a new job" becomes "a slot frees and a queued
request is admitted".

:class:`AdmissionPolicy` wraps a real registry scheduler over
``n = n_requests`` logical workers.  Scheduler *proposals* (from
``initial_workers`` / ``next_workers``) are remapped to the nearest
still-queued, already-arrived request in cyclic request-id order — the same
remap idiom the scenario lane's elastic transform uses — so every policy
keeps its character:

* ``pure``      → ≈ FIFO (a completion proposes its own id; the cyclic
  remap lands on the next queued request),
* ``shuffled``  → permutation-ordered admission,
* ``random``    → ≈ uniform-random admission,
* ``fedbuff:b=…`` → freed slots buffer until ``b`` completions, then a
  batch of admissions lands together (flush guard drains the tail).

:class:`AdmissionTrace` records the realised admissions/completions and
lowers them to an ordinary :class:`repro.core.engine.Schedule` — workers
are request ids, π_t is the completion count at admission time, finish
times are decode-step instants — so ``scenarios.tau_report`` prints serving
τ/concurrency statistics unchanged.

Inter-arrival times reuse the timing registry
(:class:`repro.core.delays.TimingModel`): :func:`draw_arrivals` parses
``"pattern[:gap=G]"`` (pattern ∈ PATTERNS) and cumulates one draw per
request into integer arrival steps on the decode-step clock.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from ..core.delays import PATTERNS, TimingModel
from ..core.engine import Schedule
from ..core.schedulers import REGISTRY, make_scheduler


def parse_admission(spec: str) -> tuple[str, int]:
    """``"fedbuff:b=2"`` → ``("fedbuff", 2)``; bare names get b=1."""
    name, _, rest = spec.partition(":")
    if name not in REGISTRY:
        raise ValueError(
            f"unknown admission policy {name!r}; want one of {sorted(REGISTRY)}")
    b = 1
    for item in filter(None, rest.split(",")):
        k, _, v = item.partition("=")
        if k != "b":
            raise ValueError(f"unknown admission option {k!r} (only b=...)")
        b = int(v)
    return name, b


def draw_arrivals(n_requests: int, spec: Optional[str],
                  seed: int = 0) -> np.ndarray:
    """``"poisson:gap=4"`` → (n_requests,) int arrival steps (cumulated
    inter-arrival draws; the first request arrives at step 0).  ``None`` /
    ``""`` → everything arrives at step 0."""
    if not spec:
        return np.zeros(n_requests, dtype=np.int64)
    pattern, _, rest = spec.partition(":")
    if pattern not in PATTERNS:
        raise ValueError(
            f"unknown arrival pattern {pattern!r}; want one of {PATTERNS}")
    gap = 4.0
    for item in filter(None, rest.split(",")):
        k, _, v = item.partition("=")
        if k != "gap":
            raise ValueError(f"unknown arrival option {k!r} (only gap=...)")
        gap = float(v)
    tm = TimingModel(np.full(n_requests, gap), pattern, seed=seed)
    gaps = tm.sample_round(np.arange(n_requests))
    arrivals = np.floor(np.cumsum(gaps) - gaps[0]).astype(np.int64)
    return arrivals


class AdmissionPolicy:
    """Registry scheduler → "which queued request fills a freed slot".

    The wrapped scheduler runs over ``n_requests`` logical workers.  Its
    proposals queue up; :meth:`pick` consumes the next proposal and cyclic-
    remaps it onto the arrived-and-queued request set.  FedBuff-style
    ``wait_b`` policies emit no proposals until ``b`` completions have
    buffered — freed slots simply stay empty until the batch lands.
    """

    def __init__(self, name: str, n_requests: int, b: int = 1, seed: int = 0):
        self.name = name
        self.n = int(n_requests)
        self.sched = make_scheduler(name, self.n, b=min(b, self.n), seed=seed)
        self.wait_b = self.sched.wait_b
        self._proposals = deque(int(w) for w in self.sched.initial_workers())
        self._queued = set(range(self.n))     # not yet admitted
        self._finished_buf: list = []         # completions awaiting wait_b

    # -- events --------------------------------------------------------------
    def notify_completion(self, rid: int) -> None:
        """A request finished decoding; the scheduler may propose successors.

        Mirrors the engine's round boundary: ``next_workers`` fires once
        per ``wait_b`` buffered completions (a fedbuff scheduler samples
        its whole batch on each call — calling it per completion would
        over-produce proposals b-fold)."""
        self._finished_buf.append(int(rid))
        if len(self._finished_buf) >= self.wait_b:
            batch = self._finished_buf[:self.wait_b]
            self._finished_buf = self._finished_buf[self.wait_b:]
            self._proposals.extend(
                int(w) for w in self.sched.next_workers(batch))

    def cancel(self, rid: int) -> None:
        """Withdraw a queued request (deadline timeout / shed / drain):
        it can no longer be admitted — scheduler proposals that land on
        it cyclic-remap to the next queued request, exactly like an
        already-admitted id."""
        self._queued.discard(int(rid))

    def requeue(self, rid: int) -> None:
        """Re-admit a failed request into the queue (retry path): the
        request becomes pickable again AND a proposal for its own id is
        pushed, so a retry never starves behind a scheduler that has no
        completions left to propose from.  Deterministic — no RNG draw —
        so retried admission orders replay exactly."""
        rid = int(rid)
        self._queued.add(rid)
        self._proposals.append(rid)

    # -- selection -----------------------------------------------------------
    def _remap(self, proposal: int, avail: set) -> int:
        """Nearest available request at/after the proposal in cyclic id
        order (the scenario lane's elastic remap idiom)."""
        return min(avail, key=lambda q: ((q - proposal) % self.n, q))

    def pick(self, arrived: set, in_flight: int) -> Optional[int]:
        """Next request to admit, or None (nothing arrived+queued, or the
        policy is withholding proposals).  ``in_flight`` feeds the flush
        guard: once nothing is decoding and no proposals are buffered, a
        wait_b tail smaller than b would deadlock — drain it FIFO."""
        avail = arrived & self._queued
        if not avail:
            return None
        while self._proposals:
            p = self._proposals.popleft()
            q = self._remap(p, avail)
            self._queued.discard(q)
            return q
        if in_flight == 0:          # flush guard (fedbuff tail < b)
            q = min(avail)
            self._queued.discard(q)
            return q
        return None

    @property
    def n_queued(self) -> int:
        return len(self._queued)

    # -- durability ----------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serialisable snapshot of the policy (proposals, queue,
        wait_b buffer, scheduler RNG/permutation state) — enough that a
        crash-resumed serve picks the SAME admission order the
        uninterrupted run would have."""
        st = {"proposals": [int(p) for p in self._proposals],
              "queued": sorted(int(q) for q in self._queued),
              "finished_buf": [int(x) for x in self._finished_buf]}
        s = self.sched
        rng = getattr(s, "_rng", None)
        if rng is not None:
            st["rng"] = rng.bit_generator.state
        if hasattr(s, "_perm"):                    # shuffled variants
            st["perm"] = [int(x) for x in s._perm]
            st["perm_pos"] = int(s._r)
        return st

    def load_state(self, st: dict) -> None:
        self._proposals = deque(int(p) for p in st["proposals"])
        self._queued = {int(q) for q in st["queued"]}
        self._finished_buf = [int(x) for x in st["finished_buf"]]
        s = self.sched
        if "rng" in st and hasattr(s, "_rng"):
            s._rng.bit_generator.state = st["rng"]
        if "perm" in st:
            s._perm = np.asarray(st["perm"], dtype=np.int64)
            s._r = int(st["perm_pos"])


class AdmissionTrace:
    """Realised admission/completion events → an ordinary :class:`Schedule`.

    One Schedule row per *completed* request, in completion order (ties by
    slot id): ``workers[t]`` = request id, ``assign_iters[t]`` = number of
    completions at its admission instant (the server "iterate" the request
    was admitted at), ``finish_times[t]`` = completion decode-step,
    ``active_jobs[t]`` = requests in flight when it completed.  τ_C is then
    the realised serving concurrency (≤ n_slots), τ_max/τ_avg the
    queueing-induced staleness — the same statistics, the same report code.
    """

    def __init__(self, n_requests: int, wait_b: int = 1):
        self.n = int(n_requests)
        self.wait_b = int(wait_b)
        self._admit_step = {}       # rid -> decode step of admission
        self._admit_iter = {}       # rid -> completions at admission
        self._events = []           # (finish_step, slot, rid, in_flight)
        self._evictions = {}        # rid -> quarantine step (device)
        self._timeouts = {}         # rid -> deadline-timeout step (host)
        self._shed = {}             # rid -> overload-shed step (host)
        self._drained = {}          # rid -> drain-cancel step (host)
        self._attempts = {}         # rid -> failed attempts consumed
        self.completions = 0

    def admitted(self, rid: int, step: int) -> None:
        self._admit_step[rid] = int(step)
        self._admit_iter[rid] = self.completions

    def completed(self, rid: int, slot: int, step: int,
                  in_flight: int) -> None:
        self._events.append((int(step), int(slot), int(rid), int(in_flight)))
        self.completions += 1

    def evicted(self, rid: int, step: int) -> None:
        """The device quarantined ``rid``'s lane (non-finite logits) at
        decode step ``step``; its slot stays booked until the scheduled
        completion, so the Schedule row is unchanged — the eviction is
        extra degradation metadata."""
        self._evictions[rid] = int(step)

    def timed_out(self, rid: int, step: int) -> None:
        """``rid``'s queue wait blew its deadline at ``step``: it is never
        admitted and contributes no Schedule row."""
        self._timeouts[rid] = int(step)

    def shed(self, rid: int, step: int) -> None:
        """``rid`` was shed by overload control at ``step`` (bounded
        queue overflow): terminal, never admitted — no Schedule row."""
        self._shed[rid] = int(step)

    def drained(self, rid: int, step: int) -> None:
        """``rid`` was cancelled at ``step`` by a graceful drain (server
        stopped admitting): terminal — no Schedule row."""
        self._drained[rid] = int(step)

    def retried(self, rid: int, attempts: int) -> None:
        """``rid`` consumed one failed attempt (eviction/timeout);
        ``attempts`` is the running count — surfaces in the report's
        degraded section so retries are visible, not silent."""
        self._attempts[rid] = int(attempts)

    def schedule(self) -> Schedule:
        ev = sorted(self._events)
        return Schedule(
            workers=np.array([e[2] for e in ev], dtype=np.int32),
            assign_iters=np.array([self._admit_iter[e[2]] for e in ev],
                                  dtype=np.int32),
            finish_times=np.array([e[0] for e in ev], dtype=np.float64),
            active_jobs=np.array([e[3] for e in ev], dtype=np.int32),
            unfinished_assign_iters=np.array([], dtype=np.int32),
            wait_b=self.wait_b,
            n_workers=self.n,
        )

    @property
    def admit_steps(self) -> dict:
        return dict(self._admit_step)

    @property
    def evictions(self) -> dict:
        return dict(self._evictions)

    @property
    def timeouts(self) -> dict:
        return dict(self._timeouts)

    @property
    def shed_map(self) -> dict:
        return dict(self._shed)

    @property
    def drained_map(self) -> dict:
        return dict(self._drained)

    @property
    def attempts(self) -> dict:
        return dict(self._attempts)

    # -- durability ----------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serialisable trace state (dict keys stringify; load_state
        converts them back)."""
        return {
            "admit_step": {str(k): v for k, v in self._admit_step.items()},
            "admit_iter": {str(k): v for k, v in self._admit_iter.items()},
            "events": [list(e) for e in self._events],
            "evictions": {str(k): v for k, v in self._evictions.items()},
            "timeouts": {str(k): v for k, v in self._timeouts.items()},
            "shed": {str(k): v for k, v in self._shed.items()},
            "drained": {str(k): v for k, v in self._drained.items()},
            "attempts": {str(k): v for k, v in self._attempts.items()},
            "completions": self.completions,
        }

    def load_state(self, st: dict) -> None:
        as_int = lambda d: {int(k): int(v) for k, v in d.items()}  # noqa: E731
        self._admit_step = as_int(st["admit_step"])
        self._admit_iter = as_int(st["admit_iter"])
        self._events = [tuple(int(x) for x in e) for e in st["events"]]
        self._evictions = as_int(st["evictions"])
        self._timeouts = as_int(st["timeouts"])
        self._shed = as_int(st["shed"])
        self._drained = as_int(st["drained"])
        self._attempts = as_int(st["attempts"])
        self.completions = int(st["completions"])
