from .specs import Spec, init_tree, abstract_tree, axes_tree, count_params
from .model import (
    param_specs,
    init_params,
    n_params,
    n_active_params,
    forward_logits,
    loss_fn,
    cache_specs,
    prefill,
    init_cache,
    decode_step,
    batch_specs,
)
from . import layers

__all__ = [
    "Spec", "init_tree", "abstract_tree", "axes_tree", "count_params",
    "param_specs", "init_params", "n_params", "n_active_params",
    "forward_logits", "loss_fn", "cache_specs", "prefill", "init_cache", "decode_step",
    "batch_specs", "layers",
]
