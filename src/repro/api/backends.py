"""The three ways to execute an :class:`ExperimentSpec`.

* :class:`SimulatorBackend` — schedule + exact jittable replay (theory tier).
  Grid stepsize policies replay every γ against ONE shared schedule in a
  single batched scan (:func:`repro.core.simulator.replay_grid`): the
  schedule is gradient-value-independent, so rebuilding it per γ — what the
  benchmarks used to do — is pure waste.
* :class:`TrainerBackend` — schedule → device-resident
  :class:`repro.runtime.RunPlan` → ``AsyncTrainer`` steps through the
  whole-run executor (production tier): ``runtime="scan"`` compiles K
  rounds per XLA launch, ``runtime="eager"`` is the per-round parity
  oracle.  Same schedulers as the simulator, identical ordering by
  construction.
* :class:`ServeBackend` — batched decoding through ``distributed.Server``.

All three return a :class:`RunResult`.
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from typing import Callable, Optional, Protocol, runtime_checkable

import jax
import numpy as np

from ..core import delay_adaptive_stepsizes, replay, replay_grid, round_masks
from ..core.trace import summarize
from ..runtime import compile_plan, execute
from .result import RunResult
from .spec import ExperimentSpec, ServeJob, StepsizePolicy, TrainJob


@runtime_checkable
class Backend(Protocol):
    name: str

    def run(self, spec: ExperimentSpec) -> RunResult: ...


def _grid_score(grad_norms: np.ndarray) -> float:
    """The paper's selection protocol (App. A.1): best final grad norm with
    small fluctuations — tail mean plus half the tail standard deviation."""
    tail = float(np.mean(grad_norms[-3:]))
    fluct = float(np.std(grad_norms[-5:]))
    return tail + 0.5 * fluct


class SimulatorBackend:
    """Exact replay of Algorithm 1: x_{t+1} = x_t − γ̃ g_{i_t}(x_{π_t})."""

    name = "simulator"

    def run(self, spec: ExperimentSpec) -> RunResult:
        prob = spec.objective
        if prob is None or not hasattr(prob, "grad_fn"):
            raise TypeError(
                "SimulatorBackend needs an objective exposing grad_fn "
                f"(got {type(prob).__name__})")
        t0 = time.time()
        schedule = spec.build_schedule()
        grad_fn = prob.grad_fn(stochastic=spec.stochastic)
        full_grad = getattr(prob, "full_grad", None)
        loss = getattr(prob, "loss", None)
        x0 = np.zeros(prob.d, dtype=np.float32)
        policy: StepsizePolicy = spec.stepsize
        kw = dict(key=jax.random.PRNGKey(spec.seed), clip=spec.clip,
                  log_every=spec.log_every, full_grad_fn=full_grad,
                  loss_fn=loss)

        if policy.kind == "grid":
            if full_grad is None:
                raise ValueError(
                    "grid stepsize selection scores grad norms; the "
                    "objective must expose full_grad")
            results = replay_grid(schedule, grad_fn, x0, policy.gammas, **kw)
            best_i, best_score = 0, None
            grid_info = {}
            for i, (g, res) in enumerate(zip(policy.gammas, results)):
                score = _grid_score(res.grad_norms)
                grid_info[g] = {"grad_norms": res.grad_norms,
                                "losses": res.losses, "score": score}
                if best_score is None or score < best_score:
                    best_i, best_score = i, score
            gamma, res = policy.gammas[best_i], results[best_i]
        else:
            gamma = policy.gamma
            if policy.kind == "delay_adaptive":
                steps = delay_adaptive_stepsizes(gamma, schedule.delays,
                                                 schedule.tau_c())
            else:
                steps = gamma
            res = replay(schedule, grad_fn, x0, steps, **kw)
            grid_info = None

        return RunResult(
            spec=spec, backend=self.name, x=res.x, xs=res.xs,
            log_ts=res.log_ts, grad_norms=res.grad_norms, losses=res.losses,
            gamma=gamma, grid=grid_info, schedule=schedule,
            trace=summarize(schedule), seconds=time.time() - t0)


class TrainerBackend:
    """Schedule → device-resident :class:`repro.runtime.RunPlan` →
    ``AsyncTrainer`` steps, dispatched by the ``repro.runtime`` executor.

    ``mesh``/``rules`` default to this host's devices and the repo sharding
    rules; ``on_step(i, state, metrics)`` is invoked once per round (for
    logging / checkpointing without owning the loop).  ``runtime`` selects
    the dispatch layer: ``"scan"`` (default) compiles
    ``rounds_per_launch`` rounds into one XLA launch; ``"eager"`` launches
    one round at a time — the parity oracle.  ``metrics`` selects the
    scan executor's metric transport (``"chunk"`` default: ``on_step``
    fires at chunk boundaries with the end-of-chunk state; ``"tap"``:
    per-round streaming, ``state=None``; ``"none"``: no curves).
    Constructor args override the spec's ``runtime``/``rounds_per_launch``
    /``metrics`` fields; both unset falls back to the defaults.

    A grid stepsize policy on the scan runtime executes ALL γ points in
    one vmapped program per chunk (the plan's γ-axis +
    :meth:`repro.runtime.PlanExecutor.run_grid`) — one trainer, one
    compile, shared masks/batches — instead of N sequential runs; the
    eager runtime keeps the sequential loop as the oracle.

    Fault tolerance rides the same lanes: a ``fault:`` scenario lowers
    its per-round gain channel into the plan, ``TrainJob(guards=True)``
    arms the trainer's non-finite guard rails, ``snapshot`` (an
    :class:`repro.checkpoint.AsyncSnapshotter`) gives scan runs
    barrier-free periodic checkpoints and ``breaker`` (a
    :class:`repro.faults.DivergenceBreaker`, ``metrics="tap"`` only)
    stops launching chunks once the loss diverges.
    """

    name = "trainer"
    default_runtime = "scan"
    default_metrics = "chunk"

    def __init__(self, mesh=None, rules=None,
                 on_step: Optional[Callable] = None,
                 runtime: Optional[str] = None,
                 rounds_per_launch: Optional[int] = None,
                 metrics: Optional[str] = None,
                 snapshot=None, breaker=None, recorder=None):
        self.mesh = mesh
        self.rules = rules
        self.on_step = on_step
        self.runtime = runtime
        self.rounds_per_launch = rounds_per_launch
        self.metrics = metrics
        self.snapshot = snapshot
        self.breaker = breaker
        self.recorder = recorder      # repro.obs.Recorder | None

    # ---- pieces shared with tests -----------------------------------------
    @staticmethod
    def world_for(spec: ExperimentSpec, n_groups: Optional[int] = None):
        """The realised :class:`repro.scenarios.ScenarioWorld` for
        ``spec.T`` rounds (identity wrap when the spec has no scenario —
        bit-identical schedule to the stationary path)."""
        sched = spec.make_scheduler(n_groups)
        return spec.build_world(T=spec.T * sched.wait_b, n=n_groups)

    @staticmethod
    def masks_for(spec: ExperimentSpec, n_groups: Optional[int] = None):
        """((rounds, n_groups) participation masks, realised Schedule) for
        ``spec.T`` rounds.  The masks are the raw schedule lowering —
        elastic availability is folded in later, at plan compile time."""
        world = TrainerBackend.world_for(spec, n_groups)
        return round_masks(world.schedule), world.schedule

    def resolve_runtime(self, spec: ExperimentSpec):
        """(runtime, rounds_per_launch, metrics): constructor overrides
        spec, both-unset → the scan/chunk defaults."""
        runtime = self.runtime or spec.runtime or self.default_runtime
        k = self.rounds_per_launch if self.rounds_per_launch is not None \
            else spec.rounds_per_launch
        metrics = self.metrics or spec.metrics or self.default_metrics
        return runtime, int(k), metrics

    def run(self, spec: ExperimentSpec) -> RunResult:
        job = spec.objective
        if not isinstance(job, TrainJob):
            raise TypeError("TrainerBackend needs a TrainJob objective")
        policy: StepsizePolicy = spec.stepsize
        if policy.kind == "grid":
            runtime, _, _ = self.resolve_runtime(spec)
            # the vmapped lane has no per-round callback hook, so an
            # on_step consumer keeps the sequential loop
            if runtime == "scan" and len(policy.gammas) > 1 \
                    and self.on_step is None:
                return self._run_grid(spec, job)
            best = None
            for g in policy.gammas:
                # scoring needs loss curves, so the sequential grid loop
                # overrides a metrics="none" resolution (as the vmapped
                # lane does)
                res = self._run_single(spec, job, g, adaptive=False,
                                       metrics_floor="chunk")
                score = float(np.mean(res.losses[-3:]))
                if best is None or score < best[0]:
                    best = (score, res)
            return best[1]
        return self._run_single(spec, job, policy.gamma,
                                adaptive=policy.kind == "delay_adaptive")

    # ---- shared construction ----------------------------------------------
    def _make_trainer(self, spec: ExperimentSpec, job: TrainJob, lr: float,
                      adaptive: bool):
        from ..distributed import AsyncTrainer, AsyncConfig, DEFAULT_RULES
        from ..faults import GuardConfig
        from ..launch.mesh import make_host_mesh
        from ..optim import OptConfig

        cfg = job.make_arch()
        mesh = self.mesh if self.mesh is not None else make_host_mesh()
        rules = self.rules if self.rules is not None else DEFAULT_RULES
        tr = AsyncTrainer(
            cfg, mesh,
            opt=OptConfig(name=job.opt, lr=lr, clip_norm=job.clip_norm,
                          update_impl=job.update_impl),
            async_cfg=AsyncConfig(delay_rounds=job.delay_rounds,
                                  delay_adaptive=adaptive,
                                  microbatches=job.microbatches,
                                  guards=GuardConfig() if job.guards
                                  else None),
            rules=rules)
        n_groups = spec.n_workers or tr.n_groups
        tr.n_groups = n_groups
        if job.global_batch % n_groups:
            raise ValueError(
                f"the {n_groups} worker groups must divide "
                f"global_batch={job.global_batch}")
        return tr, cfg, n_groups

    def _run_single(self, spec: ExperimentSpec, job: TrainJob, lr: float,
                    adaptive: bool,
                    metrics_floor: Optional[str] = None) -> RunResult:
        """One (γ, adaptive) run.  ``metrics_floor`` replaces a resolved
        ``"none"`` with a curve-producing mode for callers that must read
        the losses back (grid scoring)."""
        import jax

        t0 = time.time()
        tr, cfg, n_groups = self._make_trainer(spec, job, lr, adaptive)
        world = self.world_for(spec, n_groups)
        schedule = world.schedule
        masks = round_masks(schedule)
        state = tr.init_state(jax.random.PRNGKey(spec.seed))

        rounds = min(spec.T, masks.shape[0])
        # the whole run lowered ONCE: round masks, per-round γ-scales (the
        # delay-adaptive scale at round i belongs to the gradient APPLIED
        # at i; AsyncTrainer's single swapped-every-round gbuf makes the
        # realised extra staleness exactly one round whenever
        # delay_rounds > 0), and the folded per-round data keys.  The
        # executor replays plan slices with no per-round host work.
        # Scenario channels (elastic availability, drifting data law,
        # sparsified grads) ride into the plan as extra per-round arrays
        plan = compile_plan(schedule, job, rounds=rounds, n_groups=n_groups,
                            seed=spec.seed, adaptive=adaptive,
                            availability=world.availability,
                            zipf_as=world.zipf_as,
                            grad_density=world.grad_density,
                            fault_gain=world.fault_gain)
        runtime, rounds_per_launch, metrics = self.resolve_runtime(spec)
        if metrics == "none" and metrics_floor is not None:
            metrics = metrics_floor
        kw = {}
        if runtime == "scan":           # durability/breaker: scan-only lanes
            kw = {"snapshot": self.snapshot, "breaker": self.breaker}
        exec_res = execute(tr, plan, state, runtime=runtime,
                           rounds_per_launch=rounds_per_launch,
                           metrics=metrics, on_step=self.on_step,
                           recorder=self.recorder, **kw)

        have_curves = bool(exec_res.metrics)
        obs = self.recorder.summary(rounds=rounds) \
            if self.recorder is not None else None
        return RunResult(
            spec=spec, backend=self.name, x=exec_res.state,
            log_ts=np.arange(rounds),
            losses=exec_res.metrics["loss"].astype(np.float64)
            if have_curves else None,
            grad_norms=exec_res.metrics["grad_norm"].astype(np.float64)
            if have_curves else None,
            gamma=lr, schedule=schedule, trace=summarize(schedule),
            seconds=time.time() - t0,
            extra={"metrics": exec_res.rows, "masks": masks,
                   "arch": cfg.name, "n_groups": n_groups,
                   "update_impl": tr.update_impl,
                   "delay_scales": plan.delay_scales if adaptive else None,
                   "scenario": spec.scenario,
                   "plan_summary": plan.summary(),
                   "runtime": runtime,
                   "rounds_per_launch": rounds_per_launch,
                   "metrics_mode": metrics if runtime == "scan" else "chunk",
                   "launches": exec_res.launches,
                   "host_syncs": exec_res.host_syncs,
                   "tap_events": exec_res.tap_events,
                   "snapshots": exec_res.stats.snapshots,
                   "tripped_round": exec_res.stats.tripped_round,
                   "obs": obs})

    def _run_grid(self, spec: ExperimentSpec, job: TrainJob) -> RunResult:
        """All grid γ points in one vmapped scan program (the plan's
        γ-axis): one trainer built at γ_base = gammas[0], per-γ stepsize
        rows folded into ``plan.grid_scales``, every point scored by the
        same tail-loss protocol as the sequential loop."""
        import jax
        from ..runtime import PlanExecutor

        t0 = time.time()
        policy: StepsizePolicy = spec.stepsize
        gammas = policy.gammas
        tr, cfg, n_groups = self._make_trainer(spec, job, gammas[0],
                                               adaptive=False)
        world = self.world_for(spec, n_groups)
        schedule = world.schedule
        masks = round_masks(schedule)
        rounds = min(spec.T, masks.shape[0])
        plan = compile_plan(schedule, job, rounds=rounds, n_groups=n_groups,
                            seed=spec.seed, grid_gammas=gammas,
                            availability=world.availability,
                            zipf_as=world.zipf_as,
                            grad_density=world.grad_density,
                            fault_gain=world.fault_gain)
        _, rounds_per_launch, _ = self.resolve_runtime(spec)
        ex = PlanExecutor(tr, plan, recorder=self.recorder)
        # scoring needs curves, so the grid lane always reads them back
        # (one deferred sync for the whole grid)
        res = ex.run_grid(tr.init_state(jax.random.PRNGKey(spec.seed)),
                          rounds_per_launch=rounds_per_launch,
                          metrics="chunk")

        losses = res.metrics["loss"]          # (n_grid, rounds)
        gnorms = res.metrics["grad_norm"]
        scores = [float(np.mean(losses[i, -3:])) for i in range(len(gammas))]
        best = int(np.argmin(scores))
        grid_info = {g: {"losses": losses[i].astype(np.float64),
                         "grad_norms": gnorms[i].astype(np.float64),
                         "score": scores[i]}
                     for i, g in enumerate(gammas)}
        best_state = jax.tree_util.tree_map(lambda x: x[best], res.state)
        best_rows = [{k: float(res.metrics[k][best, q]) for k in res.metrics}
                     for q in range(rounds)]
        return RunResult(
            spec=spec, backend=self.name, x=best_state,
            log_ts=np.arange(rounds),
            losses=losses[best].astype(np.float64),
            grad_norms=gnorms[best].astype(np.float64),
            gamma=float(gammas[best]), grid=grid_info, schedule=schedule,
            trace=summarize(schedule), seconds=time.time() - t0,
            extra={"metrics": best_rows, "masks": masks,
                   "arch": cfg.name, "n_groups": n_groups,
                   "update_impl": tr.update_impl,
                   "delay_scales": None,
                   "scenario": spec.scenario,
                   "plan_summary": plan.summary(),
                   "runtime": "scan", "grid_lane": True,
                   "n_grid": len(gammas),
                   "rounds_per_launch": rounds_per_launch,
                   "metrics_mode": "chunk",
                   "launches": res.launches,
                   "host_syncs": res.host_syncs,
                   "tap_events": res.tap_events,
                   "snapshots": res.stats.snapshots,
                   "tripped_round": res.stats.tripped_round,
                   "obs": self.recorder.summary(rounds=rounds)
                   if self.recorder is not None else None})


class ServeBackend:
    """Prefill + batched decode through the sharded ``Server`` driver.

    A :class:`ServeJob` with ``n_slots`` set routes to the slot-based
    continuous-batching lane (:class:`repro.distributed.SlotServer`):
    requests flow through persistent decode slots under a
    scheduler-registry admission policy, and the realised admission trace
    lowers to an ordinary ``Schedule`` (``extra["schedule"]`` /
    ``extra["tau_report"]``).  The lock-step path stays the parity oracle.
    """

    name = "serve"

    def __init__(self, mesh=None, rules=None, recorder=None):
        self.mesh = mesh
        self.rules = rules
        self.recorder = recorder      # repro.obs.Recorder | None

    def _setup(self, spec: ExperimentSpec):
        import jax
        from ..distributed.sharding import DEFAULT_RULES
        from ..launch.mesh import make_host_mesh
        from ..models import init_params

        job = spec.objective
        if not isinstance(job, ServeJob):
            raise TypeError("ServeBackend needs a ServeJob objective")
        cfg = job.make_arch()
        mesh = self.mesh if self.mesh is not None else make_host_mesh()
        rules = self.rules if self.rules is not None else DEFAULT_RULES
        params = init_params(cfg, jax.random.PRNGKey(spec.seed))
        return job, cfg, mesh, rules, params

    def run(self, spec: ExperimentSpec) -> RunResult:
        if getattr(spec.objective, "n_slots", None):
            return self._run_slots(spec)
        import jax
        import jax.numpy as jnp
        from ..distributed import Server, ServeConfig
        from ..models import prefill

        t0 = time.time()
        rec = self.recorder
        job, cfg, mesh, rules, params = self._setup(spec)
        ctx = job.prompt_len + spec.T
        server = Server(cfg, mesh, ServeConfig(batch=job.batch, ctx_len=ctx,
                                               temperature=job.temperature,
                                               seed=spec.seed), rules=rules)
        prompts = np.random.default_rng(spec.seed).integers(
            0, cfg.vocab, (job.batch, job.prompt_len)).astype(np.int32)
        with (rec.span("prefill", "server", batch=job.batch,
                       plen=job.prompt_len)
              if rec is not None else nullcontext()):
            last, cache = prefill(cfg, params,
                                  {"tokens": jnp.asarray(prompts)},
                                  ctx_len=ctx)
            toks = jnp.argmax(last, axis=-1).astype(jnp.int32)
        t_dec = time.time()
        with (rec.span("decode", "server", steps=spec.T - 1)
              if rec is not None else nullcontext()):
            gen = server.generate(params, np.asarray(toks), spec.T - 1,
                                  start_pos=job.prompt_len, cache=cache)
        gen = np.concatenate([np.asarray(toks)[:, None], gen], axis=1)
        dt = time.time() - t_dec
        return RunResult(
            spec=spec, backend=self.name, x=gen, seconds=time.time() - t0,
            extra={"prompts": prompts, "arch": cfg.name,
                   "decode_seconds": dt,
                   "tok_per_s": job.batch * (spec.T - 1) / max(dt, 1e-9),
                   "obs": rec.summary(rounds=spec.T)
                   if rec is not None else None})

    def _run_slots(self, spec: ExperimentSpec) -> RunResult:
        """Continuous batching: ``n_requests`` requests through ``n_slots``
        ragged decode lanes; admissions follow the job's scheduler-registry
        policy, arrivals its timing-registry pattern."""
        from ..distributed import (SlotServer, SlotConfig, draw_arrivals,
                                   parse_admission, RetryPolicy,
                                   OverloadPolicy)
        from ..scenarios import tau_report

        t0 = time.time()
        job, cfg, mesh, rules, params = self._setup(spec)
        n_req = job.n_requests or job.batch
        ctx = job.prompt_len + spec.T
        retry = (RetryPolicy(max_attempts=job.max_retries,
                             backoff_base=job.retry_backoff)
                 if job.max_retries > 1 else None)
        overload = (OverloadPolicy(job.queue_cap, job.shed_policy)
                    if job.queue_cap is not None else None)
        server = SlotServer(
            cfg, mesh,
            SlotConfig(n_slots=job.n_slots, ctx_len=ctx,
                       temperature=job.temperature, seed=spec.seed,
                       steps_per_launch=job.steps_per_launch),
            rules=rules, recorder=self.recorder)
        # same prompt stream as the lock-step oracle (first batch rows
        # coincide when n_requests == batch — the parity gate relies on it)
        prompts = np.random.default_rng(spec.seed).integers(
            0, cfg.vocab, (n_req, job.prompt_len)).astype(np.int32)
        arrivals = draw_arrivals(n_req, job.arrival, seed=spec.seed)
        faults = None
        if spec.scenario:
            # the spec's scenario lowers onto the decode-step clock too:
            # slot_poison / serve_preempt cells realise here, training
            # transforms contribute nothing
            from ..faults import realise_serve_faults

            attempts = job.max_retries
            fault_horizon = (2 * (int(arrivals.max(initial=0))
                                  + n_req * spec.T * attempts
                                  + job.steps_per_launch)
                             + 4 * job.steps_per_launch)
            faults = realise_serve_faults(spec.scenario, n_req,
                                          fault_horizon, seed=spec.seed)
        t_dec = time.time()
        res = server.serve(params, prompts, spec.T,
                           admission=job.admission, arrivals=arrivals,
                           deadline=job.deadline, retry=retry,
                           overload=overload, drain_after=job.drain_after,
                           faults=faults)
        dt = time.time() - t_dec
        return RunResult(
            spec=spec, backend=self.name, x=res.tokens,
            schedule=res.schedule, seconds=time.time() - t0,
            extra={"prompts": prompts, "arch": cfg.name,
                   "decode_seconds": dt,
                   "tok_per_s": n_req * (spec.T - 1) / max(dt, 1e-9),
                   "n_slots": job.n_slots, "admission": job.admission,
                   "arrivals": arrivals, "ttft_steps": res.ttft_steps,
                   "occupancy": res.occupancy,
                   "decode_steps": res.decode_steps, "chunks": res.chunks,
                   "tap_rows": res.tap_rows,
                   "evictions": res.evictions, "timeouts": res.timeouts,
                   "shed": res.shed, "drained": res.drained,
                   "attempts": res.attempts,
                   "resumed_from": res.resumed_from,
                   "obs": self.recorder.summary(rounds=res.decode_steps)
                   if self.recorder is not None else None,
                   "tau_report": tau_report(
                       res.schedule, parse_admission(job.admission)[0],
                       concurrency=job.n_slots,
                       scenario_spec=job.arrival or "",
                       evictions=res.evictions,
                       timeouts=res.timeouts,
                       shed=res.shed, drained=res.drained,
                       attempts=res.attempts)})


def run(spec: ExperimentSpec, backend: Optional[Backend] = None) -> RunResult:
    """Execute a spec on the right backend (dispatched on the objective)."""
    if backend is None:
        if isinstance(spec.objective, TrainJob):
            backend = TrainerBackend()
        elif isinstance(spec.objective, ServeJob):
            backend = ServeBackend()
        else:
            backend = SimulatorBackend()
    return backend.run(spec)
