"""Fault-injection smoke bench: guarded vs unguarded on a faulted world.

One faulted world (``nan_grad`` poisoned receipts + a ``worker_crash``
window) is realised once, lowered to ONE ``RunPlan``, and run through the
scan executor twice on identical initial state:

* **unguarded** — the poison lands: the first NaN receipt propagates and
  the final params are non-finite (the ``unguarded_poisoned`` flag
  asserts the fault channel actually fires end-to-end);
* **guarded** — the non-finite guard skips the poisoned rounds in-mask
  and γ-health backs off/recovers; the final params stay finite
  (``guarded_final_finite``), with ``skipped_rounds`` counting the
  receipts the guard dropped.

Both are CI canaries first (the whole ``repro.faults`` lane — transform
lowering, fault_gain channel, device guard state — compiles and runs on
every push) and a perf gate second: the guard is one norm reduce plus a
``lax.cond`` around the fused apply (clean rounds pay a branch dispatch,
skipped rounds skip the apply entirely), so its documented overhead
ceiling is ≤10% of unguarded scan throughput.  The
``guard_overhead`` ratio (guarded / unguarded rounds/s, same run, same
machine — machine-portable by construction) is gated by
``benchmarks/check_perf.py`` (bench kind ``"faults"``) against that
ceiling, NOT against the committed baseline's absolute numbers.

Writes ``experiments/figs/BENCH_faults.json`` (``bench: "faults"``).

    PYTHONPATH=src python -m benchmarks.perf_faults --quick
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
from jax.sharding import Mesh

from repro.api import ExperimentSpec, TrainJob, TrainerBackend
from repro.distributed import AsyncTrainer, AsyncConfig
from repro.faults import GuardConfig
from repro.optim import OptConfig
from repro.runtime import PlanExecutor, compile_plan

#: poisoned receipts every 16 rounds plus a one-off 8-round crash window
FAULT_WORLD = ("nan_grad:k=1,every=16,span=1;"
               "worker_crash:k=1,at=16,span=8")

#: big enough that the round body (fwd+bwd+apply) dominates the guard's
#: fixed per-round cost (one norm reduce + a cond dispatch) — at the
#: dispatch-bench TINY scale the same guard measures 2-3x heavier purely
#: because everything else is free
ARCH = (("n_layers", 2), ("d_model", 128), ("n_heads", 2),
        ("n_kv_heads", 1), ("d_ff", 256), ("vocab", 512))


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


def _finite(state) -> bool:
    return all(bool(np.isfinite(np.asarray(l, np.float32)).all())
               for l in jax.tree_util.tree_leaves(state["params"]))


def run(out: str = "experiments/figs", quick: bool = False,
        rounds: int = 0, arch: str = "qwen2-0.5b") -> dict:
    os.makedirs(out, exist_ok=True)
    rounds = rounds or (64 if quick else 128)
    k = min(16, rounds)
    job = TrainJob(arch=arch, global_batch=4, seq_len=64,
                   arch_overrides=ARCH)
    mesh = _mesh()
    spec = ExperimentSpec(scheduler="fedbuff:b=2", timing="poisson:slow=6",
                          objective=job, T=rounds, n_workers=4,
                          stepsize=3e-3, seed=0, scenario=FAULT_WORLD)
    world = TrainerBackend.world_for(spec, 4)
    plan = compile_plan(world.schedule, job, rounds=rounds, n_groups=4,
                        seed=0, availability=world.availability,
                        fault_gain=world.fault_gain)
    poisoned_rounds = int((np.isnan(plan.fault_gain)
                           & (plan.masks > 0)).any(axis=1).sum())

    entries = []
    for name, guards in (("unguarded", None), ("guarded", GuardConfig())):
        tr = AsyncTrainer(job.make_arch(), mesh,
                          opt=OptConfig(lr=3e-3, clip_norm=1.0),
                          async_cfg=AsyncConfig(delay_rounds=1,
                                                guards=guards))
        tr.n_groups = 4
        ex = PlanExecutor(tr, plan, donate=False)
        state = tr.init_state(jax.random.PRNGKey(0))
        r = ex.run_scan(state, rounds_per_launch=k,
                        metrics="none")                    # compile
        jax.block_until_ready(jax.tree_util.tree_leaves(r.state)[0])
        dt = float("inf")                                  # best of 3
        for _ in range(3):
            t0 = time.time()
            r = ex.run_scan(state, rounds_per_launch=k, metrics="none")
            jax.block_until_ready(jax.tree_util.tree_leaves(r.state)[0])
            dt = min(dt, time.time() - t0)
        m = ex.run_scan(state, rounds_per_launch=k, metrics="chunk")
        skipped = int(np.asarray(m.metrics["skipped"]).sum())
        entry = {
            "mode": name,
            "rounds": rounds,
            "seconds": round(dt, 4),
            "rounds_per_s": round(rounds / dt, 2),
            "launches": r.launches,
            "final_params_finite": _finite(r.state),
            "skipped_rounds": skipped,
        }
        entries.append(entry)
        print(f"{name:<12} rounds/s={entry['rounds_per_s']:>8} "
              f"finite={entry['final_params_finite']} "
              f"skipped={skipped}")

    un, gu = entries
    overhead = gu["rounds_per_s"] / max(un["rounds_per_s"], 1e-9)
    payload = {
        "bench": "faults",
        "backend": jax.default_backend(),
        "arch": arch,
        "rounds": rounds,
        "scenario": FAULT_WORLD,
        "poisoned_rounds": poisoned_rounds,
        # smoke flags: the fault channel fires (unguarded run ends
        # non-finite) and the guard contains it (guarded run stays finite
        # and skipped exactly the poisoned rounds)
        "unguarded_poisoned": not un["final_params_finite"],
        "guarded_final_finite": gu["final_params_finite"],
        "guarded_skipped_rounds": gu["skipped_rounds"],
        # guarded / unguarded rounds/s on the SAME plan, state and
        # machine — the quantity the ≤10% overhead ceiling gates
        "guard_overhead_ratio": round(overhead, 4),
        "note": ("both rows replay the SAME faulted RunPlan from the same "
                 "initial state; absolute rounds/s is machine-local, the "
                 "guard_overhead_ratio is not.  check_perf.py (kind "
                 "'faults') gates the ratio against the documented <=10% "
                 "ceiling plus the two smoke flags."),
        "entries": entries,
    }
    path = os.path.join(out, "BENCH_faults.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"guard_overhead_ratio={overhead:.3f} "
          f"(poisoned_rounds={poisoned_rounds})")
    print("wrote", path)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="64 rounds instead of 128")
    ap.add_argument("--rounds", type=int, default=0)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--out", default="experiments/figs")
    args = ap.parse_args()
    run(out=args.out, quick=args.quick, rounds=args.rounds, arch=args.arch)


if __name__ == "__main__":
    main()
