"""Serving-resilience smoke bench: retry machinery cost + chaos soak.

Two halves, matching the two resilience contracts the repo documents:

* **clean-run no-op** — the same clean slot-serve twice, plain and with
  the full recovery layer armed (retries + bounded queue).  The armed
  run must emit EXACTLY the plain run's tokens and the tok/s ratio
  (``retry_overhead_ratio``) is the documented ≤10% ceiling — this is
  what sync-mode chunk barriers cost when nothing ever fails.
* **chaos soak** — ``slot_poison`` + ``serve_preempt`` + bursty arrivals
  + a bounded queue, composed through the fault grammar, served across
  a snapshot/resume hop with retries on.  The payload's
  ``all_accounted`` flag asserts the no-silent-loss invariant (every
  request completed or in exactly one degraded bucket) and the run's
  Chrome trace lands in ``experiments/figs/trace_chaos.json``.

Writes ``experiments/figs/BENCH_resilience.json`` (``bench:
"resilience"``), gated by ``benchmarks/check_perf.py`` against the
committed ``benchmarks/BENCH_resilience.json`` baseline — the
overhead ratio is an ABSOLUTE ceiling (CI passes ``--tolerance 0.1``),
the flags are hard.

    PYTHONPATH=src python -m benchmarks.perf_resilience --quick
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import time

import numpy as np
import jax
from jax.sharding import Mesh

from repro.api import ExperimentSpec, ServeJob
from repro.api.backends import ServeBackend
from repro.checkpoint import AsyncSnapshotter
from repro.configs import get_arch
from repro.distributed import (OverloadPolicy, RetryPolicy, ServePreempted,
                               SlotConfig, SlotServer, draw_arrivals)
from repro.faults import realise_serve_faults
from repro.models import init_params
from repro.obs import Recorder
from repro.scenarios import tau_report

#: smallest decodable arch — the bench measures the recovery layer's
#: host/dispatch cost, not model compute
TINY = (("n_layers", 1), ("d_model", 8), ("n_heads", 1), ("n_kv_heads", 1),
        ("d_ff", 16), ("vocab", 127))

CHAOS_SCENARIO = "slot_poison:rid=1,step=3,every=1;serve_preempt:at=8,every=0"


def _chaos_run(arch: str, T: int, prompt_len: int, out: str) -> dict:
    """The soak: poison + preempt + burst + cap + retry, resumed across
    the preemption; returns the accounting row."""
    cfg = get_arch(arch).reduced().with_(remat="none", **dict(TINY))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_req = 6
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (n_req, prompt_len)).astype(np.int32)
    arr = draw_arrivals(n_req, "bursty:gap=2", seed=3)
    faults = realise_serve_faults(CHAOS_SCENARIO, n_requests=n_req,
                                  horizon=4096, seed=3)
    rec = Recorder()
    srv = SlotServer(cfg, mesh,
                     SlotConfig(n_slots=2, ctx_len=prompt_len + T,
                                steps_per_launch=2), recorder=rec)
    snapdir = os.path.join(out, "chaos-snaps")
    shutil.rmtree(snapdir, ignore_errors=True)    # stale shapes from an
    resume, hops = None, 0                        # earlier geometry break resume
    t0 = time.perf_counter()
    while True:
        try:
            res = srv.serve(params, prompts, T, arrivals=arr, faults=faults,
                            retry=RetryPolicy(max_attempts=2,
                                              backoff_base=2),
                            overload=OverloadPolicy(queue_cap=3,
                                                    shed="drop-oldest"),
                            snapshot=AsyncSnapshotter(snapdir, 2, keep=3),
                            resume_from=resume)
            break
        except ServePreempted:
            hops += 1
            if hops > 4:
                raise RuntimeError("chaos preemption loop did not converge")
            resume = AsyncSnapshotter.latest(snapdir)[1]
    seconds = time.perf_counter() - t0
    rec.export_chrome(os.path.join(out, "trace_chaos.json"))

    degraded, completed = 0, 0
    all_accounted = True
    for rid in range(n_req):
        hits = sum(rid in m for m in (res.evictions, res.timeouts,
                                      res.shed, res.drained))
        full = bool((res.tokens[rid] >= 0).all())
        if hits == 0 and full:
            completed += 1
        elif hits == 1:
            degraded += 1
        else:
            all_accounted = False
    rep = tau_report(res.schedule, "pure", concurrency=2,
                     scenario_spec=CHAOS_SCENARIO, evictions=res.evictions,
                     timeouts=res.timeouts, shed=res.shed,
                     drained=res.drained, attempts=res.attempts)
    return {
        "mode": "chaos_soak",
        "scenario": CHAOS_SCENARIO,
        "n_requests": n_req,
        "steps": T,
        "seconds": round(seconds, 4),
        "preempt_hops": hops,
        "resumed_from": res.resumed_from,
        "completed": completed,
        "degraded": degraded,
        "evictions": len(res.evictions),
        "timeouts": len(res.timeouts),
        "shed": len(res.shed),
        "drained": len(res.drained),
        "retried": len(res.attempts),
        "all_accounted": all_accounted,
        "tau_c": rep["global"]["tau_c"],
    }


def run(out: str = "experiments/figs", quick: bool = False,
        steps: int = 0, arch: str = "qwen2-0.5b") -> dict:
    os.makedirs(out, exist_ok=True)
    T = steps or (16 if quick else 48)
    prompt_len = 8
    backend = ServeBackend()

    def serve_spec(**kw):
        return ExperimentSpec(
            objective=ServeJob(arch=arch, prompt_len=prompt_len,
                               arch_overrides=TINY, batch=4, n_slots=2,
                               n_requests=6, steps_per_launch=8, **kw),
            T=T, seed=0)

    entries = []

    # -- plain clean serve (warm: second run reuses the cached jits) --------
    spec = serve_spec()
    backend.run(spec)                              # compile
    plain = backend.run(spec)
    row = {"mode": "clean_plain", "steps": T,
           "decode_seconds": round(plain.extra["decode_seconds"], 4),
           "tok_per_s": round(plain.extra["tok_per_s"], 2)}
    entries.append(row)
    print(f"{'clean_plain':<14} tok/s={row['tok_per_s']:>9}")

    # -- same clean world with the recovery layer armed ---------------------
    spec = serve_spec(max_retries=3, retry_backoff=4, queue_cap=8)
    backend.run(spec)                              # compile
    armed = backend.run(spec)
    identical = bool(np.array_equal(plain.x, armed.x))
    ratio = armed.extra["tok_per_s"] / plain.extra["tok_per_s"]
    row = {"mode": "clean_retry_armed", "steps": T,
           "max_retries": 3, "queue_cap": 8,
           "decode_seconds": round(armed.extra["decode_seconds"], 4),
           "tok_per_s": round(armed.extra["tok_per_s"], 2),
           "vs_plain": round(ratio, 4),
           "token_identical": identical}
    entries.append(row)
    print(f"{'clean_armed':<14} tok/s={row['tok_per_s']:>9} "
          f"ratio={row['vs_plain']:>7} identical={identical}")

    # -- chaos soak ---------------------------------------------------------
    chaos = _chaos_run(arch, T, prompt_len, out)
    entries.append(chaos)
    print(f"{'chaos_soak':<14} completed={chaos['completed']} "
          f"degraded={chaos['degraded']} hops={chaos['preempt_hops']} "
          f"accounted={chaos['all_accounted']}")

    payload = {
        "bench": "resilience",
        "backend": jax.default_backend(),
        "arch": arch,
        "steps": T,
        "prompt_len": prompt_len,
        "note": ("warm runs on a tiny arch; absolute tok/s is "
                 "machine-local — the gate reads retry_overhead_ratio "
                 "(armed / plain on the SAME run, absolute ≤10%-cost "
                 "ceiling) and the two correctness flags, never raw "
                 "throughput.  trace_chaos.json is the soak's Chrome "
                 "trace (ui.perfetto.dev)."),
        "entries": entries,
        "retry_overhead_ratio": round(ratio, 4),
        "clean_token_identical": identical,
        "all_accounted": chaos["all_accounted"],
    }
    path = os.path.join(out, "BENCH_resilience.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", path)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="16 decode steps instead of 48")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--out", default="experiments/figs")
    args = ap.parse_args()
    run(out=args.out, quick=args.quick, steps=args.steps, arch=args.arch)


if __name__ == "__main__":
    main()
