"""Fused AsGrad server-update kernels (Pallas TPU).

The paper's hot loop is the server update x_{t+1} = x_t − γ g_{i_t}(x_{π_t})
(eq. 2).  In the production tier the stale gradient lives in the delayed
buffer; a naive implementation reads p, gbuf, g and writes p', gbuf' in
FIVE separate HBM passes (sub + copy + clip-scale).  These kernels fuse the
whole update into ONE pass per tile:

* ``async_update``: p' = p − (lr·delay_scale·clip_scale)·gbuf; gbuf' = g.
* ``fused_adam``:   full Adam step (m, v updates + parameter step) with the
  delayed gradient, f32 moments, bf16-safe parameter update.
* ``fused_adam_delayed``: ``fused_adam`` on the stale buffer PLUS the
  gbuf' = g swap in the same grid — the ``delay_rounds > 0`` production
  apply behind ``repro.optim.make_delayed_apply``.
* ``sgd_momentum_step`` / ``sgd_momentum_delayed``: heavy-ball SGD with the
  f32 momentum buffer riding the same HBM pass (m' = μ·m + clip·g;
  p' = p − lr·scale·m'), the latter with the gbuf' = g swap fused in.

Tiling: flat parameter tensors are viewed as (rows, LANE) with LANE=128
(the TPU lane width); BlockSpec tiles (block_rows, 128) keep each operand
slab in VMEM.  Scalars (lr·scales, bias corrections) arrive via a small
SMEM block, the standard scalar-plumbing pattern.

Validated under interpret=True against ``ref.reference_async_update`` /
``ref.reference_fused_adam``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
F32 = jnp.float32


def _pad_to_tiles(x, block_rows):
    n = x.size
    per_tile = block_rows * LANE
    tiles = pl.cdiv(n, per_tile)
    padded = tiles * per_tile
    flat = jnp.ravel(x)
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(tiles * block_rows, LANE), tiles


def _async_update_kernel(scal_ref, p_ref, gbuf_ref, g_ref, p_out, gbuf_out):
    eff = scal_ref[0]
    p = p_ref[...]
    stale = gbuf_ref[...].astype(F32)
    p_out[...] = (p.astype(F32) - eff * stale).astype(p_out.dtype)
    gbuf_out[...] = g_ref[...].astype(gbuf_out.dtype)


def async_update_pallas(params, gbuf, grads, *, lr, clip_scale=1.0,
                        delay_scale=1.0, block_rows=256, interpret=False):
    """Fused delayed-gradient apply on one flat tensor.

    params/gbuf/grads: same shape & dtype.  Returns (p', gbuf')."""
    assert params.shape == gbuf.shape == grads.shape
    shape, dtype = params.shape, params.dtype
    p2, tiles = _pad_to_tiles(params, block_rows)
    b2, _ = _pad_to_tiles(gbuf, block_rows)
    g2, _ = _pad_to_tiles(grads, block_rows)
    eff = jnp.asarray([lr * clip_scale * delay_scale], F32)

    p_new, gbuf_new = pl.pallas_call(
        _async_update_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p2.shape, dtype),
            jax.ShapeDtypeStruct(b2.shape, grads.dtype),
        ],
        interpret=interpret,
    )(eff, p2, b2, g2)
    n = params.size
    return (p_new.ravel()[:n].reshape(shape),
            gbuf_new.ravel()[:n].reshape(shape))


def _sgd_step_kernel(scal_ref, p_ref, g_ref, p_out):
    eff = scal_ref[0]
    p_out[...] = (p_ref[...].astype(F32)
                  - eff * g_ref[...].astype(F32)).astype(p_out.dtype)


def sgd_step_pallas(params, grads, *, lr, clip_scale=1.0, delay_scale=1.0,
                    block_rows=256, interpret=False):
    """Plain fused SGD step on one flat tensor: p' = p − eff·g, no buffer.

    The swap-free sibling of ``async_update`` for the NON-delayed path —
    a pallas_call output cannot be dead-code-eliminated, so reusing the
    delayed kernel there would pay a discarded gbuf' write per leaf."""
    assert params.shape == grads.shape
    shape, dtype = params.shape, params.dtype
    p2, tiles = _pad_to_tiles(params, block_rows)
    g2, _ = _pad_to_tiles(grads, block_rows)
    eff = jnp.asarray([lr * clip_scale * delay_scale], F32)

    p_new = pl.pallas_call(
        _sgd_step_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(p2.shape, dtype),
        interpret=interpret,
    )(eff, p2, g2)
    return p_new.ravel()[:params.size].reshape(shape)


def _sgd_momentum_kernel(scal_ref, p_ref, m_ref, g_ref, p_out, m_out,
                         *, momentum):
    lr_eff = scal_ref[0]          # lr · delay_scale
    clip = scal_ref[1]
    m = momentum * m_ref[...] + clip * g_ref[...].astype(F32)
    p_out[...] = (p_ref[...].astype(F32) - lr_eff * m).astype(p_out.dtype)
    m_out[...] = m


def sgd_momentum_step_pallas(params, m, grads, *, lr, momentum,
                             clip_scale=1.0, delay_scale=1.0, block_rows=256,
                             interpret=False):
    """Fused heavy-ball SGD on one flat tensor: m' = μ·m + clip·g,
    p' = p − lr·delay_scale·m'.  m is f32.  Returns (p', m')."""
    assert params.shape == grads.shape == m.shape
    shape, dtype = params.shape, params.dtype
    p2, tiles = _pad_to_tiles(params, block_rows)
    m2, _ = _pad_to_tiles(m.astype(F32), block_rows)
    g2, _ = _pad_to_tiles(grads, block_rows)
    scal = jnp.stack([jnp.asarray(lr * delay_scale, F32),
                      jnp.asarray(clip_scale, F32)])

    kern = functools.partial(_sgd_momentum_kernel, momentum=momentum)
    p_new, m_new = pl.pallas_call(
        kern,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p2.shape, dtype),
            jax.ShapeDtypeStruct(m2.shape, F32),
        ],
        interpret=interpret,
    )(scal, p2, m2, g2)
    n = params.size
    return (p_new.ravel()[:n].reshape(shape),
            m_new.ravel()[:n].reshape(shape))


def _sgd_momentum_delayed_kernel(scal_ref, p_ref, m_ref, gb_ref, g_ref,
                                 p_out, m_out, gbuf_out, *, momentum):
    lr_eff = scal_ref[0]
    clip = scal_ref[1]
    m = momentum * m_ref[...] + clip * gb_ref[...].astype(F32)
    p_out[...] = (p_ref[...].astype(F32) - lr_eff * m).astype(p_out.dtype)
    m_out[...] = m
    gbuf_out[...] = g_ref[...].astype(gbuf_out.dtype)


def sgd_momentum_delayed_pallas(params, m, gbuf, grads, *, lr, momentum,
                                clip_scale=1.0, delay_scale=1.0,
                                block_rows=256, interpret=False):
    """Delayed-buffer heavy-ball SGD, one HBM pass per tile:

        m'    ← μ·m + clip·gbuf        (momentum on the STALE gradient)
        p'    ← p − lr·delay_scale·m'
        gbuf' ← g                      (buffer the fresh one)

    Returns (p', m', gbuf')."""
    assert params.shape == gbuf.shape == grads.shape == m.shape
    shape, dtype = params.shape, params.dtype
    p2, tiles = _pad_to_tiles(params, block_rows)
    m2, _ = _pad_to_tiles(m.astype(F32), block_rows)
    b2, _ = _pad_to_tiles(gbuf, block_rows)
    g2, _ = _pad_to_tiles(grads, block_rows)
    scal = jnp.stack([jnp.asarray(lr * delay_scale, F32),
                      jnp.asarray(clip_scale, F32)])

    kern = functools.partial(_sgd_momentum_delayed_kernel, momentum=momentum)
    p_new, m_new, gbuf_new = pl.pallas_call(
        kern,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p2.shape, dtype),
            jax.ShapeDtypeStruct(m2.shape, F32),
            jax.ShapeDtypeStruct(b2.shape, grads.dtype),
        ],
        interpret=interpret,
    )(scal, p2, m2, b2, g2)
    n = params.size
    return (p_new.ravel()[:n].reshape(shape),
            m_new.ravel()[:n].reshape(shape),
            gbuf_new.ravel()[:n].reshape(shape))


def _adam_bias_corrections(beta1, beta2, count):
    """bc computed in f32 exactly like the reference optimizer (count may be
    a traced int32 scalar inside a jitted train step)."""
    c = jnp.asarray(count).astype(F32)
    return 1.0 - beta1 ** c, 1.0 - beta2 ** c


def _fused_adam_kernel(scal_ref, p_ref, m_ref, v_ref, g_ref,
                       p_out, m_out, v_out, *, beta1, beta2, eps):
    lr = scal_ref[0]
    bc1 = scal_ref[1]
    bc2 = scal_ref[2]
    clip = scal_ref[3]
    wd = scal_ref[4]
    g = clip * g_ref[...].astype(F32)
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    step = step + wd * p_ref[...].astype(F32)
    p_out[...] = (p_ref[...].astype(F32)
                  - lr * step).astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


def fused_adam_pallas(p, m, v, g, *, lr, beta1=0.9, beta2=0.95, eps=1e-8,
                      count=1, clip_scale=1.0, weight_decay=0.0,
                      block_rows=256, interpret=False):
    """One fused Adam step on a flat tensor; m/v f32.  Returns (p', m', v').

    ``clip_scale`` is the global-norm clip factor (the norm itself is a tree
    reduction and stays outside); ``count`` may be traced."""
    shape, dtype = p.shape, p.dtype
    p2, tiles = _pad_to_tiles(p, block_rows)
    m2, _ = _pad_to_tiles(m.astype(F32), block_rows)
    v2, _ = _pad_to_tiles(v.astype(F32), block_rows)
    g2, _ = _pad_to_tiles(g, block_rows)
    bc1, bc2 = _adam_bias_corrections(beta1, beta2, count)
    scal = jnp.asarray([lr, bc1, bc2, clip_scale, weight_decay], F32)

    kern = functools.partial(_fused_adam_kernel, beta1=beta1, beta2=beta2,
                             eps=eps)
    p_new, m_new, v_new = pl.pallas_call(
        kern,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p2.shape, dtype),
            jax.ShapeDtypeStruct(m2.shape, F32),
            jax.ShapeDtypeStruct(v2.shape, F32),
        ],
        interpret=interpret,
    )(scal, p2, m2, v2, g2)
    n = p.size
    return (p_new.ravel()[:n].reshape(shape),
            m_new.ravel()[:n].reshape(shape),
            v_new.ravel()[:n].reshape(shape))


def _fused_adam_delayed_kernel(scal_ref, p_ref, m_ref, v_ref, gb_ref, g_ref,
                               p_out, m_out, v_out, gbuf_out,
                               *, beta1, beta2, eps):
    lr = scal_ref[0]
    bc1 = scal_ref[1]
    bc2 = scal_ref[2]
    clip = scal_ref[3]
    wd = scal_ref[4]
    stale = clip * gb_ref[...].astype(F32)
    m = beta1 * m_ref[...] + (1.0 - beta1) * stale
    v = beta2 * v_ref[...] + (1.0 - beta2) * stale * stale
    step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    step = step + wd * p_ref[...].astype(F32)
    p_out[...] = (p_ref[...].astype(F32)
                  - lr * step).astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v
    gbuf_out[...] = g_ref[...].astype(gbuf_out.dtype)


def fused_adam_delayed_pallas(p, m, v, gbuf, g, *, lr, beta1=0.9, beta2=0.95,
                              eps=1e-8, count=1, clip_scale=1.0,
                              weight_decay=0.0, block_rows=256,
                              interpret=False):
    """Delayed-buffer Adam step, one HBM pass per tile:

        p', m', v' ← Adam(p, m, v; clip·gbuf)     (apply the STALE gradient)
        gbuf'      ← g                             (buffer the fresh one)

    This is the trainer's ``delay_rounds > 0`` hot loop (eq. 2 with Adam):
    the naive path reads/writes gbuf twice (once to apply, once to swap);
    here the swap rides the same grid.  Returns (p', m', v', gbuf')."""
    assert p.shape == gbuf.shape == g.shape
    shape, dtype = p.shape, p.dtype
    p2, tiles = _pad_to_tiles(p, block_rows)
    m2, _ = _pad_to_tiles(m.astype(F32), block_rows)
    v2, _ = _pad_to_tiles(v.astype(F32), block_rows)
    b2, _ = _pad_to_tiles(gbuf, block_rows)
    g2, _ = _pad_to_tiles(g, block_rows)
    bc1, bc2 = _adam_bias_corrections(beta1, beta2, count)
    scal = jnp.asarray([lr, bc1, bc2, clip_scale, weight_decay], F32)

    kern = functools.partial(_fused_adam_delayed_kernel, beta1=beta1,
                             beta2=beta2, eps=eps)
    p_new, m_new, v_new, gbuf_new = pl.pallas_call(
        kern,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p2.shape, dtype),
            jax.ShapeDtypeStruct(m2.shape, F32),
            jax.ShapeDtypeStruct(v2.shape, F32),
            jax.ShapeDtypeStruct(b2.shape, g.dtype),
        ],
        interpret=interpret,
    )(scal, p2, m2, v2, b2, g2)
    n = p.size
    return (p_new.ravel()[:n].reshape(shape),
            m_new.ravel()[:n].reshape(shape),
            v_new.ravel()[:n].reshape(shape),
            gbuf_new.ravel()[:n].reshape(shape))
