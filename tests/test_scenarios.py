"""Unit suite for ``repro.scenarios`` — grammar, wrappers, transforms, report.

The load-bearing gates:

* the IDENTITY scenario (empty spec, or explicit ``identity`` transforms)
  reproduces the stationary schedule **bit-for-bit** for every timing
  pattern — the wrapped path must consume the base RNG streams exactly as
  the unwrapped engine does,
* ``TimingModel.sample_round`` (the engine's vectorised path) is
  bit-identical to a scalar ``sample`` loop — the scalar draw stays the
  oracle,
* the ``normal`` pattern really has mean ``s_i`` / variance ``s_i``
  (the docstring convention, pinned on sampled moments),
* the τ-report's global row calls the Schedule's OWN statistics, so a
  stationary report reproduces them exactly (no parallel implementation).
"""
import numpy as np
import pytest

from repro.core import (PATTERNS, TimingModel, build_schedule,
                        heterogeneous_speeds, make_scheduler)
from repro.core.theory import RATES
from repro.scenarios import (DEFAULT_CONSTANTS, DataDrift, ElasticWorkers,
                             Identity, Scenario, ScenarioScheduler,
                             SparsifiedGrads, SpeedDrift, Straggler,
                             TRANSFORMS, WorldClock, parse_scenario,
                             predicted_rate, realise_world, render_report,
                             tau_report, window_stats)

N = 5
T = 24


def _pair(scheduler="fedbuff", b=2, pattern="poisson", seed=0):
    sched = make_scheduler(scheduler, N, b=b, seed=seed)
    timing = TimingModel(heterogeneous_speeds(N, slow_factor=4.0), pattern,
                         seed=seed)
    return sched, timing


# ---------------------------------------------------------------------------
# spec-string grammar
# ---------------------------------------------------------------------------
def test_parse_grammar_roundtrip():
    sc = parse_scenario("straggler:k=2,factor=8.5;elastic:every=3")
    assert sc.names == ("straggler", "elastic")
    st, el = sc.transforms
    assert st.k == 2 and isinstance(st.k, int)          # int coercion
    assert st.factor == 8.5 and isinstance(st.factor, float)
    assert el.every == 3 and el.k == 1                  # defaults survive
    assert sc.spec == "straggler:k=2,factor=8.5;elastic:every=3"


def test_parse_empty_and_whitespace():
    assert parse_scenario("").transforms == ()
    assert parse_scenario(" ; ").transforms == ()
    sc = parse_scenario(" drift : amp=0.25 , period=8 ; identity ")
    assert sc.names == ("drift", "identity")
    assert sc.transforms[0].amp == 0.25


def test_parse_errors_are_valueerrors():
    with pytest.raises(ValueError, match="unknown transform"):
        parse_scenario("warp:x=1")
    with pytest.raises(ValueError, match="malformed"):
        parse_scenario("straggler:k")
    with pytest.raises(ValueError, match="bad args"):
        parse_scenario("straggler:zzz=3")        # unknown kwarg
    with pytest.raises(ValueError, match="amp"):
        parse_scenario("drift:amp=2.0")          # constructor validation


def test_registry_names_match_classes():
    assert set(TRANSFORMS) == {"identity", "drift", "straggler", "elastic",
                               "data_drift", "sparsify", "nan_grad",
                               "corrupt_receipt", "worker_crash",
                               "host_preempt", "slot_poison",
                               "serve_preempt"}
    for name, cls in TRANSFORMS.items():
        assert cls.name == name


# ---------------------------------------------------------------------------
# identity bit-exactness — THE acceptance gate for the wrapped path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pattern", PATTERNS)
def test_identity_world_is_bit_for_bit_stationary(pattern):
    base = build_schedule(*_pair(pattern=pattern), T)
    # scenario seed deliberately differs from the base seed: it must only
    # drive the scenario layer, which the identity scenario never consults
    for spec in ("", "identity", "identity;identity"):
        sched, timing = _pair(pattern=pattern)
        world = realise_world(parse_scenario(spec), sched, timing, T,
                              seed=12345)
        s = world.schedule
        np.testing.assert_array_equal(s.workers, base.workers)
        np.testing.assert_array_equal(s.assign_iters, base.assign_iters)
        np.testing.assert_array_equal(s.finish_times, base.finish_times)
        assert s.tau_max() == base.tau_max()
        assert s.tau_avg() == base.tau_avg()
        assert s.tau_c() == base.tau_c()
        assert world.availability is None
        assert world.zipf_as is None
        assert world.grad_density is None
        assert world.rounds == T // 2


def test_realise_world_rejects_mismatched_n():
    sched, _ = _pair()
    timing = TimingModel(np.ones(N + 1), "fixed")
    with pytest.raises(ValueError, match="n_workers"):
        realise_world(Scenario(), sched, timing, T)


# ---------------------------------------------------------------------------
# vectorised timing draws — scalar sample() stays the oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pattern", PATTERNS)
def test_sample_round_matches_scalar_oracle(pattern):
    speeds = heterogeneous_speeds(7, slow_factor=5.0)
    batched = TimingModel(speeds, pattern, seed=3)
    scalar = TimingModel(speeds, pattern, seed=3)
    workers = [0, 3, 3, 6, 1]            # duplicates allowed
    got = batched.sample_round(workers)
    want = np.array([scalar.sample(w) for w in workers])
    np.testing.assert_array_equal(got, want)
    # an empty round consumes no RNG: the streams stay aligned after it
    assert batched.sample_round([]).shape == (0,)
    np.testing.assert_array_equal(batched.sample_round([2]),
                                  [scalar.sample(2)])


def test_normal_pattern_moments():
    """Docstring convention: r = |N(mean s, variance s)| + 1.  At s = 100
    the fold at zero is ~1e-23 mass, so the sampled moments must pin
    mean ≈ s + 1 and variance ≈ s (many standard errors of slack)."""
    s = 100.0
    tm = TimingModel([s], "normal", seed=0)
    draws = tm.sample_round(np.zeros(200_000, dtype=np.intp))
    assert abs(draws.mean() - (s + 1.0)) < 0.25      # SE ≈ 0.022
    assert abs(draws.var() - s) < 2.5                # SE ≈ 0.32
    assert draws.min() >= 1.0


# ---------------------------------------------------------------------------
# per-transform behaviour
# ---------------------------------------------------------------------------
def test_speed_drift_table():
    tr = SpeedDrift(period=8, amp=0.5)
    tr.prepare(4, 16, np.random.default_rng(0))
    ws = np.arange(4)
    assert tr.speed_factors(ws, 0)[0] == pytest.approx(1.0)  # sin(0) = 0
    for q in range(17):
        f = tr.speed_factors(ws, q)
        assert np.all(f >= 0.5 - 1e-12) and np.all(f <= 1.5 + 1e-12)
    # rounds beyond the table clamp to the final row (the t == T boundary)
    np.testing.assert_array_equal(tr.speed_factors(ws, 99),
                                  tr.speed_factors(ws, 16))
    # out-of-phase workers: the slowest seat rotates within one period
    slowest = {int(np.argmax(tr.speed_factors(ws, q))) for q in range(8)}
    assert len(slowest) > 1
    with pytest.raises(ValueError, match="amp"):
        SpeedDrift(amp=1.0)
    with pytest.raises(ValueError, match="period"):
        SpeedDrift(period=0)


def test_straggler_windows_hit_exactly_k_workers():
    tr = Straggler(k=2, factor=8.0, every=4, span=2)
    tr.prepare(5, 12, np.random.default_rng(0))
    ws = np.arange(5)
    hit_rounds = {4, 5, 8, 9, 12}        # [4,6) ∪ [8,10) ∪ [12,13)
    for q in range(13):
        f = tr.speed_factors(ws, q)
        assert np.all((f == 1.0) | (f == 8.0))
        assert int((f == 8.0).sum()) == (2 if q in hit_rounds else 0)
    assert np.all(tr.speed_factors(ws, 0) == 1.0)    # round 0 stationary
    with pytest.raises(ValueError, match="factor"):
        Straggler(factor=0)
    with pytest.raises(ValueError, match=">= 1"):
        Straggler(k=0)


def test_elastic_availability_windows():
    tr = ElasticWorkers(k=2, every=4, span=2)
    tr.prepare(5, 12, np.random.default_rng(0))
    a = tr.availability()
    assert a.shape == (12, 5)
    down_rounds = {4, 5, 8, 9}           # [4,6) ∪ [8,10); 12 is off-table
    for q in range(12):
        assert int((a[q] == 0).sum()) == (2 if q in down_rounds else 0)
    assert np.all(a[0] == 1.0)           # round 0 stationary
    # k >= n clamps: the pool is never fully dropped
    big = ElasticWorkers(k=9, every=2, span=1)
    big.prepare(3, 8, np.random.default_rng(0))
    assert np.all(big.availability().sum(axis=1) >= 1)


def test_elastic_remap_avoids_down_workers():
    avail = np.ones((4, 4), np.float32)
    avail[1:3, 0] = 0.0                  # worker 0 down at rounds 1-2

    class FakeBase:
        n, wait_b, name = 4, 1, "fake"
        def concurrency(self):
            return 4
        def reset(self):
            pass
        def initial_workers(self):
            return [0, 1]
        def next_workers(self, finished):
            return [0, 2]

    clock = WorldClock()
    ss = ScenarioScheduler(FakeBase(), clock, avail, [0, 1])
    assert ss.name == "scenario(fake)"
    assert ss.initial_workers() == [0, 1]        # round 0: everyone up
    got = ss.next_workers([0])                   # advances clock to round 1
    assert clock.round == 1
    assert 0 not in got                          # down worker vacated
    assert got[1] == 2                           # up workers untouched
    assert got[0] in (1, 3)                      # remapped to a free worker
    assert len(set(got)) == len(got)             # still without replacement
    ss.reset()
    assert clock.round == 0
    assert ss.next_workers([0]) == got           # remap RNG reset too


def test_data_drift_trajectories():
    tr = DataDrift(a0=1.0, a1=2.0)
    tr.prepare(3, 9, np.random.default_rng(0))
    z = tr.zipf_trajectory()
    assert z.shape == (9,)
    assert z[0] == pytest.approx(1.0) and z[-1] == pytest.approx(2.0)
    assert np.all(np.diff(z) > 0)                # linear ramp
    osc = DataDrift(a0=1.0, a1=2.0, period=8)
    osc.prepare(3, 17, np.random.default_rng(0))
    z2 = osc.zipf_trajectory()
    assert z2[0] == pytest.approx(1.0)
    assert z2[4] == pytest.approx(2.0)           # half period peaks at a1
    assert z2[8] == pytest.approx(1.0)           # full period back at a0
    with pytest.raises(ValueError, match="positive"):
        DataDrift(a0=0)


def test_sparsify_density_constant_and_adaptive():
    tr = SparsifiedGrads(frac=0.25)
    tr.prepare(N, 8, np.random.default_rng(0))
    np.testing.assert_array_equal(tr.grad_density(None),
                                  np.full(8, 0.25, np.float32))
    sched, timing = _pair()                      # b = 2
    s = build_schedule(sched, timing, 16)        # → 8 rounds
    ad = SparsifiedGrads(frac=0.25, adaptive=1)
    ad.prepare(N, 8, np.random.default_rng(0))
    d = ad.grad_density(s)
    assert d.shape == (8,) and d.dtype == np.float32
    tau = s.delays[:16].astype(np.float64).reshape(8, 2).mean(axis=1)
    np.testing.assert_allclose(
        d, np.clip(1.0 / (1.0 + tau), 0.25, 1.0).astype(np.float32))
    with pytest.raises(ValueError, match="frac"):
        SparsifiedGrads(frac=0.0)
    with pytest.raises(ValueError, match="frac"):
        SparsifiedGrads(frac=1.5)


# ---------------------------------------------------------------------------
# realisation: channel composition + determinism
# ---------------------------------------------------------------------------
FULL_SPEC = ("straggler:k=1,factor=6,every=4,span=2;"
             "elastic:k=1,every=4,span=2;"
             "data_drift:a0=1.1,a1=2.0;"
             "sparsify:frac=0.5;sparsify:frac=0.25")


def test_realise_world_channels_and_composition():
    world = realise_world(parse_scenario(FULL_SPEC), *_pair(), T, seed=3)
    assert world.rounds == T // 2
    assert world.availability is not None
    assert world.availability.shape == (world.rounds, N)
    assert (world.availability == 0).any()
    assert world.zipf_as.shape == (world.rounds,)
    # composing sparsifiers: the most aggressive (smallest) density wins
    np.testing.assert_array_equal(world.grad_density,
                                  np.full(world.rounds, 0.25, np.float32))
    # fully deterministic in (spec, seed)
    again = realise_world(parse_scenario(FULL_SPEC), *_pair(), T, seed=3)
    np.testing.assert_array_equal(world.schedule.workers,
                                  again.schedule.workers)
    np.testing.assert_array_equal(world.schedule.finish_times,
                                  again.schedule.finish_times)
    np.testing.assert_array_equal(world.availability, again.availability)


def test_straggler_world_perturbs_delays():
    base = build_schedule(*_pair(), T)
    world = realise_world(parse_scenario("straggler:k=2,factor=20,every=2,"
                                         "span=2"), *_pair(), T, seed=0)
    # a 20× transient slowdown must change the realised event order
    assert not np.array_equal(world.schedule.finish_times,
                              base.finish_times)


# ---------------------------------------------------------------------------
# τ-report
# ---------------------------------------------------------------------------
def test_identity_report_matches_schedule_stats_exactly():
    sched, timing = _pair()
    s = build_schedule(sched, timing, 16)
    rep = tau_report(s, "fedbuff", concurrency=sched.concurrency())
    g = rep["global"]
    assert g["tau_max"] == s.tau_max()           # exact — same methods
    assert g["tau_avg"] == s.tau_avg()
    assert g["tau_c"] == s.tau_c()
    assert rep["koloskova"]["tau_avg_le_tau_c"]
    assert rep["koloskova"]["tau_c_le_concurrency"]
    ws = rep["windows"]
    assert ws[0].lo == 0 and ws[-1].hi == 16
    assert all(a.hi == b.lo for a, b in zip(ws, ws[1:]))  # no gaps
    assert all(np.isfinite(w.rate) and w.rate > 0 for w in ws)
    txt = render_report(rep)
    assert "global" in txt and "fedbuff" in txt and "ok" in txt


def test_window_stats_bounds_global():
    s = build_schedule(*_pair(), 32)
    ws = window_stats(s, n_windows=4)
    assert len(ws) == 4
    assert max(w.tau_max for w in ws) <= s.tau_max()
    assert max(w.tau_c for w in ws) <= s.tau_c()


def test_predicted_rate_covers_every_policy():
    for policy in RATES:
        r = predicted_rate(policy, DEFAULT_CONSTANTS, T=64, tau_c=4,
                           tau_max=9, b=2, n=8)
        assert np.isfinite(r) and r > 0, policy
    with pytest.raises(KeyError):
        predicted_rate("nope", DEFAULT_CONSTANTS, T=1, tau_c=1, tau_max=1,
                       b=1, n=1)


# ---------------------------------------------------------------------------
# ExperimentSpec wiring (host-side only — no model builds)
# ---------------------------------------------------------------------------
def _spec(**kw):
    from repro.api import ExperimentSpec
    kw.setdefault("scheduler", "fedbuff:b=2")
    kw.setdefault("timing", "poisson:slow=4")
    kw.setdefault("T", 16)
    kw.setdefault("n_workers", N)
    return ExperimentSpec(**kw)


def test_spec_scenario_validation_and_world():
    with pytest.raises(ValueError, match="unknown transform"):
        _spec(scenario="warp:x=1")
    spec = _spec(scenario="straggler:k=1,factor=6,every=2,span=1")
    assert spec.make_scenario().names == ("straggler",)
    world = spec.build_world()
    assert world.rounds == 8
    assert world.schedule.T == 16
    # None scenario → stationary path; "" → identity wrap; same schedule
    plain = _spec().build_schedule()
    ident = _spec(scenario="").build_schedule()
    np.testing.assert_array_equal(ident.workers, plain.workers)
    np.testing.assert_array_equal(ident.finish_times, plain.finish_times)
    assert _spec().make_scenario().transforms == ()
