"""Micro-benchmark: AsyncTrainer train_step / serve_step wall time on the
reduced configs (CPU; TPU perf comes from §Roofline, not wall clock)."""
from __future__ import annotations

import csv
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import ARCHS, get_arch
from repro.data import DataConfig, HeterogeneousTokenPipeline
from repro.distributed import AsyncTrainer, AsyncConfig
from repro.optim import OptConfig


def run(out: str = "experiments/figs", quick: bool = False):
    os.makedirs(out, exist_ok=True)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    rows = []
    names = ["qwen2-0.5b", "deepseek-moe-16b", "mamba2-370m"] if quick \
        else sorted(ARCHS)
    for name in names:
        cfg = get_arch(name).reduced().with_(remat="none")
        tr = AsyncTrainer(cfg, mesh, opt=OptConfig(lr=1e-3),
                          async_cfg=AsyncConfig(delay_rounds=1))
        state = tr.init_state(jax.random.PRNGKey(0))
        step = jax.jit(tr.train_step_fn())
        B, S = 2, 32
        pipe = HeterogeneousTokenPipeline(DataConfig(cfg.vocab, S, B))
        from repro.models import batch_specs
        batch = {}
        for k, sp in batch_specs(cfg, B, S).items():
            if sp.dtype == "int32":
                batch[k] = jnp.asarray(pipe.batch(0)["tokens"][:, :sp.shape[1]])
            else:   # stubbed modality embeddings (vlm patches / audio frames)
                batch[k] = jax.random.normal(jax.random.PRNGKey(1), sp.shape,
                                             jnp.float32)
        mask = jnp.ones((tr.n_groups,))
        state, m = step(state, batch, mask)          # compile
        jax.block_until_ready(m["loss"])
        t0 = time.time()
        iters = 5
        for i in range(iters):
            state, m = step(state, batch, mask)
        jax.block_until_ready(m["loss"])
        us = (time.time() - t0) / iters * 1e6
        rows.append({"name": f"train_step_{name}", "us_per_call": round(us, 1),
                     "derived": f"loss={float(m['loss']):.3f}"})
    with open(os.path.join(out, "perf.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=["name", "us_per_call", "derived"])
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
