"""Unified result type returned by every backend."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np


@dataclasses.dataclass
class RunResult:
    """What an AsGrad run produced, backend-independent.

    ``x`` is the final iterate (simulator), the final train state tree
    (trainer), or the generated tokens (serve).  ``trace`` carries the
    realised-schedule statistics the theory bounds reference (τ_max, τ_avg,
    τ_C, job balance); ``grid`` holds the per-γ curves when a stepsize grid
    search ran.
    """

    spec: Any
    backend: str
    x: Any = None
    log_ts: Optional[np.ndarray] = None
    grad_norms: Optional[np.ndarray] = None
    losses: Optional[np.ndarray] = None
    xs: Optional[np.ndarray] = None          # iterate snapshots (simulator)
    gamma: Optional[float] = None            # the (selected) server stepsize
    grid: Optional[dict] = None              # γ → {"grad_norms", "losses", "score"}
    schedule: Any = None                     # realised Schedule, if one was built
    trace: dict = dataclasses.field(default_factory=dict)
    seconds: float = 0.0
    extra: dict = dataclasses.field(default_factory=dict)

    @property
    def final_grad_norm(self) -> Optional[float]:
        if self.grad_norms is None or not len(self.grad_norms):
            return None
        return float(self.grad_norms[-1])

    @property
    def final_loss(self) -> Optional[float]:
        if self.losses is None or not len(self.losses):
            return None
        return float(self.losses[-1])
