"""Slot-based continuous-batching serving: one compiled ragged decode loop.

The lock-step :class:`~repro.distributed.serve.Server` decodes a fixed
batch where every request starts and finishes together.  This module is
the production shape: ``n_slots`` persistent decode lanes, each carrying
its own position / activity / budget, stepped by ONE compiled program —
the serving analogue of the executor's per-round participation masks.

Design, mirroring the repo's schedule-is-value-independent thesis:

* **Device**: a chunk of ``steps_per_launch`` ragged decode steps runs as
  a ``lax.scan`` whose body calls ``models.decode_step`` with VECTOR
  ``pos`` (per-slot positions, ``cache_specs(..., ragged=True)``).
  Inactive slots freeze (token/pos/remaining held by the active mask) and
  their ring re-writes are idempotent, so masking replaces control flow —
  the program never retraces as requests come and go.  Each step streams
  ``(step, tokens, active)`` host-ward through an ordered ``io_callback``
  tap (the PR 5 idiom), so per-request consumers receive tokens while the
  device keeps decoding — the host never barriers the loop.
* **Host**: with a fixed per-request token budget there is no
  content-dependent exit, so admissions, completions, occupancy and TTFT
  are pure bookkeeping — ZERO device readbacks steer the loop.  Admission
  (which queued request fills a freed slot, at chunk boundaries) is a
  registry scheduler via :class:`~repro.distributed.admission.AdmissionPolicy`,
  and the realised trace lowers to an ordinary ``Schedule`` for
  ``scenarios.tau_report``.
* **Prefill** is folded in per admitted request: a cached batch-1 prefill
  jit produces the first token + a ctx-length cache, and a cached ``admit``
  jit writes the row into the slot cache at a *traced* slot index — one
  compile covers every admission.
* **Sampling state is per-request**, not per-pool: each slot carries its
  own PRNG key, reset at admission to ``fold_in(PRNGKey(seed), rid)`` and
  split once per decode step.  A request's sampled token stream is a pure
  function of (seed, rid, step-within-request) — independent of slot
  assignment, pool size and whatever else is decoding alongside it.
* **Degradation is masked, not crashed**: an active lane whose decode
  logits go non-finite is QUARANTINED on device (its budget zeroed, no
  token emitted) and the eviction surfaces host-side through the tap so
  the admission trace records it; queued requests whose wait exceeds a
  ``deadline`` are timed out at admission sweeps without ever occupying a
  slot.  Both degrade per-request — the pool keeps serving.
* **Degraded requests get a bounded second chance** (:class:`RetryPolicy`):
  quarantine-evicted and deadline-timed-out requests re-enter the
  admission queue after a deterministic exponential backoff in decode
  steps, their already-emitted prefix replayed through prefill
  (``prompt + tokens-so-far``) so completed work is never discarded, and
  attempt ``a`` re-seeds the slot key as ``fold_in(fold_in(key, rid), a)``
  — retried token streams are reproducible.  Attempts are capped; the
  final failure is accounted in ``evictions``/``timeouts`` with its
  attempt count.  With no retry policy the PR-7/8 detect-and-discard
  semantics are unchanged.
* **The server itself is durable**: pass an
  :class:`~repro.checkpoint.AsyncSnapshotter` and every due chunk
  boundary offers a non-donating device copy of the decode state PLUS the
  host ledger (queue, rid→slot map, emitted tokens, retry/backoff state,
  admission-policy RNG) as snapshot metadata; ``serve(resume_from=dir)``
  restores both and continues — unaffected requests' token streams are
  bitwise identical to an uninterrupted run (the SIGKILL gate pins it).
* **Overload degrades predictably** (:class:`OverloadPolicy`): a bounded
  admission queue sheds to ``queue_cap`` at every sweep under
  ``reject-new`` (drop the newest arrivals) or ``drop-oldest`` (drop the
  head of the queue); ``drain_after=k`` stops admitting at step k,
  finishes in-flight lanes and cancels the rest.  Shed and drained
  requests are terminal and explicitly accounted — no silent loss.
* **Faults are injectable deterministically**: a
  ``repro.faults.ServeFaults`` bundle poisons chosen (rid, decode-step)
  cells to NaN inside the chunk program (an all-false mask is bitwise
  identity — clean runs keep token parity) and schedules driver
  preemptions that raise :class:`ServePreempted` at chunk boundaries
  after forcing a snapshot offer — the chaos-soak substrate.

Compiled artifacts are cached on the instance (the PlanExecutor rule: a
fresh closure per call would silently recompile every run), asserted by
:meth:`SlotServer.compile_counts`.
"""
from __future__ import annotations

import dataclasses
from contextlib import nullcontext
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import model as M
from ..obs import CompileWatch
from .admission import AdmissionPolicy, AdmissionTrace, parse_admission
from .sharding import Rules, DEFAULT_RULES, sharded_trace, tree_shardings


def _span(rec, name, lane, **args):
    """Optional-recorder span (no-op without one — un-observed serves
    pay nothing on the dispatch path)."""
    return rec.span(name, lane, **args) if rec is not None else nullcontext()


@dataclasses.dataclass
class SlotConfig:
    """Knobs of the slot loop.

    ``steps_per_launch`` is the decode analogue of the executor's
    ``rounds_per_launch``: admissions land at chunk boundaries, so it
    trades admission latency against dispatch amortisation.
    """

    n_slots: int
    ctx_len: int
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0
    steps_per_launch: int = 8

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.steps_per_launch < 1:
            raise ValueError("steps_per_launch must be >= 1")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded re-admission of degraded requests.

    A quarantine eviction or deadline timeout consumes one *attempt*;
    while ``attempts consumed < max_attempts`` the request re-enters the
    admission queue after ``backoff_steps(failures)`` decode steps
    (deterministic exponential backoff:
    ``backoff_base · backoff_factor^(failures−1)``, in decode-step
    units), replaying its already-emitted token prefix through prefill.
    At the cap the LAST failure is terminal and lands in
    ``ServeResult.evictions`` / ``.timeouts`` with the attempt count in
    ``.attempts``.  ``max_attempts=1`` reproduces the no-retry
    detect-and-discard semantics exactly.
    """

    max_attempts: int = 2
    backoff_base: int = 4
    backoff_factor: float = 2.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1 (got {self.max_attempts})")
        if self.backoff_base < 0:
            raise ValueError(
                f"backoff_base must be >= 0 (got {self.backoff_base})")
        if self.backoff_factor < 1.0:
            raise ValueError(
                f"backoff_factor must be >= 1 (got {self.backoff_factor})")

    def backoff_steps(self, failures: int) -> int:
        """Decode steps to wait after the ``failures``-th failure."""
        return int(round(self.backoff_base
                         * self.backoff_factor ** (max(failures, 1) - 1)))


SHED_POLICIES = ("reject-new", "drop-oldest")


@dataclasses.dataclass(frozen=True)
class OverloadPolicy:
    """Bounded admission queue: at every sweep, eligible-but-waiting
    requests beyond ``queue_cap`` are SHED (terminal, accounted in
    ``ServeResult.shed``) — ``reject-new`` drops the newest entrants,
    ``drop-oldest`` drops the head of the queue to make room for them.
    """

    queue_cap: int
    shed: str = "reject-new"

    def __post_init__(self):
        if self.queue_cap < 1:
            raise ValueError(
                f"queue_cap must be >= 1 (got {self.queue_cap})")
        if self.shed not in SHED_POLICIES:
            raise ValueError(
                f"unknown shed policy {self.shed!r}; want one of "
                f"{SHED_POLICIES}")


class ServePreempted(RuntimeError):
    """Raised by ``serve`` at a scheduled ``serve_preempt`` boundary
    (after forcing a snapshot offer, when a snapshotter is attached).
    Carries the decode step the driver died at; harnesses catch it and
    resume via ``serve(resume_from=...)``."""

    def __init__(self, step: int, at: int):
        super().__init__(
            f"serve driver preempted at decode-step boundary {step} "
            f"(scheduled at step {at})")
        self.step = int(step)
        self.at = int(at)


@dataclasses.dataclass
class ServeResult:
    """Per-request token matrix + the realised admission world.

    Degraded requests pad: an evicted request's ``tokens`` row holds −1
    from its (last attempt's) quarantine point on — any prefix recovered
    by earlier attempts is kept; a timed-out / shed / drained request
    that was never admitted has an all −1 row and a −1 ``ttft_steps``
    entry.  Every submitted request lands in exactly one of: a full
    token row, ``evictions``, ``timeouts``, ``shed`` or ``drained`` —
    the no-silent-loss invariant the chaos suite asserts.
    """

    tokens: np.ndarray           # (n_requests, max_new) int32, −1 padded
    schedule: object             # repro.core.engine.Schedule of admissions
    ttft_steps: np.ndarray       # (n_requests,) admission − arrival (steps)
    occupancy: float             # mean fraction of busy slot-steps
    decode_steps: int            # launched scan steps (incl. drained tail)
    chunks: int                  # XLA launches of the chunk program
    tap_rows: int                # ordered io_callback rows delivered
    evictions: dict = dataclasses.field(default_factory=dict)
    #: rid -> decode step its lane was quarantined (non-finite logits);
    #: with retries, only TERMINAL (attempt-exhausted) evictions
    timeouts: dict = dataclasses.field(default_factory=dict)
    #: rid -> decode step its queue wait exceeded the deadline (terminal)
    shed: dict = dataclasses.field(default_factory=dict)
    #: rid -> decode step overload control shed it (terminal)
    drained: dict = dataclasses.field(default_factory=dict)
    #: rid -> decode step a graceful drain cancelled it (terminal)
    attempts: dict = dataclasses.field(default_factory=dict)
    #: rid -> failed attempts consumed (retried requests only)
    resumed_from: Optional[int] = None
    #: decode step this serve resumed a snapshot at (None = fresh run)


def _tok_int(x) -> int:
    """Host int from a deferred device tok0 (or an already-read int)."""
    return x if isinstance(x, int) else int(np.asarray(x).reshape(-1)[0])


class _Ledger:
    """Host-side bookkeeping of one serve run.

    Everything the sweep loop needs to steer admission, retries, shedding
    and accounting lives here — and it is JSON-serialisable
    (:meth:`to_json` / :meth:`from_json`), so a snapshot restores the
    DRIVER's world, not just the device carry.  Request lifecycle:
    ``queued`` (waiting / backing off, ``eligible[rid]`` = step it may be
    admitted from) → ``inflight`` (occupies a slot, ``fin[rid]`` = its
    deterministic completion step) → ``done`` (completed or terminally
    failed).
    """

    def __init__(self, n_req: int, n_slots: int, arrivals):
        self.t = 0                   # decode-step clock (chunk boundaries)
        self.chunks = 0              # lifetime chunk count (across resumes)
        self.busy_steps = 0
        self.slot_rid = [-1] * n_slots
        self.state_of = {r: "queued" for r in range(n_req)}
        self.eligible = {r: int(arrivals[r]) for r in range(n_req)}
        self.fin = {}          # rid -> completion step of CURRENT attempt
        self.admit_t = {}      # rid -> FIRST admission step (ttft)
        self.tries = {}        # rid -> failed attempts consumed
        self.emitted = {}      # rid -> ints recovered by failed attempts
        self.outputs = {}      # rid -> [tok0 (dev|int), ints...] this attempt
        self.cur_evict = {}    # rid -> quarantine step (sink-written)
        self.evict_events = []  # [rid, step] in tap order (sink-appended)
        self.evt_cursor = 0    # events before it are host-processed
        self.evictions = {}    # terminal accounting maps (rid -> step)
        self.timeouts = {}
        self.shed = {}
        self.drained = {}
        self.drain_t = None    # step the drain began (None = not draining)

    @property
    def in_flight(self) -> int:
        return sum(1 for v in self.state_of.values() if v == "inflight")

    @property
    def done(self) -> int:
        return sum(1 for v in self.state_of.values() if v == "done")

    _INT_MAPS = ("eligible", "fin", "admit_t", "tries", "cur_evict",
                 "evictions", "timeouts", "shed", "drained")

    def to_json(self) -> dict:
        out_rows = {}
        for rid, row in self.outputs.items():
            row[0] = _tok_int(row[0])         # force the deferred read once
            out_rows[str(rid)] = [int(x) for x in row]
        d = {"t": self.t, "chunks": self.chunks,
             "busy_steps": self.busy_steps,
             "slot_rid": [int(s) for s in self.slot_rid],
             "state_of": {str(k): v for k, v in self.state_of.items()},
             "emitted": {str(k): [int(x) for x in v]
                         for k, v in self.emitted.items()},
             "outputs": out_rows,
             "evict_events": [[int(a), int(b)] for a, b in
                              self.evict_events],
             "evt_cursor": int(self.evt_cursor),
             "drain_t": self.drain_t}
        for name in self._INT_MAPS:
            d[name] = {str(k): int(v)
                       for k, v in getattr(self, name).items()}
        return d

    @classmethod
    def from_json(cls, d: dict) -> "_Ledger":
        L = cls(0, len(d["slot_rid"]), [])
        L.t = int(d["t"])
        L.chunks = int(d["chunks"])
        L.busy_steps = int(d["busy_steps"])
        L.slot_rid = [int(s) for s in d["slot_rid"]]
        L.state_of = {int(k): str(v) for k, v in d["state_of"].items()}
        L.emitted = {int(k): [int(x) for x in v]
                     for k, v in d["emitted"].items()}
        L.outputs = {int(k): [int(x) for x in v]
                     for k, v in d["outputs"].items()}
        L.evict_events = [[int(a), int(b)] for a, b in d["evict_events"]]
        L.evt_cursor = int(d["evt_cursor"])
        L.drain_t = None if d["drain_t"] is None else int(d["drain_t"])
        for name in cls._INT_MAPS:
            setattr(L, name, {int(k): int(v) for k, v in d[name].items()})
        return L


class SlotServer:
    """Continuous-batching decode over ``n_slots`` ragged lanes."""

    def __init__(self, cfg: ArchConfig, mesh: Mesh, slots: SlotConfig,
                 rules: Rules = DEFAULT_RULES, recorder=None):
        if cfg.family in ("vlm", "audio"):
            raise NotImplementedError(
                f"slot serving admits token-only prompts; the {cfg.family!r} "
                "family needs per-request modality inputs (follow-up)")
        self.cfg, self.mesh, self.slots, self.rules = cfg, mesh, slots, rules
        self.recorder = recorder      # repro.obs.Recorder | None
        self.watch = CompileWatch(recorder)   # retrace sentinel
        self._chunk_fn = None         # cached jitted chunk program
        self._admit_fn = None         # cached jitted slot writer
        self._prefill_jits = {}       # prompt_len -> jitted batch-1 prefill
        self._tap_sink = None         # per-run host consumer of tap rows
        self._zero_poison = None      # cached all-false (K, S) fault mask

    # ---- shardings ---------------------------------------------------------
    def param_shardings(self):
        return tree_shardings(M.param_specs(self.cfg), self.mesh, self.rules)

    def state_shardings(self):
        S = self.slots.n_slots
        cache_sh = tree_shardings(
            M.cache_specs(self.cfg, S, self.slots.ctx_len, ragged=True),
            self.mesh, self.rules)
        lane = NamedSharding(self.mesh, P(self.rules.data_axes[-1]
                                          if S > 1 else None))
        repl = NamedSharding(self.mesh, P())
        return {"cache": cache_sh, "toks": lane, "pos": lane,
                "active": lane, "remaining": lane, "keys": repl}

    # ---- state -------------------------------------------------------------
    def _state_template(self) -> dict:
        """All slots empty: inactive lanes decode-and-discard until a
        request is admitted (their writes are idempotent)."""
        S = self.slots.n_slots
        return {
            "cache": M.init_cache(self.cfg, S, self.slots.ctx_len,
                                  ragged=True),
            "toks": jnp.zeros((S,), jnp.int32),
            "pos": jnp.zeros((S,), jnp.int32),
            "active": jnp.zeros((S,), bool),
            "remaining": jnp.zeros((S,), jnp.int32),
            # (S, 2) per-slot sampling keys; placeholders until admission
            # re-seeds each slot with its request's fold_in key
            "keys": jnp.tile(jax.random.PRNGKey(self.slots.seed)[None],
                             (S, 1)),
        }

    def init_state(self) -> dict:
        # pin the canonical shardings up front: every producer of a state
        # tree (init / admit / chunk) must agree, or the jits re-specialise
        # on their first post-admission call
        return jax.device_put(self._state_template(), self.state_shardings())

    def abstract_state(self) -> dict:
        """ShapeDtypeStruct mirror of the decode state, for
        ``checkpoint.restore`` (crash-resume) without allocating."""
        return jax.eval_shape(self._state_template)

    # ---- tap ---------------------------------------------------------------
    def _emit_tap(self, idx, toks, active, quarantined):
        """Host side of the ordered io_callback (bound once so the chunk
        program stays stable; the per-run consumer swaps in via
        ``_tap_sink``)."""
        sink = self._tap_sink
        if sink is not None:
            sink(int(idx), np.asarray(toks), np.asarray(active),
                 np.asarray(quarantined))

    # ---- compiled programs -------------------------------------------------
    def chunk_fn(self):
        """Jitted ``chunk(params, state, idx0, poison) -> state``: K
        ragged decode steps with per-step tap emission.  Compiled once;
        ``idx0`` is a traced scalar so chunk position never retraces.
        ``poison`` is a (K, n_slots) bool fault-injection mask: flagged
        cells force that lane's logits to NaN BEFORE the finite check, so
        the ordinary quarantine path fires deterministically.  An
        all-false mask is bitwise identity — clean serves pay nothing."""
        if self._chunk_fn is not None:
            return self._chunk_fn
        from jax.experimental import io_callback

        cfg, ctx = self.cfg, self.slots.ctx_len
        temp, K = self.slots.temperature, self.slots.steps_per_launch
        emit = self._emit_tap

        def decode(params, cache, toks, pos):
            return M.decode_step(cfg, params, cache, toks, pos, ctx)

        decode = sharded_trace(decode, self.mesh, self.rules)

        def chunk(params, state, idx0, poison):
            def round_fn(st, xs):
                idx, poison_row = xs["idx"], xs["poison"]
                logits, cache = decode(params, st["cache"], st["toks"],
                                       st["pos"])
                logits = jnp.where(poison_row[:, None], jnp.nan, logits)
                act = st["active"]
                # quarantine: an active lane whose logits go non-finite is
                # evicted in-mask — no token this step, budget zeroed so the
                # lane freezes (idempotent writes) until re-admission; the
                # rest of the pool is untouched
                finite = jnp.all(jnp.isfinite(logits), axis=-1)
                quar = act & ~finite
                act = act & finite
                keys = st["keys"]
                if temp > 0:
                    # per-slot streams: each lane splits its own key, so a
                    # request's samples depend only on (seed, rid, step)
                    pair = jax.vmap(jax.random.split)(keys)      # (S, 2, 2)
                    keys, subs = pair[:, 0], pair[:, 1]
                    nxt = jax.vmap(lambda k, lg: jax.random.categorical(
                        k, lg / temp))(subs, logits).astype(jnp.int32)
                else:
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                step = act.astype(jnp.int32)
                toks = jnp.where(act, nxt, st["toks"])
                rem = (st["remaining"] - step) * (~quar).astype(jnp.int32)
                # ordered: per-request consumers see tokens in decode order
                io_callback(emit, None, idx, toks, act, quar, ordered=True)
                return {"cache": cache, "toks": toks,
                        "pos": st["pos"] + step,
                        "active": act & (rem > 0), "remaining": rem,
                        "keys": keys}, None

            state, _ = jax.lax.scan(
                round_fn, state,
                {"idx": idx0 + jnp.arange(K, dtype=jnp.int32),
                 "poison": poison})
            return state

        repl = NamedSharding(self.mesh, P())
        self._chunk_fn = self.watch.wrap("chunk", jax.jit(
            chunk,
            in_shardings=(self.param_shardings(), self.state_shardings(),
                          repl, repl),
            out_shardings=self.state_shardings(),
            donate_argnums=(1,)))
        return self._chunk_fn

    def admit_fn(self):
        """Jitted ``admit(state, pcache, slot, tok0, pos0, rem0, key)``:
        write a prefilled request into slot ``slot`` (a TRACED index — one
        compile covers every admission into any slot).  ``key`` is the
        request's own sampling key (``fold_in(PRNGKey(seed), rid)``) — it
        resets the slot's stream so sampling never leaks across the
        requests that share a lane over time."""
        if self._admit_fn is not None:
            return self._admit_fn

        def admit(state, pcache, slot, tok0, pos0, rem0, key):
            def wr(c, p):
                if c.ndim == p.ndim + 1:      # per-slot positions row
                    return jax.lax.dynamic_update_slice(
                        c, p[None].astype(c.dtype), (slot, 0))
                # every other leaf: (layers, batch=n_slots, ...) ← batch-1 row
                start = (0, slot) + (0,) * (c.ndim - 2)
                return jax.lax.dynamic_update_slice(c, p.astype(c.dtype),
                                                    start)

            return {
                "cache": jax.tree_util.tree_map(wr, state["cache"], pcache),
                "toks": state["toks"].at[slot].set(tok0),
                "pos": state["pos"].at[slot].set(pos0),
                "active": state["active"].at[slot].set(rem0 > 0),
                "remaining": state["remaining"].at[slot].set(rem0),
                "keys": state["keys"].at[slot].set(key),
            }

        self._admit_fn = self.watch.wrap("admit", jax.jit(
            admit, out_shardings=self.state_shardings(),
            donate_argnums=(0,)))
        return self._admit_fn

    def prefill_fn(self, prompt_len: int):
        """Jitted batch-1 prefill → (first token (1,), ctx-length cache);
        cached per prompt length."""
        fn = self._prefill_jits.get(prompt_len)
        if fn is None:
            cfg, ctx = self.cfg, self.slots.ctx_len

            def pf(params, tokens):
                logits, cache = M.prefill(cfg, params, {"tokens": tokens},
                                          ctx_len=ctx)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

            fn = self.watch.wrap(f"prefill[{prompt_len}]", jax.jit(pf))
            self._prefill_jits[prompt_len] = fn
        return fn

    def compile_counts(self) -> dict:
        """Traced-signature counts of the cached jits (the no-retrace
        gate: rotating requests through freed slots must keep these at 1
        per program).  Backed by the :class:`repro.obs.CompileWatch`
        retrace sentinel — with a recorder attached, every compile also
        lands as an instant in the trace."""
        return self.watch.counts()

    # ---- driver ------------------------------------------------------------
    def serve(self, params, prompts: np.ndarray, max_new: int, *,
              admission: Union[str, AdmissionPolicy] = "pure",
              arrivals: Optional[np.ndarray] = None,
              deadline: Optional[int] = None,
              on_token: Optional[Callable] = None,
              retry: Optional[RetryPolicy] = None,
              overload: Optional[OverloadPolicy] = None,
              drain_after: Optional[int] = None,
              faults=None, snapshot=None,
              resume_from: Optional[str] = None) -> ServeResult:
        """Serve every prompt to its ``max_new``-token budget.

        prompts: (n_requests, prompt_len) int32; ``arrivals``: optional
        (n_requests,) arrival steps on the decode-step clock (see
        :func:`~repro.distributed.admission.draw_arrivals`); ``admission``:
        a policy name/compact spec or a prepared :class:`AdmissionPolicy`;
        ``deadline``: optional queue-wait budget in decode steps — a
        request still queued when ``now − eligible > deadline`` is timed
        out at the admission sweep (chunk-boundary granularity) and never
        occupies a slot; ``on_token(rid, token, step)`` fires per streamed
        token from the tap thread (token already a host int).

        Resilience kwargs (each ``None`` ⇒ exact PR-7/8 behaviour):

        * ``retry`` (:class:`RetryPolicy`) — evictions/timeouts consume
          attempts and re-queue with deterministic backoff instead of
          being terminal on first failure; the emitted prefix replays
          through prefill at re-admission.
        * ``overload`` (:class:`OverloadPolicy`) — bounded admission
          queue; eligible waiters beyond ``queue_cap`` are shed.
        * ``drain_after=k`` — graceful drain: at the first sweep with
          ``t >= k`` every queued request is cancelled (``drained``) and
          only in-flight lanes run to completion.
        * ``faults`` (``repro.faults.ServeFaults``-shaped) — poison
          chosen (rid, decode-step) cells to NaN inside the chunk and
          schedule :class:`ServePreempted` driver kills.
        * ``snapshot`` (:class:`~repro.checkpoint.AsyncSnapshotter`) —
          offer decode state + host ledger at every due chunk boundary;
          ``resume_from=dir`` restores such a snapshot and continues
          (``prompts``/``max_new``/knobs must match the original call).

        The loop is steered entirely by host bookkeeping: completions are
        deterministic (``admit_step + remaining``), so no device value is
        ever read to decide admission — only the final token matrix is
        assembled from the tap stream.  Quarantine evictions are the one
        DEVICE-initiated event: the host learns of them from the tap.
        Without retries the slot stays allocated until the original
        completion step (the frozen lane idle-decodes harmlessly); with
        retries the host frees it at the next sweep and re-queues the
        request.  Any of ``retry``/``faults``/``snapshot``/``resume_from``
        switches the loop to SYNC dispatch (an ``effects_barrier`` per
        chunk) so the ledger is consistent at every sweep; clean serves
        keep the fully asynchronous legacy path.
        """
        S, K = self.slots.n_slots, self.slots.steps_per_launch
        n_req, plen = prompts.shape
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if plen + max_new > self.slots.ctx_len:
            raise ValueError(
                f"prompt_len + max_new = {plen + max_new} exceeds "
                f"ctx_len = {self.slots.ctx_len}")
        if isinstance(admission, AdmissionPolicy):
            policy = admission
        else:
            name, b = parse_admission(admission)
            policy = AdmissionPolicy(name, n_req, b=b,
                                     seed=self.slots.seed)
        arr = (np.zeros(n_req, np.int64) if arrivals is None
               else np.asarray(arrivals, np.int64))
        if arr.shape != (n_req,):
            raise ValueError(f"arrivals must be ({n_req},); got {arr.shape}")
        if deadline is not None and deadline < 0:
            raise ValueError(f"deadline must be >= 0 (got {deadline})")
        if drain_after is not None and drain_after < 0:
            raise ValueError(
                f"drain_after must be >= 0 (got {drain_after})")

        poisons: dict = {}            # decode step -> set of poisoned rids
        preempts: tuple = ()
        if faults is not None:
            for rid_c, st_c in getattr(faults, "poisons", ()):
                poisons.setdefault(int(st_c), set()).add(int(rid_c))
            preempts = tuple(sorted(
                int(p) for p in getattr(faults, "preempt_steps", ())))
        # device-initiated events must be host-visible at the NEXT sweep
        # for retries/snapshots to be deterministic — barrier per chunk;
        # clean serves keep the async run-ahead dispatch
        sync = (retry is not None or snapshot is not None
                or resume_from is not None or bool(poisons)
                or bool(preempts))

        chunk = self.chunk_fn()
        admit = self.admit_fn()
        pf = self.prefill_fn(plen)
        prompts_dev = jnp.asarray(prompts, jnp.int32)
        base_key = jax.random.PRNGKey(self.slots.seed)
        if self._zero_poison is None:
            self._zero_poison = jax.device_put(
                np.zeros((K, S), bool), NamedSharding(self.mesh, P()))

        trace = AdmissionTrace(n_req, wait_b=policy.wait_b)
        resumed_from = None
        if resume_from is not None:
            from ..checkpoint import checkpointer as _ckpt

            meta = _ckpt.load_meta(resume_from)
            if "serve_ledger" not in meta:
                raise ValueError(
                    f"{resume_from} is not a serve snapshot (no ledger)")
            L = _Ledger.from_json(meta["serve_ledger"])
            if len(L.slot_rid) != S or len(L.state_of) != n_req:
                raise ValueError(
                    "snapshot geometry mismatch: ledger has "
                    f"{len(L.slot_rid)} slots / {len(L.state_of)} requests, "
                    f"server has {S} / {n_req}")
            policy.load_state(meta["admission_policy"])
            trace.load_state(meta["admission_trace"])
            state = _ckpt.restore(resume_from, self.abstract_state(),
                                  shardings=self.state_shardings())
            resumed_from = L.t
        else:
            L = _Ledger(n_req, S, arr)
            state = self.init_state()
        rec = self.recorder
        step_maps: dict = {}          # chunk start -> [(rid, fin)] snapshot
        req_ns: dict = {}             # rid -> admission wall-clock ns (obs)
        tap_stats = {"rows": 0}
        mismatches: list = []

        def sink(idx, toks, act, quar):
            tap_stats["rows"] += 1
            m = step_maps.get(idx - idx % K)
            if m is None:
                mismatches.append(f"step {idx}: no chunk snapshot")
                return
            for s, (rid, fin_s) in enumerate(m):
                if bool(quar[s]):
                    if rid < 0:
                        mismatches.append(
                            f"step {idx} slot {s}: quarantine on an empty "
                            "lane")
                        continue
                    if rid not in L.cur_evict:
                        L.cur_evict[rid] = int(idx)
                        L.evict_events.append([rid, int(idx)])
                        if rec is not None:
                            rec.instant("evict", lane="faults", rid=rid,
                                        step=int(idx))
                            rec.count("evictions")
                ev = L.cur_evict.get(rid) if rid >= 0 else None
                predicted = (rid >= 0 and idx < fin_s
                             and (ev is None or idx < ev))
                if bool(act[s]) != predicted:
                    mismatches.append(
                        f"step {idx} slot {s}: device active={bool(act[s])} "
                        f"!= host-predicted {predicted}")
                    continue
                if predicted:
                    tok = int(toks[s])
                    L.outputs[rid].append(tok)
                    if on_token is not None:
                        on_token(rid, tok, int(idx))

        def ledger_meta():
            return {"serve_ledger": L.to_json(),
                    "admission_policy": policy.state_dict(),
                    "admission_trace": trace.state_dict()}

        def drain_events():
            """Fold sink-recorded quarantine evictions into the ledger."""
            while L.evt_cursor < len(L.evict_events):
                rid, step = L.evict_events[L.evt_cursor]
                L.evt_cursor += 1
                if retry is None:
                    # legacy: the lane stays booked until its scheduled
                    # completion; the eviction is terminal metadata
                    if rid not in L.evictions:
                        L.evictions[rid] = step
                        trace.evicted(rid, step)
                    continue
                # retry: the attempt failed — free the frozen lane now
                for s in range(S):
                    if L.slot_rid[s] == rid:
                        L.slot_rid[s] = -1
                req_ns.pop(rid, None)
                row = L.outputs.pop(rid, None)
                if row is not None:
                    L.emitted[rid] = (L.emitted.get(rid, [])
                                      + [_tok_int(x) for x in row])
                L.cur_evict.pop(rid, None)
                tries = L.tries[rid] = L.tries.get(rid, 0) + 1
                trace.retried(rid, tries)
                if (tries < retry.max_attempts
                        and len(L.emitted.get(rid, [])) < max_new):
                    L.state_of[rid] = "queued"
                    L.eligible[rid] = step + retry.backoff_steps(tries)
                    policy.requeue(rid)
                    if rec is not None:
                        rec.instant("retry", lane="server", rid=rid,
                                    step=step, attempt=tries)
                        rec.count("retries")
                else:
                    L.state_of[rid] = "done"
                    L.evictions[rid] = step
                    trace.evicted(rid, step)
                    policy.cancel(rid)

        t = L.t
        start_t0 = L.t                # resumed: pre-crash preempts spent
        chunks_run = 0                # this PROCESS (tap accounting)
        last_offered = None
        drain_ns = None
        attempts_bound = retry.max_attempts if retry is not None else 1
        backoff_total = (sum(retry.backoff_steps(f)
                             for f in range(1, attempts_bound))
                         if retry is not None else 0)
        horizon = 2 * (int(arr.max(initial=0))
                       + n_req * (max_new * attempts_bound + backoff_total)
                       + K) + 4 * K
        self._tap_sink = sink
        try:
            while L.done < n_req:
                if t > horizon:
                    raise RuntimeError(
                        f"slot loop passed its horizon ({horizon} steps) "
                        f"with {n_req - L.done} requests unfinished — "
                        "admission bookkeeping is stuck")
                sweep0 = rec.now_ns() if rec is not None else 0
                drain_events()
                # -- scheduled driver preemption ---------------------------
                if preempts:
                    due_p = next(
                        (p for p in preempts if start_t0 < p <= t), None)
                    if due_p is not None:
                        if snapshot is not None:
                            if last_offered != t:
                                snapshot.offer(t, state, meta=ledger_meta())
                            snapshot.drain()
                        raise ServePreempted(t, due_p)
                # -- completions (deterministic, no readback) --------------
                freed = sorted(
                    (s for s in range(S)
                     if L.slot_rid[s] >= 0 and L.fin[L.slot_rid[s]] <= t),
                    key=lambda s: (L.fin[L.slot_rid[s]], s))
                for s in freed:
                    rid, L.slot_rid[s] = L.slot_rid[s], -1
                    L.state_of[rid] = "done"
                    trace.completed(rid, s, L.fin[rid], L.in_flight + 1)
                    policy.notify_completion(rid)
                    if rec is not None and rid in req_ns:
                        # per-request lifetime on the slot's own lane
                        rec.span_at("request", f"slot{s}", req_ns.pop(rid),
                                    rec.now_ns(), rid=rid,
                                    steps=L.fin[rid] - L.admit_t[rid] + 1)
                        rec.count("completions")
                # -- graceful drain (stop admitting, finish in-flight) -----
                if (drain_after is not None and t >= drain_after
                        and L.drain_t is None):
                    L.drain_t = t
                    drain_ns = rec.now_ns() if rec is not None else None
                    for r in sorted(L.state_of):
                        if L.state_of[r] == "queued":
                            L.state_of[r] = "done"
                            L.drained[r] = t
                            trace.drained(r, t)
                            policy.cancel(r)
                    if rec is not None:
                        rec.instant("drain_start", lane="server", step=t,
                                    cancelled=len(L.drained),
                                    in_flight=L.in_flight)
                        rec.count("drained", len(L.drained))
                # -- deadline timeouts (queue-wait budget) -----------------
                if deadline is not None:
                    for r in range(n_req):
                        if L.state_of[r] != "queued":
                            continue
                        el = L.eligible[r]
                        if el <= t and t - el > deadline:
                            if retry is not None:
                                tries = L.tries[r] = L.tries.get(r, 0) + 1
                                trace.retried(r, tries)
                                if tries < retry.max_attempts:
                                    L.eligible[r] = (
                                        t + retry.backoff_steps(tries))
                                    if rec is not None:
                                        rec.instant("retry", lane="server",
                                                    rid=r, step=t,
                                                    attempt=tries)
                                        rec.count("retries")
                                    continue
                            L.timeouts[r] = t
                            L.state_of[r] = "done"
                            policy.cancel(r)
                            trace.timed_out(r, t)
                            if rec is not None:
                                rec.instant("timeout", lane="server", rid=r,
                                            step=t, wait=t - int(el))
                                rec.count("timeouts")
                # -- admissions into free slots ----------------------------
                arrived = {r for r, st_r in L.state_of.items()
                           if st_r == "queued" and L.eligible[r] <= t}
                free = [s for s in range(S) if L.slot_rid[s] < 0]
                while free:
                    rid = policy.pick(arrived, L.in_flight)
                    if rid is None:
                        break
                    s = free[0]
                    tries = L.tries.get(rid, 0)
                    pre = L.emitted.get(rid, [])
                    e = len(pre)
                    if e:
                        # replay the recovered prefix: re-prefill
                        # prompt + tokens-emitted-so-far
                        pf_e = self.prefill_fn(plen + e)
                        ptoks = jnp.asarray(
                            np.concatenate(
                                [prompts[rid],
                                 np.asarray(pre, np.int64)])[None],
                            jnp.int32)
                    else:
                        pf_e, ptoks = pf, prompts_dev[rid:rid + 1]
                    key = jax.random.fold_in(base_key, rid)
                    if tries:
                        key = jax.random.fold_in(key, tries)
                    rem0 = max_new - 1 - e
                    with _span(rec, "prefill", "server", rid=rid,
                               plen=plen + e):
                        tok0, pcache = pf_e(params, ptoks)
                    with _span(rec, "admit", "server", rid=rid, slot=s):
                        state = admit(state, pcache, s, tok0[0],
                                      jnp.int32(plen + e),
                                      jnp.int32(rem0), key)
                    L.outputs[rid] = [tok0]
                    L.admit_t.setdefault(rid, t)
                    L.fin[rid] = t + rem0
                    trace.admitted(rid, t)
                    arrived.discard(rid)
                    if rec is not None:
                        rec.hist("ttft_steps", t - int(arr[rid]))
                        req_ns[rid] = rec.now_ns()
                    if rem0 == 0:     # budget already emitted: completes
                        L.state_of[rid] = "done"   # at admission
                        trace.completed(rid, s, t, L.in_flight + 1)
                        policy.notify_completion(rid)
                        if rec is not None and rid in req_ns:
                            rec.span_at("request", f"slot{s}",
                                        req_ns.pop(rid), rec.now_ns(),
                                        rid=rid, steps=1)
                            rec.count("completions")
                    else:
                        L.slot_rid[s] = rid
                        L.state_of[rid] = "inflight"
                        free.pop(0)
                # -- overload shedding (bounded admission queue) -----------
                if overload is not None:
                    waiting = sorted(
                        (r for r, st_r in L.state_of.items()
                         if st_r == "queued" and L.eligible[r] <= t),
                        key=lambda r: (L.eligible[r], r))
                    excess = len(waiting) - overload.queue_cap
                    if excess > 0:
                        victims = (waiting[-excess:]
                                   if overload.shed == "reject-new"
                                   else waiting[:excess])
                        for r in victims:
                            L.state_of[r] = "done"
                            L.shed[r] = t
                            trace.shed(r, t)
                            policy.cancel(r)
                            if rec is not None:
                                rec.instant("shed", lane="server", rid=r,
                                            step=t, policy=overload.shed)
                                rec.count("shed")
                if rec is not None:
                    rec.span_at("admission_sweep", "server", sweep0,
                                rec.now_ns(), t=t)
                    rec.gauge("in_flight", L.in_flight, lane="server")
                    rec.gauge("occupancy", L.in_flight / S, lane="server")
                if L.done >= n_req:
                    break
                if L.in_flight == 0:
                    # idle pool, pending arrivals/backoffs: fast-forward
                    # the clock to the next chunk boundary at/after the
                    # earliest eligibility — no launch for empty air
                    nxt = min(L.eligible[r] for r, st_r in L.state_of.items()
                              if st_r == "queued")
                    t = max(t + K, -(-int(nxt) // K) * K)
                    L.t = t
                    continue
                # -- one chunk launch --------------------------------------
                step_maps[t] = [(rid, L.fin.get(rid, -1))
                                for rid in L.slot_rid]
                for s in range(S):
                    rid = L.slot_rid[s]
                    if rid >= 0:
                        L.busy_steps += max(0, min(t + K, L.fin[rid]) - t)
                pz = self._zero_poison
                if poisons:
                    mask = np.zeros((K, S), bool)
                    hit = False
                    for j in range(K):
                        cells = poisons.get(t + j)
                        if not cells:
                            continue
                        for s in range(S):
                            if L.slot_rid[s] in cells:
                                mask[j, s] = True
                                hit = True
                    if hit:
                        pz = mask
                with _span(rec, "launch", "server", t=t,
                           in_flight=L.in_flight):
                    state = chunk(params, state, jnp.int32(t), pz)
                chunks_run += 1
                L.chunks += 1
                t += K
                L.t = t
                if sync:
                    with _span(rec, "chunk_barrier", "server", t=t):
                        jax.effects_barrier()
                if snapshot is not None and snapshot.due(t, 1 << 62):
                    drain_events()   # ledger must reflect delivered taps
                    snapshot.offer(t, state, meta=ledger_meta())
                    last_offered = t
            with _span(rec, "barrier", "server"):
                state = jax.block_until_ready(state)
                jax.effects_barrier()
            drain_events()
        finally:
            self._tap_sink = None

        if mismatches:
            raise RuntimeError(
                "device masks diverged from host bookkeeping:\n  "
                + "\n  ".join(mismatches[:10]))
        if tap_stats["rows"] != chunks_run * K:
            raise RuntimeError(
                f"serve tap delivered {tap_stats['rows']}/{chunks_run * K} "
                "rows — an io_callback was dropped or the run was "
                "interrupted mid-chunk")

        toks = np.full((n_req, max_new), -1, np.int32)
        for rid in range(n_req):
            parts = [int(x) for x in L.emitted.get(rid, [])]
            row = L.outputs.get(rid)
            if row is not None:
                parts += [_tok_int(x) for x in row]
            failed = (rid in L.evictions or rid in L.timeouts
                      or rid in L.shed or rid in L.drained)
            if failed:
                if len(parts) > max_new:
                    raise RuntimeError(
                        f"request {rid} streamed {len(parts)} tokens past "
                        f"its {max_new} budget despite degradation")
                toks[rid, :len(parts)] = parts   # −1 from the failure on
            else:
                if len(parts) != max_new:
                    raise RuntimeError(
                        f"request {rid} streamed {len(parts)}/{max_new} "
                        "tokens")
                toks[rid] = parts
        ttft = np.array([L.admit_t[r] - arr[r] if r in L.admit_t else -1
                         for r in range(n_req)], np.int64)
        occ = (L.busy_steps / (L.chunks * K * S)) if L.chunks else 0.0
        if rec is not None:
            self.watch.observe()
            rec.count("requests", n_req)
            rec.count("serve_chunks", chunks_run)
            rec.count("serve_decode_steps", chunks_run * K)
            rec.count("serve_tap_rows", tap_stats["rows"])
            rec.gauge("occupancy_mean", float(occ), lane="server")
            if L.drain_t is not None and drain_ns is not None:
                rec.span_at("drain", "server", drain_ns, rec.now_ns(),
                            t=L.drain_t, cancelled=len(L.drained))
                rec.gauge("drain_final_occupancy", L.in_flight / S,
                          lane="server")
        return ServeResult(tokens=toks, schedule=trace.schedule(),
                           ttft_steps=ttft, occupancy=float(occ),
                           decode_steps=L.chunks * K, chunks=L.chunks,
                           tap_rows=tap_stats["rows"],
                           evictions=dict(L.evictions),
                           timeouts=dict(L.timeouts),
                           shed=dict(L.shed), drained=dict(L.drained),
                           attempts=trace.attempts,
                           resumed_from=resumed_from)
