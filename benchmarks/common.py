"""Shared helpers for the paper-experiment benchmarks (§5 / App. A).

All figure benchmarks run through ``repro.api``: one ``ExperimentSpec`` per
(algorithm, pattern) cell with a grid stepsize policy.  The simulator
backend replays the whole γ-grid against ONE shared schedule in a single
batched scan — the schedule is gradient-value-independent, so the old
rebuild-per-γ Python loop did ``len(grid)×`` redundant work.
"""
from __future__ import annotations

from repro.api import ExperimentSpec, SimulatorBackend, grid
from repro.objectives import LogRegProblem

# the paper's stepsize grid (App. A.1)
PAPER_GRID = (0.005, 0.004, 0.003, 0.002, 0.001, 0.0005, 0.0001)

ALGS = ("pure", "random", "shuffled")


def run_alg(prob: LogRegProblem, alg: str, pattern: str, T: int,
            stepsizes=PAPER_GRID, stochastic: bool = False, seed: int = 0,
            slow_factor: float = 8.0, log_every: int = 100):
    """Grid-search the stepsize (paper protocol: best final grad norm with
    small fluctuations) and return (best_gamma, ts, grad_norms, seconds)."""
    spec = ExperimentSpec(
        scheduler=alg,
        timing=f"{pattern}:slow={slow_factor}",
        objective=prob,
        T=T,
        stepsize=grid(*stepsizes),
        stochastic=stochastic,
        log_every=log_every,
        seed=seed,
    )
    res = SimulatorBackend().run(spec)
    return res.gamma, res.log_ts, res.grad_norms, res.seconds
