"""AsGrad at pod scale: buffered-asynchronous training (DESIGN.md §3/§4).

Mapping of the paper onto a synchronous SPMD pod:

* the ``n`` workers are the data-parallel groups of the mesh (each group owns
  a heterogeneous data shard),
* the assignment rule (pure / random / shuffled / fedbuff) becomes a per-round
  0/1 *participation mask* over the groups, produced by the same
  ``repro.core`` schedulers that drive the exact simulator,
* staleness is the round delay: the gradient applied at round q was computed
  at round q−1's parameters, held in ONE delayed aggregated-gradient buffer
  (exactly Alg 3/5 semantics where every in-flight job shares the round
  boundary point α = ⌊t/b⌋·b) — O(1) extra memory instead of O(τ_C)
  parameter snapshots,
* the fused delayed-update (server step, eq. 2) is the Pallas
  ``async_update`` kernel's target on TPU; here it is the optimizer apply.

``delay_rounds = 0`` recovers synchronous SGD (the paper's baseline).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..faults.guards import GuardConfig
from ..models import model as M
from ..models.specs import Spec, abstract_tree, axes_tree
from ..optim import (OptConfig, adam_init, make_optimizer, make_delayed_apply,
                     global_norm, resolve_update_impl)
from ..optim.pool import (build_layout, init_pools, pool_tree, unpool_tree,
                          pooled_delayed_apply, pooled_update)
from .sharding import (Rules, DEFAULT_RULES, tree_pspecs, tree_shardings,
                       zero_pspec, logical_pspec, pool_axes, pool_shard_count,
                       pooled_pspec)


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    delay_rounds: int = 1          # 0 = synchronous baseline
    delay_adaptive: bool = False   # scale lr by 1/(delay+1) ([32]-style)
    aux_coeff: float = 0.01        # MoE load-balance coefficient
    microbatches: int = 1          # gradient accumulation (memory lever)
    #: None → take ``OptConfig.update_impl``; set to override per-trainer.
    #: ``"pallas"``/``"pallas_interpret"`` route the delayed-buffer apply
    #: through the fused kernels (one HBM pass per tile, gbuf swap included).
    update_impl: Optional[str] = None
    #: device-side guard rails (``repro.faults.GuardConfig``): non-finite
    #: rounds skip the apply mask-style (no host readback) and a per-worker
    #: health vector backs the effective stepsize off after bad receipts.
    #: None compiles the exact unguarded step (no extra state, no checks).
    guards: Optional[GuardConfig] = None


class AsyncTrainer:
    """Composable trainer: (arch config × scheduler) → pjit train_step."""

    #: class-level default so partially-constructed trainers (tests build
    #: bare instances for state_specs) read the tree layout
    pooled = False

    def __init__(self, cfg: ArchConfig, mesh: Mesh,
                 opt: OptConfig = OptConfig(),
                 async_cfg: AsyncConfig = AsyncConfig(),
                 rules: Rules = DEFAULT_RULES):
        self.cfg = cfg
        self.mesh = mesh
        if async_cfg.update_impl is not None:
            opt = dataclasses.replace(opt, update_impl=async_cfg.update_impl)
        self.opt = opt
        self.async_cfg = async_cfg
        self.rules = rules
        self.n_groups = int(np.prod([mesh.shape[a] for a in rules.data_axes
                                     if a in mesh.axis_names])) or 1
        self.update_impl = resolve_update_impl(opt.update_impl)
        #: pooled impls flatten the whole state into per-dtype pool buffers
        #: ONCE here (layout is static per arch × mesh); the update is then
        #: one kernel per dtype pool under shard_map, not one per leaf
        self.pooled = self.update_impl.startswith("pallas_pooled")
        if self.pooled:
            self._pool_interpret = self.update_impl.endswith("_interpret")
            self.pool_axes = pool_axes(mesh, rules)
            self.pool_layout = build_layout(
                abstract_tree(M.param_specs(cfg)),
                pool_shard_count(mesh, rules))
        else:
            self._init_opt, self._update = make_optimizer(opt)
            self._delayed_apply = make_delayed_apply(opt)

    # ------------------------------------------------------------------ specs
    def _pooled_state_specs(self):
        """Pooled state as Specs: per dtype group one (n_shards, cols) pool
        each for p (param dtype), m/v (f32) and — when delayed — gbuf."""
        lay = self.pool_layout

        def pspec_(dk, dtype):
            return Spec((lay.n_shards, lay.cols[dk]), (None, None),
                        "zeros", dtype)

        pools = {}
        for dk in lay.groups:
            grp = {"p": pspec_(dk, dk), "m": pspec_(dk, "float32"),
                   "v": pspec_(dk, "float32")}
            if self.async_cfg.delay_rounds > 0:
                grp["gbuf"] = pspec_(dk, dk)
            pools[dk] = grp
        specs = {
            "pools": pools,
            "opt": {"count": Spec((), (), "zeros", "int32")},
            "step": Spec((), (), "zeros", "int32"),
        }
        if self.async_cfg.guards is not None:
            specs["guard"] = self._guard_specs()
        return specs

    def _guard_specs(self):
        return {"health": Spec((self.n_groups,), (None,), "zeros", "float32")}

    def state_specs(self):
        """State tree as Specs (drives both init and shardings)."""
        if self.pooled:
            return self._pooled_state_specs()
        pspecs = M.param_specs(self.cfg)

        def f32_like(s: Spec):
            return Spec(s.shape, s.axes, "zeros", "float32")

        def grad_like(s: Spec):
            return Spec(s.shape, s.axes, "zeros", s.dtype)

        specs = {
            "params": pspecs,
            "opt": {
                "m": jax.tree_util.tree_map(f32_like, pspecs,
                                            is_leaf=lambda x: isinstance(x, Spec)),
                "v": jax.tree_util.tree_map(f32_like, pspecs,
                                            is_leaf=lambda x: isinstance(x, Spec)),
                "count": Spec((), (), "zeros", "int32"),
            },
            "step": Spec((), (), "zeros", "int32"),
        }
        if self.async_cfg.delay_rounds > 0:
            specs["gbuf"] = jax.tree_util.tree_map(
                grad_like, pspecs, is_leaf=lambda x: isinstance(x, Spec))
        if self.async_cfg.guards is not None:
            specs["guard"] = self._guard_specs()
        return specs

    def state_shardings(self, fsdp_params: bool = True):
        """Params/gbuf are 2D-sharded (model × data, FSDP-style) by default:
        at 314B even bf16 params exceed HBM if only tensor-parallel.  XLA
        inserts the per-layer all-gathers; their cost shows up in §Roofline
        and is a §Perf lever.

        Pooled impls: every pool buffer carries the pooled pspec (rows over
        the data axes — each device owns its ZeRO shard of every leaf)."""
        specs = self.state_specs()
        if self.pooled:
            psh = NamedSharding(self.mesh, pooled_pspec(self.mesh, self.rules))
            scal = NamedSharding(self.mesh, P())
            out = {
                "pools": jax.tree_util.tree_map(
                    lambda s: psh, specs["pools"],
                    is_leaf=lambda x: isinstance(x, Spec)),
                "opt": {"count": scal},
                "step": scal,
            }
            if "guard" in specs:
                out["guard"] = {"health": scal}
            return out
        out = {
            "params": tree_shardings(specs["params"], self.mesh, self.rules,
                                     zero=fsdp_params),
            "opt": {
                "m": tree_shardings(specs["opt"]["m"], self.mesh, self.rules, zero=True),
                "v": tree_shardings(specs["opt"]["v"], self.mesh, self.rules, zero=True),
                "count": NamedSharding(self.mesh, P()),
            },
            "step": NamedSharding(self.mesh, P()),
        }
        if "gbuf" in specs:
            out["gbuf"] = tree_shardings(specs["gbuf"], self.mesh, self.rules,
                                         zero=fsdp_params)
        if "guard" in specs:
            out["guard"] = {"health": NamedSharding(self.mesh, P())}
        return out

    def abstract_state(self):
        return abstract_tree(self.state_specs())

    def init_state(self, key):
        params = M.init_params(self.cfg, key)
        if self.pooled:
            state = {
                "pools": init_pools(self.pool_layout, params,
                                    delayed=self.async_cfg.delay_rounds > 0),
                "opt": {"count": jnp.zeros((), jnp.int32)},
                "step": jnp.zeros((), jnp.int32),
            }
        else:
            state = {
                "params": params,
                "opt": adam_init(params),
                "step": jnp.zeros((), jnp.int32),
            }
            if self.async_cfg.delay_rounds > 0:
                state["gbuf"] = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, p.dtype), params)
        if self.async_cfg.guards is not None:
            # every worker starts at full health (scale 1 = unguarded γ)
            state["guard"] = {
                "health": jnp.ones((self.n_groups,), jnp.float32)}
        return state

    def params_of(self, state):
        """Params tree view of a trainer state, whatever the layout
        (identity on tree states, unpool on pooled states) — for
        checkpoint/eval consumers that expect the tree."""
        if self.pooled:
            return unpool_tree(
                self.pool_layout,
                {dk: b["p"] for dk, b in state["pools"].items()})
        return state["params"]

    # ------------------------------------------------------------- train step
    def _grad_shardings(self):
        pspecs = M.param_specs(self.cfg)
        return tree_shardings(pspecs, self.mesh, self.rules, zero=True)

    def _example_weights(self, mask, batch_size: int):
        """mask (n_groups,) → per-example weights (B,): group g owns the
        contiguous slice [g·B/n, (g+1)·B/n)."""
        per = batch_size // self.n_groups
        return jnp.repeat(mask, per, total_repeat_length=batch_size)

    def train_step_fn(self):
        """The pjit train step.

        ``step(state, batch, mask, delay_scale=None, grad_density=None)``:
        ``delay_scale`` is the optional per-round stepsize scale
        (γ_q = γ·delay_scale_q) fed from the realised schedule's delay
        metadata (:func:`repro.core.round_delay_scales`); omitted, the
        static ``delay_adaptive`` 1/(1+delay_rounds) rule applies.
        ``grad_density`` is the optional per-round keep-density in (0, 1]
        (the ``repro.scenarios`` sparsified-gradients staleness remedy):
        each gradient leaf keeps only its largest-magnitude ``density``
        fraction (per-leaf quantile threshold — the density is traced, so
        k is dynamic and ``top_k`` is unavailable); 1.0 is an exact no-op.
        Sparsification happens BEFORE the ZeRO reshard / pooling, i.e. on
        the gradient the server update consumes.  With
        ``delay_rounds > 0`` the whole server update (eq. 2) — consume the
        stale ``gbuf``, step params/moments, buffer the fresh grads — is one
        :func:`repro.optim.make_delayed_apply` call, which the pallas
        ``update_impl``s execute as one fused HBM pass per tile.

        Pooled impls keep the state in per-dtype pool buffers: params are
        viewed back into the tree for the forward/backward pass (the
        constraint to the per-leaf compute shardings is where XLA inserts
        the FSDP-style gathers), the fresh grads are pooled once, and the
        whole server update runs as one kernel per dtype pool under
        shard_map over the mesh's data axes."""
        cfg, acfg = self.cfg, self.async_cfg
        if self.pooled:
            param_sh = tree_shardings(M.param_specs(cfg), self.mesh,
                                      self.rules, zero=True)
            pool_sh = NamedSharding(self.mesh,
                                    pooled_pspec(self.mesh, self.rules))

        def step(state, batch, mask, delay_scale=None, grad_density=None,
                 fault_gain=None):
            if self.pooled:
                params = unpool_tree(
                    self.pool_layout,
                    {dk: b["p"] for dk, b in state["pools"].items()},
                    shardings=param_sh)
            else:
                params = state["params"]
            bsz = batch["tokens"].shape[0]
            w = self._example_weights(mask.astype(jnp.float32), bsz)
            if fault_gain is not None:
                # fault channel: multiplicative gain on the round's RECEIVED
                # contribution (huge = inflated corrupted receipt, NaN =
                # poisoned).  Folding the gain into the example weights
                # would cancel in the CE's weight normalisation, so the
                # participation-weighted mean gain scales the post-
                # normalisation loss/grads instead (below).  Gate on the
                # mask so a non-participant's gain (even NaN) cannot leak.
                part = mask.astype(jnp.float32)
                gain = jnp.where(part > 0,
                                 jnp.asarray(fault_gain, jnp.float32), 1.0)
                fault_c = jnp.where(
                    jnp.sum(part) > 0,
                    jnp.sum(part * gain) / jnp.maximum(jnp.sum(part), 1e-6),
                    1.0)
            else:
                fault_c = None

            def lfn(p, b, wslice):
                return M.loss_fn(cfg, p, b, example_weights=wslice,
                                 aux_coeff=acfg.aux_coeff)

            k = acfg.microbatches
            if k > 1 and bsz % k == 0:
                # gradient accumulation: scan over k microbatches — peak
                # activation memory drops ~k×, grads accumulated in f32
                def split(x):
                    return x.reshape((k, bsz // k) + x.shape[1:])

                mb = jax.tree_util.tree_map(split, batch)
                wb = split(w)

                def acc_step(carry, inp):
                    g_acc, l_acc, a_acc = carry
                    b_i, w_i = inp
                    (l, parts_i), g = jax.value_and_grad(
                        lfn, has_aux=True)(params, b_i, w_i)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, x: a + x.astype(jnp.float32) / k, g_acc, g)
                    return (g_acc, l_acc + l / k, a_acc + parts_i["aux"] / k), None

                gsh = self._grad_shardings()
                g0 = jax.tree_util.tree_map(
                    lambda p, s: jax.lax.with_sharding_constraint(
                        jnp.zeros(p.shape, jnp.float32), s),
                    params, gsh)
                (g32, loss, aux), _ = jax.lax.scan(
                    acc_step, (g0, 0.0, 0.0), (mb, wb))
                grads = jax.tree_util.tree_map(
                    lambda g, p: g.astype(p.dtype), g32, params)
                parts = {"ce": loss, "aux": aux}
            else:
                (loss, parts), grads = jax.value_and_grad(
                    lfn, has_aux=True)(params, batch, w)
            if fault_c is not None:
                # the corrupted/poisoned receipt: everything the server
                # "receives" this round is scaled — grads (what the update
                # consumes) and the reported loss components alike, so the
                # breaker and the guard see exactly what the step applies
                loss = loss * fault_c
                parts = {k: v * fault_c for k, v in parts.items()}
                grads = jax.tree_util.tree_map(
                    lambda g: g * fault_c.astype(g.dtype), grads)
            if grad_density is not None:
                # magnitude top-k per leaf at traced density: threshold at
                # the (1 − density)-quantile of |g| and zero everything
                # below it.  density = 1 ⇒ threshold = min|g| ⇒ keep-all
                # (g·1.0 is bitwise identity), so a neutral channel row
                # changes nothing.
                dens = jnp.clip(jnp.asarray(grad_density, jnp.float32),
                                0.0, 1.0)

                def sparsify(g):
                    a = jnp.abs(g.astype(jnp.float32)).reshape(-1)
                    thr = jnp.quantile(a, 1.0 - dens)
                    keep = jnp.abs(g.astype(jnp.float32)) >= thr
                    return g * keep.astype(g.dtype)

                grads = jax.tree_util.tree_map(sparsify, grads)
            if acfg.guards is not None:
                # guard rails, all mask-style (no host readback): a round
                # whose loss or raw grad norm is non-finite is SKIPPED via
                # the old-vs-new state select below, which keeps every
                # leaf — params, moments AND the delay buffer — at its
                # previous value, so nothing non-finite survives the round
                # (zeroing the grads here too would just spend an extra
                # pass on values the select is about to discard).  The
                # norm check must run on the FRESH grads, pre-apply: the
                # delayed path's own gnorm is the stale buffer's, and a
                # poisoned receipt has to be caught before it is buffered.
                # Health: participants of a bad round (non-finite, or a
                # finite norm spike past spike_norm) back off; clean
                # participants recover toward 1.
                gd = acfg.guards
                raw_norm = global_norm(grads)
                finite = jnp.isfinite(loss) & jnp.isfinite(raw_norm)
                bad = ~finite
                if gd.spike_norm is not None:
                    bad = bad | (raw_norm > gd.spike_norm)
                part = mask.astype(jnp.float32)
                h = state["guard"]["health"]
                gscale = jnp.sum(h * part) / jnp.maximum(part.sum(), 1.0)
                h_next = jnp.clip(
                    jnp.where(part > 0,
                              jnp.where(bad, h * gd.backoff,
                                        jnp.minimum(h * gd.recover, 1.0)),
                              h),
                    gd.min_scale, 1.0)
                skipped = 1.0 - finite.astype(jnp.float32)
            else:
                finite = None
                gscale = jnp.float32(1.0)
                skipped = jnp.float32(0.0)
            if delay_scale is not None:
                lr_scale = jnp.asarray(delay_scale, jnp.float32)
            elif acfg.delay_adaptive and acfg.delay_rounds > 0:
                lr_scale = 1.0 / (1.0 + acfg.delay_rounds)
            else:
                lr_scale = 1.0

            # skip the very first round (empty buffer) via a smooth gate
            gate = jnp.where(
                (state["step"] == 0) & (acfg.delay_rounds > 0), 0.0, 1.0)
            if acfg.guards is not None:
                # participation-weighted mean health scales this round's γ
                gate = gate * gscale

            def _apply_update(_):
                # ZeRO: reshard grads to the optimizer-state sharding before
                # the update (reduce-scatter) — clip/Adam f32 temps shrink by
                # the data-axis factor, which is what makes 314B fit.  The
                # pooled path reshards straight into pool layout instead: one
                # concat pass, constrained so each device materialises only
                # its rows
                if self.pooled:
                    grad_pools = pool_tree(self.pool_layout, grads,
                                           sharding=pool_sh)
                    apply = pooled_delayed_apply if acfg.delay_rounds > 0 \
                        else pooled_update
                    new_pools, new_count, gnorm = apply(
                        grad_pools, state["pools"], state["opt"]["count"],
                        self.opt, lr_scale=lr_scale * gate, mesh=self.mesh,
                        axes=self.pool_axes, interpret=self._pool_interpret)
                    return {
                        "pools": new_pools,
                        "opt": {"count": new_count},
                        "step": state["step"] + 1,
                    }, gnorm
                g = jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, grads,
                    self._grad_shardings())
                if acfg.delay_rounds > 0:
                    # one fused apply: consume the stale buffer, write the
                    # fresh grads back (reference impl composes the same
                    # semantics)
                    new_params, new_gbuf, new_opt, gnorm = \
                        self._delayed_apply(
                            g, state["gbuf"], state["opt"], params,
                            self.opt, lr_scale=lr_scale * gate)
                    return {
                        "params": new_params,
                        "opt": new_opt,
                        "step": state["step"] + 1,
                        "gbuf": new_gbuf,
                    }, gnorm
                new_params, new_opt, gnorm = self._update(
                    g, state["opt"], params, self.opt,
                    lr_scale=lr_scale * gate)
                return {
                    "params": new_params,
                    "opt": new_opt,
                    "step": state["step"] + 1,
                }, gnorm

            if acfg.guards is None:
                new_state, gnorm = _apply_update(None)
            else:
                # skipped round: every leaf keeps its previous value — the
                # cond's false branch passes the old state straight through,
                # so under the round scan a clean round pays one branch
                # dispatch (not an old-vs-new select pass over every leaf)
                # and a poisoned round skips the apply entirely.  Under the
                # grid lane's vmap the cond lowers back to a select — both
                # branches run, exactly the old cost.  The step counter
                # always advances, and the health vector is how the skip is
                # charged; a skipped round reports grad_norm 0 (no gradient
                # was applied).
                def _skip(_):
                    old = {k: v for k, v in state.items() if k != "guard"}
                    old["step"] = state["step"] + 1
                    return old, jnp.float32(0.0)

                new_state, gnorm = jax.lax.cond(
                    finite, _apply_update, _skip, None)
                new_state["guard"] = {"health": h_next}
            metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"],
                       "grad_norm": gnorm,
                       "participation": jnp.mean(mask.astype(jnp.float32)),
                       "skipped": skipped, "gscale": gscale}
            return new_state, metrics

        from .sharding import sharded_trace
        return sharded_trace(step, self.mesh, self.rules)

    def jit_train_step(self, batch_shape, donate: bool = True,
                       with_delay_scale: bool = False,
                       with_grad_density: bool = False,
                       with_fault_gain: bool = False):
        """pjit-compiled train step for a (batch, seq) shape.

        The compiled signature is exactly positional: ``step(state, batch,
        mask)`` plus one replicated traced extra per enabled channel, in
        the fixed order ``delay_scale`` (per-round stepsize scale), then
        ``grad_density`` (per-round gradient keep-density), then
        ``fault_gain`` (per-worker loss-weight gains) — each present only
        when its ``with_*`` flag is on, the remaining channels pinned to
        None inside (so e.g. density-without-scale leaves the trainer's
        static stepsize rule in charge)."""
        bspecs = M.batch_specs(self.cfg, *batch_shape)
        batch_sh = tree_shardings(bspecs, self.mesh, self.rules)
        state_sh = self.state_shardings()
        repl = NamedSharding(self.mesh, P())
        step = self.train_step_fn()
        names = [n for n, on in (("delay_scale", with_delay_scale),
                                 ("grad_density", with_grad_density),
                                 ("fault_gain", with_fault_gain)) if on]

        def fn_(state, batch, mask, *extras):
            return step(state, batch, mask, **dict(zip(names, extras)))

        in_sh = (state_sh, batch_sh, repl) + (repl,) * len(names)
        fn = jax.jit(
            fn_,
            in_shardings=in_sh,
            out_shardings=(state_sh, None),
            donate_argnums=(0,) if donate else (),
        )
        return fn

    # ------------------------------------------------------------- input specs
    def batch_struct(self, batch: int, seq: int):
        specs = M.batch_specs(self.cfg, batch, seq)
        sh = tree_shardings(specs, self.mesh, self.rules)
        ab = abstract_tree(specs)
        return jax.tree_util.tree_map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            ab, sh)
