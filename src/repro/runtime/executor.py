"""Streaming whole-run executor: K rounds per XLA launch, three metric paths.

The eager dispatch loop pays three per-round costs the hardware never asked
for: a Python dispatch of the jitted step, a host-built batch shipped to
device, and a device→host sync to read the metrics.  The scan executor
removes all three — the :class:`RunPlan` is device-resident, batches are
synthesised on device from the plan's folded PRNG keys, and how metrics
reach the host is the ``metrics`` mode:

* ``"chunk"`` (default) — metrics accumulate into the stacked ys of the
  scan and cross to host once per chunk.  With an ``on_step`` callback the
  host blocks on every chunk (the PR-4 path: callbacks see values, so the
  readback is the barrier); WITHOUT a callback the host never blocks
  mid-run — chunk c+1 is enqueued while chunk c executes (the carry is
  donated, so XLA chains the launches) and all metric buffers are read
  back at the end in ONE sync.
* ``"tap"`` — a :func:`jax.experimental.io_callback` inside the scan body
  streams each round's metric row to the host as the device reaches it.
  ``on_step`` fires per ROUND (not per chunk) with no readback barrier at
  all, which is what lets ``rounds_per_launch`` grow to the whole run
  while keeping live logging.  The callback sees metric values only — the
  mid-scan train state never materialises on host, so ``on_step`` receives
  ``state=None`` (checkpoint barriers need ``"chunk"``).
* ``"none"`` — the scan body discards metrics entirely: zero host syncs,
  zero tap events, the fastest path when only the final state matters.

``rounds_per_launch`` (K) is the dispatch-vs-control-granularity trade-off:
K = 1 degenerates to eager dispatch, K = rounds is one launch for the whole
run, and intermediate K bounds retrace cost and (in ``"chunk"`` mode) sets
the ``on_step``/checkpoint barrier cadence.

:func:`PlanExecutor.run_grid` is the vmapped γ-grid lane: a plan compiled
with a γ-axis (``compile_plan(..., grid_gammas=...)``) executes ALL grid
points in one compiled program — the chunk body is ``vmap``-ed over the
per-γ state and per-γ stepsize scales while the plan's masks, keys and
synthesised batches stay shared, exactly mirroring the simulator tier's
batched grid search.

:func:`run_eager` is the same plan executed one round per launch — the
parity oracle the scan executor is gated against (same step function, same
device-synthesised batches, same plan slices; only the dispatch differs).
"""
from __future__ import annotations

import dataclasses
from contextlib import nullcontext
from typing import Callable, Optional

import numpy as np

from ..obs import CompileWatch
from .plan import RunPlan

#: fixed metric order of the on-device accumulator row; mirrors the dict
#: returned by ``AsyncTrainer.train_step_fn`` (``skipped``/``gscale`` are
#: the guard-rail channels — 0.0/1.0 on an unguarded trainer)
METRICS = ("loss", "ce", "aux", "grad_norm", "participation",
           "skipped", "gscale")

_LOSS_IDX = METRICS.index("loss")
_SKIP_IDX = METRICS.index("skipped")
_GSCALE_IDX = METRICS.index("gscale")


def _span(rec, name, lane, **args):
    """Optional-recorder span: a real span when observing, else a no-op
    (un-observed runs must pay nothing on the dispatch path)."""
    return rec.span(name, lane, **args) if rec is not None else nullcontext()

#: metric transport modes of the scan executor
METRIC_MODES = ("chunk", "tap", "none")


@dataclasses.dataclass
class ExecStats:
    """Honest dispatch accounting, one counter per mechanism.

    * ``launches`` — XLA dispatches of the train step / chunk program.
      The eager loop's separate batch-synthesis jit is NOT counted (it is
      a synthesis detail, not a round dispatch — the scan executor fuses
      it into the chunk, so counting it would make the eager/scan columns
      incomparable).
    * ``host_syncs`` — times the host BLOCKED on a device→host metric
      readback mid-run (eager: every round; scan ``"chunk"`` with
      ``on_step``: every chunk; scan ``"chunk"`` without ``on_step``: one
      deferred readback at the end; ``"tap"``/``"none"``: zero — the
      end-of-run ``block_until_ready`` on the carried state is a
      completion barrier, not a metric transfer).
    * ``tap_events`` — metric rows streamed host-ward by the io_callback
      tap (one per round in ``"tap"`` mode, zero otherwise).
    * ``snapshots`` — async device snapshots offered to the run's
      :class:`repro.checkpoint.AsyncSnapshotter` (zero without one).
    * ``tripped_round`` — round at which the divergence breaker tripped
      through the tap lane (None = never tripped / no breaker): the run
      stopped launching after the chunk containing it.
    """

    launches: int = 0
    host_syncs: int = 0
    tap_events: int = 0
    snapshots: int = 0
    tripped_round: Optional[int] = None


@dataclasses.dataclass
class ExecResult:
    """Final carried state + per-round metric curves (host numpy).

    ``metrics`` maps each name in :data:`METRICS` to a ``(rounds,)`` array
    — or ``(n_grid, rounds)`` for :meth:`PlanExecutor.run_grid` results —
    and is EMPTY under ``metrics="none"``.
    """

    state: object
    metrics: dict
    stats: ExecStats = dataclasses.field(default_factory=ExecStats)

    # convenience views (older call sites and the benches read these)
    @property
    def launches(self) -> int:
        return self.stats.launches

    @property
    def host_syncs(self) -> int:
        return self.stats.host_syncs

    @property
    def tap_events(self) -> int:
        return self.stats.tap_events

    @property
    def rows(self) -> list:
        """Metrics as one dict per round (the eager loop's legacy shape).
        Only defined for single-run (1-D) curves — grid results keep the
        (n_grid, rounds) arrays."""
        if not self.metrics:
            return []
        first = next(iter(self.metrics.values()))
        if first.ndim != 1:
            raise ValueError(
                "rows is a single-run view; grid results carry "
                f"(n_grid, rounds) curves (got shape {first.shape})")
        return [{k: float(v[i]) for k, v in self.metrics.items()}
                for i in range(len(first))]


def make_batch_fn(plan: RunPlan, cfg) -> Callable:
    """``batch_of(key, cdf_i=None) -> batch dict``, entirely on device.

    Tokens: inverse-CDF Zipf draws (``searchsorted`` on the plan's
    cumulative pmf) pushed through each group's vocab permutation — the
    same marginal law and heterogeneity structure as the host
    ``HeterogeneousTokenPipeline``, as a pure jittable function of the
    round key.  Non-token modalities (vision patches / audio frames) are
    the same stubbed normal draws the host path used, keyed per-modality
    via ``fold_in``.

    ``cdf_i`` is the data-drift phase index (``plan.cdf_index[q]``): on a
    drifting plan round q samples from ``cdf_bank[cdf_i]`` — one extra
    device gather — instead of the static ``token_cdf``.  Static plans
    ignore it, so stationary call sites stay one-argument.
    """
    import jax
    import jax.numpy as jnp
    from ..models import batch_specs

    specs = batch_specs(cfg, plan.global_batch, plan.seq_len)
    cdf = jnp.asarray(plan.token_cdf)
    bank = None if plan.cdf_bank is None else jnp.asarray(plan.cdf_bank)
    perms = jnp.asarray(plan.group_perms)
    per = plan.global_batch // plan.n_groups
    gidx = jnp.repeat(jnp.arange(plan.n_groups), per)

    def batch_of(key, cdf_i=None):
        cdf_q = cdf if bank is None or cdf_i is None else bank[cdf_i]
        out = {}
        for j, (k, sp) in enumerate(sorted(specs.items())):
            kj = jax.random.fold_in(key, j)
            if sp.dtype == "int32":          # tokens (possibly shortened)
                u = jax.random.uniform(kj, (plan.global_batch, sp.shape[1]))
                ranks = jnp.clip(jnp.searchsorted(cdf_q, u), 0,
                                 cdf_q.shape[0] - 1).astype(jnp.int32)
                out[k] = perms[gidx[:, None], ranks]
            else:                            # stubbed modality embeddings
                out[k] = jax.random.normal(kj, sp.shape, jnp.float32)
        return out

    return batch_of


def _metrics_row(m: dict):
    import jax.numpy as jnp
    return jnp.stack([jnp.asarray(m[k], jnp.float32) for k in METRICS])


def _row_dict(row) -> dict:
    return {k: float(v) for k, v in zip(METRICS, row)}


def _chunk_bounds(rounds: int, rounds_per_launch: int, start: int):
    k = max(int(rounds_per_launch), 1)
    lo = start
    while lo < rounds:
        hi = min(lo + k, rounds)
        yield lo, hi
        lo = hi


class PlanExecutor:
    """Holds the compiled artifacts for one (trainer × plan): build once,
    run many.  The jitted chunk programs are cached on the instance (one
    per metric mode, plus one per grid width), so repeated runs
    (benchmark warm timings, grid restarts, resumed runs) pay
    tracing/compilation only on first use per (mode, chunk length) — a
    fresh closure per run would silently recompile every time.
    """

    def __init__(self, trainer, plan: RunPlan, *, donate: bool = True,
                 recorder=None):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        self.trainer = trainer
        self.plan = plan
        self.donate = donate
        self.recorder = recorder      # repro.obs.Recorder | None
        self.watch = CompileWatch(recorder)   # retrace sentinel over the jits
        self._batch_of = make_batch_fn(plan, trainer.cfg)
        self._repl = NamedSharding(trainer.mesh, P())   # plan slices
        self._step = trainer.train_step_fn()
        self._eager = None            # lazily built parity-oracle pair
        self._chunk_jits = {}         # metric mode -> jitted chunk
        self._grid_jits = {}          # (n_grid, mode) -> jitted grid chunk
        self._stack_jit = None        # cached γ-axis state tiler
        self._tap_sink = None         # per-run host consumer of tap rows

    def compile_counts(self) -> dict:
        """Traced-signature counts of the cached jits (the executor twin
        of ``SlotServer.compile_counts`` — warm reruns must not grow
        these beyond the first run's, incl. its ragged-tail length)."""
        return self.watch.counts()

    # ------------------------------------------------------------- chunk body
    def _scan_body(self, *, force_scale: bool = False):
        """Shared round body: synthesise batch, pin it replicated, step.

        The pin matters: GSPMD otherwise propagates the data-axis sharding
        back into the RNG ops, and legacy (non-partitionable) threefry
        generates DIFFERENT bits per shard than the replicated generation
        the eager oracle uses — 2% loss divergence, not FMA noise.

        ``force_scale``: only an ADAPTIVE plan carries a real per-round
        γ-scale; for a neutral plan the step is called 3-arg so the
        trainer's own static ``AsyncConfig.delay_adaptive`` rule stays in
        charge (an explicit all-ones scale would silently override it).
        The γ-grid lane forces the explicit-scale step — its scale rows
        ARE the whole stepsize policy per grid point.  A sparsified plan
        (``grad_density`` channel) also forces it: the density is the
        step's 5th positional argument, so the scale slot must be filled
        (scan and eager agree, so parity is unaffected).

        Scenario channels ride the same xs dict: ``xs["cdf"]`` (data-drift
        phase index) feeds the batch synthesiser, ``xs["dens"]``
        (keep-density) feeds the step's sparsifier, ``xs["gain"]``
        (per-worker fault gains) feeds the step's fault channel.
        """
        import jax

        step, batch_of, repl = self._step, self._batch_of, self._repl
        with_density = self.plan.grad_density is not None
        with_gain = self.plan.fault_gain is not None
        with_scale = self.plan.adaptive or force_scale or with_density

        def body(st, xs):
            batch = jax.tree_util.tree_map(
                lambda x: jax.lax.with_sharding_constraint(x, repl),
                batch_of(xs["key"], xs.get("cdf")))
            kw = {}
            if with_scale:
                kw["delay_scale"] = xs["scale"]
            if with_density:
                kw["grad_density"] = xs["dens"]
            if with_gain:
                kw["fault_gain"] = xs["gain"]
            st, m = step(st, batch, xs["mask"], **kw)
            return st, m

        return body

    def _emit_tap(self, idx, row):
        """Host side of the io_callback tap (bound once so the jitted
        program is stable across runs; the per-run consumer swaps in via
        ``_tap_sink``)."""
        sink = self._tap_sink
        if sink is not None:
            sink(int(idx), np.asarray(row))

    def _chunk_jit(self, mode: str):
        """Jitted ``chunk(state, xs)`` for one metric mode, where ``xs``
        is the per-round slice dict from :meth:`_slices`; ``"chunk"``
        additionally returns the stacked metric rows."""
        if mode in self._chunk_jits:
            return self._chunk_jits[mode]
        import jax
        from jax.experimental import io_callback

        body = self._scan_body()
        emit = self._emit_tap

        def round_fn(st, xs):
            st, m = body(st, xs)
            if mode == "chunk":
                return st, _metrics_row(m)
            if mode == "tap":
                # ordered: rows must reach the host in round order (the
                # sink builds the curve and fires on_step sequentially)
                io_callback(emit, None, xs["idx"], _metrics_row(m),
                            ordered=True)
            return st, None

        def chunk(state, xs):
            state, ys = jax.lax.scan(round_fn, state, xs)
            return (state, ys) if mode == "chunk" else state

        state_sh = self.trainer.state_shardings()
        # self._repl is a pytree PREFIX: every plan slice in xs replicated
        fn = self.watch.wrap(f"chunk[{mode}]", jax.jit(
            chunk,
            in_shardings=(state_sh, self._repl),
            out_shardings=(state_sh, None) if mode == "chunk" else state_sh,
            donate_argnums=(0,) if self.donate else ()))
        self._chunk_jits[mode] = fn
        return fn

    def _grid_jit(self, n_grid: int, mode: str):
        """Jitted ``chunk(states, shared, grid_scales)`` vmapped over the
        γ-axis: states carry a leading ``(n_grid,)`` axis, ``grid_scales``
        is ``(n_grid, K)``, and the shared xs dict (masks, keys, scenario
        channels, batches) is broadcast across grid points (the ordering
        and the data stream do not depend on γ — the same observation
        behind the simulator tier's batched ``replay_grid``)."""
        key = (n_grid, mode)
        if key in self._grid_jits:
            return self._grid_jits[key]
        import jax

        body = self._scan_body(force_scale=True)

        def one_gamma(st, scales, shared):
            def round_fn(s, xs):
                s, m = body(s, xs)
                return s, (_metrics_row(m) if mode == "chunk" else None)

            return jax.lax.scan(round_fn, st, dict(shared, scale=scales))

        def chunk(states, shared, grid_scales):
            states, ys = jax.vmap(one_gamma, in_axes=(0, 0, None))(
                states, grid_scales, shared)
            return (states, ys) if mode == "chunk" else states

        fn = self.watch.wrap(f"grid[{n_grid},{mode}]",
                             jax.jit(chunk, donate_argnums=(0,)
                                     if self.donate else ()))
        self._grid_jits[key] = fn
        return fn

    def _slices(self, lo: int, hi: int) -> dict:
        """Per-round xs dict for rounds ``[lo, hi)``: always idx / mask /
        key / scale, plus the plan's scenario channels when present."""
        import jax.numpy as jnp

        masks, keys, scales = self.plan.device_slices(lo, hi)
        xs = {"idx": jnp.arange(lo, hi, dtype=jnp.int32),
              "mask": masks, "key": keys, "scale": scales}
        if self.plan.cdf_index is not None:
            xs["cdf"] = jnp.asarray(self.plan.cdf_index[lo:hi])
        if self.plan.grad_density is not None:
            xs["dens"] = jnp.asarray(self.plan.grad_density[lo:hi])
        if self.plan.fault_gain is not None:
            xs["gain"] = jnp.asarray(self.plan.fault_gain[lo:hi])
        return xs

    def _maybe_snapshot(self, snapshot, hi: int, state, stats) -> None:
        """Offer the end-of-chunk carry to the async snapshotter.  The
        offer dispatches a non-donating device copy and starts the host
        fetch, then returns — the device pipeline never drains (the next
        chunk is already free to launch), which is the barrier-free
        durability contract."""
        if snapshot is not None and snapshot.due(hi, self.plan.rounds):
            with _span(self.recorder, "snapshot_offer", "snapshot",
                       round=hi):
                snapshot.offer(hi, state)
            stats.snapshots += 1

    def _attach_obs(self, snapshot, breaker=None) -> None:
        """Thread this run's recorder into the collaborators that emit
        their own spans (snapshot finalise happens inside the
        snapshotter, possibly a whole cadence after the offer)."""
        rec = self.recorder
        if rec is None:
            return
        if snapshot is not None and getattr(snapshot, "recorder",
                                            None) is None:
            snapshot.recorder = rec

    def _record_stats(self, stats: "ExecStats", rounds: int) -> None:
        """Fold the run's dispatch accounting into the obs counters (and
        let the retrace sentinel stamp any compile events it missed)."""
        rec = self.recorder
        if rec is None:
            return
        self.watch.observe()
        rec.count("rounds", rounds)
        rec.count("launches", stats.launches)
        rec.count("host_syncs", stats.host_syncs)
        rec.count("tap_events", stats.tap_events)
        rec.count("snapshots", stats.snapshots)

    # ------------------------------------------------------------------ scan
    def run_scan(self, state, *, rounds_per_launch: int = 8,
                 metrics: str = "chunk",
                 on_step: Optional[Callable] = None,
                 start_round: int = 0,
                 snapshot=None, breaker=None) -> ExecResult:
        """Execute plan rounds ``[start_round, rounds)``, K per launch.

        One XLA launch covers K = ``rounds_per_launch`` rounds; the
        carried state is donated launch-to-launch (the chunk's input
        buffers are reused, so state never doubles in memory).  A ragged
        tail (``rounds % K != 0``) costs at most one extra compile for the
        remainder length.

        ``metrics`` selects the transport (module docstring):

        * ``"chunk"`` — ``on_step(i, state, metrics_i)`` fires for every
          round at chunk boundaries with the END-of-chunk state
          (checkpoint barriers land on multiples of K; align
          ``ckpt_every`` with K for exact-resume semantics).  Without
          ``on_step`` the host never blocks mid-run: chunks overlap and
          ONE deferred readback at the end assembles the curves.
        * ``"tap"`` — ``on_step(i, None, metrics_i)`` fires per round from
          the device-side tap; no mid-run readback, state is not
          available to the callback.
        * ``"none"`` — no metrics at all (``on_step`` is rejected).

        ``start_round > 0`` resumes mid-plan: the data keys are a pure
        function of (seed, round), so a restored run regenerates the
        identical batch stream.  ``start_round == rounds`` is an exact
        no-op (zero launches, empty curves, state returned untouched).

        ``snapshot`` (any metric mode) is a
        :class:`repro.checkpoint.AsyncSnapshotter`: chunk-boundary carries
        it declares due are offered barrier-free — a non-donating device
        copy plus an async host fetch, finalised to an atomic checkpoint
        while later chunks keep the device busy — which is what gives
        ``"tap"``/``"none"`` runs durability without mid-run host
        barriers.  ``breaker`` (tap mode only) is a
        :class:`repro.faults.DivergenceBreaker` fed each round's loss from
        the tap sink; once tripped, no further chunks are launched
        (enqueued ones drain normally) and the trip round is reported in
        ``stats.tripped_round`` with the curves truncated to the rounds
        actually launched.
        """
        import jax

        if metrics not in METRIC_MODES:
            raise ValueError(f"unknown metrics mode {metrics!r}; want one "
                             f"of {METRIC_MODES}")
        if metrics == "none" and on_step is not None:
            raise ValueError(
                'metrics="none" discards metrics on device; an on_step '
                'callback would never fire — use "tap" or "chunk"')
        if breaker is not None and metrics != "tap":
            raise ValueError(
                'the divergence breaker trips through the tap lane — run '
                'with metrics="tap" (chunk/none never stream per-round '
                'losses to the host mid-run)')
        plan = self.plan
        fn = self._chunk_jit(metrics)
        stats = ExecStats()
        rec = self.recorder
        self._attach_obs(snapshot, breaker)
        bounds = list(_chunk_bounds(plan.rounds, rounds_per_launch,
                                    start_round))

        if metrics == "tap":
            tap_rows = {}
            tripped_seen = [False]

            def sink(i, row):
                tap_rows[i] = row
                stats.tap_events += 1
                if rec is not None:
                    # host boundary that already exists (the io_callback
                    # sink runs per round regardless) — one instant, plus
                    # the guard-rail channels when they fire
                    rec.instant("tap_round", lane="tap", round=i)
                    if row[_SKIP_IDX] > 0:
                        rec.instant("guard_skip", lane="faults", round=i,
                                    gscale=float(row[_GSCALE_IDX]))
                    elif row[_GSCALE_IDX] != 1.0:
                        rec.gauge("gscale", float(row[_GSCALE_IDX]),
                                  lane="faults")
                if breaker is not None:
                    breaker.observe(i, row[_LOSS_IDX])
                    if breaker.tripped and not tripped_seen[0]:
                        tripped_seen[0] = True
                        if rec is not None:
                            rec.instant("breaker_trip", lane="faults",
                                        round=breaker.tripped_round)
                if on_step is not None:
                    on_step(i, None, _row_dict(row))

            launched_hi = start_round
            self._tap_sink = sink
            try:
                for lo, hi in bounds:
                    if breaker is not None and breaker.tripped:
                        break               # stop launching; queue drains
                    with _span(rec, "launch", "executor", lo=lo, hi=hi):
                        state = fn(state, self._slices(lo, hi))
                    stats.launches += 1
                    launched_hi = hi
                    self._maybe_snapshot(snapshot, hi, state, stats)
                # completion barrier (not a metric transfer): flushes the
                # enqueued chunks, then drains the callback queue — array
                # readiness alone does NOT guarantee pending io_callbacks
                # have run on every backend
                with _span(rec, "barrier", "executor"):
                    state = jax.block_until_ready(state)
                    jax.effects_barrier()
            finally:
                self._tap_sink = None
            if snapshot is not None:
                snapshot.drain()
            if breaker is not None:
                stats.tripped_round = breaker.tripped_round
            n_rounds = launched_hi - start_round
            if len(tap_rows) != n_rounds:
                raise RuntimeError(
                    f"metrics tap delivered {len(tap_rows)}/{n_rounds} "
                    f"rows — an io_callback was dropped or the run was "
                    f"interrupted mid-chunk")
            all_ms = (np.stack([tap_rows[i] for i in
                                range(start_round, launched_hi)])
                      if n_rounds else np.zeros((0, len(METRICS)),
                                                np.float32))
            self._record_stats(stats, n_rounds)
            return ExecResult(
                state=state,
                metrics={k: all_ms[:, j] for j, k in enumerate(METRICS)},
                stats=stats)

        if metrics == "none":
            for lo, hi in bounds:
                with _span(rec, "launch", "executor", lo=lo, hi=hi):
                    state = fn(state, self._slices(lo, hi))
                stats.launches += 1
                self._maybe_snapshot(snapshot, hi, state, stats)
            with _span(rec, "barrier", "executor"):
                state = jax.block_until_ready(state)
            if snapshot is not None:
                snapshot.drain()
            self._record_stats(stats,
                               bounds[-1][1] - start_round if bounds else 0)
            return ExecResult(state=state, metrics={}, stats=stats)

        # metrics == "chunk"
        rows = []
        for lo, hi in bounds:
            with _span(rec, "launch", "executor", lo=lo, hi=hi):
                state, ms = fn(state, self._slices(lo, hi))
            stats.launches += 1
            self._maybe_snapshot(snapshot, hi, state, stats)
            if on_step is not None:
                with _span(rec, "host_sync", "executor", lo=lo, hi=hi):
                    ms = np.asarray(ms)      # blocking readback per chunk
                stats.host_syncs += 1
                for i in range(lo, hi):
                    on_step(i, state, _row_dict(ms[i - lo]))
            rows.append(ms)                  # device buffer when deferred
        if on_step is None and rows:
            # overlapped path: every chunk is already enqueued; block once
            # and read all metric buffers back in one sync point
            with _span(rec, "host_sync", "executor", deferred=True):
                rows = [np.asarray(r) for r in jax.block_until_ready(rows)]
            stats.host_syncs = 1
        with _span(rec, "barrier", "executor"):
            state = jax.block_until_ready(state)
        if snapshot is not None:
            snapshot.drain()
        all_ms = np.concatenate([np.asarray(r) for r in rows], axis=0) \
            if rows else np.zeros((0, len(METRICS)), np.float32)
        if rec is not None and all_ms.size:
            # guard-skip events from the materialised rows (the chunk
            # transport has no per-round host boundary; args carry the
            # round, the timestamp is the readback that surfaced it)
            for i in np.nonzero(all_ms[:, _SKIP_IDX] > 0)[0]:
                rec.instant("guard_skip", lane="faults",
                            round=int(i) + start_round,
                            gscale=float(all_ms[i, _GSCALE_IDX]))
        self._record_stats(stats, int(all_ms.shape[0]))
        return ExecResult(
            state=state,
            metrics={k: all_ms[:, j] for j, k in enumerate(METRICS)},
            stats=stats)

    # ------------------------------------------------------------------ grid
    def stack_state(self, state):
        """Tile one initial state with a leading ``(n_grid,)`` axis — every
        grid point starts from the same iterate, as in the sequential
        grid search.  The tiler jit is cached on the executor: a fresh
        closure per call would retrace (and recompile) every run."""
        import jax
        import jax.numpy as jnp

        if self._stack_jit is None:
            g = self.plan.n_grid
            self._stack_jit = self.watch.wrap("stack_state", jax.jit(
                lambda s: jax.tree_util.tree_map(
                    lambda x: jnp.repeat(x[None], g, axis=0), s)))
        return self._stack_jit(state)

    def run_grid(self, state, *, rounds_per_launch: int = 8,
                 metrics: str = "chunk",
                 start_round: int = 0, snapshot=None) -> ExecResult:
        """Execute ALL grid points of a γ-axis plan in one compiled
        program per chunk (vmap over γ).

        ``state`` may be a single trainer state (tiled via
        :meth:`stack_state`) or an already-stacked ``(n_grid, ...)`` tree
        (a resumed grid run).  Metrics come back as ``(n_grid, rounds)``
        curves under ``"chunk"`` (deferred single readback — there is no
        per-γ ``on_step``; the grid lane is a search, not a logging loop)
        or not at all under ``"none"``.  ``"tap"`` is rejected: io_callback
        rows interleave unordered across vmapped lanes, so a per-round
        stream would be misleading.

        ``snapshot`` offers the STACKED ``(n_grid, ...)`` carry at due
        chunk boundaries — a restored grid snapshot feeds straight back in
        as the already-stacked state of a resumed grid run.
        """
        import jax

        plan = self.plan
        if plan.grid_scales is None:
            raise ValueError(
                "plan has no γ-axis; compile it with grid_gammas=... to "
                "use the grid lane")
        if metrics not in ("chunk", "none"):
            raise ValueError(
                f'grid lane supports metrics="chunk"|"none" (got '
                f'{metrics!r})')
        g = plan.n_grid
        fn = self._grid_jit(g, metrics)
        # single vs already-stacked state: every AsyncTrainer state carries
        # a scalar "step" counter, so a vectorised one shows ndim == 1
        if isinstance(state, dict) and "step" in state:
            stacked = getattr(state["step"], "ndim", 0) == 1
        else:
            leaves = jax.tree_util.tree_leaves(state)
            stacked = bool(leaves) and \
                getattr(leaves[0], "shape", ())[:1] == (g,)
        states = state if stacked else self.stack_state(state)

        stats = ExecStats()
        rec = self.recorder
        self._attach_obs(snapshot)
        rows = []
        last_hi = start_round
        for lo, hi in _chunk_bounds(plan.rounds, rounds_per_launch,
                                    start_round):
            shared = self._slices(lo, hi)
            del shared["scale"]          # per-γ rows replace the base scale
            scales = plan.grid_slice(lo, hi)
            with _span(rec, "launch", "executor", lo=lo, hi=hi, grid=g):
                out = fn(states, shared, scales)
            states, ms = out if metrics == "chunk" else (out, None)
            stats.launches += 1
            last_hi = hi
            self._maybe_snapshot(snapshot, hi, states, stats)
            if ms is not None:
                rows.append(ms)
        if rows:
            with _span(rec, "host_sync", "executor", deferred=True):
                rows = [np.asarray(r) for r in jax.block_until_ready(rows)]
            stats.host_syncs = 1
        with _span(rec, "barrier", "executor"):
            states = jax.block_until_ready(states)
        if snapshot is not None:
            snapshot.drain()
        all_ms = np.concatenate(rows, axis=1) if rows else None
        self._record_stats(stats, last_hi - start_round)
        return ExecResult(
            state=states,
            metrics=({} if all_ms is None else
                     {k: all_ms[:, :, j] for j, k in enumerate(METRICS)}),
            stats=stats)

    # ----------------------------------------------------------------- eager
    def run_eager(self, state, *, on_step: Optional[Callable] = None,
                  start_round: int = 0) -> ExecResult:
        """The parity oracle: the same plan, one launch + one host sync
        per round (the pre-runtime dispatch loop, kept as the semantic
        reference).  ``launches`` counts the train-step dispatches; the
        batch-synthesis jit that precedes each one is a data detail, not a
        round launch (see :class:`ExecStats`)."""
        import jax
        import jax.numpy as jnp

        plan = self.plan
        with_density = plan.grad_density is not None
        with_gain = plan.fault_gain is not None
        with_scale = plan.adaptive or with_density
        if self._eager is None:
            self._eager = (
                self.watch.wrap("eager_batch", jax.jit(self._batch_of)),
                self.watch.wrap("eager_step", self.trainer.jit_train_step(
                    (plan.global_batch, plan.seq_len),
                    donate=self.donate,
                    with_delay_scale=with_scale,
                    with_grad_density=with_density,
                    with_fault_gain=with_gain)))
        batch_of, step = self._eager
        rec = self.recorder
        rows = []
        stats = ExecStats()
        for i in range(start_round, plan.rounds):
            key = jnp.asarray(plan.data_keys[i])
            batch = batch_of(key, jnp.int32(plan.cdf_index[i])) \
                if plan.cdf_index is not None else batch_of(key)
            args = (state, batch, jnp.asarray(plan.masks[i]))
            if with_scale:          # neutral plans: the trainer's own
                args += (jnp.float32(plan.delay_scales[i]),)  # static rule
            if with_density:
                args += (jnp.float32(plan.grad_density[i]),)
            if with_gain:
                args += (jnp.asarray(plan.fault_gain[i]),)
            with _span(rec, "launch", "executor", lo=i, hi=i + 1):
                state, m = step(*args)
            stats.launches += 1
            with _span(rec, "host_sync", "executor", lo=i, hi=i + 1):
                row = {k: float(m[k]) for k in METRICS}  # host sync / round
            stats.host_syncs += 1
            rows.append([row[k] for k in METRICS])
            if on_step is not None:
                on_step(i, state, row)
        all_ms = np.asarray(rows, np.float32) if rows else \
            np.zeros((0, len(METRICS)), np.float32)
        self._record_stats(stats, plan.rounds - start_round)
        return ExecResult(
            state=state,
            metrics={k: all_ms[:, j] for j, k in enumerate(METRICS)},
            stats=stats)


def run_scan(trainer, plan: RunPlan, state, *, rounds_per_launch: int = 8,
             metrics: str = "chunk", on_step: Optional[Callable] = None,
             start_round: int = 0, donate: bool = True,
             snapshot=None, breaker=None, recorder=None) -> ExecResult:
    """One-shot convenience over :meth:`PlanExecutor.run_scan` (compiles
    fresh; hold a :class:`PlanExecutor` to reuse compiled chunks)."""
    return PlanExecutor(trainer, plan, donate=donate,
                        recorder=recorder).run_scan(
        state, rounds_per_launch=rounds_per_launch, metrics=metrics,
        on_step=on_step, start_round=start_round,
        snapshot=snapshot, breaker=breaker)


def run_eager(trainer, plan: RunPlan, state, *,
              on_step: Optional[Callable] = None, start_round: int = 0,
              donate: bool = True, recorder=None) -> ExecResult:
    """One-shot convenience over :meth:`PlanExecutor.run_eager`."""
    return PlanExecutor(trainer, plan, donate=donate,
                        recorder=recorder).run_eager(
        state, on_step=on_step, start_round=start_round)


def run_grid(trainer, plan: RunPlan, state, *, rounds_per_launch: int = 8,
             metrics: str = "chunk", start_round: int = 0,
             donate: bool = True, snapshot=None, recorder=None) -> ExecResult:
    """One-shot convenience over :meth:`PlanExecutor.run_grid`."""
    return PlanExecutor(trainer, plan, donate=donate,
                        recorder=recorder).run_grid(
        state, rounds_per_launch=rounds_per_launch, metrics=metrics,
        start_round=start_round, snapshot=snapshot)


RUNTIMES = {"scan": run_scan, "eager": run_eager}


def execute(trainer, plan: RunPlan, state, *, runtime: str = "scan",
            rounds_per_launch: int = 8, metrics: str = "chunk",
            **kw) -> ExecResult:
    """Dispatch on ``runtime`` (`"scan"` | `"eager"`).  ``metrics`` applies
    to the scan runtime only — eager reads every round back by
    construction."""
    if runtime not in RUNTIMES:
        raise ValueError(
            f"unknown runtime {runtime!r}; want one of {sorted(RUNTIMES)}")
    if runtime == "scan":
        kw["rounds_per_launch"] = rounds_per_launch
        kw["metrics"] = metrics
    return RUNTIMES[runtime](trainer, plan, state, **kw)
