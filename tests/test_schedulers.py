"""Unit tests for schedulers and the discrete-event engine.

(The hypothesis property tests live in ``test_schedulers_property.py`` so
this module collects without the optional dependency.)
"""
import numpy as np
import pytest

from repro.core import (
    TimingModel,
    PATTERNS,
    build_schedule,
    make_scheduler,
    round_masks,
    heterogeneous_speeds,
    PureAsync,
    PureAsyncWaiting,
    RandomAsync,
    RandomAsyncWaiting,
    ShuffledAsync,
    MiniBatch,
    RandomReshuffling,
)

N, T = 8, 200


def _timing(pattern="fixed", n=N, seed=0):
    return TimingModel(heterogeneous_speeds(n), pattern=pattern, seed=seed)


def _schedule(sched, pattern="fixed", T=T):
    return build_schedule(sched, _timing(pattern, sched.n), T)


# ---------------------------------------------------------------------------
# basic invariants (R_t ⊆ A_t etc.) for every scheduler × delay pattern
# ---------------------------------------------------------------------------
ALL = [
    PureAsync(N),
    PureAsyncWaiting(N, b=4),
    RandomAsync(N),
    RandomAsyncWaiting(N, b=4),
    ShuffledAsync(N),
    MiniBatch(N, b=4),
    RandomReshuffling(N),
]


@pytest.mark.parametrize("sched", ALL, ids=lambda s: s.name)
@pytest.mark.parametrize("pattern", PATTERNS)
def test_schedule_invariants(sched, pattern):
    s = _schedule(sched, pattern)
    assert s.T == T
    # π_t ≤ t (only assigned jobs can be received) and delays are non-negative
    assert np.all(s.assign_iters <= np.arange(T))
    assert np.all(s.delays >= 0)
    # receive times are non-decreasing (server processes in completion order)
    assert np.all(np.diff(s.finish_times) >= -1e-9)
    # Def 1/2 sanity
    assert s.tau_avg() <= s.tau_max() + 1e-9
    assert 1 <= s.tau_c() <= max(sched.concurrency(), sched.wait_b) + sched.wait_b
    # workers in range
    assert s.workers.min() >= 0 and s.workers.max() < sched.n


@pytest.mark.parametrize("sched", ALL, ids=lambda s: s.name)
def test_tau_avg_le_2_tau_c(sched):
    """Remark 5 of [24], used in Lemma C.3: τ_avg ≤ 2 τ_C."""
    s = _schedule(sched)
    assert s.tau_avg() <= 2 * s.tau_c() + 1e-9


def test_pure_async_fixed_speeds_round_robin_like():
    """With equal fixed speeds pure async degenerates to cyclic order with
    constant delay n−1 and τ_C = n."""
    tm = TimingModel(np.ones(N), pattern="fixed")
    s = build_schedule(PureAsync(N), tm, T)
    assert s.tau_c() == N
    assert np.all(np.sort(s.workers[:N]) == np.arange(N))
    # steady-state delay is n − 1 (a worker's gradient is n−1 updates stale)
    assert s.tau_max() == N - 1
    assert np.all(s.delays[N:] == N - 1)


def test_pure_async_slow_worker_has_max_delay():
    """The slowest worker's gradients carry the largest staleness."""
    speeds = np.array([1.0] * (N - 1) + [50.0])
    s = build_schedule(PureAsync(N), TimingModel(speeds, "fixed"), 400)
    slow_updates = np.where(s.workers == N - 1)[0]
    assert len(slow_updates) >= 1
    d = s.delays
    assert d[slow_updates].max() == s.tau_max()
    assert d[slow_updates].mean() > d[s.workers != N - 1].mean()


def test_shuffled_balance():
    """Alg 6's raison d'être: equal jobs per worker in every cycle."""
    s = _schedule(ShuffledAsync(N), "poisson", T=N * 20)
    jpw = s.jobs_per_worker()
    # assignments are balanced; receipts may lag by at most in-flight jobs
    assert jpw.max() - jpw.min() <= N
    # within full epochs of *assignments*, each worker appears once per epoch:
    # re-derive assignment order from the scheduler directly
    sched = ShuffledAsync(N, seed=0)
    sched.reset()
    seq = [sched.next_workers([0])[0] for _ in range(N * 10)]
    for e in range(10):
        assert sorted(seq[e * N:(e + 1) * N]) == list(range(N))


def test_rr_zero_delay():
    """SGD-RR is concurrency-1 and delay-free (§C.3.4)."""
    s = _schedule(RandomReshuffling(N), "uniform")
    assert s.tau_c() == 1
    assert s.tau_max() == 0
    assert np.all(s.delays == 0)


def test_minibatch_delays():
    """§C.3.2: mini-batch SGD has τ_max = τ_C = b − 1 ... bounded by b."""
    b = 4
    s = _schedule(MiniBatch(N, b=b), "normal", T=200)
    assert s.tau_c() <= b
    assert s.tau_max() <= b
    # all jobs in a round share the same assignment point
    ai = s.assign_iters.reshape(-1, b)
    assert np.all(ai == ai[:, :1])
    # assignment points are the round boundaries ⌊t/b⌋·b
    assert np.all(ai[:, 0] == np.arange(ai.shape[0]) * b)


def test_waiting_round_structure():
    """Alg 3: every job is assigned at a round boundary α = ⌊t/b⌋·b.

    (Receipts within a round may still carry older α — slow workers' initial
    jobs drain over several rounds; only the *assignment* grid is aligned.)"""
    b = 4
    s = _schedule(PureAsyncWaiting(N, b=b), "poisson", T=200)
    assert np.all(s.assign_iters % b == 0)
    # with equal speeds the rounds do align exactly
    tm = TimingModel(np.ones(N), "fixed")
    s2 = build_schedule(PureAsyncWaiting(N, b=N), tm, 200)
    ai = s2.assign_iters.reshape(-1, N)
    assert np.all(ai == ai[:, :1])


def test_random_async_queues():
    """Random assignment may stack jobs on one worker — τ_C stays ≤ n but
    per-worker queues imply delays can exceed n."""
    s = _schedule(RandomAsync(N), "fixed", T=500)
    assert s.tau_c() <= N
    jpw = s.jobs_per_worker()
    assert jpw.sum() == 500


def test_round_masks_shape_and_counts():
    b = 4
    s = _schedule(RandomAsyncWaiting(N, b=b), "poisson", T=200)
    m = round_masks(s)
    assert m.shape == (200 // b, N)
    assert np.all(m.sum(axis=1) == b)
