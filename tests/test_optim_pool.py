"""Pooled-state fused update (repro.optim.pool) — single-device suite.

The pooled impl changes the optimizer-state MEMORY LAYOUT (per-dtype
(n_shards, cols) pool buffers, built once) and the launch count (one
pallas_call per dtype pool instead of one per leaf); the numbers must not
change.  Parity bounds follow tests/test_optim_fused.py: pure copies and
counts bitwise, f32 math within FMA-contraction rounding, bf16 at bf16
resolution, pooled global norms allclose (different reduction order than
the per-leaf Python sum).

The multi-device (shard_map over ZeRO shards) half of the suite lives in
tests/test_pool_multidevice.py.
"""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import (OptConfig, adam_init, build_layout, global_norm,
                         init_pools, make_delayed_apply, make_optimizer,
                         pool_tree, pooled_delayed_apply,
                         pooled_global_norm, pooled_update,
                         reference_delayed_apply, sgd_update, adam_update,
                         unpool_tree, resolve_update_impl)
from repro.optim import optimizers as _optimizers

F32 = jnp.float32


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _tree(seed=0):
    """Mixed-dtype pytree (two pool groups) with padding-edge sizes: odd
    flat sizes, 2-D, a scalar, and sizes not divisible by n_shards."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "w": jax.random.normal(ks[0], (33, 7), F32).astype(jnp.bfloat16),
        "b": jax.random.normal(ks[1], (5,), F32),
        "scalar": jnp.asarray(0.37, F32),
        "big": jax.random.normal(ks[2], (1000,), F32).astype(jnp.bfloat16),
        "f32w": jax.random.normal(ks[3], (17, 3), F32),
    }


def _grads_like(params, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), len(params))
    return {k: (jax.random.normal(kk, p.shape, F32).astype(p.dtype)
                if p.ndim else jnp.asarray(0.1 * (seed + 1), p.dtype))
            for kk, (k, p) in zip(ks, sorted(params.items()))}


def _pools_for(layout, params, delayed=True):
    return init_pools(layout, params, delayed=delayed)


def _assert_tree_close(ref_tree, got_tree, param_tree=None):
    """Tolerance keyed off the PARAM dtype: bf16 params make the reference
    round-trip the clipped grad through bf16 before the moment update (the
    kernels keep f32), so their f32 moments still differ at bf16
    resolution — see tests/test_optim_fused.py."""
    params = param_tree if param_tree is not None else ref_tree
    for k in ref_tree:
        a = np.asarray(ref_tree[k], np.float32)
        b = np.asarray(got_tree[k], np.float32)
        if jnp.asarray(params[k]).dtype == jnp.bfloat16:
            np.testing.assert_allclose(a, b, rtol=3e-2, atol=3e-2)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=5e-7)


# ---------------------------------------------------------------------------
# layout / roundtrip
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_shards", [1, 3, 4])
def test_layout_roundtrip_bitwise(n_shards):
    tree = _tree()
    lay = build_layout(tree, n_shards)
    assert lay.n_pools == 2                     # bf16 + f32 groups
    assert lay.n_leaves == len(tree)
    pools = pool_tree(lay, tree)
    for dk, pool in pools.items():
        assert pool.shape == (n_shards, lay.cols[dk])
        assert str(pool.dtype) == dk
    back = unpool_tree(lay, pools)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree[k], np.float32),
                                      np.asarray(back[k], np.float32))


def test_pool_f32_override_groups_by_param_dtype():
    """Moments pool in f32 but under their PARAM's group (aligned bands)."""
    tree = _tree()
    lay = build_layout(tree, 4)
    m = jax.tree_util.tree_map(lambda p: jnp.ones(p.shape, F32), tree)
    pools = pool_tree(lay, m, dtype=F32)
    assert set(pools) == set(lay.groups)
    for dk, pool in pools.items():
        assert pool.dtype == F32
        assert pool.shape == (4, lay.cols[dk])


def test_pooled_global_norm_matches_tree_norm():
    """Pad columns are zero ⇒ the single fused reduction per pool is the
    exact global norm (allclose: different summation order)."""
    tree = _tree()
    for n in (1, 4):
        lay = build_layout(tree, n)
        pools = pool_tree(lay, tree)
        np.testing.assert_allclose(float(pooled_global_norm(pools)),
                                   float(global_norm(tree)), rtol=1e-6)


def test_pool_tree_wrong_tree_raises():
    lay = build_layout(_tree(), 2)
    with pytest.raises(ValueError, match="leaves"):
        pool_tree(lay, {"just_one": jnp.zeros((3,))})


def test_layout_is_o_dtypes_not_o_leaves():
    """The launch-count claim: one kernel per dtype pool, however many
    leaves — here 5 leaves collapse into 2 pools."""
    lay = build_layout(_tree(), 2)
    assert lay.n_leaves == 5
    assert lay.n_pools == 2


# ---------------------------------------------------------------------------
# pooled update parity (single shard, no mesh)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("delay_scale", [1.0, 1.0 / (1.0 + 3.0)])
@pytest.mark.parametrize("name,momentum", [("adam", 0.0), ("sgd", 0.0),
                                           ("sgd", 0.9)])
def test_pooled_delayed_apply_parity_multistep(name, momentum, delay_scale):
    """Pooled delayed apply ≡ reference compose-and-swap over a 4-step
    trajectory, for Adam, SGD and momentum-SGD, on ZeRO-chunked (n_shards=4)
    pools."""
    cfg = OptConfig(name=name, lr=1e-2, momentum=momentum, clip_norm=1.0)
    tree = _tree()
    lay = build_layout(tree, 4)
    p_ref, s_ref = tree, adam_init(tree)
    b_ref = jax.tree_util.tree_map(jnp.zeros_like, tree)
    pools = _pools_for(lay, tree)
    count = jnp.zeros((), jnp.int32)
    for step in range(4):
        g = _grads_like(p_ref, step)
        p_ref, b_ref, s_ref, gn_r = reference_delayed_apply(
            g, b_ref, s_ref, p_ref, cfg, lr_scale=delay_scale)
        pools, count, gn_p = pooled_delayed_apply(
            pool_tree(lay, g), pools, count, cfg, lr_scale=delay_scale)
        np.testing.assert_allclose(float(gn_r), float(gn_p), rtol=1e-6)
        # the fresh-grads swap is a pure copy: bitwise through the pool
        got_b = unpool_tree(lay, {dk: b["gbuf"] for dk, b in pools.items()})
        for k in g:
            np.testing.assert_array_equal(np.asarray(got_b[k]),
                                          np.asarray(g[k]))
    assert int(count) == int(s_ref["count"])
    _assert_tree_close(p_ref,
                       unpool_tree(lay, {dk: b["p"]
                                         for dk, b in pools.items()}))
    _assert_tree_close(s_ref["m"],
                       unpool_tree(lay, {dk: b["m"]
                                         for dk, b in pools.items()}),
                       param_tree=p_ref)
    if name == "adam":
        _assert_tree_close(s_ref["v"],
                           unpool_tree(lay, {dk: b["v"]
                                             for dk, b in pools.items()}),
                           param_tree=p_ref)


@pytest.mark.parametrize("name,momentum", [("adam", 0.0), ("sgd", 0.0),
                                           ("sgd", 0.9)])
def test_pooled_update_parity_sync(name, momentum):
    """delay_rounds == 0: pooled_update ≡ the tree update (no gbuf)."""
    cfg = OptConfig(name=name, lr=1e-2, momentum=momentum, clip_norm=1.0)
    update = adam_update if name == "adam" else sgd_update
    tree = _tree()
    lay = build_layout(tree, 3)
    p_ref, s_ref = tree, adam_init(tree)
    pools = _pools_for(lay, tree, delayed=False)
    count = jnp.zeros((), jnp.int32)
    for step in range(3):
        g = _grads_like(p_ref, step)
        p_ref, s_ref, gn_r = update(g, s_ref, p_ref, cfg, lr_scale=0.5)
        pools, count, gn_p = pooled_update(
            pool_tree(lay, g), pools, count, cfg, lr_scale=0.5)
        np.testing.assert_allclose(float(gn_r), float(gn_p), rtol=1e-6)
    assert int(count) == int(s_ref["count"])
    _assert_tree_close(p_ref,
                       unpool_tree(lay, {dk: b["p"]
                                         for dk, b in pools.items()}))


def test_pooled_first_round_gate_is_identity():
    """zero buffer + lr_scale 0 leaves the params pool bitwise untouched
    and still buffers the fresh grads (trainer round 0)."""
    cfg = OptConfig(name="adam", lr=1e-2, clip_norm=1.0)
    tree = _tree()
    lay = build_layout(tree, 2)
    pools = _pools_for(lay, tree)
    g = _grads_like(tree, 0)
    new_pools, count, _ = pooled_delayed_apply(
        pool_tree(lay, g), pools, jnp.zeros((), jnp.int32), cfg, lr_scale=0.0)
    for dk in pools:
        np.testing.assert_array_equal(np.asarray(new_pools[dk]["p"]),
                                      np.asarray(pools[dk]["p"]))
    got_b = unpool_tree(lay, {dk: b["gbuf"] for dk, b in new_pools.items()})
    for k in g:
        np.testing.assert_array_equal(np.asarray(got_b[k]), np.asarray(g[k]))
    assert int(count) == 1


def test_pooled_apply_under_jit():
    """Production call site is a jitted train step: the pooled apply (pool
    the grads, one kernel per dtype) must trace/compile cleanly."""
    cfg = OptConfig(name="adam", lr=1e-2, clip_norm=1.0)
    tree = _tree()
    lay = build_layout(tree, 2)
    pools = _pools_for(lay, tree)

    @jax.jit
    def step(pools, g_pools, count, scale):
        return pooled_delayed_apply(g_pools, pools, count, cfg,
                                    lr_scale=scale)

    g = _grads_like(tree, 1)
    new_pools, count, gnorm = step(pools, pool_tree(lay, g),
                                   jnp.zeros((), jnp.int32),
                                   jnp.float32(0.25))
    want_pools, want_count, want_gn = pooled_delayed_apply(
        pool_tree(lay, g), pools, jnp.zeros((), jnp.int32), cfg,
        lr_scale=0.25)
    np.testing.assert_allclose(float(gnorm), float(want_gn), rtol=1e-6)
    for a, w in zip(jax.tree_util.tree_leaves(new_pools),
                    jax.tree_util.tree_leaves(want_pools)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# impl plumbing
# ---------------------------------------------------------------------------
def test_make_optimizer_rejects_pooled_impls():
    """Pooled impls change the state layout: the tree-based factories must
    refuse them loudly, not silently produce the wrong contract."""
    with pytest.raises(ValueError, match="pool"):
        make_optimizer(OptConfig(update_impl="pallas_pooled_interpret"))
    with pytest.raises(ValueError, match="pool"):
        make_delayed_apply(OptConfig(update_impl="pallas_pooled_interpret"))


def test_resolve_degrade_warns_once():
    """Off-TPU, "pallas"/"pallas_pooled" degrade to interpret with a
    ONE-TIME RuntimeWarning (silent interpreter-speed runs are a perf
    footgun); "*_interpret" requests stay silent."""
    if jax.default_backend() == "tpu":
        pytest.skip("degradation only happens off-TPU")
    _optimizers._degrade_warned.clear()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        assert resolve_update_impl("pallas_pooled") \
            == "pallas_pooled_interpret"
        assert resolve_update_impl("pallas_pooled") \
            == "pallas_pooled_interpret"   # second call: no new warning
        assert resolve_update_impl("pallas_pooled_interpret") \
            == "pallas_pooled_interpret"
        assert resolve_update_impl("reference") == "reference"
    ours = [w for w in caught if issubclass(w.category, RuntimeWarning)
            and "pallas_pooled" in str(w.message)]
    assert len(ours) == 1
    assert "interpret" in str(ours[0].message).lower()
    _optimizers._degrade_warned.clear()


# ---------------------------------------------------------------------------
# trainer-level: pooled state end-to-end on the tier-1 workload
# ---------------------------------------------------------------------------
def _trainer_pieces(impl, delay_rounds=1):
    from jax.sharding import Mesh
    from repro.configs import get_arch
    from repro.data import DataConfig, HeterogeneousTokenPipeline
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.optim import OptConfig as OC

    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    pipe = HeterogeneousTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=16, global_batch=4, n_groups=1))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    tr = AsyncTrainer(cfg, mesh,
                      opt=OC(lr=1e-2, clip_norm=1.0, update_impl=impl),
                      async_cfg=AsyncConfig(delay_rounds=delay_rounds))
    return tr, batch


def test_async_trainer_pooled_state_structure():
    tr, _ = _trainer_pieces("pallas_pooled_interpret")
    assert tr.pooled and tr.update_impl == "pallas_pooled_interpret"
    lay = tr.pool_layout
    assert lay.n_shards == 1                 # 1-device mesh: one ZeRO shard
    state = tr.init_state(jax.random.PRNGKey(0))
    assert set(state) == {"pools", "opt", "step"}
    for dk, grp in state["pools"].items():
        assert set(grp) == {"p", "m", "v", "gbuf"}
        assert grp["p"].shape == (lay.n_shards, lay.cols[dk])
        assert grp["m"].dtype == jnp.float32
    # abstract/sharding trees mirror the concrete state
    ab = tr.abstract_state()
    assert jax.tree_util.tree_structure(ab) \
        == jax.tree_util.tree_structure(state)
    sh = tr.state_shardings()
    assert jax.tree_util.tree_structure(sh) \
        == jax.tree_util.tree_structure(state)
    # params_of unpools back to the init tree bitwise
    from repro.models import model as M
    want = M.init_params(tr.cfg, jax.random.PRNGKey(0))
    got = tr.params_of(state)
    for a, b in zip(jax.tree_util.tree_leaves(want),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_trainer_pooled_matches_reference_curves():
    """Acceptance: AsyncTrainer(update_impl="pallas_pooled_interpret")
    reproduces the reference training curve within the documented
    tolerances, including the delayed buffer and per-round delay_scale."""
    curves, finals = {}, {}
    for impl in ("reference", "pallas_pooled_interpret"):
        tr, batch = _trainer_pieces(impl)
        state = tr.init_state(jax.random.PRNGKey(0))
        step = jax.jit(tr.train_step_fn())
        losses = []
        for i in range(5):
            scale = jnp.float32(1.0 if i % 2 == 0 else 0.5)
            state, m = step(state, batch, jnp.ones((tr.n_groups,)), scale)
            losses.append(float(m["loss"]))
        curves[impl] = losses
        finals[impl] = tr.params_of(state)
    np.testing.assert_allclose(curves["reference"],
                               curves["pallas_pooled_interpret"], rtol=5e-3)
    # bf16 per-element drift is chaotic over 5 steps: compare leaf norms
    for a, b in zip(jax.tree_util.tree_leaves(finals["reference"]),
                    jax.tree_util.tree_leaves(
                        finals["pallas_pooled_interpret"])):
        na = float(jnp.linalg.norm(jnp.ravel(a).astype(F32)))
        nb = float(jnp.linalg.norm(jnp.ravel(b).astype(F32)))
        np.testing.assert_allclose(na, nb, rtol=5e-2, atol=1e-4)


def test_async_trainer_pooled_sync_baseline():
    """delay_rounds == 0 (synchronous SGD baseline) through the pooled
    update: no gbuf pool in the state, curves track reference."""
    curves = {}
    for impl in ("reference", "pallas_pooled_interpret"):
        tr, batch = _trainer_pieces(impl, delay_rounds=0)
        state = tr.init_state(jax.random.PRNGKey(0))
        if impl.startswith("pallas_pooled"):
            for grp in state["pools"].values():
                assert "gbuf" not in grp
        step = jax.jit(tr.train_step_fn())
        losses = []
        for _ in range(3):
            state, m = step(state, batch, jnp.ones((tr.n_groups,)))
            losses.append(float(m["loss"]))
        curves[impl] = losses
    np.testing.assert_allclose(curves["reference"],
                               curves["pallas_pooled_interpret"], rtol=5e-3)
