"""Batched serving example: prefill a batch of prompts, then decode with the
ring-buffer KV cache through ``repro.api``'s serve backend (which drives the
Server's sharded, cache-donating jitted step).

  PYTHONPATH=src python examples/serve_batched.py [--arch qwen2-0.5b]
"""
import argparse

from repro.api import ExperimentSpec, ServeJob, ServeBackend


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    spec = ExperimentSpec(
        objective=ServeJob(arch=args.arch, batch=args.batch,
                           prompt_len=args.prompt_len, temperature=0.8),
        T=args.gen, seed=0)
    res = ServeBackend().run(spec)
    gen = res.x
    print(f"decoded {gen.shape} in {res.extra['decode_seconds']:.2f}s "
          f"({res.extra['tok_per_s']:.1f} tok/s, total {res.seconds:.2f}s "
          f"incl. prefill)")
    print("sample:", gen[0][:12])


if __name__ == "__main__":
    main()
