"""repro.scenarios — composable non-stationary worlds for async SGD.

The scenario layer wraps any (Scheduler, TimingModel) pair from the core
registries in round-indexed world transforms (speed drift, stragglers,
elastic membership, data drift, gradient sparsification) and realises the
result with the UNMODIFIED discrete-event engine, yielding an ordinary
``Schedule`` plus per-round side channels that ``runtime.compile_plan``
folds into the device-resident ``RunPlan``.  See ``scenario.py`` for the
spec-string grammar and the bit-exactness contract (identity scenario ≡
stationary world, bit-for-bit).
"""
from .transforms import (
    TRANSFORMS,
    DataDrift,
    ElasticWorkers,
    Identity,
    SparsifiedGrads,
    SpeedDrift,
    Straggler,
    WorldTransform,
)
from .scenario import (
    Scenario,
    ScenarioScheduler,
    ScenarioTimingModel,
    ScenarioWorld,
    WorldClock,
    parse_scenario,
    realise_world,
)
from .report import (
    DEFAULT_CONSTANTS,
    WindowStats,
    predicted_rate,
    render_report,
    tau_report,
    window_stats,
)

# importing repro.faults registers the fault transforms (nan_grad,
# corrupt_receipt, worker_crash, host_preempt) into TRANSFORMS, so every
# spec-string consumer knows the fault grammar without extra imports
from .. import faults as _faults  # noqa: E402,F401  (registration side effect)

__all__ = [
    "TRANSFORMS",
    "WorldTransform",
    "Identity",
    "SpeedDrift",
    "Straggler",
    "ElasticWorkers",
    "DataDrift",
    "SparsifiedGrads",
    "Scenario",
    "parse_scenario",
    "ScenarioWorld",
    "ScenarioScheduler",
    "ScenarioTimingModel",
    "WorldClock",
    "realise_world",
    "WindowStats",
    "window_stats",
    "tau_report",
    "predicted_rate",
    "render_report",
    "DEFAULT_CONSTANTS",
]
