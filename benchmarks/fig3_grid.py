"""Figure 3 (App. A.3): full-gradient Syn(α,β) × delay-pattern grid."""
from __future__ import annotations

import csv
import os

import numpy as np

from repro.core import PATTERNS
from repro.objectives import LogRegProblem, make_synthetic
from .common import run_alg, ALGS


def run(T: int = 2500, out: str = "experiments/figs", quick: bool = False):
    os.makedirs(out, exist_ok=True)
    levels = ((0.5, 0.5), (1.5, 1.5)) if not quick else ((1.0, 1.0),)
    patterns = PATTERNS if not quick else ("normal",)
    rows = []
    for (a, b_) in levels:
        A, b = make_synthetic(a, b_, n=10, m=200, d=300, seed=1)
        prob = LogRegProblem(A, b, lam=0.1)
        for pattern in patterns:
            for alg in ALGS:
                gamma, ts, gns, secs = run_alg(prob, alg, pattern, T)
                rows.append({"alpha": a, "beta": b_, "pattern": pattern,
                             "alg": alg, "gamma": gamma,
                             "final_grad_norm": float(np.min(gns[-3:])),
                             "seconds": round(secs, 1)})
    with open(os.path.join(out, "fig3.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=sorted({k for r in rows for k in r}))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
