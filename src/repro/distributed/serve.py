"""Batched serving: prefill-free decode loop over a KV/SSM cache.

``Server`` drives ``models.decode_step`` under pjit with the same logical
sharding rules as training; batches of requests decode in lock-step (the
assigned decode shapes are single-step latencies, this loop is the
end-to-end driver used by examples/serve_batched.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import model as M
from ..models.specs import abstract_tree
from .sharding import Rules, DEFAULT_RULES, tree_shardings


@dataclasses.dataclass
class ServeConfig:
    batch: int
    ctx_len: int
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0


class Server:
    def __init__(self, cfg: ArchConfig, mesh: Mesh, serve: ServeConfig,
                 rules: Rules = DEFAULT_RULES):
        self.cfg, self.mesh, self.serve, self.rules = cfg, mesh, serve, rules
        self._jit_steps = {}          # donate_cache -> cached jit wrapper
        self._key = None              # sampling key, advanced across calls

    # ---- shardings -----------------------------------------------------------
    def cache_shardings(self):
        specs = M.cache_specs(self.cfg, self.serve.batch, self.serve.ctx_len)
        return tree_shardings(specs, self.mesh, self.rules)

    def cache_struct(self):
        specs = M.cache_specs(self.cfg, self.serve.batch, self.serve.ctx_len)
        ab = abstract_tree(specs)
        sh = self.cache_shardings()
        return jax.tree_util.tree_map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s), ab, sh)

    def param_shardings(self):
        return tree_shardings(M.param_specs(self.cfg), self.mesh, self.rules)

    # ---- step ----------------------------------------------------------------
    def serve_step_fn(self):
        cfg, ctx = self.cfg, self.serve.ctx_len

        def step(params, cache, tokens, pos):
            return M.decode_step(cfg, params, cache, tokens, pos, ctx)

        from .sharding import sharded_trace
        return sharded_trace(step, self.mesh, self.rules)

    def jit_serve_step(self, donate_cache: bool = True):
        # cached per donation mode: a fresh jax.jit wrapper per call would
        # carry its own tracing cache, silently recompiling every generate()
        step = self._jit_steps.get(donate_cache)
        if step is None:
            tok_sh = NamedSharding(self.mesh,
                                   P(self.rules.data_axes[-1]
                                     if self.serve.batch > 1 else None))
            step = jax.jit(
                self.serve_step_fn(),
                in_shardings=(self.param_shardings(), self.cache_shardings(),
                              tok_sh, NamedSharding(self.mesh, P())),
                donate_argnums=(1,) if donate_cache else (),
            )
            self._jit_steps[donate_cache] = step
        return step

    # ---- driver ----------------------------------------------------------------
    def generate(self, params, prompts: np.ndarray, n_steps: int,
                 start_pos: int = 0, cache=None, key=None):
        """prompts: (B,) current last tokens.  Greedy/temperature sampling.

        Decodes through :meth:`jit_serve_step` — the sharded, cache-donating
        compiled step — so the driver and the single-step latency benchmarks
        execute the same program.  Pass a prefilled ``cache`` to continue
        from a prompt; otherwise decoding starts from an empty cache.

        Sampling state: the server's PRNG key is seeded lazily from
        ``serve.seed`` and THREADED across calls — successive sampled calls
        draw fresh streams instead of replaying the seed.  Pass an explicit
        ``key`` for one-off reproducible draws; it is consumed for this
        call only and the persistent key is left untouched.
        """
        if cache is None:
            cache = M.init_cache(self.cfg, self.serve.batch, self.serve.ctx_len)
        toks = jnp.asarray(prompts, jnp.int32)
        if n_steps <= 0:
            return np.zeros((toks.shape[0], 0), dtype=np.int32)
        step = self.jit_serve_step()
        explicit_key = key is not None
        if not explicit_key:
            if self._key is None:
                self._key = jax.random.PRNGKey(self.serve.seed)
            key = self._key
        out = []
        for i in range(n_steps):
            logits, cache = step(params, cache, toks, jnp.int32(start_pos + i))
            if self.serve.temperature > 0:
                key, sub = jax.random.split(key)
                toks = jax.random.categorical(
                    sub, logits / self.serve.temperature, axis=-1).astype(jnp.int32)
            else:
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(np.asarray(toks))
        if not explicit_key:
            self._key = key            # persist the advanced stream
        return np.stack(out, axis=1)   # (B, n_steps)
