"""Training launcher: --arch × --scheduler × mesh → AsyncTrainer loop.

The production entry point.  On real hardware the mesh comes from
``make_production_mesh``; on this container ``--host-mesh`` uses whatever
devices exist (the reduced configs train end-to-end on CPU).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --host-mesh --steps 20 --scheduler shuffled --pattern poisson
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-sized variant of the arch family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--scheduler", default="shuffled",
                    choices=["pure", "pure_waiting", "random", "fedbuff",
                             "shuffled"])
    ap.add_argument("--wait-b", type=int, default=1)
    ap.add_argument("--pattern", default="poisson")
    ap.add_argument("--n-groups", type=int, default=0,
                    help="worker groups (0 = data-axis size)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--delay-rounds", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--sync", action="store_true")
    ap.add_argument("--host-mesh", action="store_true",
                    help="use this host's devices instead of the 16x16 pod")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--auto-rules", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--heterogeneity", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from ..configs import get_arch
    from ..core import (TimingModel, build_schedule, round_masks,
                        make_scheduler, heterogeneous_speeds)
    from ..data import DataConfig, HeterogeneousTokenPipeline
    from ..distributed import AsyncTrainer, AsyncConfig, DEFAULT_RULES, auto_rules
    from ..models import n_params, batch_specs
    from ..optim import OptConfig
    from .. import checkpoint
    from .mesh import make_production_mesh, make_host_mesh

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced().with_(remat="none")
    mesh = make_host_mesh() if args.host_mesh else \
        make_production_mesh(multi_pod=args.multi_pod)
    rules = auto_rules(cfg, mesh.shape.get("model", 1)) if args.auto_rules \
        else DEFAULT_RULES

    tr = AsyncTrainer(cfg, mesh,
                      opt=OptConfig(lr=args.lr, clip_norm=1.0),
                      async_cfg=AsyncConfig(
                          delay_rounds=0 if args.sync else args.delay_rounds,
                          microbatches=args.microbatches),
                      rules=rules)
    n_groups = args.n_groups or tr.n_groups
    tr.n_groups = n_groups
    if args.global_batch % n_groups:
        raise SystemExit(f"--global-batch must divide {n_groups} groups")

    print(f"arch={cfg.name} params={n_params(cfg)/1e6:.1f}M mesh={dict(mesh.shape)} "
          f"groups={n_groups} scheduler={args.scheduler} b={args.wait_b} "
          f"delay={0 if args.sync else args.delay_rounds}")

    sched = make_scheduler(args.scheduler, n_groups, b=args.wait_b,
                           seed=args.seed)
    tm = TimingModel(heterogeneous_speeds(n_groups, 6.0), args.pattern,
                     seed=args.seed)
    masks = round_masks(build_schedule(sched, tm, args.steps * sched.wait_b))

    pipe = HeterogeneousTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.global_batch,
        n_groups=n_groups, heterogeneity=args.heterogeneity, seed=args.seed))
    state = tr.init_state(jax.random.PRNGKey(args.seed))
    step = jax.jit(tr.train_step_fn())

    def make_batch(i):
        b = {"tokens": jnp.asarray(pipe.batch(i)["tokens"])}
        for k, sp in batch_specs(cfg, args.global_batch, args.seq_len).items():
            if k != "tokens" and sp.dtype != "int32":   # stubbed modalities
                b[k] = jax.random.normal(jax.random.PRNGKey(i), sp.shape,
                                         jnp.float32)
            elif k == "tokens":
                b[k] = b[k][:, :sp.shape[1]]
        return b

    t0 = time.time()
    for i in range(min(args.steps, masks.shape[0])):
        state, m = step(state, make_batch(i), jnp.asarray(masks[i]))
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"|g|={float(m['grad_norm']):.3f} "
                  f"part={float(m['participation']):.2f} "
                  f"{time.time()-t0:7.1f}s", flush=True)
        if args.ckpt and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt, state, step=i + 1,
                            meta={"arch": cfg.name})
    if args.ckpt:
        checkpoint.save(args.ckpt, state, step=args.steps,
                        meta={"arch": cfg.name})
        print("final checkpoint:", args.ckpt)


if __name__ == "__main__":
    main()
