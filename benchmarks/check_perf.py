"""CI gate: fail on a dispatch-layer perf regression vs the committed
baseline ``benchmarks/BENCH_runtime.json``.

Absolute rounds/s across heterogeneous CI hosts is pure noise — a GitHub
runner and the laptop that wrote the baseline differ by far more than any
real regression.  What IS machine-portable is each row's rounds/s
normalised by the SAME payload's eager row: that ratio isolates the
dispatch/metric-transport layer (launch amortisation, readback barriers,
tap overhead) from raw core speed, which is exactly what this bench
exists to track.  The gate fails when any scan/grid row's normalised
throughput (or the grid lane's ``grid_speedup``) drops more than
``--tolerance`` (default 30%) below the baseline's.

Only the ``runtime_dispatch_ab`` bench kind has a regression gate; any
other payload (e.g. the ``scenarios`` smoke bench, or a future kind this
script predates) is SKIPPED loudly with exit 0 — an artifact-only bench
must never fail CI just because the gate doesn't know how to read it.
A missing file skips the same way (benches run under ``if: always()``,
so an earlier failed step may legitimately leave no payload behind).

Usage::

    python benchmarks/check_perf.py experiments/figs/BENCH_runtime.json \
        benchmarks/BENCH_runtime.json --tolerance 0.3
"""
from __future__ import annotations

import argparse
import json
import os
import sys

#: bench kinds this gate knows how to compare (payload "bench" field)
KNOWN_KINDS = {"runtime_dispatch_ab"}


def _rows(payload: dict) -> dict:
    """(runtime, metrics, K) -> entry, plus the eager rounds/s."""
    eager = [e for e in payload["entries"] if e["runtime"] == "eager"]
    if not eager:
        raise SystemExit("payload has no eager row to normalise against")
    rows = {(e["runtime"], e.get("metrics", "chunk"),
             e["rounds_per_launch"]): e
            for e in payload["entries"]}
    return rows, float(eager[0]["rounds_per_s"])


def check(current: dict, baseline: dict, tolerance: float) -> list:
    cur_rows, cur_eager = _rows(current)
    base_rows, base_eager = _rows(baseline)
    failures = []
    print(f"{'row':<28} {'base':>8} {'now':>8} {'floor':>8}  verdict")
    for key, base in sorted(base_rows.items(), key=str):
        if key[0] == "eager":
            continue                      # the normaliser, not a subject
        cur = cur_rows.get(key)
        if cur is None:
            failures.append(f"{key}: missing from current payload")
            print(f"{str(key):<28} {'':>8} {'':>8} {'':>8}  MISSING")
            continue
        base_n = float(base["rounds_per_s"]) / base_eager
        cur_n = float(cur["rounds_per_s"]) / cur_eager
        floor = base_n * (1.0 - tolerance)
        ok = cur_n >= floor
        print(f"{str(key):<28} {base_n:>8.3f} {cur_n:>8.3f} "
              f"{floor:>8.3f}  {'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(
                f"{key}: normalised rounds/s {cur_n:.3f} < floor "
                f"{floor:.3f} (baseline {base_n:.3f}, "
                f"tolerance {tolerance:.0%})")
        if "grid_speedup" in base:
            g_base = float(base["grid_speedup"])
            g_cur = float(cur.get("grid_speedup", 0.0))
            g_floor = g_base * (1.0 - tolerance)
            g_ok = g_cur >= g_floor
            print(f"{'  grid_speedup':<28} {g_base:>8.3f} {g_cur:>8.3f} "
                  f"{g_floor:>8.3f}  {'ok' if g_ok else 'REGRESSION'}")
            if not g_ok:
                failures.append(
                    f"{key}: grid_speedup {g_cur:.3f} < floor "
                    f"{g_floor:.3f}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="freshly produced BENCH_runtime.json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.3,
                    help="allowed fractional drop in normalised rounds/s "
                         "(default 0.3 = 30%%)")
    args = ap.parse_args()
    payloads = {}
    for label, path in (("current", args.current),
                        ("baseline", args.baseline)):
        if not os.path.exists(path):
            print(f"SKIP: {label} bench file {path!r} does not exist — "
                  "nothing to gate (not a failure: benches run under "
                  "if: always(), so an earlier failed step may have left "
                  "no payload)")
            return
        with open(path) as f:
            payloads[label] = json.load(f)
    for label, payload in payloads.items():
        kind = payload.get("bench", "<missing>")
        if kind not in KNOWN_KINDS:
            print(f"SKIP: {label} bench file {getattr(args, label)!r} has "
                  f"kind {kind!r}, which this gate cannot compare (known: "
                  f"{sorted(KNOWN_KINDS)}) — treating as artifact-only, "
                  "not a failure")
            return
    failures = check(payloads["current"], payloads["baseline"],
                     args.tolerance)
    if failures:
        print("\nPERF REGRESSION vs committed baseline:")
        for msg in failures:
            print(" -", msg)
        sys.exit(1)
    print("\nno dispatch-layer regression "
          f"(tolerance {args.tolerance:.0%})")


if __name__ == "__main__":
    main()
