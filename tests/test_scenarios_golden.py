"""Golden-trace regression tests for realised scenario worlds.

Companion to ``tests/test_engine_golden.py``: that suite freezes the
STATIONARY engine output; this one freezes the scenario layer on top of
it — one straggler world and one elastic world per timing pattern, with
the realised ``workers``/``assign_iters`` ordering, the paper's delay
statistics and the availability channel all pinned **bit-identical** to
fixtures under ``tests/fixtures/scenarios``.  A silent change in the
wrapper RNG discipline (transform trajectory seeding, remap stream
consumption, clock advancement) would shift every non-stationary result
downstream while each individual run still "looks plausible".

Regenerate (ONLY after an intentional semantic change, and say so in the
commit message):

    PYTHONPATH=src python tests/test_scenarios_golden.py --regen
"""
import json
import os

import numpy as np
import pytest

from repro.core import (PATTERNS, TimingModel, heterogeneous_speeds,
                        make_scheduler)
from repro.scenarios import parse_scenario, realise_world

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures",
                           "scenarios")

N_WORKERS = 5
T = 24
SEED = 0
SLOW = 4.0
WAIT_B = 2      # fedbuff keeps queueing + waiting semantics in play

#: fixture worlds — short windows so every trajectory fires inside T
WORLDS = {
    "straggler": "straggler:k=2,factor=8,every=3,span=2",
    "elastic": "elastic:k=1,every=3,span=2",
}

CASES = [(w, p) for w in sorted(WORLDS) for p in PATTERNS]


def _build(world: str, pattern: str):
    sched = make_scheduler("fedbuff", N_WORKERS, b=WAIT_B, seed=SEED)
    timing = TimingModel(heterogeneous_speeds(N_WORKERS, slow_factor=SLOW),
                         pattern, seed=SEED)
    return realise_world(parse_scenario(WORLDS[world]), sched, timing, T,
                         seed=SEED)


def _fixture_path(world: str, pattern: str) -> str:
    return os.path.join(FIXTURE_DIR, f"{world}_{pattern}.json")


def _to_record(w) -> dict:
    s = w.schedule
    return {
        "workers": [int(x) for x in s.workers],
        "assign_iters": [int(x) for x in s.assign_iters],
        "unfinished_assign_iters": [int(x)
                                    for x in s.unfinished_assign_iters],
        "tau_max": s.tau_max(),
        "tau_avg": s.tau_avg(),     # exact float64 repr round-trips JSON
        "tau_c": s.tau_c(),
        "wait_b": s.wait_b,
        "rounds": w.rounds,
        "availability": (None if w.availability is None
                         else [[int(v) for v in row]
                               for row in w.availability]),
    }


@pytest.mark.parametrize("world,pattern", CASES,
                         ids=[f"{w}-{p}" for w, p in CASES])
def test_world_matches_golden_trace(world, pattern):
    path = _fixture_path(world, pattern)
    assert os.path.exists(path), (
        f"missing fixture {path}; regenerate with "
        "`PYTHONPATH=src python tests/test_scenarios_golden.py --regen`")
    with open(path) as f:
        want = json.load(f)
    got = _to_record(_build(world, pattern))
    np.testing.assert_array_equal(got["workers"], want["workers"])
    np.testing.assert_array_equal(got["assign_iters"], want["assign_iters"])
    np.testing.assert_array_equal(got["unfinished_assign_iters"],
                                  want["unfinished_assign_iters"])
    assert got["tau_max"] == want["tau_max"]
    assert got["tau_avg"] == want["tau_avg"]
    assert got["tau_c"] == want["tau_c"]
    assert got["wait_b"] == want["wait_b"]
    assert got["rounds"] == want["rounds"]
    if want["availability"] is None:
        assert got["availability"] is None
    else:
        np.testing.assert_array_equal(got["availability"],
                                      want["availability"])


def test_realise_world_is_deterministic():
    """Two realisations of the same world must agree with themselves, not
    just the fixture (guards against hidden global RNG state)."""
    a = _to_record(_build("elastic", "poisson"))
    b = _to_record(_build("elastic", "poisson"))
    assert a == b


def _regen():
    os.makedirs(FIXTURE_DIR, exist_ok=True)
    for world, pattern in CASES:
        rec = _to_record(_build(world, pattern))
        rec["_scenario"] = {"n_workers": N_WORKERS, "T": T, "seed": SEED,
                            "slow_factor": SLOW, "wait_b": WAIT_B,
                            "spec": WORLDS[world]}
        with open(_fixture_path(world, pattern), "w") as f:
            json.dump(rec, f, indent=1)
        print("wrote", _fixture_path(world, pattern))


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
