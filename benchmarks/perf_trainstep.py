"""Micro-benchmark: AsyncTrainer train_step / serve_step wall time on the
reduced configs (CPU; TPU perf comes from §Roofline, not wall clock).

Two modes:

* default      — per-arch train_step wall time → ``perf.csv`` (legacy).
* ``--ab``     — reference vs fused ``update_impl`` A/B on the SAME arch,
  batch and state → ``BENCH_trainstep.json``.  On TPU the fused column is
  the compiled Mosaic kernels (the number that matters); off-TPU it is the
  Pallas interpreter, so treat the CPU "speedup" as a correctness artifact,
  not a perf claim (the JSON records backend + impl so nobody misreads it).
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import ARCHS, get_arch
from repro.data import DataConfig, HeterogeneousTokenPipeline
from repro.distributed import AsyncTrainer, AsyncConfig
from repro.optim import OptConfig, resolve_update_impl


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _batch_for(cfg, B, S, seed=0):
    pipe = HeterogeneousTokenPipeline(DataConfig(cfg.vocab, S, B))
    from repro.models import batch_specs
    batch = {}
    for k, sp in batch_specs(cfg, B, S).items():
        if sp.dtype == "int32":
            batch[k] = jnp.asarray(pipe.batch(0)["tokens"][:, :sp.shape[1]])
        else:   # stubbed modality embeddings (vlm patches / audio frames)
            batch[k] = jax.random.normal(jax.random.PRNGKey(1), sp.shape,
                                         jnp.float32)
    return batch


def _time_step(tr, batch, iters):
    state = tr.init_state(jax.random.PRNGKey(0))
    step = jax.jit(tr.train_step_fn())
    mask = jnp.ones((tr.n_groups,))
    state, m = step(state, batch, mask)          # compile
    jax.block_until_ready(m["loss"])
    t0 = time.time()
    for _ in range(iters):
        state, m = step(state, batch, mask)
    jax.block_until_ready(m["loss"])
    return (time.time() - t0) / iters * 1e6, float(m["loss"])


def run(out: str = "experiments/figs", quick: bool = False):
    os.makedirs(out, exist_ok=True)
    mesh = _mesh()
    rows = []
    names = ["qwen2-0.5b", "deepseek-moe-16b", "mamba2-370m"] if quick \
        else sorted(ARCHS)
    for name in names:
        cfg = get_arch(name).reduced().with_(remat="none")
        tr = AsyncTrainer(cfg, mesh, opt=OptConfig(lr=1e-3),
                          async_cfg=AsyncConfig(delay_rounds=1))
        B, S = 2, 32
        batch = _batch_for(cfg, B, S)
        us, loss = _time_step(tr, batch, iters=5)
        rows.append({"name": f"train_step_{name}", "us_per_call": round(us, 1),
                     "derived": f"loss={loss:.3f}"})
    with open(os.path.join(out, "perf.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=["name", "us_per_call", "derived"])
        w.writeheader()
        w.writerows(rows)
    return rows


def run_ab(out: str = "experiments/figs", quick: bool = False, iters: int = 5,
           archs=None):
    """Reference-vs-fused A/B on identical (arch, state, batch) pairs.

    Writes ``BENCH_trainstep.json``: one entry per arch with
    ``reference_us`` / ``fused_us`` / ``speedup`` plus enough provenance
    (backend, effective impl, shapes) to interpret the numbers."""
    os.makedirs(out, exist_ok=True)
    mesh = _mesh()
    if archs is None:
        archs = ["qwen2-0.5b"] if quick else \
            ["qwen2-0.5b", "deepseek-moe-16b", "mamba2-370m"]
    fused_impl = resolve_update_impl("pallas")
    entries = []
    for name in archs:
        cfg = get_arch(name).reduced().with_(remat="none")
        B, S = 2, 32
        batch = _batch_for(cfg, B, S)
        entry = {"arch": name, "batch": B, "seq_len": S, "iters": iters}
        for label, impl in (("reference", "reference"), ("fused", fused_impl)):
            tr = AsyncTrainer(
                cfg, mesh,
                opt=OptConfig(lr=1e-3, update_impl=impl),
                async_cfg=AsyncConfig(delay_rounds=1))
            us, loss = _time_step(tr, batch, iters)
            entry[f"{label}_us"] = round(us, 1)
            entry[f"{label}_loss"] = round(loss, 4)
        entry["fused_impl"] = fused_impl
        entry["speedup"] = round(entry["reference_us"] / entry["fused_us"], 3)
        entries.append(entry)
        print(f"{name}: reference={entry['reference_us']:.0f}us "
              f"fused[{fused_impl}]={entry['fused_us']:.0f}us "
              f"speedup={entry['speedup']}x")
    payload = {
        "bench": "trainstep_ab",
        "backend": jax.default_backend(),
        "fused_impl": fused_impl,
        "note": ("fused==pallas_interpret means the Pallas INTERPRETER ran "
                 "(off-TPU correctness mode); speedups are only meaningful "
                 "when fused_impl == 'pallas' on a TPU backend"),
        "entries": entries,
    }
    path = os.path.join(out, "BENCH_trainstep.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print("wrote", path)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ab", action="store_true",
                    help="reference-vs-fused update_impl A/B → "
                         "BENCH_trainstep.json")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--out", default="experiments/figs")
    ap.add_argument("--archs", default=None,
                    help="comma-separated arch names (A/B mode)")
    args = ap.parse_args()
    archs = args.archs.split(",") if args.archs else None
    if args.ab:
        run_ab(out=args.out, quick=args.quick, iters=args.iters, archs=archs)
    else:
        for r in run(out=args.out, quick=args.quick):
            print(r)


if __name__ == "__main__":
    main()
