from .checkpointer import (CheckpointError, load_meta, restore, save,
                           verify)
from .snapshot import AsyncSnapshotter

__all__ = ["save", "restore", "load_meta", "verify", "CheckpointError",
           "AsyncSnapshotter"]
