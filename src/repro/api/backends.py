"""The three ways to execute an :class:`ExperimentSpec`.

* :class:`SimulatorBackend` — schedule + exact jittable replay (theory tier).
  Grid stepsize policies replay every γ against ONE shared schedule in a
  single batched scan (:func:`repro.core.simulator.replay_grid`): the
  schedule is gradient-value-independent, so rebuilding it per γ — what the
  benchmarks used to do — is pure waste.
* :class:`TrainerBackend` — schedule → participation ``round_masks`` →
  ``AsyncTrainer`` pjit loop (production tier).  Same schedulers, identical
  ordering by construction.
* :class:`ServeBackend` — batched decoding through ``distributed.Server``.

All three return a :class:`RunResult`.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Protocol, runtime_checkable

import jax
import numpy as np

from ..core import (delay_adaptive_stepsizes, replay, replay_grid,
                    round_delay_scales, round_masks)
from ..core.trace import summarize
from .result import RunResult
from .spec import ExperimentSpec, ServeJob, StepsizePolicy, TrainJob


@runtime_checkable
class Backend(Protocol):
    name: str

    def run(self, spec: ExperimentSpec) -> RunResult: ...


def _grid_score(grad_norms: np.ndarray) -> float:
    """The paper's selection protocol (App. A.1): best final grad norm with
    small fluctuations — tail mean plus half the tail standard deviation."""
    tail = float(np.mean(grad_norms[-3:]))
    fluct = float(np.std(grad_norms[-5:]))
    return tail + 0.5 * fluct


class SimulatorBackend:
    """Exact replay of Algorithm 1: x_{t+1} = x_t − γ̃ g_{i_t}(x_{π_t})."""

    name = "simulator"

    def run(self, spec: ExperimentSpec) -> RunResult:
        prob = spec.objective
        if prob is None or not hasattr(prob, "grad_fn"):
            raise TypeError(
                "SimulatorBackend needs an objective exposing grad_fn "
                f"(got {type(prob).__name__})")
        t0 = time.time()
        schedule = spec.build_schedule()
        grad_fn = prob.grad_fn(stochastic=spec.stochastic)
        full_grad = getattr(prob, "full_grad", None)
        loss = getattr(prob, "loss", None)
        x0 = np.zeros(prob.d, dtype=np.float32)
        policy: StepsizePolicy = spec.stepsize
        kw = dict(key=jax.random.PRNGKey(spec.seed), clip=spec.clip,
                  log_every=spec.log_every, full_grad_fn=full_grad,
                  loss_fn=loss)

        if policy.kind == "grid":
            if full_grad is None:
                raise ValueError(
                    "grid stepsize selection scores grad norms; the "
                    "objective must expose full_grad")
            results = replay_grid(schedule, grad_fn, x0, policy.gammas, **kw)
            best_i, best_score = 0, None
            grid_info = {}
            for i, (g, res) in enumerate(zip(policy.gammas, results)):
                score = _grid_score(res.grad_norms)
                grid_info[g] = {"grad_norms": res.grad_norms,
                                "losses": res.losses, "score": score}
                if best_score is None or score < best_score:
                    best_i, best_score = i, score
            gamma, res = policy.gammas[best_i], results[best_i]
        else:
            gamma = policy.gamma
            if policy.kind == "delay_adaptive":
                steps = delay_adaptive_stepsizes(gamma, schedule.delays,
                                                 schedule.tau_c())
            else:
                steps = gamma
            res = replay(schedule, grad_fn, x0, steps, **kw)
            grid_info = None

        return RunResult(
            spec=spec, backend=self.name, x=res.x, xs=res.xs,
            log_ts=res.log_ts, grad_norms=res.grad_norms, losses=res.losses,
            gamma=gamma, grid=grid_info, schedule=schedule,
            trace=summarize(schedule), seconds=time.time() - t0)


class TrainerBackend:
    """Schedule → round participation masks → ``AsyncTrainer`` pjit loop.

    ``mesh``/``rules`` default to this host's devices and the repo sharding
    rules; ``on_step(i, state, metrics)`` is invoked once per round (for
    logging / checkpointing without owning the loop).
    """

    name = "trainer"

    def __init__(self, mesh=None, rules=None,
                 on_step: Optional[Callable] = None):
        self.mesh = mesh
        self.rules = rules
        self.on_step = on_step

    # ---- pieces shared with tests -----------------------------------------
    @staticmethod
    def masks_for(spec: ExperimentSpec, n_groups: Optional[int] = None):
        """((rounds, n_groups) participation masks, realised Schedule) for
        ``spec.T`` rounds."""
        sched = spec.make_scheduler(n_groups)
        schedule = spec.build_schedule(T=spec.T * sched.wait_b, n=n_groups)
        return round_masks(schedule), schedule

    def _make_batch_fn(self, cfg, job: TrainJob, n_groups: int, seed: int):
        import jax
        import jax.numpy as jnp
        from ..data import DataConfig, HeterogeneousTokenPipeline
        from ..models import batch_specs

        pipe = HeterogeneousTokenPipeline(DataConfig(
            vocab=cfg.vocab, seq_len=job.seq_len,
            global_batch=job.global_batch, n_groups=n_groups,
            heterogeneity=job.heterogeneity, seed=seed))
        specs = batch_specs(cfg, job.global_batch, job.seq_len)

        def make_batch(i):
            b = {"tokens": jnp.asarray(pipe.batch(i)["tokens"])}
            for k, sp in specs.items():
                if k != "tokens" and sp.dtype != "int32":  # stubbed modalities
                    b[k] = jax.random.normal(jax.random.PRNGKey(i), sp.shape,
                                             jnp.float32)
                elif k == "tokens":
                    b[k] = b[k][:, :sp.shape[1]]
            return b

        return make_batch

    def run(self, spec: ExperimentSpec) -> RunResult:
        job = spec.objective
        if not isinstance(job, TrainJob):
            raise TypeError("TrainerBackend needs a TrainJob objective")
        policy: StepsizePolicy = spec.stepsize
        if policy.kind == "grid":
            best = None
            for g in policy.gammas:
                res = self._run_single(spec, job, g, adaptive=False)
                score = float(np.mean(res.losses[-3:]))
                if best is None or score < best[0]:
                    best = (score, res)
            return best[1]
        return self._run_single(spec, job, policy.gamma,
                                adaptive=policy.kind == "delay_adaptive")

    def _run_single(self, spec: ExperimentSpec, job: TrainJob, lr: float,
                    adaptive: bool) -> RunResult:
        import jax
        import jax.numpy as jnp
        from ..distributed import AsyncTrainer, AsyncConfig, DEFAULT_RULES
        from ..launch.mesh import make_host_mesh
        from ..optim import OptConfig

        t0 = time.time()
        cfg = job.make_arch()
        mesh = self.mesh if self.mesh is not None else make_host_mesh()
        rules = self.rules if self.rules is not None else DEFAULT_RULES
        tr = AsyncTrainer(
            cfg, mesh,
            opt=OptConfig(name=job.opt, lr=lr, clip_norm=job.clip_norm,
                          update_impl=job.update_impl),
            async_cfg=AsyncConfig(delay_rounds=job.delay_rounds,
                                  delay_adaptive=adaptive,
                                  microbatches=job.microbatches),
            rules=rules)
        n_groups = spec.n_workers or tr.n_groups
        tr.n_groups = n_groups
        if job.global_batch % n_groups:
            raise ValueError(
                f"the {n_groups} worker groups must divide "
                f"global_batch={job.global_batch}")

        masks, schedule = self.masks_for(spec, n_groups)
        make_batch = self._make_batch_fn(cfg, job, n_groups, spec.seed)
        state = tr.init_state(jax.random.PRNGKey(spec.seed))

        rounds = min(spec.T, masks.shape[0])
        # delay-adaptive: the per-round γ scale comes from the realised
        # schedule's delay metadata and rides into the step (a traced
        # scalar — one compile covers all rounds); the scale at round i
        # belongs to the gradient APPLIED at i.  AsyncTrainer's gbuf is a
        # single swapped-every-round buffer, so the realised extra
        # staleness is exactly ONE round whenever delay_rounds > 0,
        # whatever the nominal config value says
        scales = round_delay_scales(
            schedule, rounds,
            delay_rounds=1 if job.delay_rounds > 0 else 0) \
            if adaptive else None
        # the production pjit entry point: explicit state shardings +
        # buffer donation (not a bare jax.jit of the step fn)
        step = tr.jit_train_step((job.global_batch, job.seq_len),
                                 with_delay_scale=scales is not None)
        losses, grad_norms, metrics_rows = [], [], []
        for i in range(rounds):
            args = (state, make_batch(i), jnp.asarray(masks[i]))
            if scales is not None:
                state, m = step(*args, jnp.float32(scales[i]))
            else:
                state, m = step(*args)
            m = {k: float(v) for k, v in m.items()}
            losses.append(m["loss"])
            grad_norms.append(m["grad_norm"])
            metrics_rows.append(m)
            if self.on_step is not None:
                self.on_step(i, state, m)

        return RunResult(
            spec=spec, backend=self.name, x=state,
            log_ts=np.arange(rounds), losses=np.asarray(losses),
            grad_norms=np.asarray(grad_norms), gamma=lr,
            schedule=schedule, trace=summarize(schedule),
            seconds=time.time() - t0,
            extra={"metrics": metrics_rows, "masks": masks,
                   "arch": cfg.name, "n_groups": n_groups,
                   "update_impl": tr.update_impl,
                   "delay_scales": scales})


class ServeBackend:
    """Prefill + batched decode through the sharded ``Server`` driver."""

    name = "serve"

    def __init__(self, mesh=None, rules=None):
        self.mesh = mesh
        self.rules = rules

    def run(self, spec: ExperimentSpec) -> RunResult:
        import jax
        import jax.numpy as jnp
        from ..distributed import Server, ServeConfig
        from ..distributed.sharding import DEFAULT_RULES
        from ..launch.mesh import make_host_mesh
        from ..models import init_params, prefill

        job = spec.objective
        if not isinstance(job, ServeJob):
            raise TypeError("ServeBackend needs a ServeJob objective")
        t0 = time.time()
        cfg = job.make_arch()
        mesh = self.mesh if self.mesh is not None else make_host_mesh()
        rules = self.rules if self.rules is not None else DEFAULT_RULES
        ctx = job.prompt_len + spec.T
        server = Server(cfg, mesh, ServeConfig(batch=job.batch, ctx_len=ctx,
                                               temperature=job.temperature,
                                               seed=spec.seed), rules=rules)
        params = init_params(cfg, jax.random.PRNGKey(spec.seed))
        prompts = np.random.default_rng(spec.seed).integers(
            0, cfg.vocab, (job.batch, job.prompt_len)).astype(np.int32)
        last, cache = prefill(cfg, params, {"tokens": jnp.asarray(prompts)},
                              ctx_len=ctx)
        toks = jnp.argmax(last, axis=-1).astype(jnp.int32)
        t_dec = time.time()
        gen = server.generate(params, np.asarray(toks), spec.T - 1,
                              start_pos=job.prompt_len, cache=cache)
        gen = np.concatenate([np.asarray(toks)[:, None], gen], axis=1)
        dt = time.time() - t_dec
        return RunResult(
            spec=spec, backend=self.name, x=gen, seconds=time.time() - t0,
            extra={"prompts": prompts, "arch": cfg.name,
                   "decode_seconds": dt,
                   "tok_per_s": job.batch * (spec.T - 1) / max(dt, 1e-9)})


def run(spec: ExperimentSpec, backend: Optional[Backend] = None) -> RunResult:
    """Execute a spec on the right backend (dispatched on the objective)."""
    if backend is None:
        if isinstance(spec.objective, TrainJob):
            backend = TrainerBackend()
        elif isinstance(spec.objective, ServeJob):
            backend = ServeBackend()
        else:
            backend = SimulatorBackend()
    return backend.run(spec)
