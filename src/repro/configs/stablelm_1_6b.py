"""StableLM-2-1.6B.  [hf:stabilityai/stablelm-2-1_6b]

24L, d_model 2048, 32 heads (MHA kv=32, d_head 64), d_ff 5632, vocab 100352.
Deviation noted in DESIGN.md: the release uses 25% partial rotary; we apply
full rotary embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_head=64,
    d_ff=5632,
    vocab=100352,
)
