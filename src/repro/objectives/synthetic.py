"""Synthetic dataset generation — Appendix A.2, followed step by step.

Syn(α, β): larger α, β ⇒ more heterogeneous local datasets.
Also provides w7a/phishing stand-ins with matched (n, m, d, sparsity):
LibSVM is not reachable offline, so we generate data with the same shape
statistics and run the identical protocol (noted in DESIGN.md §8).
"""
from __future__ import annotations

import numpy as np


def make_synthetic(alpha: float, beta: float, n: int = 10, m: int = 200,
                   d: int = 300, seed: int = 0):
    """Appendix A.2 generator, verbatim:

    1. B_i ~ N(0, β);  2. v_i ∈ R^d, [v_i]_j ~ N(B_i, 1);
    3. a_ij ~ N(v_i, Σ), Σ_kk = k^{−1.2};
    4. u_i ~ N(0, α), c_i ~ N(u_i, 1);  5. [w_i]_j ~ N(u_i, 1);
    6. p_ij = σ(w_iᵀ a_ij + c_i);  7. b_ij = −1 w.p. p_ij else +1.
    """
    rng = np.random.default_rng(seed)
    B = rng.normal(0.0, np.sqrt(beta), size=n)
    v = rng.normal(B[:, None], 1.0, size=(n, d))
    Sigma = np.diag((np.arange(1, d + 1) ** -1.2))
    a = np.einsum("nmd,dk->nmk", rng.normal(0.0, 1.0, size=(n, m, d)), np.sqrt(Sigma))
    a = a + v[:, None, :]
    u = rng.normal(0.0, np.sqrt(alpha), size=n)
    c = rng.normal(u, 1.0)
    w = rng.normal(u[:, None], 1.0, size=(n, d))
    logits = np.einsum("nd,nmd->nm", w, a) + c[:, None]
    p = np.where(logits >= 0, 1.0 / (1.0 + np.exp(-np.abs(logits))),
                 np.exp(-np.abs(logits)) / (1.0 + np.exp(-np.abs(logits))))
    b = np.where(rng.uniform(size=(n, m)) < p, -1.0, 1.0)
    return a.astype(np.float32), b.astype(np.float32)


def make_libsvm_like(name: str, n: int = 10, seed: int = 0):
    """Stand-ins for the LibSVM datasets used in §5 (offline container):

    * w7a:      n=10 workers, m=2505, d=300, sparse binary-ish features
    * phishing: n=10 workers, m=1105, d=68, dense features in [0, 1]
    """
    import zlib
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 2 ** 16)
    if name == "w7a":
        m, d, density = 2505, 300, 0.04
        feats = (rng.uniform(size=(n, m, d)) < density).astype(np.float32)
        wstar = rng.normal(size=d) * (rng.uniform(size=d) < 0.3)
        shift = rng.normal(0.0, 0.5, size=(n, 1))        # worker covariate shift
        logits = feats @ wstar + shift
        labels = np.where(logits + rng.logistic(size=(n, m)) > 0, 1.0, -1.0)
        # w7a is heavily imbalanced (~3% positives); skew it
        labels = np.where(rng.uniform(size=(n, m)) < 0.9, -1.0, labels)
    elif name == "phishing":
        m, d = 1105, 68
        feats = rng.uniform(size=(n, m, d)).astype(np.float32)
        wstar = rng.normal(size=d)
        shift = rng.normal(0.0, 0.5, size=(n, 1))
        logits = feats @ wstar - np.median(feats @ wstar) + shift
        labels = np.where(logits + rng.logistic(size=(n, m)) > 0, 1.0, -1.0)
    else:
        raise ValueError(f"unknown dataset {name!r}")
    return feats.astype(np.float32), labels.astype(np.float32)
