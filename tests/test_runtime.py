"""Golden parity suite for the ``repro.runtime`` whole-run executor.

The load-bearing guarantee: the scan executor is the SAME run as the eager
per-round loop — same plan, same device-synthesised batches, same step
function — only the dispatch differs.  Curves must therefore agree within
the documented FMA-contraction tolerances (tests/test_optim_fused.py:
XLA may contract multiply-adds differently when the step is compiled
inside a ``lax.scan`` body than when compiled standalone; bitwise f32
equality is NOT attainable, rtol=1e-5 + small atol is the contract).

Covered here:

* plan lowering (masks/scales/keys shapes, resume-stable key folding,
  γ-axis grid_scales),
* scan-vs-eager curve parity across (scheduler × update_impl ×
  delay-adaptive) combos, including the sync (delay_rounds=0) baseline,
* the metric transports: per-chunk readback, overlapped deferred readback,
  the per-round io_callback tap, and metric-free execution — all the same
  curves, with honest ExecStats (launches / host_syncs / tap_events),
* the vmapped γ-grid lane: ``run_grid[i]`` ≡ a single-γ scan run on a
  trainer built at γ_i,
* chunk-boundary edge cases: ``rounds_per_launch`` of 1, ``rounds``, and a
  ragged ``rounds % K != 0`` split, plus ``on_step`` barrier semantics,
* checkpoint-resume at a chunk boundary (pooled state) ≡ uninterrupted,
* ``TrainerBackend`` wiring (spec/constructor runtime+metrics resolution,
  the grid lane end-to-end vs the sequential oracle), and
* an 8-virtual-device pooled ZeRO-sharded scan run (subprocess
  self-bootstrap on single-device hosts, mirroring
  tests/test_pool_multidevice.py).
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import ExperimentSpec, RunResult, TrainJob, TrainerBackend
from repro.core import lower_rounds, round_delay_scales, round_masks
from repro.runtime import (METRICS, PlanExecutor, RunPlan, compile_plan,
                           execute, fold_data_keys, make_batch_fn,
                           run_eager, run_grid, run_scan)

MULTI = jax.device_count() >= 8

#: micro transformer: jit/compile dominates CPU test wall time, so shrink
#: the per-step math to noise and spend the budget on dispatch coverage
MICRO = (("n_layers", 1), ("d_model", 64), ("n_heads", 2), ("n_kv_heads", 1),
         ("d_ff", 64), ("vocab", 97))

TOL = dict(rtol=1e-5, atol=1e-7)


def _job(**kw):
    kw.setdefault("arch", "qwen2-0.5b")
    kw.setdefault("global_batch", 8)
    kw.setdefault("seq_len", 16)
    kw.setdefault("arch_overrides", MICRO)
    return TrainJob(**kw)


def _spec(job, scheduler="shuffled", T=6, adaptive=False, **kw):
    kw.setdefault("stepsize",
                  f"delay_adaptive:{3e-3}" if adaptive else 3e-3)
    return ExperimentSpec(scheduler=scheduler, timing="poisson:slow=6",
                          objective=job, T=T, n_workers=4, seed=0, **kw)


def _trainer(job, mesh=None, lr=3e-3):
    from jax.sharding import Mesh
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.optim import OptConfig

    if mesh is None:
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
    tr = AsyncTrainer(
        job.make_arch(), mesh,
        opt=OptConfig(lr=lr, clip_norm=job.clip_norm,
                      update_impl=job.update_impl),
        async_cfg=AsyncConfig(delay_rounds=job.delay_rounds))
    tr.n_groups = 4
    return tr


def _plan_for(spec, job):
    _, schedule = TrainerBackend.masks_for(spec, 4)
    return compile_plan(schedule, job, rounds=spec.T, n_groups=4,
                        seed=spec.seed,
                        adaptive=spec.stepsize.kind == "delay_adaptive")


@pytest.mark.skipif(MULTI, reason="already on a multi-device host")
def test_multidevice_suite_in_subprocess():
    """Single-device hosts: run this file under 8 virtual CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-k", "multidevice"],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, \
        f"8-device runtime suite failed:\n{r.stdout}\n{r.stderr}"
    assert " passed" in r.stdout


# ---------------------------------------------------------------------------
# plan lowering
# ---------------------------------------------------------------------------
def test_lower_rounds_matches_components():
    spec = _spec(_job(), scheduler="fedbuff:b=2", T=10)
    _, schedule = TrainerBackend.masks_for(spec, 4)
    masks, ones = lower_rounds(schedule, 10)
    np.testing.assert_array_equal(masks, round_masks(schedule, 10))
    np.testing.assert_array_equal(ones, np.ones(10, np.float32))
    m2, scales = lower_rounds(schedule, 10, delay_rounds=1, adaptive=True)
    np.testing.assert_array_equal(m2, masks)
    np.testing.assert_array_equal(
        scales, round_delay_scales(schedule, 10, delay_rounds=1))


def test_compile_plan_builds_arch_once():
    """Regression: with zipf_as set, compile_plan used to call
    job.make_arch() twice (once for the vocab probe, once for the
    pipeline config) — the probe must reuse the single build."""
    calls = []

    class CountingJob(TrainJob):
        def make_arch(self):
            calls.append(1)
            return super().make_arch()

    job = CountingJob(arch_overrides=MICRO)
    spec = _spec(job, T=5)
    _, schedule = TrainerBackend.masks_for(spec, 4)
    compile_plan(schedule, job, rounds=5, n_groups=4, seed=0,
                 zipf_as=np.full(5, 1.2))
    assert len(calls) == 1, f"make_arch called {len(calls)} times"


def test_compile_plan_shapes_and_validation():
    job = _job()
    spec = _spec(job, T=7)
    plan = _plan_for(spec, job)
    assert plan.rounds == 7 and plan.n_groups == 4
    assert plan.masks.shape == (7, 4)
    assert plan.delay_scales.shape == (7,)
    assert plan.data_keys.shape == (7, 2)
    assert plan.vocab == 97                      # MICRO override
    assert plan.group_perms.shape == (4, 97)
    assert np.all(np.diff(plan.token_cdf) >= 0)
    assert abs(plan.token_cdf[-1] - 1.0) < 1e-5
    # not adaptive → neutral scales
    np.testing.assert_array_equal(plan.delay_scales, np.ones(7, np.float32))
    with pytest.raises(ValueError, match="rounds"):
        RunPlan(masks=plan.masks, delay_scales=plan.delay_scales[:3],
                data_keys=plan.data_keys, token_cdf=plan.token_cdf,
                group_perms=plan.group_perms, global_batch=8, seq_len=16,
                seed=0)
    with pytest.raises(ValueError, match="divide"):
        RunPlan(masks=plan.masks, delay_scales=plan.delay_scales,
                data_keys=plan.data_keys, token_cdf=plan.token_cdf,
                group_perms=plan.group_perms, global_batch=9, seq_len=16,
                seed=0)


def test_fold_data_keys_resume_stable():
    """Key at round q must not depend on the horizon — that is what makes
    a resumed run regenerate the identical batch stream."""
    k10, k4 = fold_data_keys(3, 10), fold_data_keys(3, 4)
    np.testing.assert_array_equal(k10[:4], k4)
    assert not np.array_equal(fold_data_keys(4, 4), k4)      # seed matters
    assert len({tuple(k) for k in k10}) == 10                # distinct rounds


def test_device_batch_synthesis_is_grouped_and_deterministic():
    job = _job()
    plan = _plan_for(_spec(job, T=3), job)
    batch_of = make_batch_fn(plan, job.make_arch())
    b0 = batch_of(jnp.asarray(plan.data_keys[0]))
    b0b = batch_of(jnp.asarray(plan.data_keys[0]))
    b1 = batch_of(jnp.asarray(plan.data_keys[1]))
    toks = np.asarray(b0["tokens"])
    assert toks.shape == (8, 16) and toks.dtype == np.int32
    assert toks.min() >= 0 and toks.max() < plan.vocab
    np.testing.assert_array_equal(toks, np.asarray(b0b["tokens"]))
    assert not np.array_equal(toks, np.asarray(b1["tokens"]))


# ---------------------------------------------------------------------------
# golden scan-vs-eager parity (scheduler × update_impl × delay-adaptive)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheduler,impl,adaptive,delay_rounds", [
    ("shuffled", "reference", False, 1),
    ("fedbuff:b=2", "reference", True, 1),
    ("pure", "reference", False, 0),                  # sync baseline
    ("random", "pallas_interpret", False, 1),
    ("shuffled", "pallas_pooled_interpret", True, 1),
])
def test_scan_matches_eager(scheduler, impl, adaptive, delay_rounds):
    job = _job(update_impl=impl, delay_rounds=delay_rounds)
    spec = _spec(job, scheduler=scheduler, T=6, adaptive=adaptive)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    r_e = run_eager(tr, plan, tr.init_state(jax.random.PRNGKey(0)))
    r_s = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=4)               # ragged: 4 + 2
    # honest accounting: eager = one STEP launch + one blocking metric
    # readback per round (the batch-synthesis jit is not a round launch);
    # scan without a callback overlaps chunks and reads back ONCE
    assert r_e.launches == 6 and r_e.host_syncs == 6
    assert r_e.tap_events == 0
    assert r_s.launches == 2 and r_s.host_syncs == 1
    assert r_s.tap_events == 0
    for k in METRICS:
        np.testing.assert_allclose(r_s.metrics[k], r_e.metrics[k], **TOL,
                                   err_msg=f"metric {k}")
    if adaptive:        # the adaptive lowering actually ran (the rule may
        assert plan.adaptive     # still saturate at 1 for short horizons)
        assert np.all(plan.delay_scales <= 1.0)


# ---------------------------------------------------------------------------
# chunk-boundary edge cases + on_step barrier semantics
# ---------------------------------------------------------------------------
def test_chunk_boundary_edge_cases():
    """K=1 (degenerate eager), K=rounds (one launch), ragged K — all the
    same curves; on_step fires once per round, at chunk boundaries, in
    order.  With a callback the readback blocks every chunk (host_syncs
    == launches — the callback must see values)."""
    job = _job()
    spec = _spec(job, T=5)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    base = run_eager(tr, plan, tr.init_state(jax.random.PRNGKey(0)))
    for k, launches in ((1, 5), (3, 2), (5, 1)):
        seen = []
        r = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                     rounds_per_launch=k,
                     on_step=lambda i, st, m: seen.append((i, m["loss"])))
        assert r.launches == launches and r.host_syncs == launches
        assert [i for i, _ in seen] == list(range(5))
        np.testing.assert_allclose([l for _, l in seen],
                                   base.metrics["loss"], **TOL)
        for name in METRICS:
            np.testing.assert_allclose(r.metrics[name], base.metrics[name],
                                       **TOL, err_msg=f"K={k} {name}")


# ---------------------------------------------------------------------------
# metric transports: tap / none / overlapped chunk
# ---------------------------------------------------------------------------
def test_metrics_tap_streams_per_round():
    """The io_callback tap delivers every round's metrics in order with
    ZERO blocking readbacks, fires on_step per round with state=None
    (mid-scan state never materialises on host), and the curves match the
    eager oracle — even at rounds_per_launch == rounds (one launch for
    the whole run, the configuration a chunk barrier would make
    log-silent)."""
    job = _job()
    spec = _spec(job, T=6)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    base = run_eager(tr, plan, tr.init_state(jax.random.PRNGKey(0)))
    seen = []
    r = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                 rounds_per_launch=6, metrics="tap",
                 on_step=lambda i, st, m: seen.append((i, st, m["loss"])))
    assert r.launches == 1 and r.host_syncs == 0 and r.tap_events == 6
    assert [i for i, _, _ in seen] == list(range(6))
    assert all(st is None for _, st, _ in seen)
    np.testing.assert_allclose([l for _, _, l in seen],
                               base.metrics["loss"], **TOL)
    for k in METRICS:
        np.testing.assert_allclose(r.metrics[k], base.metrics[k], **TOL,
                                   err_msg=f"tap {k}")
    # ragged chunking under tap: same stream, one tap per round
    seen2 = []
    r2 = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                  rounds_per_launch=4, metrics="tap",
                  on_step=lambda i, st, m: seen2.append(i))
    assert r2.launches == 2 and r2.tap_events == 6
    assert seen2 == list(range(6))
    np.testing.assert_allclose(r2.metrics["loss"], base.metrics["loss"],
                               **TOL)


def test_tap_row_drop_fails_loudly():
    """A tap row that never reaches the host sink must abort the run with
    the delivered/expected accounting — never return silently truncated
    curves.  The chunk jit binds ``self._emit_tap`` at trace time, so the
    lossy transport is patched onto the instance BEFORE the first
    ``run_scan`` builds the program."""
    job = _job()
    spec = _spec(job, T=6)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    ex = PlanExecutor(tr, plan, donate=False)
    orig = ex._emit_tap

    def lossy(idx, row):
        if int(idx) == 2:
            return                    # swallow one io_callback delivery
        orig(idx, row)

    ex._emit_tap = lossy
    state = tr.init_state(jax.random.PRNGKey(0))
    with pytest.raises(RuntimeError, match="io_callback was dropped"):
        ex.run_scan(state, rounds_per_launch=3, metrics="tap")
    # the counts in the message are the delivered/expected pair
    with pytest.raises(RuntimeError, match=r"5/6"):
        ex.run_scan(tr.init_state(jax.random.PRNGKey(0)),
                    rounds_per_launch=3, metrics="tap")


def test_metrics_none_discards_on_device():
    """metrics="none": no curves, no syncs, no taps — and an on_step
    callback is rejected up front (it would silently never fire)."""
    job = _job()
    spec = _spec(job, T=4)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    base = run_eager(tr, plan, tr.init_state(jax.random.PRNGKey(0)))
    r = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                 rounds_per_launch=2, metrics="none")
    assert r.metrics == {}
    assert r.launches == 2 and r.host_syncs == 0 and r.tap_events == 0
    # the run still trained: final params match the eager oracle's
    pe = tr.params_of(base.state)
    pn = tr.params_of(r.state)
    for a, b in zip(jax.tree_util.tree_leaves(pe),
                    jax.tree_util.tree_leaves(pn)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-6)
    with pytest.raises(ValueError, match="on_step"):
        run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                 metrics="none", on_step=lambda i, st, m: None)
    with pytest.raises(ValueError, match="unknown metrics"):
        run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                 metrics="streaming")


def test_neutral_plan_honors_trainer_static_delay_rule():
    """A NON-adaptive plan must not override the trainer's own static
    ``AsyncConfig(delay_adaptive=True)`` 1/(1+delay) rule with an explicit
    all-ones scale — the executor calls the 3-arg step, the trainer's
    config stays in charge, and scan still matches eager."""
    from jax.sharding import Mesh
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.optim import OptConfig

    job = _job()
    spec = _spec(job, T=4)
    plan = _plan_for(spec, job)
    assert not plan.adaptive
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    tr_static = AsyncTrainer(
        job.make_arch(), mesh,
        opt=OptConfig(lr=3e-3, clip_norm=job.clip_norm),
        async_cfg=AsyncConfig(delay_rounds=1, delay_adaptive=True))
    tr_static.n_groups = 4
    r_e = run_eager(tr_static, plan,
                    tr_static.init_state(jax.random.PRNGKey(0)))
    r_s = run_scan(tr_static, plan,
                   tr_static.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=2)
    for k in METRICS:
        np.testing.assert_allclose(r_s.metrics[k], r_e.metrics[k], **TOL)
    # and the halved stepsize actually bit: curves diverge from the plain
    # (delay_adaptive=False) trainer once the first buffered grad applies
    plain = run_eager(_trainer(job), plan,
                      tr_static.init_state(jax.random.PRNGKey(0)))
    assert not np.allclose(plain.metrics["loss"][2:],
                           r_e.metrics["loss"][2:], rtol=1e-6)


def test_execute_dispatch_and_unknown_runtime():
    job = _job()
    plan = _plan_for(_spec(job, T=2), job)
    tr = _trainer(job)
    r = execute(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                runtime="scan", rounds_per_launch=2)
    assert r.launches == 1
    r = execute(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                runtime="scan", rounds_per_launch=2, metrics="none")
    assert r.metrics == {}
    with pytest.raises(ValueError, match="unknown runtime"):
        execute(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                runtime="vectorized")


# ---------------------------------------------------------------------------
# vmapped γ-grid lane
# ---------------------------------------------------------------------------
#: exact-binary γ ratios so the lane's γ_g = γ_base·(γ_g/γ_base) product is
#: bitwise the single-run lr and the remaining diff is pure FMA noise
GRID_GAMMAS = (3e-3, 1.5e-3, 7.5e-4, 3.75e-4)


def _grid_plan_for(spec, job, gammas=GRID_GAMMAS):
    _, schedule = TrainerBackend.masks_for(spec, 4)
    return compile_plan(schedule, job, rounds=spec.T, n_groups=4,
                        seed=spec.seed, grid_gammas=gammas)


def test_grid_plan_lowering_and_validation():
    job = _job()
    spec = _spec(job, T=5)
    plan = _grid_plan_for(spec, job)
    assert plan.n_grid == 4
    assert plan.grid_scales.shape == (4, 5)
    # row g is γ_g/γ_0 × the (neutral) per-round scales
    np.testing.assert_allclose(
        plan.grid_scales,
        (np.asarray(GRID_GAMMAS, np.float32) / np.float32(3e-3))[:, None]
        * np.ones((1, 5), np.float32))
    assert plan.summary()["n_grid"] == 4
    single = _plan_for(spec, job)
    assert single.n_grid == 0
    with pytest.raises(ValueError, match="γ-axis"):
        single.grid_slice(0, 2)
    with pytest.raises(ValueError, match="grid_scales"):
        RunPlan(masks=plan.masks, delay_scales=plan.delay_scales,
                data_keys=plan.data_keys, token_cdf=plan.token_cdf,
                group_perms=plan.group_perms, global_batch=8, seq_len=16,
                seed=0, grid_scales=plan.grid_scales[:, :3])


def test_run_grid_matches_single_gamma_runs():
    """The load-bearing grid-lane gate: lane i of one vmapped grid run ≡
    a standalone scan run on a trainer built at lr=γ_i (same plan, same
    batches), within the documented FMA tolerances."""
    job = _job()
    spec = _spec(job, T=6)
    gplan = _grid_plan_for(spec, job)
    plan = _plan_for(spec, job)
    tr = _trainer(job)                    # lr = 3e-3 = γ_base
    rg = run_grid(tr, gplan, tr.init_state(jax.random.PRNGKey(0)),
                  rounds_per_launch=4)    # ragged: 4 + 2
    assert rg.metrics["loss"].shape == (4, 6)
    assert rg.launches == 2 and rg.host_syncs == 1
    # γ really differed across lanes
    assert not np.allclose(rg.metrics["loss"][0], rg.metrics["loss"][3],
                           rtol=1e-6)
    for i, g in enumerate(GRID_GAMMAS):
        tri = _trainer(_job(), lr=g)
        ri = run_scan(tri, plan, tri.init_state(jax.random.PRNGKey(0)),
                      rounds_per_launch=4)
        for k in METRICS:
            np.testing.assert_allclose(
                rg.metrics[k][i], ri.metrics[k], **TOL,
                err_msg=f"grid lane γ={g} metric {k}")
    # rows is a single-run view; grid curves must not silently flatten
    with pytest.raises(ValueError, match="grid"):
        rg.rows


def test_run_grid_stacked_resume_and_modes():
    """run_grid accepts an already-stacked state (resume), supports
    metrics="none", and rejects tap / plans without a γ-axis."""
    job = _job()
    spec = _spec(job, T=4)
    _, schedule = TrainerBackend.masks_for(spec, 4)
    gplan = _grid_plan_for(spec, job)
    # the same schedule truncated to its first 2 rounds — a run stopped
    # at the chunk boundary (plan prefixes are exact: lower_rounds slices
    # the same realisation, data keys are horizon-independent)
    head_plan = compile_plan(schedule, job, rounds=2, n_groups=4, seed=0,
                             grid_gammas=GRID_GAMMAS)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    ex = PlanExecutor(tr, gplan, donate=False)
    full = ex.run_grid(tr.init_state(jax.random.PRNGKey(0)),
                       rounds_per_launch=2)
    head = PlanExecutor(tr, head_plan, donate=False).run_grid(
        tr.init_state(jax.random.PRNGKey(0)), rounds_per_launch=2)
    # resume: feed the stacked carry back in at the boundary
    tail = ex.run_grid(head.state, rounds_per_launch=2, start_round=2)
    assert tail.metrics["loss"].shape == (4, 2)
    np.testing.assert_allclose(tail.metrics["loss"],
                               full.metrics["loss"][:, 2:], **TOL)
    r_none = ex.run_grid(tr.init_state(jax.random.PRNGKey(0)),
                         rounds_per_launch=4, metrics="none")
    assert r_none.metrics == {} and r_none.host_syncs == 0
    with pytest.raises(ValueError, match="tap"):
        ex.run_grid(tr.init_state(jax.random.PRNGKey(0)), metrics="tap")
    with pytest.raises(ValueError, match="γ-axis"):
        run_grid(tr, plan, tr.init_state(jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# checkpoint-resume parity at a chunk boundary (pooled state)
# ---------------------------------------------------------------------------
def test_checkpoint_resume_parity_pooled(tmp_path):
    """Save at a chunk boundary via repro.checkpoint, restore (pooled
    pools + scalars), finish — loss/grad-norm curves must match an
    uninterrupted run within the FMA tolerances."""
    from repro import checkpoint

    job = _job(update_impl="pallas_pooled_interpret")
    spec = _spec(job, T=6)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    assert tr.pooled

    full = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                    rounds_per_launch=3)

    ckpt = str(tmp_path / "ckpt")
    saved = {}

    def barrier(i, state, m):
        if i == 2:                  # chunk boundary: state is post-round-3
            checkpoint.save(ckpt, state, step=i + 1)
            saved["step"] = i + 1

    first = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                     rounds_per_launch=3, on_step=barrier)
    assert saved["step"] == 3
    for k in METRICS:
        np.testing.assert_allclose(first.metrics[k], full.metrics[k], **TOL)

    restored = checkpoint.restore(ckpt, tr.abstract_state(),
                                  shardings=tr.state_shardings())
    assert int(restored["step"]) == 3
    tail = run_scan(tr, plan, restored, rounds_per_launch=3, start_round=3)
    for k in ("loss", "grad_norm"):
        np.testing.assert_allclose(tail.metrics[k], full.metrics[k][3:],
                                   **TOL, err_msg=f"resumed {k}")


def test_run_grid_snapshot_resume_midgrid(tmp_path):
    """Resume edge case 1: a γ-grid run snapshotted mid-run by the async
    snapshotter restores as the already-STACKED carry and resumes ≡ the
    uninterrupted grid run (curves and final stacked states)."""
    from repro import checkpoint
    from repro.checkpoint import AsyncSnapshotter

    job = _job()
    spec = _spec(job, T=4)
    gplan = _grid_plan_for(spec, job)
    tr = _trainer(job)
    ex = PlanExecutor(tr, gplan, donate=False)
    snapdir = str(tmp_path / "grid-snaps")
    snap = AsyncSnapshotter(snapdir, 2, keep=4)
    full = ex.run_grid(tr.init_state(jax.random.PRNGKey(0)),
                       rounds_per_launch=2, snapshot=snap)
    assert full.stats.snapshots == 2              # boundaries 2 and 4

    # the stacked template gives restore the (n_grid, ...) structure
    template = ex.stack_state(tr.init_state(jax.random.PRNGKey(0)))
    restored = checkpoint.restore(str(tmp_path / "grid-snaps" /
                                      "round-00000002"), template)
    np.testing.assert_array_equal(np.asarray(restored["step"]),
                                  np.full(4, 2))
    tail = ex.run_grid(restored, rounds_per_launch=2, start_round=2)
    assert tail.metrics["loss"].shape == (4, 2)
    np.testing.assert_allclose(tail.metrics["loss"],
                               full.metrics["loss"][:, 2:], **TOL)
    for a, b in zip(jax.tree_util.tree_leaves(full.state),
                    jax.tree_util.tree_leaves(tail.state)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-7)


def test_resume_after_final_chunk_is_noop():
    """Resume edge case 2: ``start_round == rounds`` (a run restored from
    its FINAL snapshot) is an exact no-op on every lane — zero launches,
    empty curves, the carry handed back untouched."""
    job = _job()
    spec = _spec(job, T=4)
    plan = _plan_for(spec, job)
    tr = _trainer(job)
    ex = PlanExecutor(tr, plan, donate=False)
    state = tr.init_state(jax.random.PRNGKey(0))
    done = ex.run_scan(state, rounds_per_launch=2).state

    for metrics in ("chunk", "tap", "none"):
        r = ex.run_scan(done, rounds_per_launch=2, metrics=metrics,
                        start_round=4)
        assert r.launches == 0 and r.host_syncs == 0 and r.tap_events == 0
        if metrics == "none":
            assert r.metrics == {}
        else:
            assert all(len(v) == 0 for v in r.metrics.values())
        for a, b in zip(jax.tree_util.tree_leaves(done),
                        jax.tree_util.tree_leaves(r.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    r_e = ex.run_eager(done, start_round=4)
    assert r_e.launches == 0
    assert all(len(v) == 0 for v in r_e.metrics.values())

    gex = PlanExecutor(tr, _grid_plan_for(spec, job), donate=False)
    gdone = gex.run_grid(tr.init_state(jax.random.PRNGKey(0)),
                         rounds_per_launch=2)
    rg = gex.run_grid(gdone.state, rounds_per_launch=2, start_round=4)
    assert rg.launches == 0 and rg.metrics == {}
    for a, b in zip(jax.tree_util.tree_leaves(gdone.state),
                    jax.tree_util.tree_leaves(rg.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# TrainerBackend wiring
# ---------------------------------------------------------------------------
def test_backend_runtime_resolution():
    be = TrainerBackend()
    assert be.resolve_runtime(_spec(_job())) == ("scan", 8, "chunk")
    assert be.resolve_runtime(
        _spec(_job(), runtime="eager", rounds_per_launch=3,
              metrics="tap")) == ("eager", 3, "tap")
    assert TrainerBackend(runtime="eager", rounds_per_launch=2,
                          metrics="none") \
        .resolve_runtime(_spec(_job(), runtime="scan",
                               metrics="tap")) == ("eager", 2, "none")
    with pytest.raises(ValueError, match="unknown runtime"):
        _spec(_job(), runtime="vectorized")
    with pytest.raises(ValueError, match="unknown metrics"):
        _spec(_job(), metrics="streaming")
    with pytest.raises(ValueError, match="rounds_per_launch"):
        _spec(_job(), rounds_per_launch=0)


def test_backend_scan_eager_parity_and_result_roundtrip():
    """End-to-end through ``repro.api``: default scan ≡ eager oracle, the
    RunResult records the dispatch provenance, and the archived JSON
    round-trips the curves exactly."""
    job = _job()
    spec = _spec(job, T=4, rounds_per_launch=2)
    res_s = TrainerBackend().run(spec)
    res_e = TrainerBackend(runtime="eager").run(spec)
    assert res_s.extra["runtime"] == "scan"
    assert res_s.extra["rounds_per_launch"] == 2
    assert res_s.extra["metrics_mode"] == "chunk"
    # no on_step → overlapped chunks, one deferred readback
    assert res_s.extra["launches"] == 2 and res_s.extra["host_syncs"] == 1
    assert res_s.extra["tap_events"] == 0
    assert res_e.extra["runtime"] == "eager"
    assert res_e.extra["launches"] == 4 and res_e.extra["host_syncs"] == 4
    np.testing.assert_allclose(res_s.losses, res_e.losses, **TOL)
    np.testing.assert_allclose(res_s.grad_norms, res_e.grad_norms, **TOL)
    assert len(res_s.extra["metrics"]) == 4

    r2 = RunResult.from_json(res_s.to_json())
    np.testing.assert_array_equal(r2.losses, res_s.losses)
    np.testing.assert_array_equal(r2.grad_norms, res_s.grad_norms)
    assert r2.backend == "trainer"
    assert r2.extra["runtime"] == "scan"
    assert r2.schedule["tau_max"] == res_s.schedule.tau_max()


def test_backend_tap_and_none_modes():
    """Spec-level metrics selection reaches the executor: tap streams
    per-round rows to on_step (state=None), none returns no curves."""
    job = _job()
    seen = []
    res_t = TrainerBackend(
        metrics="tap",
        on_step=lambda i, st, m: seen.append((i, st))).run(
            _spec(job, T=4, rounds_per_launch=4))
    assert res_t.extra["metrics_mode"] == "tap"
    assert res_t.extra["tap_events"] == 4
    assert res_t.extra["host_syncs"] == 0
    assert [i for i, _ in seen] == list(range(4))
    assert all(st is None for _, st in seen)
    assert res_t.losses is not None and len(res_t.losses) == 4

    res_n = TrainerBackend().run(_spec(job, T=4, metrics="none"))
    assert res_n.extra["metrics_mode"] == "none"
    assert res_n.losses is None and res_n.grad_norms is None
    assert res_n.extra["metrics"] == []
    assert res_n.x is not None

    # a grid spec that misses the vmapped lane (single γ) still has to
    # SCORE runs, so the sequential fallback must override metrics="none"
    # instead of crashing on losses=None
    res_1g = TrainerBackend().run(
        _spec(job, T=4, stepsize=(3e-3,), metrics="none"))
    assert res_1g.losses is not None and len(res_1g.losses) == 4


def test_backend_grid_lane_matches_sequential_oracle():
    """End-to-end grid policy on the scan runtime: ONE vmapped program,
    same winner and same winning curves as the sequential eager-runtime
    grid loop (the oracle), per-γ curves preserved in RunResult.grid."""
    job = _job()
    spec = _spec(job, T=6, rounds_per_launch=4, stepsize=GRID_GAMMAS)
    res_g = TrainerBackend().run(spec)
    res_q = TrainerBackend(runtime="eager").run(spec)
    assert res_g.extra.get("grid_lane") and res_g.extra["n_grid"] == 4
    assert res_g.extra["launches"] == 2       # 2 chunks, ALL γ per launch
    assert set(res_g.grid) == set(GRID_GAMMAS)
    assert res_g.gamma == res_q.gamma         # same selected stepsize
    np.testing.assert_allclose(res_g.losses, res_q.losses, **TOL)
    for g in GRID_GAMMAS:
        assert res_g.grid[g]["losses"].shape == (6,)
        assert np.isfinite(res_g.grid[g]["score"])
    # an on_step consumer forces the sequential path (the lane has no
    # per-round hook)
    res_cb = TrainerBackend(on_step=lambda i, st, m: None).run(spec)
    assert not res_cb.extra.get("grid_lane")
    np.testing.assert_allclose(res_cb.losses, res_g.losses, **TOL)

    # grid-lane results archive and restore: per-γ curves exact, float
    # keys recovered, provenance fields intact
    r2 = RunResult.from_json(res_g.to_json())
    assert set(r2.grid) == set(GRID_GAMMAS)
    for g in GRID_GAMMAS:
        np.testing.assert_array_equal(r2.grid[g]["losses"],
                                      res_g.grid[g]["losses"])
        assert r2.grid[g]["score"] == res_g.grid[g]["score"]
    np.testing.assert_array_equal(r2.losses, res_g.losses)
    assert r2.extra["grid_lane"] and r2.extra["n_grid"] == 4
    assert r2.gamma == res_g.gamma


# ---------------------------------------------------------------------------
# scenario worlds on the compiled path
# ---------------------------------------------------------------------------
SCENARIO = ("straggler:k=1,factor=6,every=4,span=2;"
            "elastic:k=1,every=4,span=2;"
            "data_drift:a0=1.1,a1=2.0;"
            "sparsify:frac=0.5")


def test_scenario_channel_lowering_and_validation():
    """The extra RunPlan channels lower and validate without any executor
    work: zipf trajectories quantise into a monotone CDF bank, and
    malformed channels are rejected up front."""
    from repro.runtime import quantize_zipf_trajectory

    bank, idx = quantize_zipf_trajectory(np.linspace(1.0, 2.0, 12), 97,
                                         n_phases=4)
    assert bank.shape[1] == 97 and 2 <= bank.shape[0] <= 4
    assert idx.shape == (12,)
    assert idx.min() >= 0 and idx.max() < bank.shape[0]
    np.testing.assert_allclose(bank[:, -1], 1.0, atol=1e-5)
    assert np.all(np.diff(bank, axis=1) >= -1e-7)      # each row is a CDF
    # a constant trajectory collapses to a single phase
    b1, i1 = quantize_zipf_trajectory(np.full(5, 1.5), 97)
    assert b1.shape[0] == 1 and np.all(i1 == 0)

    job = _job()
    plan = _plan_for(_spec(job, T=4), job)
    common = dict(masks=plan.masks, delay_scales=plan.delay_scales,
                  data_keys=plan.data_keys, token_cdf=plan.token_cdf,
                  group_perms=plan.group_perms, global_batch=8, seq_len=16,
                  seed=0)
    with pytest.raises(ValueError, match="set together"):
        RunPlan(cdf_index=np.zeros(4, np.int32), **common)
    with pytest.raises(ValueError, match="out of cdf_bank range"):
        RunPlan(cdf_bank=bank, cdf_index=np.full(4, 99, np.int32), **common)
    with pytest.raises(ValueError, match=r"in \(0, 1\]"):
        RunPlan(grad_density=np.zeros(4, np.float32), **common)
    with pytest.raises(ValueError, match="grad_density"):
        RunPlan(grad_density=np.ones(3, np.float32), **common)


def test_scenario_plan_scan_matches_eager():
    """A full four-channel scenario world (straggler speeds + elastic
    membership + drifting Zipf data + top-k sparsified grads) lowers into
    ONE RunPlan, and the scan executor still matches the eager oracle —
    including rounds where elastic hard-drop zeroes a worker's mask entry
    that held a live receipt."""
    job = _job()
    spec = ExperimentSpec(scheduler="fedbuff:b=2", timing="poisson:slow=6",
                          objective=job, T=12, n_workers=4, seed=3,
                          scenario=SCENARIO)
    world = TrainerBackend.world_for(spec, 4)
    plan = compile_plan(world.schedule, job, rounds=12, n_groups=4, seed=3,
                        availability=world.availability,
                        zipf_as=world.zipf_as,
                        grad_density=world.grad_density)
    s = plan.summary()
    assert s["sparsified"] and s["n_cdf_phases"] >= 2
    # hard-drop: every (round, worker) the world marked down is zeroed...
    avail = world.availability[:12]
    assert (avail == 0).any()
    assert np.all(plan.masks[avail == 0] == 0.0)
    # ...and at least one of those entries held a receipt before the drop
    raw, _ = lower_rounds(world.schedule, 12)
    assert (raw[avail == 0] != 0).any()

    tr = _trainer(job)
    r_e = run_eager(tr, plan, tr.init_state(jax.random.PRNGKey(0)))
    r_s = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=5)                # ragged: 5 + 5 + 2
    assert r_s.launches == 3 and r_e.launches == 12
    for k in METRICS:
        np.testing.assert_allclose(r_s.metrics[k], r_e.metrics[k], **TOL,
                                   err_msg=f"scenario metric {k}")
    pe = tr.params_of(r_e.state)
    ps = tr.params_of(r_s.state)
    for a, b in zip(jax.tree_util.tree_leaves(pe),
                    jax.tree_util.tree_leaves(ps)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# 8-virtual-device pooled scan run (ZeRO-sharded pools under shard_map)
# ---------------------------------------------------------------------------
@pytest.mark.skipif(not MULTI, reason="needs >= 8 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_scan_pooled_multidevice_parity():
    """Scan executor on a 4-data × 2-model mesh with pooled ZeRO-sharded
    state ≡ the eager oracle on the same mesh, and the carried pools keep
    their sharding across chunk launches (donation must not silently
    replicate)."""
    from repro.launch.mesh import _make_mesh
    from repro.distributed import pooled_pspec
    from jax.sharding import NamedSharding

    mesh = _make_mesh((4, 2), ("data", "model"))
    job = _job(update_impl="pallas_pooled_interpret")
    spec = _spec(job, T=4)
    plan = _plan_for(spec, job)
    tr = _trainer(job, mesh=mesh)
    assert tr.pool_layout.n_shards == 4

    r_e = run_eager(tr, plan, tr.init_state(jax.random.PRNGKey(0)))
    r_s = run_scan(tr, plan, tr.init_state(jax.random.PRNGKey(0)),
                   rounds_per_launch=2)
    for k in METRICS:
        np.testing.assert_allclose(r_s.metrics[k], r_e.metrics[k], **TOL,
                                   err_msg=f"metric {k}")
    want = NamedSharding(mesh, pooled_pspec(mesh))
    for dk, grp in r_s.state["pools"].items():
        for name, buf in grp.items():
            assert buf.sharding.is_equivalent_to(want, buf.ndim), \
                f"pool {dk}/{name} lost ZeRO sharding: {buf.sharding}"
