"""ServeBackend / Server.generate edge cases (PR-1 followups).

The decode driver has two boundary behaviours that previously had no
dedicated assertions: ``n_steps <= 0`` (must return an empty (B, 0) array
WITHOUT compiling or stepping anything) and a batch of one prompt (the
token sharding switches to replicated when batch == 1).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.api import ExperimentSpec, ServeBackend, ServeJob, run
from repro.configs import get_arch
from repro.distributed import Server, ServeConfig
from repro.models import init_params


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _server(batch, ctx=24, temperature=0.0):
    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    srv = Server(cfg, _mesh(), ServeConfig(batch=batch, ctx_len=ctx,
                                           temperature=temperature))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, srv, params


def test_generate_zero_steps_returns_empty():
    cfg, srv, params = _server(batch=2)
    prompts = np.array([3, 5], dtype=np.int32)
    for n_steps in (0, -1):
        out = srv.generate(params, prompts, n_steps)
        assert out.shape == (2, 0)
        assert out.dtype == np.int32


def test_generate_zero_steps_does_not_compile(monkeypatch):
    """The n_steps <= 0 early-out must not pay a jit compile (the whole
    point of the guard)."""
    cfg, srv, params = _server(batch=2)

    def boom(*a, **k):
        raise AssertionError("jit_serve_step must not be called")

    monkeypatch.setattr(srv, "jit_serve_step", boom)
    out = srv.generate(params, np.array([1, 2], dtype=np.int32), 0)
    assert out.shape == (2, 0)


def test_generate_batch_of_one_prompt():
    """batch == 1 flips the token sharding to replicated — the driver must
    still decode and keep shapes (1, n_steps)."""
    cfg, srv, params = _server(batch=1)
    out = srv.generate(params, np.array([7], dtype=np.int32), 4)
    assert out.shape == (1, 4)
    assert out.dtype == np.int32
    assert np.all((out >= 0) & (out < cfg.vocab))


def test_generate_greedy_is_deterministic():
    cfg, srv, params = _server(batch=1)
    a = srv.generate(params, np.array([7], dtype=np.int32), 3)
    b = srv.generate(params, np.array([7], dtype=np.int32), 3)
    np.testing.assert_array_equal(a, b)


def test_serve_backend_single_decode_step():
    """spec.T == 1: only the prefill token is emitted (generate runs for
    T − 1 = 0 steps) — exactly (batch, 1), finite throughput stats."""
    res = ServeBackend(mesh=_mesh()).run(ExperimentSpec(
        scheduler="pure", objective=ServeJob(batch=2, prompt_len=4), T=1,
        n_workers=2, seed=0))
    assert res.x.shape == (2, 1)
    assert res.extra["prompts"].shape == (2, 4)
    assert np.isfinite(res.extra["tok_per_s"])


def test_serve_backend_batch_of_one():
    res = run(ExperimentSpec(
        scheduler="pure", objective=ServeJob(batch=1, prompt_len=3), T=3,
        n_workers=1, seed=1))
    assert res.backend == "serve"
    assert res.x.shape == (1, 3)


def test_generate_compiles_once_across_calls():
    """Regression: jit_serve_step used to build a FRESH jax.jit wrapper per
    generate call, so every call retraced and recompiled the step.  The
    wrapper must now be cached on the instance, and a second generate must
    add ZERO backend compiles and ZERO traced signatures."""
    from jax import monitoring

    cfg, srv, params = _server(batch=2)
    compiles = []

    def listener(event, duration, **kw):
        if event == "/jax/core/compile/backend_compile_duration":
            compiles.append(event)

    monitoring.register_event_duration_secs_listener(listener)
    try:
        prompts = np.array([3, 5], dtype=np.int32)
        srv.generate(params, prompts, 3)
        assert srv.jit_serve_step() is srv.jit_serve_step()
        n_compiles = len(compiles)
        n_traces = srv.jit_serve_step()._cache_size()
        srv.generate(params, prompts, 3)
        assert len(compiles) == n_compiles, \
            "second generate() recompiled the serve step"
        assert srv.jit_serve_step()._cache_size() == n_traces, \
            "second generate() retraced the serve step"
    finally:
        monitoring.clear_event_listeners()


def test_generate_threads_sampling_key_across_calls():
    """Regression: generate used to rebuild PRNGKey(seed) per call, so
    successive temperature-sampled calls replayed the SAME stream."""
    cfg, srv, params = _server(batch=2, temperature=1.0)
    prompts = np.array([3, 5], dtype=np.int32)
    a = srv.generate(params, prompts, 6)
    b = srv.generate(params, prompts, 6)
    assert not np.array_equal(a, b), \
        "two consecutive sampled calls replayed the same PRNG stream"


def test_generate_explicit_key_reproduces_without_consuming_stream():
    """A caller-supplied key gives reproducible draws and must not disturb
    the server's persistent stream."""
    cfg, srv, params = _server(batch=2, temperature=1.0)
    prompts = np.array([3, 5], dtype=np.int32)
    first = srv.generate(params, prompts, 4)
    k = jax.random.PRNGKey(7)
    e1 = srv.generate(params, prompts, 4, key=k)
    e2 = srv.generate(params, prompts, 4, key=k)
    np.testing.assert_array_equal(e1, e2)
    second = srv.generate(params, prompts, 4)
    # an identical fresh server draws the same first-then-second streams,
    # proving the explicit-key calls consumed nothing from the instance
    _, srv2, _ = _server(batch=2, temperature=1.0)
    np.testing.assert_array_equal(first, srv2.generate(params, prompts, 4))
    np.testing.assert_array_equal(second, srv2.generate(params, prompts, 4))


def test_serve_backend_rejects_wrong_objective():
    with pytest.raises(TypeError, match="ServeJob"):
        ServeBackend(mesh=_mesh()).run(
            ExperimentSpec(scheduler="pure", objective=None, n_workers=2,
                           T=2))
