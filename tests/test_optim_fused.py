"""Parity suite: fused Pallas optimizer path ≡ reference path (tier-1).

Everything runs ``update_impl="pallas_interpret"`` so it gates on CPU CI;
the compiled ``"pallas"`` impl is the same kernels minus the interpreter.

Exactness contract, checked leaf-by-leaf:

* step counts, clip norms and the gbuf swap: **bitwise identical**.
* f32 params / moments: a few ulp (rtol 1e-5 with a tiny atol for
  cancellation near zero) — the kernel body is op-identical to the
  reference, but XLA contracts its multiply-adds (m, v updates; the final
  ``p − lr·step``) into FMAs, one rounding where the eager reference takes
  two.  Only same-arithmetic survives this bound: a transposed operand,
  wrong bias correction or dropped clip factor fails by orders of
  magnitude.
* bf16 params: tolerance (the reference rounds the STEP to bf16 before
  subtracting; the kernel subtracts in f32 and rounds once).

Shapes deliberately exercise the ``_pad_to_tiles`` edge: sizes that are not
a multiple of block_rows·128, multi-dim leaves, and scalar () leaves.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import (OptConfig, adam_init, fused_delayed_apply,
                         make_delayed_apply, make_optimizer,
                         reference_delayed_apply, resolve_update_impl)

F32 = jnp.float32


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _tree(dtype=jnp.float32, seed=0):
    """Pytree with padding-edge sizes: odd flat sizes, 2-D, and a scalar."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "w": jax.random.normal(ks[0], (33, 7), F32).astype(dtype),
        "b": jax.random.normal(ks[1], (5,), F32).astype(dtype),
        "scalar": jnp.asarray(0.37, dtype),
        "big": jax.random.normal(ks[2], (1000,), F32).astype(dtype),
    }


def _grads_like(params, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), len(params))
    return {k: (jax.random.normal(kk, p.shape, F32).astype(p.dtype)
                if p.ndim else jnp.asarray(0.1 * (seed + 1), p.dtype))
            for kk, (k, p) in zip(ks, sorted(params.items()))}


def _pair(name="adam", dtype=jnp.float32, **kw):
    cfg_ref = OptConfig(name=name, lr=1e-2, update_impl="reference", **kw)
    cfg_fused = OptConfig(name=name, lr=1e-2,
                          update_impl="pallas_interpret", **kw)
    return cfg_ref, cfg_fused


def _assert_state_close(sr, sf, dtype=jnp.float32):
    """count bitwise; f32 moments within FMA-contraction rounding.  With
    bf16 grads the reference round-trips the CLIPPED grad through bf16
    before the moment update (the kernel keeps it f32), so moments carry
    bf16-resolution differences."""
    np.testing.assert_array_equal(np.asarray(sr["count"]),
                                  np.asarray(sf["count"]))
    tol = dict(rtol=1e-5, atol=1e-8) if dtype == jnp.float32 \
        else dict(rtol=5e-2, atol=5e-5)
    for key in ("m", "v"):
        for a, b in zip(jax.tree_util.tree_leaves(sr[key]),
                        jax.tree_util.tree_leaves(sf[key])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)


def _assert_params(pr, pf, dtype):
    for k in pr:
        a, b = np.asarray(pr[k], np.float32), np.asarray(pf[k], np.float32)
        if dtype == jnp.float32:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=5e-7)
        else:
            np.testing.assert_allclose(a, b, rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# resolve / config plumbing
# ---------------------------------------------------------------------------
def test_resolve_update_impl_falls_back_off_tpu():
    assert resolve_update_impl("reference") == "reference"
    assert resolve_update_impl("pallas_interpret") == "pallas_interpret"
    want = "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"
    assert resolve_update_impl("pallas") == want
    with pytest.raises(ValueError, match="update_impl"):
        resolve_update_impl("cuda")


def test_make_optimizer_rejects_unknown_impl():
    with pytest.raises(ValueError):
        make_optimizer(OptConfig(update_impl="fast"))


# ---------------------------------------------------------------------------
# plain (non-delayed) update parity over multi-step trajectories
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("name", ["adam", "sgd"])
def test_update_parity_multistep(name, dtype):
    cfg_ref, cfg_fused = _pair(name, dtype, clip_norm=1.0)
    init_r, upd_r = make_optimizer(cfg_ref)
    init_f, upd_f = make_optimizer(cfg_fused)
    pr = pf = _tree(dtype)
    sr, sf = init_r(pr), init_f(pf)
    for step in range(4):
        g = _grads_like(pr, step)
        pr, sr, gn_r = upd_r(g, sr, pr, cfg_ref, lr_scale=0.5)
        pf, sf, gn_f = upd_f(g, sf, pf, cfg_fused, lr_scale=0.5)
        np.testing.assert_array_equal(np.asarray(gn_r), np.asarray(gn_f))
    _assert_state_close(sr, sf, dtype)
    _assert_params(pr, pf, dtype)


def test_adam_weight_decay_and_no_clip_parity():
    cfg_ref, cfg_fused = _pair("adam", clip_norm=None, weight_decay=0.01)
    init_r, upd_r = make_optimizer(cfg_ref)
    _, upd_f = make_optimizer(cfg_fused)
    pr = pf = _tree()
    sr = sf = init_r(pr)
    g = _grads_like(pr, 3)
    pr, sr, _ = upd_r(g, sr, pr, cfg_ref)
    pf, sf, _ = upd_f(g, sf, pf, cfg_fused)
    _assert_state_close(sr, sf)
    _assert_params(pr, pf, jnp.float32)


def test_sgd_momentum_fused_parity():
    """Momentum-SGD runs the fused ``sgd_momentum_step`` kernel (m-buffer in
    the same HBM pass): trajectory tracks the reference within FMA rounding,
    m buffers included."""
    cfg_ref, cfg_fused = _pair("sgd", momentum=0.9, clip_norm=1.0)
    init_r, upd_r = make_optimizer(cfg_ref)
    _, upd_f = make_optimizer(cfg_fused)
    pr = pf = _tree()
    sr, sf = init_r(pr), init_r(pf)
    for step in range(3):
        g = _grads_like(pr, step)
        pr, sr, gn_r = upd_r(g, sr, pr, cfg_ref, lr_scale=0.5)
        pf, sf, gn_f = upd_f(g, sf, pf, cfg_fused, lr_scale=0.5)
        np.testing.assert_array_equal(np.asarray(gn_r), np.asarray(gn_f))
    _assert_state_close(sr, sf)
    _assert_params(pr, pf, jnp.float32)


def test_sgd_momentum_delayed_fused_parity():
    """Delayed momentum-SGD: one kernel consumes the stale buffer, updates
    the m-buffer, steps params AND swaps in the fresh grads (the last
    reference-fallback in ``fused_delayed_apply`` is gone)."""
    cfg_ref, cfg_fused = _pair("sgd", momentum=0.9, clip_norm=1.0)
    apply_r = make_delayed_apply(cfg_ref)
    apply_f = make_delayed_apply(cfg_fused)
    init, _ = make_optimizer(cfg_ref)
    pr = pf = _tree()
    sr, sf = init(pr), init(pf)
    br = bf = jax.tree_util.tree_map(jnp.zeros_like, pr)
    for step in range(4):
        g = _grads_like(pr, step)
        pr, br, sr, gn_r = apply_r(g, br, sr, pr, cfg_ref, lr_scale=0.25)
        pf, bf, sf, gn_f = apply_f(g, bf, sf, pf, cfg_fused, lr_scale=0.25)
        np.testing.assert_array_equal(np.asarray(gn_r), np.asarray(gn_f))
        for k in g:   # buffer swap is a pure copy: bitwise
            np.testing.assert_array_equal(np.asarray(bf[k]), np.asarray(g[k]))
    _assert_state_close(sr, sf)
    _assert_params(pr, pf, jnp.float32)


# ---------------------------------------------------------------------------
# delayed-buffer apply parity (the trainer's delay_rounds > 0 hot path)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("delay_scale", [1.0, 1.0 / (1.0 + 3.0)])
@pytest.mark.parametrize("name", ["adam", "sgd"])
def test_delayed_apply_parity_multistep(name, delay_scale):
    """Fused apply consumes gbuf, steps params, buffers the fresh grads —
    trajectory must track the reference compose-and-swap leaf-by-leaf, for
    delay_scale ∈ {1, 1/(1+τ)}."""
    cfg_ref, cfg_fused = _pair(name, clip_norm=1.0)
    apply_r = make_delayed_apply(cfg_ref)
    apply_f = make_delayed_apply(cfg_fused)
    init, _ = make_optimizer(cfg_ref)
    pr = pf = _tree()
    sr, sf = init(pr), init(pf)
    br = bf = jax.tree_util.tree_map(jnp.zeros_like, pr)  # empty buffer
    for step in range(4):
        g = _grads_like(pr, step)
        pr, br, sr, gn_r = apply_r(g, br, sr, pr, cfg_ref,
                                   lr_scale=delay_scale)
        pf, bf, sf, gn_f = apply_f(g, bf, sf, pf, cfg_fused,
                                   lr_scale=delay_scale)
        np.testing.assert_array_equal(np.asarray(gn_r), np.asarray(gn_f))
        # the buffer swap is a pure copy: bitwise, and equal to the fresh g
        for k in g:
            np.testing.assert_array_equal(np.asarray(bf[k]), np.asarray(g[k]))
            np.testing.assert_array_equal(np.asarray(br[k]), np.asarray(bf[k]))
    _assert_state_close(sr, sf)
    _assert_params(pr, pf, jnp.float32)


def test_delayed_apply_first_step_empty_buffer_is_identity():
    """gate semantics: zero buffer + lr_scale 0 must leave params bitwise
    untouched on BOTH impls (trainer round 0)."""
    cfg_ref, cfg_fused = _pair("adam")
    init, _ = make_optimizer(cfg_ref)
    p = _tree()
    s = init(p)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, p)
    g = _grads_like(p, 0)
    for cfg, apply in ((cfg_ref, reference_delayed_apply),
                       (cfg_fused, make_delayed_apply(cfg_fused))):
        newp, newb, news, _ = apply(g, zeros, s, p, cfg, lr_scale=0.0)
        for k in p:
            np.testing.assert_array_equal(np.asarray(newp[k]),
                                          np.asarray(p[k]))
            np.testing.assert_array_equal(np.asarray(newb[k]),
                                          np.asarray(g[k]))
        assert int(news["count"]) == 1


def test_fused_delayed_apply_under_jit():
    """The production call site is inside a jitted train step — the fused
    tree_map of pallas_calls must trace/compile cleanly."""
    cfg = OptConfig(name="adam", lr=1e-2, update_impl="pallas_interpret")
    init, _ = make_optimizer(cfg)
    p = _tree()
    s = init(p)
    b = jax.tree_util.tree_map(jnp.zeros_like, p)
    apply = make_delayed_apply(cfg)

    @jax.jit
    def step(p, b, s, g, scale):
        return apply(g, b, s, p, cfg, lr_scale=scale)

    g = _grads_like(p, 1)
    p1, b1, s1, gn = step(p, b, s, g, jnp.float32(0.25))
    want_p, want_b, want_s, _ = fused_delayed_apply(
        g, b, s, p, cfg, lr_scale=0.25, interpret=True)
    for a, w in zip(jax.tree_util.tree_leaves((p1, b1, s1)),
                    jax.tree_util.tree_leaves((want_p, want_b, want_s))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# trainer-level: fused curves track reference on the tier-1 workload
# ---------------------------------------------------------------------------
def test_async_trainer_fused_matches_reference_curves():
    """Acceptance: AsyncTrainer(update_impl="pallas_interpret") reproduces
    the reference training curve within tolerance on the reduced tier-1
    arch, including the delayed buffer and the per-round delay_scale
    input."""
    from jax.sharding import Mesh
    from repro.configs import get_arch
    from repro.data import DataConfig, HeterogeneousTokenPipeline
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.optim import OptConfig as OC

    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    pipe = HeterogeneousTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=16, global_batch=4, n_groups=1))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    curves, finals = {}, {}
    for impl in ("reference", "pallas_interpret"):
        tr = AsyncTrainer(cfg, mesh,
                          opt=OC(lr=1e-2, clip_norm=1.0, update_impl=impl),
                          async_cfg=AsyncConfig(delay_rounds=1))
        assert tr.update_impl == impl
        state = tr.init_state(jax.random.PRNGKey(0))
        step = jax.jit(tr.train_step_fn())
        losses = []
        for i in range(5):
            scale = jnp.float32(1.0 if i % 2 == 0 else 0.5)  # delay_scale in
            state, m = step(state, batch, jnp.ones((tr.n_groups,)), scale)
            losses.append(float(m["loss"]))
        curves[impl] = losses
        finals[impl] = state
    np.testing.assert_allclose(curves["reference"],
                               curves["pallas_interpret"], rtol=5e-3)
    # params are bf16 in the reduced arch: per-ELEMENT drift after 5
    # chaotic steps is unbounded in principle (rounding feeds back through
    # the gradients), so the state check is per-leaf norms, the curve
    # check above is the tight elementwise one
    for a, b in zip(jax.tree_util.tree_leaves(finals["reference"]),
                    jax.tree_util.tree_leaves(finals["pallas_interpret"])):
        na = float(jnp.linalg.norm(jnp.ravel(a).astype(F32)))
        nb = float(jnp.linalg.norm(jnp.ravel(b).astype(F32)))
        np.testing.assert_allclose(na, nb, rtol=5e-2, atol=1e-4)


def test_async_config_update_impl_overrides_opt():
    from jax.sharding import Mesh
    from repro.configs import get_arch
    from repro.distributed import AsyncTrainer, AsyncConfig
    from repro.optim import OptConfig as OC

    cfg = get_arch("qwen2-0.5b").reduced().with_(remat="none")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    tr = AsyncTrainer(cfg, mesh, opt=OC(update_impl="reference"),
                      async_cfg=AsyncConfig(delay_rounds=1,
                                            update_impl="pallas_interpret"))
    assert tr.update_impl == "pallas_interpret"
    assert tr.opt.update_impl == "pallas_interpret"
