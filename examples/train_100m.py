"""End-to-end driver: train a ~100M-parameter qwen2-family model with the
AsGrad async trainer on heterogeneous data for a few hundred steps.

One ``ExperimentSpec`` + ``TrainJob`` through ``repro.api``'s trainer
backend — the same spec vocabulary as the theory-tier simulator.

Presets:
  --preset smoke   tiny model, 20 steps   (runs anywhere, CI-sized)
  --preset 100m    ~100M params, 300 steps (the deliverable run; sized for a
                   real accelerator — on this CPU container use smoke)

  PYTHONPATH=src python examples/train_100m.py --preset smoke \
      --scheduler shuffled --pattern poisson
"""
import argparse
import dataclasses

from repro.api import ExperimentSpec, TrainJob, TrainerBackend
from repro import checkpoint


def build_job(preset: str):
    if preset == "smoke":
        job = TrainJob(arch="qwen2-0.5b", reduced=True, remat="none",
                       global_batch=8, seq_len=64)
        steps, n_groups = 20, 4
    else:  # ~100M active params
        job = TrainJob(
            arch="qwen2-0.5b", reduced=False, remat=None,
            arch_overrides=(("n_layers", 12), ("d_model", 768),
                            ("n_heads", 12), ("n_kv_heads", 4),
                            ("d_head", 64), ("d_ff", 2048),
                            ("vocab", 32768), ("tie_embeddings", True)),
            global_batch=32, seq_len=512)
        steps, n_groups = 300, 8
    return job, steps, n_groups


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--scheduler", default="shuffled",
                    choices=["pure", "random", "shuffled", "fedbuff"])
    ap.add_argument("--pattern", default="poisson")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--sync", action="store_true",
                    help="synchronous baseline (delay_rounds=0)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    job, steps, n_groups = build_job(args.preset)
    if args.sync:
        job = dataclasses.replace(job, delay_rounds=0)
    spec = ExperimentSpec(
        scheduler=f"{args.scheduler}:b={max(n_groups // 2, 1)}"
        if args.scheduler == "fedbuff" else args.scheduler,
        timing=f"{args.pattern}:slow=6",
        objective=job, T=steps, n_workers=n_groups,
        stepsize=args.lr, seed=0)

    cfg = job.make_arch()
    from repro.models import n_params
    print(f"arch={cfg.name}-derived  params={n_params(cfg)/1e6:.1f}M  "
          f"steps={steps}  batch={job.global_batch}x{job.seq_len}  "
          f"groups={n_groups}")

    def on_step(i, state, m):
        if i % max(steps // 10, 1) == 0 or i == steps - 1:
            print(f"step {i:4d}  loss={m['loss']:.4f}  "
                  f"|g|={m['grad_norm']:.3f}  part={m['participation']:.2f}")

    res = TrainerBackend(on_step=on_step).run(spec)
    print(f"done in {res.seconds:.1f}s  final loss={res.losses[-1]:.4f}  "
          f"tau_max={res.trace['tau_max']}")
    if args.ckpt:
        checkpoint.save(args.ckpt, res.x, step=steps, meta={"arch": cfg.name})
        print("checkpoint saved to", args.ckpt)


if __name__ == "__main__":
    main()
